"""Property tests on the selection-system invariants.

Seeded ``numpy`` randomness only — the container cannot install
``hypothesis``, so the old ``@given`` sweeps are replaced by explicit
seed/shape grids (same invariants, deterministic, always collected).

The central contract: every strategy in ``STRATEGIES`` returns a
``SelectionResult`` whose weights are >= 0 and sum to 1 over the mask, and
whose ``indices`` lie in ``[0, n) ∪ {-1}`` with ``-1`` exactly on the
off-mask slots.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as sel_lib
from repro.core.craig import craig, pairwise_sim
from repro.core.glister import glister
from repro.core.gradmatch import (SelectionResult, expand_batch_selection,
                                  gradmatch, gradmatch_pb)
from repro.core.omp import omp_select

SEEDS = (0, 1, 2)


def _g(seed, n, d):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))


def _check_invariants(sel: SelectionResult, n: int, what: str,
                      expect_mass: bool = True):
    idx = np.asarray(sel.indices)
    w = np.asarray(sel.weights)
    m = np.asarray(sel.mask)
    assert (w >= 0).all(), f"{what}: negative weights"
    assert (w[~m] == 0).all(), f"{what}: off-mask weights nonzero"
    if expect_mass:
        s = float(np.where(m, w, 0.0).sum())
        assert abs(s - 1.0) < 1e-4, f"{what}: weights sum {s} != 1"
    assert ((idx[m] >= 0) & (idx[m] < n)).all(), \
        f"{what}: on-mask indices out of [0, n)"
    assert (idx[~m] == -1).all(), f"{what}: off-mask indices != -1"


@pytest.mark.parametrize("strategy", sel_lib.STRATEGIES)
@pytest.mark.parametrize("seed", SEEDS)
def test_every_strategy_selection_invariants(strategy, seed):
    n, d, k = 48, 8, 12
    g = _g(seed, n, d)
    labels = jnp.arange(n) % 4
    sel = sel_lib.select(strategy, jax.random.PRNGKey(seed), g, k=k,
                         labels=labels, num_classes=4, batch_size=4,
                         chunk_size=16, stream_buffer=16)
    n_ground = n // 4 if strategy.endswith("-pb") else n
    _check_invariants(sel, n_ground, strategy)
    assert int(np.asarray(sel.mask).sum()) >= 1


@pytest.mark.parametrize("strategy", sel_lib.STRATEGIES)
def test_every_strategy_invariants_after_pb_expansion(strategy):
    """Invariants survive expand_if_pb back to example space."""
    n, d, k = 40, 8, 12
    g = _g(7, n, d)
    labels = jnp.arange(n) % 4
    sel = sel_lib.select(strategy, jax.random.PRNGKey(7), g, k=k,
                         labels=labels, num_classes=4, batch_size=4,
                         chunk_size=16, stream_buffer=16)
    ex = sel_lib.expand_if_pb(strategy, sel, 4, n)
    _check_invariants(ex, n, f"{strategy} expanded")


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("n,d,k", [(8, 4, 1), (33, 16, 8), (64, 32, 8)])
def test_gradmatch_weights_normalized(seed, n, d, k):
    sel = gradmatch(_g(seed, n, d), k=min(k, n))
    s = float(jnp.sum(jnp.where(sel.mask, sel.weights, 0.0)))
    assert abs(s - 1.0) < 1e-4
    assert bool(jnp.all(sel.weights >= 0))


@pytest.mark.parametrize("seed", SEEDS)
def test_omp_err_nonincreasing_rounds(seed):
    """Greedy chain: err after k rounds <= err after k-1 rounds."""
    g = _g(seed, 40, 12)
    t = jnp.sum(g, axis=0)
    e_prev = None
    for k in (1, 2, 4):
        err = float(omp_select(g, t, k=k, lam=0.1)[3])
        if e_prev is not None:
            assert err <= e_prev + 1e-4
        e_prev = err


@pytest.mark.parametrize("seed", SEEDS)
def test_craig_gain_monotone(seed):
    """Facility-location objective is monotone: coverage grows with k."""
    g = _g(seed, 24, 8)
    sim = pairwise_sim(g)
    covs = []
    for kk in (1, 2, 4, 6):
        sel = craig(g, kk, sim=sim)
        sel_idx = np.asarray(sel.indices)[np.asarray(sel.mask)]
        cov = float(jnp.sum(jnp.max(sim[:, sel_idx], axis=1)))
        covs.append(cov)
    for a, b in zip(covs, covs[1:]):
        assert b >= a - 1e-3


@pytest.mark.parametrize("seed", SEEDS)
def test_craig_weights_are_cluster_masses(seed):
    g = _g(seed, 30, 8)
    sel = craig(g, 6)
    s = float(jnp.sum(sel.weights))
    assert abs(s - 1.0) < 1e-4
    assert bool(jnp.all(sel.weights >= 0))


@pytest.mark.parametrize("seed", SEEDS)
def test_glister_unweighted_uniform(seed):
    g = _g(seed, 32, 8)
    sel = glister(g, jnp.sum(g, 0), 6)
    kk = int(jnp.sum(sel.mask))
    w = np.asarray(sel.weights)[np.asarray(sel.mask)]
    np.testing.assert_allclose(w, np.full(kk, 1.0 / kk), rtol=1e-5)


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("nb,bs,kb", [(4, 3, 2), (8, 6, 4), (5, 2, 1)])
def test_pb_expansion_preserves_mass(seed, nb, bs, kb):
    """Expanding a per-batch selection to examples keeps sum(w) == 1 and
    maps batch j to examples [j*B, (j+1)*B)."""
    n = nb * bs
    g = _g(seed, n, 8)
    sel = gradmatch_pb(g, bs, min(kb, nb))
    ex = expand_batch_selection(sel, bs, n)
    s = float(jnp.sum(jnp.where(ex.mask, ex.weights, 0.0)))
    assert abs(s - 1.0) < 1e-4
    idx = np.asarray(ex.indices)[np.asarray(ex.mask)]
    src = np.asarray(sel.indices)[np.asarray(sel.mask)]
    assert set(idx // bs).issubset(set(src.tolist()))


def test_pb_expansion_truncated_last_batch_preserves_mass():
    """n_examples % batch_size != 0: the final partial batch expands to
    fewer examples but the total weight is renormalized to exactly 1."""
    bs, n = 4, 14                       # batches: 0..2 full, batch 3 = 2 ex
    k = 3
    # hand-built selection that includes the truncated final batch
    sel = SelectionResult(
        indices=jnp.array([3, 0, 1], jnp.int32),
        weights=jnp.array([0.5, 0.3, 0.2], jnp.float32),
        mask=jnp.ones((k,), bool),
        err=jnp.float32(0.0),
    )
    ex = expand_batch_selection(sel, bs, n)
    idx = np.asarray(ex.indices)
    m = np.asarray(ex.mask)
    w = np.asarray(ex.weights)
    assert abs(float(w[m].sum()) - 1.0) < 1e-5
    # batch 3 contributes only examples 12, 13 (14, 15 are off the end)
    assert set(idx[m]) == {12, 13, 0, 1, 2, 3, 4, 5, 6, 7}
    assert (w[~m] == 0).all() and (idx[~m] == -1).all()


def test_select_dispatch_all_strategies():
    for seed in SEEDS:
        g = _g(seed, 32, 8)
        labels = jnp.arange(32) % 4
        for strat in sel_lib.STRATEGIES:
            sel = sel_lib.select(strat, jax.random.PRNGKey(seed), g, k=8,
                                 labels=labels, num_classes=4, batch_size=4,
                                 chunk_size=16, stream_buffer=16)
            assert sel.indices.shape[0] >= 1
            assert bool(jnp.all(sel.weights >= 0))


def test_warm_start_split_matches_paper():
    """kappa=1/2: T_s = T/2 subset epochs, T_f = T_s * budget full epochs —
    equal compute halves (paper §4)."""
    t_f, t_s = sel_lib.warm_start_epochs(300, 0.1, kappa=0.5)
    assert t_s == 150 and t_f == 15
    # compute parity: T_f full epochs == T_f/f subset-equivalents
    assert abs(t_f / 0.1 - t_s) <= 1


def test_selection_schedule_cadence():
    sched = sel_lib.SelectionSchedule(select_every=20, warm_epochs=15)
    fires = [e for e in range(100) if sched.is_selection_epoch(e)]
    assert fires == [15, 35, 55, 75, 95]
