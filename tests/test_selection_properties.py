"""Hypothesis property tests on the selection-system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import selection as sel_lib
from repro.core.craig import craig, pairwise_sim
from repro.core.glister import glister
from repro.core.gradmatch import expand_batch_selection, gradmatch
from repro.core.omp import omp_select

SETTINGS = dict(max_examples=15, deadline=None)


def _g(seed, n, d):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


@given(seed=st.integers(0, 100), n=st.integers(8, 64), d=st.integers(4, 32),
       k=st.integers(1, 8))
@settings(**SETTINGS)
def test_gradmatch_weights_normalized(seed, n, d, k):
    sel = gradmatch(_g(seed, n, d), k=min(k, n))
    s = float(jnp.sum(jnp.where(sel.mask, sel.weights, 0.0)))
    assert abs(s - 1.0) < 1e-4
    assert bool(jnp.all(sel.weights >= 0))


@given(seed=st.integers(0, 100), n=st.integers(8, 48), d=st.integers(4, 16))
@settings(**SETTINGS)
def test_omp_err_nonincreasing_rounds(seed, n, d):
    """Greedy chain: err after k rounds <= err after k-1 rounds."""
    g = _g(seed, n, d)
    t = jnp.sum(g, axis=0)
    e_prev = None
    for k in (1, 2, 4):
        err = float(omp_select(g, t, k=k, lam=0.1)[3])
        if e_prev is not None:
            assert err <= e_prev + 1e-4
        e_prev = err


@given(seed=st.integers(0, 100), n=st.integers(6, 40), k=st.integers(1, 6))
@settings(**SETTINGS)
def test_craig_gain_monotone(seed, n, k):
    """Facility-location objective is monotone: coverage grows with k."""
    g = _g(seed, n, 8)
    sim = pairwise_sim(g)
    covs = []
    for kk in range(1, min(k, n) + 1):
        sel = craig(g, kk, sim=sim)
        sel_idx = np.asarray(sel.indices)[np.asarray(sel.mask)]
        cov = float(jnp.sum(jnp.max(sim[:, sel_idx], axis=1)))
        covs.append(cov)
    for a, b in zip(covs, covs[1:]):
        assert b >= a - 1e-3


@given(seed=st.integers(0, 100), n=st.integers(8, 40), k=st.integers(2, 8))
@settings(**SETTINGS)
def test_craig_weights_are_cluster_masses(seed, n, k):
    g = _g(seed, n, 8)
    sel = craig(g, min(k, n))
    # normalized cluster sizes: sum to 1, each >= 0
    s = float(jnp.sum(sel.weights))
    assert abs(s - 1.0) < 1e-4
    assert bool(jnp.all(sel.weights >= 0))


@given(seed=st.integers(0, 100), n=st.integers(8, 40), k=st.integers(1, 8))
@settings(**SETTINGS)
def test_glister_unweighted_uniform(seed, n, k):
    g = _g(seed, n, 8)
    sel = glister(g, jnp.sum(g, 0), min(k, n))
    kk = int(jnp.sum(sel.mask))
    w = np.asarray(sel.weights)[np.asarray(sel.mask)]
    np.testing.assert_allclose(w, np.full(kk, 1.0 / kk), rtol=1e-5)


@given(seed=st.integers(0, 50), nb=st.integers(2, 8), bs=st.integers(2, 6),
       kb=st.integers(1, 4))
@settings(**SETTINGS)
def test_pb_expansion_preserves_mass(seed, nb, bs, kb):
    """Expanding a per-batch selection to examples keeps sum(w) == 1 and
    maps batch j to examples [j*B, (j+1)*B)."""
    n = nb * bs
    g = _g(seed, n, 8)
    from repro.core.gradmatch import gradmatch_pb
    sel = gradmatch_pb(g, bs, min(kb, nb))
    ex = expand_batch_selection(sel, bs, n)
    s = float(jnp.sum(jnp.where(ex.mask, ex.weights, 0.0)))
    assert abs(s - 1.0) < 1e-4
    idx = np.asarray(ex.indices)[np.asarray(ex.mask)]
    src = np.asarray(sel.indices)[np.asarray(sel.mask)]
    assert set(idx // bs).issubset(set(src.tolist()))


@given(seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_select_dispatch_all_strategies(seed):
    g = _g(seed, 32, 8)
    labels = jnp.arange(32) % 4
    for strat in sel_lib.STRATEGIES:
        sel = sel_lib.select(strat, jax.random.PRNGKey(seed), g, k=8,
                             labels=labels, num_classes=4, batch_size=4)
        assert sel.indices.shape[0] >= 1
        assert bool(jnp.all(sel.weights >= 0))


def test_warm_start_split_matches_paper():
    """kappa=1/2: T_s = T/2 subset epochs, T_f = T_s * budget full epochs —
    equal compute halves (paper §4)."""
    t_f, t_s = sel_lib.warm_start_epochs(300, 0.1, kappa=0.5)
    assert t_s == 150 and t_f == 15
    # compute parity: T_f full epochs == T_f/f subset-equivalents
    assert abs(t_f / 0.1 - t_s) <= 1


def test_selection_schedule_cadence():
    sched = sel_lib.SelectionSchedule(select_every=20, warm_epochs=15)
    fires = [e for e in range(100) if sched.is_selection_epoch(e)]
    assert fires == [15, 35, 55, 75, 95]
