"""Streaming block-OMP subsystem (core/streaming.py, DESIGN.md §4).

Chunking knobs (chunk size, per-chunk top-m, buffer size) are
implementation details — any setting must reproduce the in-memory
selection exactly.  Also covers the out-of-core path (np.memmap pools),
the chunked proxy extraction plumbing, the certification/pass accounting,
and the pmap shard-parallel chunk scorer.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import streaming as stream_lib
from repro.core.omp import omp_select
from repro.data.loader import ChunkedPool


def _pool(seed, n, d):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _ref(g, target, k, **kw):
    return omp_select(jnp.asarray(g), jnp.asarray(target), k=k, **kw)


def _assert_matches(out, ref):
    np.testing.assert_array_equal(np.asarray(out.indices),
                                  np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(out.mask), np.asarray(ref[2]))
    np.testing.assert_allclose(np.asarray(out.weights), np.asarray(ref[1]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(out.err), float(ref[3]), rtol=1e-4,
                               atol=1e-5)


def test_chunk_size_invariant():
    """Chunk size (divisor or not) never changes the selection."""
    g = _pool(0, 256, 24)
    target = g.sum(axis=0)
    ref = _ref(g, target, 32, lam=0.2)
    for cs in (32, 100, 256, 1000):
        out = stream_lib.omp_select_streaming(
            stream_lib.array_chunks(g, cs), target, 32, lam=0.2,
            buffer_size=64)
        _assert_matches(out, ref)


def test_buffer_size_invariant():
    """Top-M buffer size trades passes for memory, never the result."""
    g = _pool(1, 192, 16)
    target = g.sum(axis=0)
    ref = _ref(g, target, 24, lam=0.3)
    passes = []
    for m in (4, 32, 256):
        out = stream_lib.omp_select_streaming(
            stream_lib.array_chunks(g, 64), target, 24, lam=0.3,
            buffer_size=m)
        _assert_matches(out, ref)
        passes.append(out.stats.passes)
    # a buffer that swallows the pool certifies everything in one pass
    assert passes[-1] == 1
    assert passes[0] >= passes[-1]


def test_chunk_topm_smaller_than_buffer():
    g = _pool(2, 160, 12)
    target = g.sum(axis=0)
    ref = _ref(g, target, 20, lam=0.2)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 40), target, 20, lam=0.2,
        buffer_size=32, chunk_topm=4)
    _assert_matches(out, ref)


def test_multi_pass_and_certified_accounting():
    """Small buffer forces rescans; k >= n tail certifies in-buffer."""
    g = _pool(3, 100, 8)
    target = g.sum(axis=0)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 32), target, 120, lam=0.2,
        buffer_size=16)
    assert out.stats.passes > 1                      # rescans happened
    assert out.stats.rounds == 120
    assert out.stats.certified_rounds > 0            # buffer rounds fired
    assert out.stats.pool_size == 100
    _assert_matches(out, _ref(g, target, 120, lam=0.2))


def test_out_of_core_memmap_pool(tmp_path):
    """np.memmap pool: selection without ever materializing the pool."""
    n, d = 4096, 32
    g = _pool(4, n, d)
    path = os.path.join(tmp_path, "pool.f32")
    mm = np.memmap(path, dtype=np.float32, mode="w+", shape=(n, d))
    mm[:] = g
    mm.flush()
    del mm
    pool = np.memmap(path, dtype=np.float32, mode="r", shape=(n, d))
    target, total = stream_lib.streaming_target(
        stream_lib.array_chunks(pool, 512))
    assert total == n
    np.testing.assert_allclose(np.asarray(target), g.sum(axis=0), rtol=1e-5)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(pool, 512), jnp.asarray(g.sum(axis=0)), 48,
        lam=0.2, buffer_size=128)
    _assert_matches(out, _ref(g, g.sum(axis=0), 48, lam=0.2))


def test_gradmatch_streaming_wrappers():
    from repro.core.gradmatch import gradmatch

    g = _pool(5, 200, 16)
    ref = gradmatch(jnp.asarray(g), k=24, lam=0.5)
    sel = stream_lib.gradmatch_streaming_array(g, 24, lam=0.5,
                                               chunk_size=64,
                                               buffer_size=64)
    np.testing.assert_array_equal(np.asarray(sel.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_allclose(np.asarray(sel.weights),
                               np.asarray(ref.weights), rtol=1e-4,
                               atol=1e-5)
    # factory variant computes the target with its own summing pass
    sel2 = stream_lib.gradmatch_streaming(
        stream_lib.array_chunks(g, 64), 24, lam=0.5, buffer_size=64)
    np.testing.assert_array_equal(np.asarray(sel2.indices),
                                  np.asarray(ref.indices))


def test_select_dispatch_stream_strategy():
    from repro.core import selection as sel_lib

    g = jnp.asarray(_pool(6, 128, 12))
    a = sel_lib.select("gradmatch", jax.random.PRNGKey(0), g, k=16,
                       per_class=False)
    b = sel_lib.select("gradmatch-stream", jax.random.PRNGKey(0), g, k=16,
                       chunk_size=48, stream_buffer=32)
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))
    np.testing.assert_allclose(np.asarray(a.weights), np.asarray(b.weights),
                               rtol=1e-4, atol=1e-5)


def test_chunked_pool_iteration():
    x = np.arange(23 * 3, dtype=np.float32).reshape(23, 3)
    y = np.arange(23)
    pool = ChunkedPool(x, y, chunk_size=10)
    assert pool.n == 23 and pool.num_chunks() == 3
    for _ in range(2):                    # re-iterable, same order
        chunks = list(pool.chunks())
        assert [c[2] for c in chunks] == [0, 10, 20]
        assert [c[0].shape[0] for c in chunks] == [10, 10, 3]
        np.testing.assert_array_equal(np.concatenate([c[0] for c in chunks]),
                                      x)
        np.testing.assert_array_equal(np.concatenate([c[1] for c in chunks]),
                                      y)


def test_proxy_chunk_stream_matches_full_extraction():
    """Chunked proxy extraction == full-pool extraction, chunk by chunk."""
    from repro.core import proxies as proxy_lib

    rng = np.random.default_rng(7)
    n, dh, c = 64, 8, 5
    hidden = rng.standard_normal((n, dh)).astype(np.float32)
    logits = rng.standard_normal((n, c)).astype(np.float32)
    labels = rng.integers(0, c, n)

    def proxy_fn(params, x, y):
        del params
        h, z = x
        return (proxy_lib.per_class_grad_proxy(h, z, y),
                proxy_lib.bias_grad_proxy(z, y))

    def raw_chunks():
        for lo in (0, 24, 48):
            hi = min(lo + 24, n)
            yield ((hidden[lo:hi], logits[lo:hi]), labels[lo:hi], lo)

    chunks = proxy_lib.proxy_chunk_stream(raw_chunks, proxy_fn, None)
    got = np.concatenate([np.asarray(p) for p, _ in chunks()])
    want = np.asarray(proxy_lib.bias_grad_proxy(jnp.asarray(logits),
                                                jnp.asarray(labels)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_pmap_chunk_scorer_parity():
    """The distributed (pmap) chunk scorer is a drop-in for the local one
    — same selection on this host's device set."""
    from repro.core.distributed import pmap_chunk_topm

    g = _pool(8, 160, 16)
    target = g.sum(axis=0)
    ref = _ref(g, target, 20, lam=0.2)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 48), target, 20, lam=0.2,
        buffer_size=32, score_chunk_fn=pmap_chunk_topm)
    _assert_matches(out, ref)


def test_streaming_guard_on_unstable_iterator():
    """A pool iterator that returns nothing must not loop forever."""
    def empty():
        return iter(())

    out = stream_lib.omp_select_streaming(empty, jnp.ones((8,)), 4)
    assert int(np.asarray(out.mask).sum()) == 0
    assert out.stats.passes == 0
