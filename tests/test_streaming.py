"""Streaming block-OMP subsystem (core/streaming.py, DESIGN.md §4).

Chunking knobs (chunk size, per-chunk top-m, buffer size) are
implementation details — any setting must reproduce the in-memory
selection exactly.  Also covers the out-of-core path (np.memmap pools),
the chunked proxy extraction plumbing, the certification/pass accounting,
and the pmap shard-parallel chunk scorer.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import streaming as stream_lib
from repro.core.omp import omp_select
from repro.data.loader import ChunkedPool


def _pool(seed, n, d):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _ref(g, target, k, **kw):
    return omp_select(jnp.asarray(g), jnp.asarray(target), k=k, **kw)


def _assert_matches(out, ref):
    np.testing.assert_array_equal(np.asarray(out.indices),
                                  np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(out.mask), np.asarray(ref[2]))
    np.testing.assert_allclose(np.asarray(out.weights), np.asarray(ref[1]),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(out.err), float(ref[3]), rtol=1e-4,
                               atol=1e-5)


def test_chunk_size_invariant():
    """Chunk size (divisor or not) never changes the selection."""
    g = _pool(0, 256, 24)
    target = g.sum(axis=0)
    ref = _ref(g, target, 32, lam=0.2)
    for cs in (32, 100, 256, 1000):
        out = stream_lib.omp_select_streaming(
            stream_lib.array_chunks(g, cs), target, 32, lam=0.2,
            buffer_size=64)
        _assert_matches(out, ref)


def test_buffer_size_invariant():
    """Top-M buffer size trades passes for memory, never the result."""
    g = _pool(1, 192, 16)
    target = g.sum(axis=0)
    ref = _ref(g, target, 24, lam=0.3)
    passes = []
    for m in (4, 32, 256):
        out = stream_lib.omp_select_streaming(
            stream_lib.array_chunks(g, 64), target, 24, lam=0.3,
            buffer_size=m)
        _assert_matches(out, ref)
        passes.append(out.stats.passes)
    # a buffer that swallows the pool certifies everything in one pass
    assert passes[-1] == 1
    assert passes[0] >= passes[-1]


def test_chunk_topm_smaller_than_buffer():
    g = _pool(2, 160, 12)
    target = g.sum(axis=0)
    ref = _ref(g, target, 20, lam=0.2)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 40), target, 20, lam=0.2,
        buffer_size=32, chunk_topm=4)
    _assert_matches(out, ref)


def test_multi_pass_and_certified_accounting():
    """Small buffer forces rescans; k >= n tail certifies in-buffer."""
    g = _pool(3, 100, 8)
    target = g.sum(axis=0)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 32), target, 120, lam=0.2,
        buffer_size=16)
    assert out.stats.passes > 1                      # rescans happened
    assert out.stats.rounds == 120
    assert out.stats.certified_rounds > 0            # buffer rounds fired
    assert out.stats.pool_size == 100
    _assert_matches(out, _ref(g, target, 120, lam=0.2))


def test_out_of_core_memmap_pool(tmp_path):
    """np.memmap pool: selection without ever materializing the pool."""
    n, d = 4096, 32
    g = _pool(4, n, d)
    path = os.path.join(tmp_path, "pool.f32")
    mm = np.memmap(path, dtype=np.float32, mode="w+", shape=(n, d))
    mm[:] = g
    mm.flush()
    del mm
    pool = np.memmap(path, dtype=np.float32, mode="r", shape=(n, d))
    target, total = stream_lib.streaming_target(
        stream_lib.array_chunks(pool, 512))
    assert total == n
    np.testing.assert_allclose(np.asarray(target), g.sum(axis=0), rtol=1e-5)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(pool, 512), jnp.asarray(g.sum(axis=0)), 48,
        lam=0.2, buffer_size=128)
    _assert_matches(out, _ref(g, g.sum(axis=0), 48, lam=0.2))


def test_gradmatch_streaming_wrappers():
    from repro.core.gradmatch import gradmatch

    g = _pool(5, 200, 16)
    ref = gradmatch(jnp.asarray(g), k=24, lam=0.5)
    sel = stream_lib.gradmatch_streaming_array(g, 24, lam=0.5,
                                               chunk_size=64,
                                               buffer_size=64)
    np.testing.assert_array_equal(np.asarray(sel.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_allclose(np.asarray(sel.weights),
                               np.asarray(ref.weights), rtol=1e-4,
                               atol=1e-5)
    # factory variant computes the target with its own summing pass
    sel2 = stream_lib.gradmatch_streaming(
        stream_lib.array_chunks(g, 64), 24, lam=0.5, buffer_size=64)
    np.testing.assert_array_equal(np.asarray(sel2.indices),
                                  np.asarray(ref.indices))


def test_select_dispatch_stream_strategy():
    from repro.core import selection as sel_lib

    g = jnp.asarray(_pool(6, 128, 12))
    a = sel_lib.select("gradmatch", jax.random.PRNGKey(0), g, k=16,
                       per_class=False)
    b = sel_lib.select("gradmatch-stream", jax.random.PRNGKey(0), g, k=16,
                       chunk_size=48, stream_buffer=32)
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))
    np.testing.assert_allclose(np.asarray(a.weights), np.asarray(b.weights),
                               rtol=1e-4, atol=1e-5)


def test_chunked_pool_iteration():
    x = np.arange(23 * 3, dtype=np.float32).reshape(23, 3)
    y = np.arange(23)
    pool = ChunkedPool(x, y, chunk_size=10)
    assert pool.n == 23 and pool.num_chunks() == 3
    for _ in range(2):                    # re-iterable, same order
        chunks = list(pool.chunks())
        assert [c[2] for c in chunks] == [0, 10, 20]
        assert [c[0].shape[0] for c in chunks] == [10, 10, 3]
        np.testing.assert_array_equal(np.concatenate([c[0] for c in chunks]),
                                      x)
        np.testing.assert_array_equal(np.concatenate([c[1] for c in chunks]),
                                      y)


def test_proxy_chunk_stream_matches_full_extraction():
    """Chunked proxy extraction == full-pool extraction, chunk by chunk."""
    from repro.core import proxies as proxy_lib

    rng = np.random.default_rng(7)
    n, dh, c = 64, 8, 5
    hidden = rng.standard_normal((n, dh)).astype(np.float32)
    logits = rng.standard_normal((n, c)).astype(np.float32)
    labels = rng.integers(0, c, n)

    def proxy_fn(params, x, y):
        del params
        h, z = x
        return (proxy_lib.per_class_grad_proxy(h, z, y),
                proxy_lib.bias_grad_proxy(z, y))

    def raw_chunks():
        for lo in (0, 24, 48):
            hi = min(lo + 24, n)
            yield ((hidden[lo:hi], logits[lo:hi]), labels[lo:hi], lo)

    chunks = proxy_lib.proxy_chunk_stream(raw_chunks, proxy_fn, None)
    got = np.concatenate([np.asarray(p) for p, _ in chunks()])
    want = np.asarray(proxy_lib.bias_grad_proxy(jnp.asarray(logits),
                                                jnp.asarray(labels)))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_pmap_chunk_scorer_parity():
    """The distributed (pmap) chunk scorer is a drop-in for the local one
    — same selection on this host's device set."""
    from repro.core.distributed import pmap_chunk_topm

    g = _pool(8, 160, 16)
    target = g.sum(axis=0)
    ref = _ref(g, target, 20, lam=0.2)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 48), target, 20, lam=0.2,
        buffer_size=32, score_chunk_fn=pmap_chunk_topm)
    _assert_matches(out, ref)


def test_streaming_guard_on_unstable_iterator():
    """A pool iterator that returns nothing must not loop forever."""
    def empty():
        return iter(())

    out = stream_lib.omp_select_streaming(empty, jnp.ones((8,)), 4)
    assert int(np.asarray(out.mask).sum()) == 0
    assert out.stats.passes == 0


# ---------------------------------------------------------------------------
# multi-round-per-pass engine: compressed cache, repairs, refills (§7)
# ---------------------------------------------------------------------------

def test_multi_round_certification_with_cache():
    """With the compressed cache + row fetch, the engine commits many
    rounds per loader pass: the pass count must be a small fraction of
    the rounds (the whole point of PR 5) while staying index-exact."""
    n, d, k = 1024, 32, 96
    g = _pool(20, n, d)
    target = g.sum(axis=0)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 256), target, k, buffer_size=128,
        row_fetch=stream_lib.array_row_fetch(g))
    _assert_matches(out, _ref(g, target, k))
    s = out.stats
    assert s.passes <= k // 8 + 2, s.summary()
    assert s.certified_rounds >= 0.5 * s.rounds, s.summary()
    assert s.cache_hit_rate == 1.0, s.summary()


def test_cache_thrash_smaller_than_chunk():
    """A cache too small for even one chunk disables the interval rung;
    the sketch rung + loader rescans must still terminate index-exact
    (the PR-2 worst case)."""
    g = _pool(21, 300, 16)
    target = g.sum(axis=0)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 100), target, 24, buffer_size=32,
        cache_bytes=64,                      # < one row of sidecars
        row_fetch=stream_lib.array_row_fetch(g))
    _assert_matches(out, _ref(g, target, 24))
    assert out.stats.cache_hits == 0
    assert out.stats.passes >= 1


def test_cache_lru_eviction_partial_coverage():
    """A cache holding ~half the chunks evicts LRU but keeps the solver
    exact: uncached chunks fall back to the sketch bound."""
    from repro.core.streaming import ChunkCache

    n, d, chunk = 512, 16, 128
    g = _pool(22, n, d)
    target = g.sum(axis=0)
    cache = ChunkCache(2 * 128 * (2 * d + 15) + 64, d)   # ~2 of 4 chunks
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, chunk), target, 48, buffer_size=64,
        cache=cache, row_fetch=stream_lib.array_row_fetch(g))
    _assert_matches(out, _ref(g, target, 48))
    assert cache.cap_slots < 4
    assert cache.evictions > 0
    assert out.stats.cache_misses > 0       # sketch rung was consulted


def test_adversarial_bf16_resolution_pool():
    """Rows that differ below bf16 resolution: every interval overlaps,
    so the certificate (almost) never fires — the engine must fail
    closed into repairs/rescans and still match the oracle index-exactly
    (f32 scoring resolves what bf16 cannot).

    The oracle here is the *dense* solver: the near-rank-1 pool puts the
    residual at the f32 noise floor within a few rounds, where the
    incremental solver's cached-correlation scores diverge from the
    direct ``G @ r`` ones — streaming scores the pool directly, so it
    tracks the dense formulation through that regime (see
    tests/test_omp_parity.py's grid notes)."""
    from repro.core.omp import omp_select_dense

    rng = np.random.default_rng(23)
    n, d, k = 96, 16, 12
    base = rng.standard_normal((d,)).astype(np.float32)
    g = np.tile(base, (n, 1))
    # Per-row perturbation ~1e-3 relative: far below the bf16 interval
    # width, far above the f32 noise floor for the early rounds.
    g += 1e-3 * rng.standard_normal((n, d)).astype(np.float32)
    target = g.sum(axis=0)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 32), target, k, buffer_size=16,
        chunk_topm=8, row_fetch=stream_lib.array_row_fetch(g))
    dense = omp_select_dense(jnp.asarray(g), jnp.asarray(target), k=k)
    _assert_matches(out, dense)
    assert out.stats.passes <= k + 2


def test_pass_budget_error_carries_stats():
    """The max_passes guard must raise *with* the accumulated stats so
    the failure is diagnosable (satellite: no more silent wasted work)."""
    g = _pool(24, 128, 8)
    target = g.sum(axis=0)
    with pytest.raises(stream_lib.StreamingPassBudgetError) as ei:
        stream_lib.omp_select_streaming(
            stream_lib.array_chunks(g, 64), target, 64, buffer_size=4,
            chunk_topm=2, cache_bytes=0, max_passes=1)
    assert ei.value.stats.passes == 1
    assert "passes=1" in str(ei.value)
    assert ei.value.cap == 1


def test_select_stats_exposed_on_results():
    """Every streaming entry point surfaces SelectStats on its result."""
    from repro.core import selection as sel_lib

    g = _pool(25, 200, 12)
    sel = stream_lib.gradmatch_streaming_array(g, 24, chunk_size=64,
                                               buffer_size=64)
    assert isinstance(sel.stats, stream_lib.SelectStats)
    assert sel.stats.rounds == 24
    sel2 = stream_lib.gradmatch_streaming(
        stream_lib.array_chunks(g, 64), 24, buffer_size=64)
    assert isinstance(sel2.stats, stream_lib.SelectStats)
    sel3 = sel_lib.select("gradmatch-stream", jax.random.PRNGKey(0),
                          jnp.asarray(g), k=16)
    assert isinstance(sel3.stats, stream_lib.SelectStats)
    # non-streaming strategies carry no stats
    assert sel_lib.select("random", jax.random.PRNGKey(0),
                          jnp.asarray(g), k=16).stats is None


def test_serve_admission_prefills_cache_zero_passes():
    """The registry's admission summing pass doubles as the cache fill:
    a later streaming request bootstraps from the warmed cache and never
    touches the loader (passes == 0)."""
    from repro.data.loader import ChunkedPool
    from repro.serve.registry import PoolRegistry

    g = _pool(26, 384, 16)
    reg = PoolRegistry()
    pid = reg.register_chunked(ChunkedPool(g, None, chunk_size=128))
    entry = reg.get(pid)
    assert entry.cache is not None and entry.cache.complete == 3
    sel = stream_lib.gradmatch_streaming(
        entry.chunk_iter, 32, target=entry.target_sum,
        cache=entry.cache, row_fetch=entry.row_fetch)
    assert sel.stats.passes == 0
    ref = _ref(g, np.asarray(entry.target_sum), 32)
    np.testing.assert_array_equal(np.asarray(sel.indices),
                                  np.asarray(ref[0]))


def test_unstable_iterator_detected_by_cache():
    """An iterator whose chunk offsets move between passes is caught at
    the cache layer instead of looping to the pass budget."""
    g = _pool(27, 128, 8)
    state = {"n": 0}

    def unstable():
        state["n"] += 1
        cs = 32 if state["n"] == 1 else 48    # offsets shift on pass 2
        for lo in range(0, 128, cs):
            yield g[lo:lo + cs], None

    with pytest.raises(RuntimeError, match="unstable"):
        stream_lib.omp_select_streaming(
            unstable, jnp.asarray(g.sum(axis=0)), 64, buffer_size=8,
            chunk_topm=4)


def test_refill_non_power_of_two_arena():
    """Regression: a cache whose row capacity is not a power of two used
    to crash the cache refill when the candidate bucket rounded past the
    arena length (fetched/live shape mismatch)."""
    from repro.core.streaming import ChunkCache

    rng = np.random.default_rng(30)
    n, d, chunk = 384, 16, 128
    base = rng.standard_normal((d,)).astype(np.float32)
    g = np.tile(base, (n, 1)) + 1e-3 * rng.standard_normal(
        (n, d)).astype(np.float32)          # intervals overlap heavily
    target = g.sum(axis=0)
    cache = ChunkCache(3 * 128 * ChunkCache(0, d).bytes_per_row + 47, d)
    assert cache.cap_rows_budget not in (256, 512)   # non-pow2 capacity
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, chunk), target, 48, buffer_size=96,
        cache=cache, row_fetch=stream_lib.array_row_fetch(g))
    from repro.core.omp import omp_select_dense
    dense = omp_select_dense(jnp.asarray(g), jnp.asarray(target), k=48)
    np.testing.assert_array_equal(np.asarray(out.indices),
                                  np.asarray(dense[0]))


def test_repair_annex_overflow_clamped():
    """Regression: with repair_slots not a multiple of the fetch batch,
    a repair whose prefetch band exceeded the free annex room used to
    scatter-drop buffer writes while still marking the rows in-buffer
    arena-side — rows invisible to both scans, a silent exactness hole.
    The clamp keeps every admission inside the annex."""
    rng = np.random.default_rng(31)
    n, d, k = 512, 24, 64
    base = rng.standard_normal((d,)).astype(np.float32)
    g = np.tile(base, (n, 1)) + 1e-3 * rng.standard_normal(
        (n, d)).astype(np.float32)
    target = g.sum(axis=0)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 128), target, k, buffer_size=48,
        repair_slots=200,                    # free room hits 72, 8, ...
        row_fetch=stream_lib.array_row_fetch(g))
    from repro.core.omp import omp_select_dense
    dense = omp_select_dense(jnp.asarray(g), jnp.asarray(target), k=k)
    np.testing.assert_array_equal(np.asarray(out.indices),
                                  np.asarray(dense[0]))
