"""OMP solver correctness + the paper's theoretical invariants (Thm 2/3),
plus incremental-vs-dense parity (the dense solver is the reference the
production incremental path must reproduce, see DESIGN.md §2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.omp import (matching_error, omp_select, omp_select_dense,
                            omp_select_per_class)


def _k(i):
    return jax.random.PRNGKey(i)


def test_recovers_planted_support():
    """Target = positive combo of 5 rows of an incoherent G -> OMP finds
    exactly those rows."""
    g = jax.random.normal(_k(0), (200, 128))
    g = g / jnp.linalg.norm(g, axis=1, keepdims=True)
    support = jnp.array([3, 50, 77, 120, 199])
    w_true = jnp.array([1.0, 2.0, 0.5, 1.5, 3.0])
    target = w_true @ g[support]
    idx, w, mask, err = omp_select(g, target, k=5, lam=1e-6)
    assert set(np.asarray(idx).tolist()) == set(np.asarray(support).tolist())
    assert float(err) < 1e-3


def test_error_monotone_in_k():
    """E_lambda(X_k) is non-increasing as the budget k grows (greedy
    chain property of Alg. 2)."""
    g = jax.random.normal(_k(1), (100, 64))
    target = jnp.sum(g[:30], axis=0)
    errs = [float(omp_select(g, target, k=k, lam=0.1)[3])
            for k in (1, 2, 4, 8, 16, 32)]
    for a, b in zip(errs, errs[1:]):
        assert b <= a + 1e-5, errs


def test_weights_nonnegative_and_masked():
    g = jax.random.normal(_k(2), (64, 32))
    target = jnp.sum(g, axis=0)
    idx, w, mask, _ = omp_select(g, target, k=10, lam=0.5)
    assert bool(jnp.all(w >= 0))
    assert bool(jnp.all(jnp.where(~mask, w == 0, True)))
    assert bool(jnp.all(jnp.where(~mask, idx == -1, idx >= 0)))


def test_eps_stopping_short_circuits():
    """If 2 rows reconstruct the target exactly, slots 3.. stay unused."""
    g = jax.random.normal(_k(3), (50, 40))
    target = g[7] * 2.0 + g[31] * 1.0
    idx, w, mask, err = omp_select(g, target, k=10, lam=1e-8, eps=1e-6)
    assert int(jnp.sum(mask)) <= 4  # 2 needed; tiny slack for regularizer
    assert float(err) < 1e-4


def test_no_duplicate_selections():
    g = jax.random.normal(_k(4), (30, 16))
    target = jnp.sum(g, axis=0)
    idx, w, mask, _ = omp_select(g, target, k=20, lam=0.5)
    sel = np.asarray(idx)[np.asarray(mask)]
    assert len(sel) == len(set(sel.tolist()))


@pytest.mark.parametrize("method", ["incremental", "dense"])
def test_no_duplicate_when_last_candidate_selected(method):
    """Regression: candidate n-1 selected early must stay masked out of
    later rounds (the taken-mask scatter once used n-1 as its sentinel,
    racing duplicate writes).  Few NNLS iters keep the residual correlated
    with the taken row, which is what exposed the race."""
    g = jax.random.normal(_k(44), (12, 8))
    target = g[11] * 5.0 + jnp.sum(g, axis=0) * 0.1
    idx, w, mask, _ = omp_select(g, target, k=6, nnls_iters=2,
                                 method=method)
    sel = np.asarray(idx)[np.asarray(mask)]
    assert len(sel) == len(set(sel.tolist())), sel


def test_valid_mask_respected():
    g = jax.random.normal(_k(5), (60, 32))
    valid = jnp.arange(60) < 20
    target = jnp.sum(g[:20], axis=0)
    idx, w, mask, _ = omp_select(g, target, k=10, valid=valid)
    sel = np.asarray(idx)[np.asarray(mask)]
    assert (sel < 20).all()


def test_matching_error_decreases_vs_random():
    """OMP's Err is far below a random subset of the same size (paper
    Table 9 ordering)."""
    g = jax.random.normal(_k(6), (256, 64))
    target = jnp.sum(g, axis=0)
    idx, w, mask, _ = omp_select(g, target, k=32, lam=0.1)
    e_omp = float(matching_error(g, target, idx, w, mask))
    ridx = jax.random.permutation(_k(7), 256)[:32].astype(jnp.int32)
    rmask = jnp.ones((32,), bool)
    rw = jnp.full((32,), float(256 / 32), jnp.float32)  # unbiased scaling
    e_rand = float(matching_error(g, target, ridx, rw, rmask))
    assert e_omp < e_rand


def test_per_class_selects_within_class():
    g = jax.random.normal(_k(8), (120, 32))
    labels = jnp.arange(120) % 3
    onehot = jax.nn.one_hot(labels, 3, dtype=g.dtype)
    targets = onehot.T @ g
    idx, w, mask = omp_select_per_class(g, labels, targets, 3, 5)
    idx_np, mask_np = np.asarray(idx), np.asarray(mask)
    lab_np = np.asarray(labels)
    for c in range(3):
        block = idx_np[c * 5:(c + 1) * 5]
        bm = mask_np[c * 5:(c + 1) * 5]
        assert (lab_np[block[bm]] == c).all()


# ---------------------------------------------------------------------------
# incremental vs dense reference parity (DESIGN.md §2)
# ---------------------------------------------------------------------------

PARITY_SHAPES = [
    (200, 32, 24),    # narrow regime (d < k): residual scoring
    (100, 256, 16),   # wide regime (k < d): column-cache scoring
    (64, 16, 40),     # k > n/2, heavy masking
    (300, 128, 150),  # crosses the wide->narrow regime boundary
]


@pytest.mark.parametrize("n,d,k", PARITY_SHAPES)
@pytest.mark.parametrize("lam", [1e-6, 0.3])
def test_incremental_matches_dense(n, d, k, lam):
    """The cached-correlation solver must reproduce the dense reference's
    selections exactly and its weights/err to f32 tolerance."""
    g = jax.random.normal(_k(n + d + k), (n, d))
    target = jnp.sum(g, axis=0)
    i1, w1, m1, e1 = omp_select(g, target, k=k, lam=lam)
    i2, w2, m2, e2 = omp_select_dense(g, target, k=k, lam=lam)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_allclose(w1, w2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4, atol=1e-5)


def test_incremental_matches_dense_valid_mask():
    g = jax.random.normal(_k(77), (120, 48))
    valid = jax.random.bernoulli(_k(78), 0.4, (120,))
    target = jnp.sum(jnp.where(valid[:, None], g, 0.0), axis=0)
    i1, w1, m1, e1 = omp_select(g, target, k=16, lam=0.2, valid=valid)
    i2, w2, m2, e2 = omp_select_dense(g, target, k=16, lam=0.2, valid=valid)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(w1, w2, rtol=1e-4, atol=1e-5)


def test_incremental_matches_dense_negative_scores():
    """positive=False (|scores| selection) parity."""
    g = jax.random.normal(_k(79), (150, 32))
    target = -jnp.sum(g[:40], axis=0)   # anti-aligned target
    i1, w1, m1, e1 = omp_select(g, target, k=12, lam=0.1, positive=False)
    i2, w2, m2, e2 = omp_select_dense(g, target, k=12, lam=0.1,
                                      positive=False)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(w1, w2, rtol=1e-4, atol=1e-5)


def test_incremental_block_size_invariant():
    """The blocked prefix growth is an implementation detail: any block
    size must yield the same selection."""
    g = jax.random.normal(_k(80), (128, 24))
    target = jnp.sum(g, axis=0)
    ref = omp_select(g, target, k=33, lam=0.2, block=128)
    for block in (1, 7, 33, 64):
        got = omp_select(g, target, k=33, lam=0.2, block=block)
        np.testing.assert_array_equal(np.asarray(got[0]),
                                      np.asarray(ref[0]))
        np.testing.assert_allclose(got[1], ref[1], rtol=1e-4, atol=1e-6)


def test_per_class_incremental_matches_dense():
    """The vmapped per-class decomposition agrees between solvers."""
    g = jax.random.normal(_k(81), (120, 32))
    labels = jnp.arange(120) % 3
    onehot = jax.nn.one_hot(labels, 3, dtype=g.dtype)
    targets = onehot.T @ g
    i1, w1, m1 = omp_select_per_class(g, labels, targets, 3, 8)
    i2, w2, m2 = omp_select_per_class(g, labels, targets, 3, 8,
                                      method="dense")
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_allclose(w1, w2, rtol=1e-4, atol=1e-5)


def test_incremental_eps_stop_matches_dense():
    """Exact 2-row target: both solvers stop at the same round."""
    g = jax.random.normal(_k(82), (50, 40))
    target = g[7] * 2.0 + g[31] * 1.0
    i1, w1, m1, e1 = omp_select(g, target, k=10, lam=1e-8, eps=1e-6)
    i2, w2, m2, e2 = omp_select_dense(g, target, k=10, lam=1e-8, eps=1e-6)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


def test_matching_error_consistent_with_solver_err():
    """matching_error is the squared paper objective — it must equal the
    err the solver tracks internally (both formulations)."""
    g = jax.random.normal(_k(83), (90, 40))
    target = jnp.sum(g, axis=0)
    for method in ("incremental", "dense"):
        idx, w, mask, err = omp_select(g, target, k=12, lam=0.3,
                                       method=method)
        ext = matching_error(g, target, idx, w, mask, lam=0.3)
        np.testing.assert_allclose(float(ext), float(err), rtol=1e-4,
                                   atol=1e-5)


def test_lambda_regularizes_weights():
    """Larger lambda -> smaller ||w||^2 (Fig. 4g mechanism)."""
    g = jax.random.normal(_k(9), (80, 48))
    target = jnp.sum(g, axis=0)
    norms = []
    for lam in (1e-4, 0.5, 50.0):
        _, w, _, _ = omp_select(g, target, k=16, lam=lam)
        norms.append(float(jnp.sum(w ** 2)))
    assert norms[0] >= norms[1] >= norms[2]
