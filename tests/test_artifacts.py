"""Durable selection-artifact store (DESIGN.md §12).

Three families of claims, all seeded (``FAULT_SEED`` parametrizes the
disk-fault schedule the same way CI's fault-suite job does for the
transient-fault tests):

1. **Differential guarantee**: an artifact round-trips through disk and
   serves answers bit-identical to the live solvers at *every*
   ``k <= k_max`` — indices/mask equal to the one-shot ``omp_select``
   and the anytime session engine, weights bit-equal to the session
   engine (the recorded path), allclose to the one-shot.
2. **Fail closed under every disk fault**: for each
   ``DISK_FAULT_KINDS`` member and each ``CRASH_STAGES`` kill point, a
   read either returns a fully verified artifact or a miss (with the
   corrupt manifest quarantined) — never bytes that decode to a wrong
   answer.  End to end, the service then serves the same request off
   the live ladder instead.
3. **GC safety**: mark-then-sweep never collects a referenced blob,
   always collects unreferenced debris past the grace window, and a
   swept store still verifies.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.artifacts import (SCHEMA_VERSION, ArtifactStore,
                             artifact_key_for, build_artifact,
                             content_digest_array)
from repro.artifacts.store import manifest_self_sha
from repro.core.gradmatch import _normalize
from repro.core.omp import omp_select, omp_session_start
from repro.resilience import (DISK_FAULT_KINDS, SimulatedCrash,
                              crash_after, inject_disk_fault)
from repro.serve.service import SelectionService

SEED = int(os.environ.get("FAULT_SEED", "7"))

N, D, K_MAX = 256, 24, 24


def _pool(seed=0, n=N, d=D):
    return np.asarray(
        jax.random.normal(jax.random.PRNGKey(seed), (n, d)), np.float32)


def _target(g):
    return np.asarray(jnp.sum(jnp.asarray(g), axis=0), np.float32)


@pytest.fixture()
def store(tmp_path):
    return ArtifactStore(str(tmp_path / "store"))


@pytest.fixture()
def built(store):
    g = _pool(SEED)
    tgt = _target(g)
    key, ident = build_artifact(store, g, tgt, K_MAX)
    return store, g, tgt, key, ident


# -- differential guarantee ---------------------------------------------------

def test_roundtrip_bit_exact_at_every_k(built):
    store, g, tgt, key, _ = built
    art = store.get(key)
    assert art is not None and art.k_max == K_MAX
    for k in range(1, K_MAX + 1):
        idx, w, mask, err = art.slice(k)
        li, lw, lm, le = omp_select(g, tgt, k)
        sess = omp_session_start(g, tgt, k)
        assert np.array_equal(idx, np.asarray(li)), k
        assert np.array_equal(mask, np.asarray(lm)), k
        assert np.array_equal(idx, np.asarray(sess.indices)), k
        assert np.array_equal(w, np.asarray(sess.weights)), k
        assert np.allclose(w, np.asarray(lw), rtol=1e-4, atol=1e-5), k
        assert np.array_equal(err, np.float32(np.asarray(sess.err))), k


def test_slice_bounds(built):
    store, _, _, key, _ = built
    art = store.get(key)
    for bad in (0, -1, K_MAX + 1):
        with pytest.raises(ValueError):
            art.slice(bad)


def test_key_isolation_full_content_digest(store):
    """S1: the artifact key hashes *every* byte.  Two pools identical in
    the registry's sampled fingerprint rows but differing in one
    unsampled element must produce distinct artifacts."""
    g1 = _pool(SEED)
    g2 = g1.copy()
    g2[1, 0] += 1.0          # row 1 is unsampled at 64-row stride over 256
    from repro.serve.registry import _fingerprint_array
    assert _fingerprint_array(g1) == _fingerprint_array(g2)
    assert content_digest_array(g1) != content_digest_array(g2)
    t1, t2 = _target(g1), _target(g2)
    k1, _ = build_artifact(store, g1, t1, 4)
    k2, _ = build_artifact(store, g2, t2, 4)
    assert k1.ident() != k2.ident()
    a1, a2 = store.get(k1), store.get(k2)
    assert not np.array_equal(a1.arrays["weights_traj"],
                              a2.arrays["weights_traj"])


def test_key_sensitivity(built):
    store, g, tgt, key, _ = built
    assert store.get(artifact_key_for(g, tgt, 0.25, 1e-10, True)) is None
    assert store.get(artifact_key_for(g, tgt, 0.5, 1e-10, False)) is None
    assert store.get(
        artifact_key_for(g, tgt + np.float32(1), 0.5, 1e-10, True)) is None


# -- fail-closed under disk faults --------------------------------------------

@pytest.mark.parametrize("kind", DISK_FAULT_KINDS)
def test_disk_fault_fail_closed(built, kind):
    store, g, tgt, key, ident = built
    info = inject_disk_fault(store, ident, kind, seed=SEED)
    assert info["kind"] == kind
    art = store.get(key)
    # Either the fault left the artifact fully verifiable (possible only
    # for kinds that touch an unluckily-unused byte — not these), or the
    # read is a clean miss; corrupt bytes are never served.
    assert art is None
    if kind != "kill-between-rename":        # manifest gone entirely
        assert not os.path.exists(store.manifest_path(ident))
    assert store.quarantined >= (0 if kind == "kill-between-rename" else 1)
    # The store stays usable: a rebuild recommits and serves again.
    key2, ident2 = build_artifact(store, g, tgt, K_MAX)
    assert ident2 == ident
    art = store.get(key2)
    assert art is not None
    idx, w, _, _ = art.slice(K_MAX)
    sess = omp_session_start(g, tgt, K_MAX)
    assert np.array_equal(idx, np.asarray(sess.indices))
    assert np.array_equal(w, np.asarray(sess.weights))


@pytest.mark.parametrize("kind", DISK_FAULT_KINDS)
def test_disk_fault_deterministic(built, kind):
    """Same (seed, kind, ident) -> same mutation.  The store is healed
    by a recommit between injections (put verifies resident blobs on
    collision), so both calls act on byte-identical state."""
    store, g, tgt, _, ident = built
    a = inject_disk_fault(store, ident, kind, seed=SEED)
    build_artifact(store, g, tgt, K_MAX)     # heal: recommit in place
    b = inject_disk_fault(store, ident, kind, seed=SEED)
    assert a == b


@pytest.mark.parametrize("stage", ["pre-blob", "between-rename"])
def test_crash_during_put_not_servable(store, stage):
    g = _pool(SEED)
    tgt = _target(g)
    with pytest.raises(SimulatedCrash):
        build_artifact(store, g, tgt, 6, crash=crash_after(stage))
    key = artifact_key_for(g, tgt, 0.5, 1e-10, True)
    assert store.get(key) is None            # miss, not corruption
    # and the interrupted commit can simply be retried
    key2, _ = build_artifact(store, g, tgt, 6)
    assert store.get(key2) is not None


def test_crash_post_commit_is_servable(store):
    g = _pool(SEED)
    tgt = _target(g)
    with pytest.raises(SimulatedCrash):
        build_artifact(store, g, tgt, 6,
                       crash=crash_after("post-commit"))
    key = artifact_key_for(g, tgt, 0.5, 1e-10, True)
    art = store.get(key)                     # rename completed: durable
    assert art is not None and art.k_max == 6


def test_stale_version_quarantined_on_read(built):
    """A manifest whose self-checksum is *valid* but whose schema is not
    ours must still be rejected (version skew, not bit rot)."""
    store, _, _, key, ident = built
    inject_disk_fault(store, ident, "stale-version", seed=SEED)
    man = json.load(open(store.manifest_path(ident)))
    assert man["schema"] != SCHEMA_VERSION
    assert store.get(key) is None
    assert os.path.exists(
        os.path.join(store.quarantine_dir, f"{ident}.json"))
    reason = open(
        os.path.join(store.quarantine_dir, f"{ident}.reason")).read()
    assert "schema" in reason


def test_tampered_manifest_field_rejected(built):
    """In-place edit of any manifest field breaks the self-checksum."""
    store, _, _, key, ident = built
    path = store.manifest_path(ident)
    man = json.load(open(path))
    man["meta"]["k_max"] = 999
    with open(path, "w") as f:
        json.dump(man, f, sort_keys=True)
    assert store.get(key) is None
    assert store.quarantined == 1


def test_norm_sidecar_catches_value_swap(built):
    """Two blobs' bytes swapped *with their hashes* still fail: the blob
    digests verify but dtype/shape/norm expectations do not."""
    store, _, _, key, ident = built
    path = store.manifest_path(ident)
    man = json.load(open(path))
    a, b = man["blobs"]["indices"], man["blobs"]["err_trace"]
    man["blobs"]["indices"], man["blobs"]["err_trace"] = b, a
    man["manifest_sha"] = manifest_self_sha(man)
    with open(path, "w") as f:
        json.dump(man, f, sort_keys=True)
    assert store.get(key) is None
    assert store.quarantined == 1


# -- GC safety ----------------------------------------------------------------

def test_gc_never_collects_referenced_blobs(built):
    store, _, _, key, _ = built
    rep = store.gc(grace_s=0.0)
    assert rep["objects_swept"] == 0
    assert store.get(key) is not None


def test_gc_sweeps_orphans_after_grace(built):
    store, g, tgt, key, ident = built
    # kill-between-rename: blobs committed, manifest never landed
    with pytest.raises(SimulatedCrash):
        build_artifact(store, _pool(SEED + 1), _target(_pool(SEED + 1)),
                       4, crash=crash_after("between-rename"))
    rep0 = store.gc(grace_s=3600.0)
    assert rep0["objects_swept"] == 0        # grace window protects
    rep = store.gc(grace_s=0.0)
    assert rep["objects_swept"] > 0
    assert rep["tmp_swept"] >= 1
    assert store.get(key) is not None        # survivor still verifies


def test_gc_ignores_unparseable_manifest(built):
    """GC must not crash on (or mark through) a torn manifest; the
    verifier quarantines it on the next read instead."""
    store, _, _, key, ident = built
    inject_disk_fault(store, ident, "truncated-manifest", seed=SEED)
    rep = store.gc(grace_s=3600.0)
    assert rep["marked"] == 0
    assert store.get(key) is None            # quarantined, fail closed


# -- serve integration --------------------------------------------------------

def _service(tmp_path, g):
    svc = SelectionService(
        artifact_store=str(tmp_path / "store"))
    pid = svc.register_pool(g)
    return svc, pid


def test_serve_hit_bit_equal_live(tmp_path):
    """Artifact-served tickets match live-served tickets: identical
    indices at every probed k, weights within 1 ulp.  (The live queued
    path solves through ``omp_select_batched``, whose NNLS arithmetic
    differs from the session engine the artifact records in the last
    ulp; exact weight equality vs the session engine is asserted in
    ``test_serve_weights_normalized_like_live``.)"""
    g = _pool(SEED)
    svc, pid = _service(tmp_path, g)
    entry = svc.registry.get(pid)
    tgt = np.asarray(entry.target_sum, np.float32)
    build_artifact(svc.artifacts, g, tgt, K_MAX,
                   fingerprint=entry.content_digest)
    live = SelectionService()
    live_pid = live.register_pool(g)
    for k in (1, K_MAX // 2, K_MAX):
        t = svc.submit(pid, k)
        assert t.status == "done" and t.degradation == "artifact"
        lt = live.submit(live_pid, k)
        live.drain()
        assert lt.status == "done" and lt.degradation != "artifact"
        assert np.array_equal(np.asarray(t.result.indices),
                              np.asarray(lt.result.indices))
        assert np.allclose(np.asarray(t.result.weights),
                           np.asarray(lt.result.weights),
                           rtol=1e-6, atol=1e-7)
    st = svc.stats()
    assert st["registry"]["artifact_hits"] == 3
    assert st["artifacts"]["loads"] == 1     # memoized after first hit


def test_serve_miss_falls_through_live(tmp_path):
    g = _pool(SEED)
    svc, pid = _service(tmp_path, g)
    entry = svc.registry.get(pid)
    tgt = np.asarray(entry.target_sum, np.float32)
    build_artifact(svc.artifacts, g, tgt, 8,
                   fingerprint=entry.content_digest)
    # k beyond coverage -> live path
    t = svc.submit(pid, 12)
    done = svc.drain()
    assert t in done and t.degradation != "artifact"
    # custom target -> different key -> live path
    t2 = svc.submit(pid, 4, target=tgt + np.float32(1))
    svc.drain()
    assert t2.status == "done" and t2.degradation != "artifact"
    # covered ask still hits
    t3 = svc.submit(pid, 8)
    assert t3.degradation == "artifact"
    c = svc.scheduler.counters
    assert c["admitted"] == (c["completed"] + c["shed"] + c["failed"]
                             + svc.scheduler.pending())


@pytest.mark.parametrize("kind", DISK_FAULT_KINDS)
def test_serve_fault_falls_through_never_corrupt(tmp_path, kind):
    """The end-to-end guarantee: under any disk fault the service
    answers off the live ladder with the *same selection* a fault-free
    live solve produces — the artifact tier can only ever accelerate."""
    g = _pool(SEED)
    svc, pid = _service(tmp_path, g)
    entry = svc.registry.get(pid)
    tgt = np.asarray(entry.target_sum, np.float32)
    _, ident = build_artifact(svc.artifacts, g, tgt, K_MAX,
                              fingerprint=entry.content_digest)
    inject_disk_fault(svc.artifacts, ident, kind, seed=SEED)
    t = svc.submit(pid, K_MAX)
    if t.status != "done":
        svc.drain()
    assert t.status == "done"
    assert t.degradation != "artifact"       # fell through, fail closed
    live = SelectionService()
    live_pid = live.register_pool(g)
    lt = live.submit(live_pid, K_MAX)
    live.drain()
    assert np.array_equal(np.asarray(t.result.indices),
                          np.asarray(lt.result.indices))
    assert np.array_equal(np.asarray(t.result.weights),
                          np.asarray(lt.result.weights))
    st = svc.stats()["registry"]
    assert st["artifact_hits"] == 0


def test_serve_weights_normalized_like_live(tmp_path):
    g = _pool(SEED)
    svc, pid = _service(tmp_path, g)
    entry = svc.registry.get(pid)
    tgt = np.asarray(entry.target_sum, np.float32)
    build_artifact(svc.artifacts, g, tgt, K_MAX,
                   fingerprint=entry.content_digest)
    t = svc.submit(pid, K_MAX)
    sess = omp_session_start(g, tgt, K_MAX)
    want = _normalize(jnp.asarray(np.asarray(sess.weights)),
                      jnp.asarray(np.asarray(sess.mask)))
    assert np.array_equal(np.asarray(t.result.weights),
                          np.asarray(want))


def test_chunked_pools_have_no_artifact_path(tmp_path):
    from repro.data.loader import ChunkedPool
    g = _pool(SEED, n=128, d=8)
    svc = SelectionService(artifact_store=str(tmp_path / "store"))
    pid = svc.register_chunked_pool(ChunkedPool(g, chunk_size=32))
    entry = svc.registry.get(pid)
    assert entry.content_digest is None
    t = svc.submit(pid, 8)
    svc.drain()
    assert t.status == "done" and t.degradation != "artifact"
