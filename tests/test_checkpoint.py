"""Checkpointing: atomicity, async, keep-K GC, reshard-on-restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              load_solver_state, restore_sharded,
                              save_checkpoint, save_solver_state)
from repro.checkpoint.checkpoint import intact_steps, latest_step


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)),
                   "b": jnp.zeros((4,))},
        "opt": {"step": jnp.int32(7),
                "slots": {"w": jnp.ones((8, 4)), "b": jnp.ones((4,))}},
        "meta": {"epoch": np.int64(3)},
    }


def _assert_tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 10, t)
    got = load_checkpoint(str(tmp_path))
    _assert_tree_equal(t, got)


def test_latest_selection(tmp_path):
    for s in (5, 20, 10):
        save_checkpoint(str(tmp_path), s, _tree(s))
    assert latest_step(str(tmp_path)) == 20
    got = load_checkpoint(str(tmp_path))
    _assert_tree_equal(_tree(20), got)


def test_keep_k_gc(tmp_path):
    for s in range(6):
        save_checkpoint(str(tmp_path), s, _tree(s), keep=3)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 3
    assert steps[-1] == "step_0000000005"


def test_crashed_tmp_ignored(tmp_path):
    """A partial tmp dir (crash mid-write) must not corrupt restore."""
    save_checkpoint(str(tmp_path), 1, _tree(1))
    os.makedirs(tmp_path / "tmp.99.12345")
    (tmp_path / "tmp.99.12345" / "arrays.npz").write_bytes(b"garbage")
    got = load_checkpoint(str(tmp_path))
    _assert_tree_equal(_tree(1), got)
    # a later save GCs the stale tmp dir
    save_checkpoint(str(tmp_path), 2, _tree(2), keep=5)
    assert not any(d.startswith("tmp.") for d in os.listdir(tmp_path))


def test_gc_sweeps_partial_step_dirs(tmp_path):
    """A manifest-less step dir (kill-during-save debris) is swept as an
    orphan, not counted toward keep-K — with keep=2 the two *restorable*
    checkpoints must both survive."""
    save_checkpoint(str(tmp_path), 1, _tree(1))
    save_checkpoint(str(tmp_path), 2, _tree(2))
    # Inject a partial step dir newer than both: rename happened, content
    # never finished (no manifest).
    partial = tmp_path / "step_0000000099"
    os.makedirs(partial)
    (partial / "arrays.npz").write_bytes(b"torn")
    save_checkpoint(str(tmp_path), 3, _tree(3), keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    # orphan gone; the two newest intact checkpoints retained
    assert steps == ["step_0000000002", "step_0000000003"]
    assert intact_steps(str(tmp_path)) == [2, 3]
    _assert_tree_equal(_tree(2), load_checkpoint(str(tmp_path), 2))


def test_solver_state_falls_back_past_corrupt_latest(tmp_path):
    """A corrupt newest step (manifest intact, arrays unreadable) must
    fall back to the previous intact step — not raise, not return junk."""
    save_solver_state(str(tmp_path), 1, {"s": np.arange(3)})
    save_solver_state(str(tmp_path), 2, {"s": np.arange(3) * 2})
    (tmp_path / "step_0000000002" / "arrays.npz").write_bytes(b"rotted")
    got = load_solver_state(str(tmp_path))
    assert got is not None
    np.testing.assert_array_equal(got["s"], np.arange(3))


def test_solver_state_empty_latest_step_dir(tmp_path):
    """An emptied latest step dir (manifest deleted too) is simply not a
    candidate; the previous step resumes."""
    save_solver_state(str(tmp_path), 1, {"s": np.ones(2)})
    save_solver_state(str(tmp_path), 2, {"s": np.zeros(2)})
    d = tmp_path / "step_0000000002"
    for f in os.listdir(d):
        os.unlink(d / f)
    got = load_solver_state(str(tmp_path))
    np.testing.assert_array_equal(got["s"], np.ones(2))


def test_solver_state_none_when_nothing_loads(tmp_path):
    """Every retained step corrupt -> None (start fresh), never raise."""
    assert load_solver_state(str(tmp_path)) is None        # no dir at all
    save_solver_state(str(tmp_path), 1, {"s": np.ones(2)})
    (tmp_path / "step_0000000001" / "arrays.npz").write_bytes(b"x")
    assert load_solver_state(str(tmp_path)) is None


def test_async_manager(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(3)
    m.save(100, t, blocking=False)
    m.wait()
    got = m.restore()
    _assert_tree_equal(t, got)


def test_async_overlapping_saves(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in range(4):
        m.save(s, _tree(s), blocking=False)  # each save joins the previous
    m.wait()
    assert m.latest_step() == 3


def test_restore_sharded_single_device(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 1, t)
    got = load_checkpoint(str(tmp_path))
    dev = jax.devices()[0]
    sh = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), got)
    placed = restore_sharded(got, sh)
    _assert_tree_equal(t, placed)
    leaf = jax.tree_util.tree_leaves(placed)[0]
    assert leaf.devices() == {dev}


def test_snapshot_isolated_from_mutation(tmp_path):
    """Async save snapshots at call time: later mutations don't leak in."""
    m = CheckpointManager(str(tmp_path))
    arr = np.ones((4,), np.float32)
    m.save(1, {"a": arr}, blocking=False)
    arr[:] = 7.0  # mutate after handing off
    m.wait()
    got = m.restore()
    assert got["a"].sum() == 4.0  # the pre-mutation snapshot
