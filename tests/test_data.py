"""Data substrate: determinism, restartability, imbalance protocol."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.loader import SubsetLoader
from repro.data.synthetic import make_classification, make_imbalanced, split
from repro.data.tokens import TokenStream, token_batch


# ---------------------------------------------------------------------------
# Token stream
# ---------------------------------------------------------------------------

def test_token_batch_deterministic():
    a = token_batch(0, step=7, shard=2, batch=4, seq_len=32, vocab=100)
    b = token_batch(0, step=7, shard=2, batch=4, seq_len=32, vocab=100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["targets"], b["targets"])


def test_token_batch_distinct_across_steps_and_shards():
    base = token_batch(0, 0, 0, 4, 32, 100)["tokens"]
    for step, shard in [(1, 0), (0, 1), (5, 3)]:
        other = token_batch(0, step, shard, 4, 32, 100)["tokens"]
        assert not np.array_equal(base, other), (step, shard)


def test_token_targets_are_shifted_tokens():
    b = token_batch(0, 0, 0, 2, 16, 50)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_token_stream_restart_is_bit_exact():
    """Checkpoint = one integer: resuming at step k replays batch k."""
    s = TokenStream(seed=3, batch_per_shard=2, seq_len=16, vocab=64,
                    n_shards=4)
    ref = [s.batch(i, 1)["tokens"] for i in range(10)]
    state = s.state(6)
    resume_at = TokenStream.resume(state)
    for i in range(resume_at, 10):
        np.testing.assert_array_equal(s.batch(i, 1)["tokens"], ref[i])


@given(vocab=st.integers(20, 200), seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_token_range(vocab, seed):
    b = token_batch(seed, 0, 0, 4, 32, vocab)
    assert int(b["tokens"].min()) >= 0
    assert int(b["tokens"].max()) < vocab


# ---------------------------------------------------------------------------
# Synthetic classification
# ---------------------------------------------------------------------------

def test_classification_is_learnable_structure():
    """Class means are separated: a nearest-mean rule beats chance by a
    lot (the gradient-space class structure GRAD-MATCH exploits)."""
    ds = make_classification(jax.random.PRNGKey(0), n=2000, dim=32,
                             num_classes=5, sep=6.0)
    means = jnp.stack([ds.x[ds.y == c].mean(0) for c in range(5)])
    d = jnp.linalg.norm(ds.x[:, None] - means[None], axis=-1)
    acc = float(jnp.mean((jnp.argmin(d, 1) == ds.y)))
    assert acc > 0.6, acc


def test_imbalance_protocol():
    train, val = make_imbalanced(jax.random.PRNGKey(1), n=4000, dim=16,
                                 num_classes=10, imbalanced_frac=0.3,
                                 keep_frac=0.1)
    counts = np.bincount(np.asarray(train.y), minlength=10)
    imb, bal = counts[:3], counts[3:]
    # imbalanced classes should be ~10x rarer
    assert imb.mean() < 0.3 * bal.mean(), counts
    vcounts = np.bincount(np.asarray(val.y), minlength=10)
    assert vcounts.min() > 0  # validation stays clean/balanced-ish


def test_split_disjoint_and_complete():
    ds = make_classification(jax.random.PRNGKey(2), n=500, dim=8)
    tr, va = split(ds, jax.random.PRNGKey(3), val_frac=0.2)
    assert tr.n + va.n == 500
    assert va.n == 100


# ---------------------------------------------------------------------------
# Subset loader
# ---------------------------------------------------------------------------

def _loader(n=64, bs=8):
    x = jnp.arange(n, dtype=jnp.float32)[:, None] * jnp.ones((1, 4))
    y = jnp.arange(n, dtype=jnp.int32) % 4
    return SubsetLoader(x, y, bs, seed=5)


def test_loader_serves_selection_only():
    ld = _loader()
    idx = np.array([1, 5, 9, 13, 17, 21, 25, 29])
    ld.set_selection(idx, np.full(8, 1 / 8, np.float32), np.ones(8, bool))
    for _ in range(5):
        b = ld.next_batch()
        rows = np.asarray(b["x"][:, 0]).astype(int)
        assert set(rows).issubset(set(idx.tolist()))
        np.testing.assert_allclose(float(b["weights"].sum()), 1.0,
                                   rtol=1e-5)


def test_loader_checkpoint_resume_bit_exact():
    ld = _loader()
    ld.set_selection(np.arange(32), np.full(32, 1 / 32, np.float32),
                     np.ones(32, bool))
    for _ in range(3):
        ld.next_batch()
    snap = ld.checkpoint_state()
    ref = [np.asarray(ld.next_batch()["x"]) for _ in range(6)]
    ld2 = _loader()
    ld2.restore_state(snap)
    got = [np.asarray(ld2.next_batch()["x"]) for _ in range(6)]
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)


def test_loader_epoch_covers_subset():
    ld = _loader(n=32, bs=8)
    ld.set_selection(np.arange(16), np.full(16, 1 / 16, np.float32),
                     np.ones(16, bool))
    seen = set()
    for b in ld.epoch_batches():
        seen.update(np.asarray(b["x"][:, 0]).astype(int).tolist())
    assert seen == set(range(16))


def test_loader_padded_selection_filtered():
    ld = _loader()
    idx = np.array([3, 7, -1, -1])
    mask = np.array([True, True, False, False])
    w = np.array([0.6, 0.4, 0.0, 0.0], np.float32)
    ld.set_selection(idx, w, mask)
    assert ld.subset_size == 2
