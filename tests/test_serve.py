"""Differential + behavioral tests for the selection service (DESIGN.md §6).

Three layers:

* **Anytime parity** — ``omp_session_start(k)`` + ``omp_session_extend(k')``
  must select index-identically (weights to f32 tolerance) to a one-shot
  ``omp_select(k')`` across the omp-parity grid, including duplicate rows,
  masked pools and ``k' >= n`` tails; chained extensions must be
  bit-identical to a single extension (the resume property).
* **Batched parity** — ``omp_select_batched`` row ``b`` must match
  per-target ``omp_select`` exactly on indices/mask.
* **Service behavior** — micro-batching accounting, admission backpressure
  (queue caps, tenant budgets), session TTL/LRU with an injected clock,
  registry fingerprint dedupe + eviction, chunked-pool serving, and the
  schedule-validation errors from core/selection.py.

Grid k values stay below the f32 noise floor (see
tests/test_omp_parity.py and the DESIGN.md §4 discussion) — beyond it
every solver ranks reassociation noise and parity is undefined.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as sel_lib
from repro.core import streaming as stream_lib
from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.omp import (omp_select, omp_select_batched,
                            omp_session_extend, omp_session_start,
                            session_prefix_result, session_result)
from repro.data.loader import ChunkedPool
from repro.resilience import (CircuitOpen, FaultPlan, FaultyChunkIterator,
                              RetryPolicy)
from repro.serve import (BudgetExhausted, QueueFull, SelectionService,
                         SessionGone, UnknownPool)


def _pool(seed, n, d):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _assert_match(got, want, what, exact_weights=False):
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]),
                                  err_msg=f"{what}: indices differ")
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]),
                                  err_msg=f"{what}: masks differ")
    tol = {} if exact_weights else dict(rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got[1]), np.asarray(want[1]),
                               err_msg=f"{what}: weights differ", **tol)


# ---------------------------------------------------------------------------
# anytime extension parity (the certified k -> k' claim)
# ---------------------------------------------------------------------------

GRID = [
    # (seed, n, d, k_first, k_ext) — same shapes as the omp-parity grid,
    # extensions crossing the narrow/wide regimes and block boundaries
    (0, 96, 12, 8, 16),
    (1, 160, 48, 10, 24),
    (2, 200, 8, 6, 16),
    (3, 64, 32, 24, 96),     # k' > n: the masked tail must agree too
]


@pytest.mark.parametrize("seed,n,d,k1,k2", GRID)
@pytest.mark.parametrize("lam", [1e-6, 0.3])
def test_extension_matches_oneshot(seed, n, d, k1, k2, lam):
    g = jnp.asarray(_pool(seed, n, d))
    target = jnp.sum(g, axis=0)
    sess = omp_session_start(g, target, k1, lam=lam)
    sess = omp_session_extend(g, sess, k2)
    one = omp_select(g, target, k=k2, lam=lam)
    _assert_match(session_result(sess), one, f"extend {k1}->{k2}")


def test_extension_duplicate_rows():
    g = _pool(10, 80, 12)
    g[1::2] = g[::2]
    g = jnp.asarray(g)
    target = jnp.sum(g, axis=0)
    sess = omp_session_start(g, target, 9, lam=0.2)
    sess = omp_session_extend(g, sess, 24)
    one = omp_select(g, target, k=24, lam=0.2)
    _assert_match(session_result(sess), one, "extend (duplicates)")


def test_extension_masked_pool():
    g = jnp.asarray(_pool(12, 72, 10))
    valid = jnp.asarray(np.arange(72) < 9)
    target = jnp.sum(g * valid[:, None], axis=0)
    sess = omp_session_start(g, target, 5, lam=0.2, valid=valid)
    sess = omp_session_extend(g, sess, 32)        # k' >> #valid
    one = omp_select(g, target, k=32, lam=0.2, valid=valid)
    _assert_match(session_result(sess), one, "extend (masked, k'>=n_valid)")


def test_chained_extension_bit_identical():
    """extend(k1); extend(k2) == extend(k2) directly — the resume is a
    resume, not a re-solve with different rounding."""
    g = jnp.asarray(_pool(4, 150, 24))
    target = jnp.sum(g, axis=0)
    chained = omp_session_start(g, target, 7, lam=0.1)
    chained = omp_session_extend(g, chained, 19)
    chained = omp_session_extend(g, chained, 40)
    direct = omp_session_start(g, target, 40, lam=0.1)
    _assert_match(session_result(chained), session_result(direct),
                  "chained vs direct", exact_weights=True)
    np.testing.assert_array_equal(np.asarray(chained.st.gram),
                                  np.asarray(direct.st.gram))


def test_extension_shrink_and_noop():
    g = jnp.asarray(_pool(5, 64, 16))
    target = jnp.sum(g, axis=0)
    sess = omp_session_start(g, target, 12)
    assert omp_session_extend(g, sess, 12) is sess
    with pytest.raises(ValueError, match="shrink"):
        omp_session_extend(g, sess, 6)


# ---------------------------------------------------------------------------
# batched multi-target parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n,d,k", [(0, 96, 12, 16), (1, 160, 48, 24),
                                        (3, 64, 32, 96)])
def test_batched_matches_sequential(seed, n, d, k):
    g = jnp.asarray(_pool(seed, n, d))
    targets = jnp.stack([
        jnp.sum(g, axis=0),
        jnp.sum(g[: n // 2], axis=0),
        g[3] * 2.0 + g[7],
        jnp.sum(g[::3], axis=0),
    ])
    bi, bw, bm, be = omp_select_batched(g, targets, k=k, lam=0.3)
    for b in range(targets.shape[0]):
        one = omp_select(g, targets[b], k=k, lam=0.3)
        _assert_match((bi[b], bw[b], bm[b], be[b]), one, f"batch row {b}")


def test_batched_per_request_valid_masks():
    g = jnp.asarray(_pool(6, 120, 20))
    rng = np.random.default_rng(6)
    valids = jnp.asarray(rng.random((3, 120)) < 0.5)
    targets = jnp.stack([jnp.sum(g * valids[b][:, None], axis=0)
                         for b in range(3)])
    bi, bw, bm, be = omp_select_batched(g, targets, k=16, lam=0.2,
                                        valid=valids)
    for b in range(3):
        one = omp_select(g, targets[b], k=16, lam=0.2, valid=valids[b])
        _assert_match((bi[b], bw[b], bm[b], be[b]), one,
                      f"masked batch row {b}")
        sel = np.asarray(bi[b])[np.asarray(bm[b])]
        assert np.asarray(valids[b])[sel].all()


def test_batched_dense_method():
    g = jnp.asarray(_pool(7, 80, 16))
    targets = jnp.stack([jnp.sum(g, axis=0), g[5] * 3.0])
    bi, _, bm, _ = omp_select_batched(g, targets, k=12, method="dense")
    for b in range(2):
        one = omp_select(g, targets[b], k=12, method="dense")
        np.testing.assert_array_equal(np.asarray(bi[b]),
                                      np.asarray(one[0]))


# ---------------------------------------------------------------------------
# service: scheduler batching + differential result check
# ---------------------------------------------------------------------------

def _service(**kw):
    kw.setdefault("max_batch", 8)
    return SelectionService(**kw)


def test_scheduler_micro_batches_same_pool():
    svc = _service()
    g1, g2 = _pool(0, 192, 24), _pool(1, 160, 24)
    p1, p2 = svc.register_pool(g1), svc.register_pool(g2)
    tickets = [svc.submit(p1 if i % 2 == 0 else p2, k=16,
                          tenant=f"t{i % 2}") for i in range(8)]
    done = svc.drain()
    assert [t.status for t in done] == ["done"] * 8
    assert all(t.batched_with == 4 for t in done)
    assert svc.scheduler.batches_run == 2
    for t in tickets:
        g = g1 if t.request.pool_id == p1 else g2
        gj = jnp.asarray(g)
        one = omp_select(gj, jnp.sum(gj, axis=0), k=16)
        np.testing.assert_array_equal(np.asarray(t.result.indices),
                                      np.asarray(one[0]))
        s = float(np.asarray(t.result.weights)[
            np.asarray(t.result.mask)].sum())
        assert s == pytest.approx(1.0, rel=1e-5)


def test_scheduler_batch_respects_distinct_keys():
    svc = _service()
    p = svc.register_pool(_pool(2, 128, 16))
    a = svc.submit(p, k=12)
    b = svc.submit(p, k=20)          # different k -> different batch
    svc.drain()
    assert a.batched_with == 1 and b.batched_with == 1
    assert int(np.asarray(a.result.mask).sum()) == 12
    assert int(np.asarray(b.result.mask).sum()) == 20


def test_scheduler_craig_and_random_single():
    svc = _service()
    p = svc.register_pool(_pool(3, 96, 16))
    t1 = svc.submit(p, k=8, strategy="craig-lazy")
    t2 = svc.submit(p, k=8, strategy="random", seed=1)
    svc.drain()
    assert t1.status == "done" and t2.status == "done"
    assert int(np.asarray(t1.result.mask).sum()) == 8
    # cached FL scan is reused across craig requests
    entry = svc.registry.get(p)
    assert entry._fl is not None


def test_unknown_strategy_and_pool():
    svc = _service()
    p = svc.register_pool(_pool(4, 64, 8))
    with pytest.raises(ValueError, match="unservable"):
        svc.submit(p, k=4, strategy="gradmatch-pb")
    with pytest.raises(UnknownPool):
        svc.submit("nope", k=4)


def test_chunked_pool_served_via_streaming():
    g = _pool(5, 200, 16)
    svc = _service()
    pid = svc.register_chunked_pool(ChunkedPool(g, chunk_size=48))
    res = svc.select(pid, k=20)
    gj = jnp.asarray(g)
    one = omp_select(gj, jnp.sum(gj, axis=0), k=20)
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  np.asarray(one[0]))
    with pytest.raises(UnknownPool, match="chunked"):
        svc.open_session(pid, k=8)


def test_partitioned_strategy_served_both_pool_kinds():
    from repro.core import partition as part_lib
    g = _pool(55, 400, 16)
    svc = _service()
    # Array pool: hashed partition-and-merge, matches the library path.
    pid = svc.register_pool(g, partitions=3)
    t = svc.submit(pid, k=20, strategy="gradmatch-partitioned")
    svc.drain()
    assert t.status == "done" and t.degradation == "certified"
    lib = part_lib.gradmatch_partitioned(g, 20, partitions=3)
    np.testing.assert_array_equal(np.asarray(t.result.indices),
                                  np.asarray(lib.indices))
    # Chunked pool: contiguous ranges through the streaming engine.
    pid2 = svc.register_chunked_pool(ChunkedPool(g, chunk_size=96),
                                     partitions=4)
    assert svc.registry.get(pid2).partitions == 4
    res = svc.select(pid2, k=20, strategy="gradmatch-partitioned")
    lib2 = part_lib.gradmatch_partitioned_stream(pool=g, k=20, partitions=4)
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  np.asarray(lib2.indices))
    assert res.stats.num_parts == 4 and res.stats.stream is not None


# ---------------------------------------------------------------------------
# admission / backpressure
# ---------------------------------------------------------------------------

def test_queue_full_backpressure():
    svc = _service(max_queue=3)
    p = svc.register_pool(_pool(6, 64, 8))
    for _ in range(3):
        svc.submit(p, k=4)
    with pytest.raises(QueueFull):
        svc.submit(p, k=4)
    svc.drain()
    svc.submit(p, k=4)               # drained queue admits again


def test_tenant_budget_exhaustion_and_inflight_cap():
    svc = _service(default_budget_units=None)
    p = svc.register_pool(_pool(7, 64, 8))
    svc.admission.set_budget("metered", budget_units=1500.0)
    t = svc.submit(p, k=1, tenant="metered")      # 64*8 + 1*72 = 584
    with pytest.raises(BudgetExhausted, match="budget"):
        svc.submit(p, k=8, tenant="metered")
    svc.drain()
    assert t.status == "done"
    # in-flight cap is independent of the unit budget
    svc.admission.set_budget("capped", budget_units=None, max_inflight=2)
    svc.submit(p, k=4, tenant="capped")
    svc.submit(p, k=4, tenant="capped")
    with pytest.raises(BudgetExhausted, match="in flight"):
        svc.submit(p, k=4, tenant="capped")
    svc.drain()
    assert svc.admission.account("capped").inflight == 0


def test_session_extension_charges_delta_only():
    svc = _service()
    p = svc.register_pool(_pool(8, 128, 16))
    sid, _ = svc.open_session(p, k=16, tenant="m")
    used_after_open = svc.admission.account("m").used_units
    svc.extend_session(sid, 24)
    delta = svc.admission.account("m").used_units - used_after_open
    from repro.serve import estimate_cost
    assert delta == pytest.approx(estimate_cost(128, 16, 8))


# ---------------------------------------------------------------------------
# sessions: TTL + LRU with an injected clock
# ---------------------------------------------------------------------------

def test_session_ttl_expiry_and_lru_eviction():
    clock = {"t": 0.0}
    svc = _service(max_sessions=2, session_ttl_s=100.0,
                   clock=lambda: clock["t"])
    p = svc.register_pool(_pool(9, 96, 12))
    sid1, _ = svc.open_session(p, k=8)
    clock["t"] = 50.0
    sid2, _ = svc.open_session(p, k=8)
    clock["t"] = 120.0                       # sid1 idle 120s > TTL
    with pytest.raises(SessionGone):
        svc.extend_session(sid1, 16)
    svc.extend_session(sid2, 16)             # idle 70s: still alive
    # LRU: capacity 2, opening two more evicts sid2
    sid3, _ = svc.open_session(p, k=8)
    sid4, _ = svc.open_session(p, k=8)
    with pytest.raises(SessionGone):
        svc.extend_session(sid2, 24)
    svc.extend_session(sid4, 16)
    stats = svc.sessions.stats()
    assert stats["expirations"] >= 1 and stats["evictions"] >= 1


def test_extension_after_service_roundtrip_matches_oneshot():
    svc = _service()
    g = _pool(11, 160, 24)
    p = svc.register_pool(g)
    sid, first = svc.open_session(p, k=10, lam=0.3)
    ext = svc.extend_session(sid, 24)
    gj = jnp.asarray(g)
    idx, w, mask, err = omp_select(gj, jnp.sum(gj, axis=0), k=24, lam=0.3)
    np.testing.assert_array_equal(np.asarray(ext.indices), np.asarray(idx))
    # first-k prefix of the extension is the original selection
    np.testing.assert_array_equal(np.asarray(ext.indices)[:10],
                                  np.asarray(first.indices))


# ---------------------------------------------------------------------------
# failure paths: the queue never wedges, budgets never leak
# ---------------------------------------------------------------------------

def test_pool_evicted_between_submit_and_drain_fails_ticket_not_queue():
    svc = _service(max_pools=1)
    g1 = _pool(20, 64, 8)
    p1 = svc.register_pool(g1)
    t1 = svc.submit(p1, k=4, tenant="m")
    p2 = svc.register_pool(_pool(21, 64, 8))   # LRU-evicts p1
    t2 = svc.submit(p2, k=4, tenant="m")
    done = svc.drain()                          # must not raise
    assert t1.status == "failed" and "unknown pool" in t1.error.lower()
    assert t2.status == "done"
    assert svc.scheduler.pending() == 0
    assert svc.admission.account("m").inflight == 0


def test_malformed_target_fails_group_releases_inflight_and_refunds():
    svc = _service(default_budget_units=1e9)
    p = svc.register_pool(_pool(22, 64, 8))
    bad = svc.submit(p, k=4, tenant="m", target=np.zeros((3,), np.float32))
    good_other_key = svc.submit(p, k=6, tenant="m")
    used_before = svc.admission.account("m").used_units
    done = svc.drain()                          # must not raise
    assert bad.status == "failed" and bad.error
    assert good_other_key.status == "done"
    acct = svc.admission.account("m")
    assert acct.inflight == 0
    # failed work refunded, delivered work still charged
    assert acct.used_units == pytest.approx(used_before - bad.cost)


def test_chunked_pool_rejects_per_request_valid():
    g = _pool(23, 96, 8)
    svc = _service()
    pid = svc.register_chunked_pool(ChunkedPool(g, chunk_size=32))
    t = svc.submit(pid, k=8, valid=np.ones((96,), bool))
    svc.drain()
    assert t.status == "failed" and "valid" in t.error
    with pytest.raises(ValueError, match="chunk factory"):
        svc.register_chunked_pool(
            lambda: iter([(g, None)]), valid=np.ones((96,), bool))


def test_failed_session_open_refunds_budget():
    svc = _service()
    svc.admission.set_budget("m", budget_units=1e9)
    p = svc.register_pool(_pool(24, 64, 8))
    with pytest.raises(Exception):
        svc.open_session(p, k=8, tenant="m",
                         target=np.zeros((5,), np.float32))  # wrong d
    acct = svc.admission.account("m")
    assert acct.used_units == 0.0 and acct.inflight == 0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_stale_session_after_pool_replacement_raises():
    svc = _service()
    a, b = _pool(27, 96, 12), _pool(28, 96, 12)
    svc.register_pool(a, pool_id="p")
    sid, _ = svc.open_session("p", k=8)
    svc.register_pool(b, pool_id="p")       # same id, new content
    with pytest.raises(SessionGone, match="stale"):
        svc.extend_session(sid, 16)


def test_extension_idempotent_retry_charges_nothing():
    svc = _service()
    svc.admission.set_budget("m", budget_units=1e9)
    p = svc.register_pool(_pool(29, 96, 12))
    sid, first = svc.open_session(p, k=8, tenant="m")
    used = svc.admission.account("m").used_units
    again = svc.extend_session(sid, 8)      # no-op retry
    assert svc.admission.account("m").used_units == used
    np.testing.assert_array_equal(np.asarray(again.indices),
                                  np.asarray(first.indices))
    with pytest.raises(ValueError, match="shrink"):
        svc.extend_session(sid, 4)


def test_registry_dedupe_respects_valid_mask():
    svc = _service()
    g = _pool(30, 80, 8)
    mask = np.arange(80) < 40
    p_all = svc.register_pool(g)
    p_masked = svc.register_pool(g, valid=mask)
    assert p_all != p_masked                # same rows, different pool
    sel = svc.select(p_masked, k=8)
    chosen = np.asarray(sel.indices)[np.asarray(sel.mask)]
    assert mask[chosen].all()


def test_random_and_glister_honor_pool_valid():
    svc = _service()
    g = _pool(31, 80, 8)
    mask = np.arange(80) < 10
    p = svc.register_pool(g, valid=mask)
    for strategy in ("random", "glister"):
        sel = svc.select(p, k=8, strategy=strategy, seed=3)
        chosen = np.asarray(sel.indices)[np.asarray(sel.mask)]
        assert mask[chosen].all(), strategy


def test_registry_overwrite_retires_old_fingerprint():
    svc = _service()
    a, b = _pool(25, 64, 8), _pool(26, 64, 8)
    svc.register_pool(a, pool_id="x")
    svc.register_pool(b, pool_id="x")           # same id, new content
    # re-registering A's content must NOT dedupe onto "x" (now holds B)
    pa = svc.register_pool(a)
    assert pa != "x"
    ga = np.asarray(svc.registry.get(pa).grads)
    np.testing.assert_array_equal(ga, a)

def test_registry_fingerprint_dedupe_and_eviction():
    svc = _service(max_pools=2)
    g1, g2, g3 = _pool(0, 64, 8), _pool(1, 64, 8), _pool(2, 64, 8)
    p1 = svc.register_pool(g1)
    assert svc.register_pool(g1.copy()) == p1       # content dedupe
    p2 = svc.register_pool(g2)
    p3 = svc.register_pool(g3)                      # evicts p1 (LRU)
    assert p1 not in svc.registry and p2 in svc.registry
    with pytest.raises(UnknownPool):
        svc.submit(p1, k=4)
    assert svc.registry.stats()["evictions"] == 1


# ---------------------------------------------------------------------------
# schedule validation (core/selection.py satellites)
# ---------------------------------------------------------------------------

def test_warm_start_epochs_validation():
    assert sel_lib.warm_start_epochs(300, 0.1) == (15, 150)
    with pytest.raises(ValueError, match="budget_frac"):
        sel_lib.warm_start_epochs(300, 1.0)
    with pytest.raises(ValueError, match="budget_frac"):
        sel_lib.warm_start_epochs(300, 0.0)
    with pytest.raises(ValueError, match="total_epochs"):
        sel_lib.warm_start_epochs(0, 0.1)
    with pytest.raises(ValueError, match="kappa"):
        sel_lib.warm_start_epochs(300, 0.1, kappa=0.0)


def test_selection_schedule_validation():
    sched = sel_lib.SelectionSchedule(select_every=5, warm_epochs=2,
                                      total_epochs=20)
    assert not sched.is_selection_epoch(1)
    assert sched.is_selection_epoch(2)
    with pytest.raises(ValueError, match="select_every"):
        sel_lib.SelectionSchedule(select_every=0)
    with pytest.raises(ValueError, match="warm_epochs"):
        sel_lib.SelectionSchedule(select_every=5, warm_epochs=-1)
    with pytest.raises(ValueError, match="swallows"):
        sel_lib.SelectionSchedule(select_every=5, warm_epochs=20,
                                  total_epochs=20)


# ---------------------------------------------------------------------------
# benchmark persistence merge (satellite: no more section overwrites)
# ---------------------------------------------------------------------------

def test_persist_merges_by_table(tmp_path, monkeypatch):
    common = pytest.importorskip("benchmarks.common")
    monkeypatch.setattr(common, "REPO_ROOT", tmp_path)
    rows_a = []
    rec_a = common.make_recorder("selection_time", rows_a)
    rec_a(strategy="gradmatch", pool=512, ms=1.0)
    common.persist("test", rows_a)
    # a later partial run writing a different table must keep table A
    rows_b = []
    rec_b = common.make_recorder("selection_serve", rows_b)
    rec_b(strategy="serve-batched", pool=512, ms=2.0)
    path = common.persist("test", rows_b)
    import json
    data = json.loads(path.read_text())
    tables = {r["table"] for r in data["rows"]}
    assert tables == {"selection_time", "selection_serve"}
    # re-running table A replaces its rows instead of appending
    rows_a2 = []
    rec_a2 = common.make_recorder("selection_time", rows_a2)
    rec_a2(strategy="gradmatch", pool=512, ms=9.0)
    data = json.loads(common.persist("test", rows_a2).read_text())
    tms = [r["ms"] for r in data["rows"]
           if r["table"] == "selection_time"]
    assert tms == [9.0]
    # legacy rows without a table tag survive via field-signature inference
    legacy = {"strategy": "gradmatch-stream", "pool": 64, "ms": 3.0}
    data["rows"].append(legacy)
    (tmp_path / "BENCH_test.json").write_text(json.dumps(data))
    data2 = json.loads(common.persist("test", rows_a2).read_text())
    assert any(r.get("strategy") == "gradmatch-stream"
               for r in data2["rows"])


# ---------------------------------------------------------------------------
# resilience: circuit breakers, degradation ladder, deadlines (DESIGN.md §8)
# ---------------------------------------------------------------------------

_FAST_RETRY = RetryPolicy(max_retries=2, backoff_s=0.0, sleep=lambda s: None)


def _poisoned_factory(g, chunk=32, die_after=5, die_once=False, seed=0):
    """A chunk factory that dies permanently (or once) mid-stream.

    Registration consumes 1 peeked chunk + one full warm pass, so for a
    4-chunk pool ``die_after=5`` admits cleanly and kills the very first
    serving solve that touches the loader.
    """
    pool = ChunkedPool(g, chunk_size=chunk)
    return FaultyChunkIterator(
        stream_lib.chunked_pool_iter(pool),
        FaultPlan(seed=seed, die_after_chunks=die_after, die_once=die_once))


def test_breaker_opens_poisoned_pool_healthy_pools_unaffected():
    """Survivability smoke: a poisoned pool trips its breaker and fails
    its queued work with labelled tickets; other pools on the same
    service keep serving certified answers; no queue wedge, no in-flight
    slot leak, no tenant-budget leak."""
    clock = {"t": 0.0}
    svc = _service(default_budget_units=1e9, breaker_threshold=2,
                   breaker_cooldown_s=60.0, clock=lambda: clock["t"],
                   retry_policy=_FAST_RETRY)
    p_arr = svc.register_pool(_pool(30, 64, 8))
    p_ok = svc.register_chunked_pool(ChunkedPool(_pool(31, 128, 8),
                                                 chunk_size=32))
    p_bad = svc.register_chunked_pool(_poisoned_factory(_pool(32, 128, 8)),
                                      cache_bytes=0)
    t_bad1 = svc.submit(p_bad, k=8, tenant="m")
    t_bad2 = svc.submit(p_bad, k=8, tenant="m")
    t_bad3 = svc.submit(p_bad, k=8, tenant="m")
    t_arr = svc.submit(p_arr, k=8, tenant="m")
    t_ok = svc.submit(p_ok, k=8, tenant="m")
    svc.drain()
    assert t_arr.status == "done" and t_arr.degradation == "certified"
    assert t_ok.status == "done" and t_ok.degradation == "certified"
    for t in (t_bad1, t_bad2, t_bad3):
        assert t.status == "failed" and t.degradation == "failed"
    # the third never ran: the breaker opened at threshold=2 and the
    # drain fast-failed the rest of the pool's queued group
    assert "circuit" in t_bad3.error.lower()
    acct = svc.admission.account("m")
    assert acct.inflight == 0
    assert acct.used_units == pytest.approx(t_arr.cost + t_ok.cost)
    assert svc.scheduler.pending() == 0
    # while open, submit fast-fails before charging the tenant
    with pytest.raises(CircuitOpen):
        svc.submit(p_bad, k=8, tenant="m")
    assert svc.admission.account("m").inflight == 0
    # cooldown -> half-open trial; the pool is still dead -> re-opens
    clock["t"] = 61.0
    t_retry = svc.submit(p_bad, k=8, tenant="m")
    svc.drain()
    assert t_retry.status == "failed"
    with pytest.raises(CircuitOpen):
        svc.submit(p_bad, k=8, tenant="m")
    assert svc.admission.account("m").inflight == 0
    assert svc.admission.account("m").used_units == pytest.approx(
        t_arr.cost + t_ok.cost)


def test_degradation_exhausted_refunds_exactly_once():
    """Nested failure (certified attempt dies, every ladder rung declines)
    must refund the admission charge exactly once — not zero times (a
    metered tenant paying for undelivered work) and not twice (the
    degrade path double-refunding inside the failure handler)."""
    svc = _service(default_budget_units=1e9, retry_policy=_FAST_RETRY,
                   breaker_threshold=100)
    p_ok = svc.register_pool(_pool(33, 64, 8))
    t_ok = svc.submit(p_ok, k=4, tenant="m")
    svc.drain()
    base = svc.admission.account("m").used_units
    assert base > 0                      # a real charge to drift against
    # cache_bytes=0: the stochastic rung has no arena to fall back on,
    # so with no checkpoints and no sessions the whole ladder declines.
    pid = svc.register_chunked_pool(_poisoned_factory(_pool(34, 128, 8)),
                                    cache_bytes=0)
    for _ in range(2):                   # repeatable: no cumulative drift
        t = svc.submit(pid, k=8, tenant="m")
        assert svc.admission.account("m").used_units == pytest.approx(
            base + t.cost)
        svc.drain()
        assert t.status == "failed" and t.degradation == "failed"
        acct = svc.admission.account("m")
        assert acct.used_units == pytest.approx(base)
        assert acct.inflight == 0


def test_degradation_stochastic_rung_serves_from_cache():
    """Stream dead, no checkpoint, no session: the ladder's last rung
    serves a seeded stochastic selection from the admission-warmed chunk
    cache, labelled — never passed off as certified."""
    svc = _service(retry_policy=_FAST_RETRY)
    g = _pool(35, 128, 8)
    pid = svc.register_chunked_pool(_poisoned_factory(g))
    svc.scheduler.stream_buffer = 16     # force the solve to the loader
    t = svc.submit(pid, k=12)
    svc.drain()
    assert t.status == "done" and t.degradation == "stochastic"
    idx = np.asarray(t.result.indices)
    m = np.asarray(t.result.mask)
    sel = idx[m]
    assert len(set(sel.tolist())) == 12
    assert sel.min() >= 0 and sel.max() < 128
    assert np.asarray(t.result.weights)[m].sum() == pytest.approx(1.0,
                                                                  rel=1e-5)
    assert svc.scheduler.stats()["degraded_served"] == {"stochastic": 1}
    # same seed, same cache -> deterministic fallback
    t2 = svc.submit(pid, k=12)
    svc.drain()
    np.testing.assert_array_equal(np.asarray(t2.result.indices), idx)


def test_degradation_resumed_rung_bit_identical(tmp_path):
    """A solve killed mid-stream once (crashed-and-restarted loader) is
    re-run by the ladder's first rung, resumes from its own mid-solve
    checkpoint, and returns the *certified* answer — bit-identical to a
    never-faulted service — labelled "resumed"."""
    g = _pool(36, 128, 8)

    ref_svc = _service(retry_policy=_FAST_RETRY)
    ref_pid = ref_svc.register_chunked_pool(
        stream_lib.chunked_pool_iter(ChunkedPool(g, chunk_size=32)),
        cache_bytes=0)
    ref_svc.scheduler.stream_buffer = 16
    ref = ref_svc.select(ref_pid, k=12)

    svc = _service(retry_policy=_FAST_RETRY,
                   checkpoint_root=str(tmp_path / "ckpt"))
    pid = svc.register_chunked_pool(
        _poisoned_factory(g, die_after=12, die_once=True), cache_bytes=0)
    svc.scheduler.stream_buffer = 16
    svc.scheduler.checkpoint_every = 1
    t = svc.submit(pid, k=12)
    svc.drain()
    assert t.status == "done" and t.degradation == "resumed"
    np.testing.assert_array_equal(np.asarray(t.result.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_array_equal(np.asarray(t.result.mask),
                                  np.asarray(ref.mask))
    np.testing.assert_array_equal(np.asarray(t.result.weights),
                                  np.asarray(ref.weights))
    assert svc.scheduler.stats()["degraded_served"] == {"resumed": 1}


def test_degradation_anytime_prefix_rung():
    """When a live anytime session covers the same pool content at k' >=
    k, the ladder serves its first-k prefix (indices certified by the
    prefix property) before falling to stochastic."""
    svc = _service(retry_policy=_FAST_RETRY)
    g = _pool(38, 128, 8)
    pid = svc.register_chunked_pool(_poisoned_factory(g), cache_bytes=0)
    gj = jnp.asarray(g)
    target = jnp.sum(gj, axis=0)
    sess = omp_session_start(gj, target, 24)
    calls = []

    def lookup(pool_id, fingerprint, k):
        calls.append((pool_id, k))
        idx, w, mask, err = session_prefix_result(sess, k)
        return SelectionResult(idx, _normalize(w, mask), mask, err)

    svc.scheduler.session_lookup = lookup
    t = svc.submit(pid, k=10)
    svc.drain()
    assert t.status == "done" and t.degradation == "anytime-prefix"
    assert calls == [(pid, 10)]
    one = omp_select(gj, target, k=24)
    np.testing.assert_array_equal(np.asarray(t.result.indices),
                                  np.asarray(one[0])[:10])


def test_deadline_expired_ticket_timeout_refund():
    """A request whose deadline expires while queued fails fast with the
    "timeout" label before any solve runs, refunds its charge, and does
    not count against the pool's breaker."""
    clock = {"t": 0.0}
    svc = _service(default_budget_units=1e9, clock=lambda: clock["t"])
    g = _pool(37, 96, 8)
    pid = svc.register_chunked_pool(ChunkedPool(g, chunk_size=32))
    t_late = svc.submit(pid, k=8, tenant="m", deadline_s=5.0)
    t_ok = svc.submit(pid, k=6, tenant="m")          # no deadline
    clock["t"] = 9.0                                 # queued past deadline
    svc.drain()
    assert t_late.status == "failed"
    assert t_late.degradation == "timeout"
    assert "DeadlineExceeded" in t_late.error
    assert t_ok.status == "done" and t_ok.degradation == "certified"
    acct = svc.admission.account("m")
    assert acct.inflight == 0
    assert acct.used_units == pytest.approx(t_ok.cost)
    # a deadline miss is the caller's fault, not the pool's
    assert svc.submit(pid, k=4, tenant="m") is not None
