"""End-to-end adaptive trainer (paper Algorithm 1) + fault tolerance."""

import jax
import numpy as np
import pytest

from repro.configs.paper import PaperHParams, mlp
from repro.data.synthetic import make_classification, split
from repro.train.trainer import AdaptiveTrainer, TrainerConfig


@pytest.fixture(scope="module")
def data():
    ds = make_classification(jax.random.PRNGKey(0), n=1024, dim=24,
                             num_classes=8, sep=5.0)
    return split(ds, jax.random.PRNGKey(1))


def _cfg(**kw):
    kw.setdefault("budget", 0.25)
    kw.setdefault("epochs", 12)
    kw.setdefault("batch_size", 32)
    kw.setdefault("hp", PaperHParams(select_every=4))
    return TrainerConfig(**kw)


def test_gradmatch_pb_learns(data):
    train, val = data
    rep = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                          _cfg(strategy="gradmatch-pb"), train, val).run()
    assert rep.final_acc > 0.3          # well above 1/8 chance
    assert rep.selection_rounds >= 2
    assert rep.subset_size <= int(train.n * 0.25) + 32


def test_subset_work_much_less_than_full(data):
    train, val = data
    r_sub = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                            _cfg(strategy="gradmatch-pb"), train, val).run()
    r_full = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                             _cfg(strategy="full"), train, val).run()
    # paper Fig. 1: ~1/budget work reduction (selection overhead included)
    assert r_sub.work_units < 0.5 * r_full.work_units


def test_warm_variant_runs(data):
    train, val = data
    rep = AdaptiveTrainer(
        mlp(in_dim=24, num_classes=8),
        _cfg(strategy="gradmatch-pb", warm_start=True, epochs=16),
        train, val).run()
    assert rep.strategy.endswith("-warm")
    assert rep.final_acc > 0.25


def test_isvalid_matches_validation_gradient(data):
    train, val = data
    rep = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                          _cfg(strategy="gradmatch", is_valid=True),
                          train, val).run()
    assert rep.final_acc > 0.25


def test_checkpoint_resume_continues(data, tmp_path):
    train, val = data
    kw = dict(strategy="gradmatch-pb", checkpoint_dir=str(tmp_path),
              checkpoint_every=4, seed=7)
    # run 1: interrupt by running fewer epochs (simulates preemption at 8)
    AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                    _cfg(epochs=8, **kw), train, val).run()
    # run 2: full schedule resumes from the snapshot, not from scratch
    rep = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                          _cfg(epochs=12, **kw), train, val).run()
    assert rep.final_acc > 0.25
    # work_units carries over the snapshot's counter: the resumed total
    # must equal a solo 12-epoch run (~1.0x), NOT solo + the redone 8
    # epochs (~1.67x) — i.e. resume does not redo pre-crash work.
    solo = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                           _cfg(epochs=12, strategy="gradmatch-pb",
                                seed=7), train, val).run()
    assert rep.work_units < 1.25 * solo.work_units


def test_early_stop_budget(data):
    train, val = data
    rep = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                          _cfg(strategy="full", early_stop_frac=0.25),
                          train, val).run()
    assert rep.work_units < 0.35 * (train.n * 3 * 12)
