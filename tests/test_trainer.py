"""End-to-end adaptive trainer (paper Algorithm 1) + fault tolerance."""

import jax
import numpy as np
import pytest

from repro.configs.paper import PaperHParams, mlp
from repro.data.synthetic import make_classification, split
from repro.train.trainer import AdaptiveTrainer, TrainerConfig


@pytest.fixture(scope="module")
def data():
    ds = make_classification(jax.random.PRNGKey(0), n=1024, dim=24,
                             num_classes=8, sep=5.0)
    return split(ds, jax.random.PRNGKey(1))


def _cfg(**kw):
    kw.setdefault("budget", 0.25)
    kw.setdefault("epochs", 12)
    kw.setdefault("batch_size", 32)
    kw.setdefault("hp", PaperHParams(select_every=4))
    return TrainerConfig(**kw)


def test_gradmatch_pb_learns(data):
    train, val = data
    rep = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                          _cfg(strategy="gradmatch-pb"), train, val).run()
    assert rep.final_acc > 0.3          # well above 1/8 chance
    assert rep.selection_rounds >= 2
    assert rep.subset_size <= int(train.n * 0.25) + 32


def test_subset_work_much_less_than_full(data):
    train, val = data
    r_sub = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                            _cfg(strategy="gradmatch-pb"), train, val).run()
    r_full = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                             _cfg(strategy="full"), train, val).run()
    # paper Fig. 1: ~1/budget work reduction (selection overhead included)
    assert r_sub.work_units < 0.5 * r_full.work_units


def test_warm_variant_runs(data):
    train, val = data
    rep = AdaptiveTrainer(
        mlp(in_dim=24, num_classes=8),
        _cfg(strategy="gradmatch-pb", warm_start=True, epochs=16),
        train, val).run()
    assert rep.strategy.endswith("-warm")
    assert rep.final_acc > 0.25


def test_isvalid_matches_validation_gradient(data):
    train, val = data
    rep = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                          _cfg(strategy="gradmatch", is_valid=True),
                          train, val).run()
    assert rep.final_acc > 0.25


def test_checkpoint_resume_continues(data, tmp_path):
    train, val = data
    kw = dict(strategy="gradmatch-pb", checkpoint_dir=str(tmp_path),
              checkpoint_every=4, seed=7)
    # run 1: interrupt by running fewer epochs (simulates preemption at 8)
    AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                    _cfg(epochs=8, **kw), train, val).run()
    # run 2: full schedule resumes from the snapshot, not from scratch
    rep = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                          _cfg(epochs=12, **kw), train, val).run()
    assert rep.final_acc > 0.25
    # work_units carries over the snapshot's counter: the resumed total
    # must equal a solo 12-epoch run (~1.0x), NOT solo + the redone 8
    # epochs (~1.67x) — i.e. resume does not redo pre-crash work.
    solo = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                           _cfg(epochs=12, strategy="gradmatch-pb",
                                seed=7), train, val).run()
    assert rep.work_units < 1.25 * solo.work_units


def test_gradmatch_stream_learns(data):
    """The streaming (out-of-core) selection path trains end to end."""
    train, val = data
    rep = AdaptiveTrainer(
        mlp(in_dim=24, num_classes=8),
        _cfg(strategy="gradmatch-stream", chunk_size=256, stream_buffer=128),
        train, val).run()
    assert rep.final_acc > 0.3
    assert rep.selection_rounds >= 2
    assert rep.subset_size <= int(train.n * 0.25)


def test_resume_bit_exact(data, tmp_path):
    """Interrupted + resumed training reproduces the uninterrupted run
    bit-for-bit: same selection rounds fired, identical final params."""
    from repro.checkpoint.checkpoint import load_checkpoint

    train, val = data
    kw = dict(strategy="gradmatch-pb", checkpoint_dir=str(tmp_path),
              checkpoint_every=4, seed=11, epochs=12)
    # uninterrupted run: snapshots at epochs 4, 8, 12
    rep1 = AdaptiveTrainer(mlp(in_dim=24, num_classes=8), _cfg(**kw),
                           train, val).run()
    snap1 = load_checkpoint(str(tmp_path), 12)
    # simulate preemption after epoch 8: discard the final snapshot
    import shutil
    shutil.rmtree(tmp_path / "step_0000000012")
    # resume: picks up at epoch 8, re-fires the epoch-8 selection, runs to 12
    rep2 = AdaptiveTrainer(mlp(in_dim=24, num_classes=8), _cfg(**kw),
                           train, val).run()
    snap2 = load_checkpoint(str(tmp_path), 12)
    assert rep2.selection_rounds == rep1.selection_rounds == 3
    leaves1, treedef1 = jax.tree_util.tree_flatten(snap1["params"])
    leaves2, treedef2 = jax.tree_util.tree_flatten(snap2["params"])
    assert treedef1 == treedef2
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert snap1["meta"]["work"] == snap2["meta"]["work"]


def test_early_stop_budget(data):
    train, val = data
    rep = AdaptiveTrainer(mlp(in_dim=24, num_classes=8),
                          _cfg(strategy="full", early_stop_frac=0.25),
                          train, val).run()
    assert rep.work_units < 0.35 * (train.n * 3 * 12)
