"""Fault injection, certified recovery, and checkpoint/resume (DESIGN.md §8).

The differential fault guarantee under test: with seeded *transient*
faults injected into chunk reads and row fetches (at well above a 5%
chunk rate), the streaming engine's selection must be **bit-identical**
to the fault-free run — retries and re-verification may cost passes, but
never change the answer.  Silent corruption must be detected against the
f32 exact-norm sidecars: transient corruption is cleared by re-reads,
persistent corruption is quarantined fail-closed (the row can never be
selected).  A solve killed mid-stream must resume from its checkpoint
and reproduce the fault-free selection exactly.

``FAULT_SEED`` parametrizes the whole fault schedule (CI's fault-suite
step runs this file under three seeds); every schedule is a pure
function of the seed, so each seed's run is deterministic end to end.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection as sel_lib
from repro.core import streaming as S
from repro.data.loader import ChunkedPool
from repro.resilience import (ChunkReadError, CircuitBreaker, CircuitOpen,
                              FaultPlan, FaultyChunkIterator, RetryExhausted,
                              RetryPolicy, StreamDied, TransientFault,
                              faulty_row_fetch, stochastic_fallback,
                              with_retries)

SEED = int(os.environ.get("FAULT_SEED", "7"))

# Zero backoff keeps the suite fast; max_retries=8 keeps the probability
# of 9 consecutive injected encounters (which would legitimately exhaust
# the policy) at rate^9 ~ 1e-8 for the rates used here.
FAST = RetryPolicy(max_retries=8, backoff_s=0.0, sleep=lambda s: None)

N, D, K, CHUNK, BUF = 256, 32, 32, 64, 16


def _x(seed=0):
    return np.random.default_rng(seed).standard_normal((N, D)).astype(
        np.float32)


def _target(x):
    return jnp.sum(jnp.asarray(x), axis=0)


def _small_cache_bytes(x):
    # Room for ~2 of the 4 chunks: forces eviction churn, repairs and
    # extra loader passes — the busiest recovery surface.
    return 2 * CHUNK * (x.shape[1] * 2 + 8)


def _solve(pool_iter, x, row_fetch=None, cache_bytes=None, **kw):
    cb = _small_cache_bytes(x) if cache_bytes is None else cache_bytes
    return S.omp_select_streaming(
        pool_iter, _target(x), K, buffer_size=BUF, cache_bytes=cb,
        row_fetch=row_fetch, retry=kw.pop("retry", FAST), **kw)


# -- retry policy ------------------------------------------------------------

def test_retry_policy_backoff_schedule_and_exhaustion():
    slept = []
    pol = RetryPolicy(max_retries=3, backoff_s=0.1, backoff_mult=2.0,
                      max_backoff_s=0.25, sleep=slept.append)
    calls = []

    def always_fails():
        calls.append(1)
        raise ChunkReadError("nope")

    with pytest.raises(RetryExhausted) as ei:
        with_retries(always_fails, pol)
    assert len(calls) == 4                       # 1 try + 3 retries
    assert slept == [0.1, 0.2, 0.25]             # capped exponential
    assert "nope" in str(ei.value)

    # A non-transient error passes straight through, unretried.
    def boom():
        raise ValueError("not a fault")

    with pytest.raises(ValueError):
        with_retries(boom, pol)

    # Success after a transient consumes exactly the failed attempts.
    state = {"left": 2}

    def flaky():
        if state["left"]:
            state["left"] -= 1
            raise ChunkReadError("flake")
        return 42

    retries = []
    assert with_retries(flaky, pol,
                        on_retry=lambda a, e: retries.append(a)) == 42
    assert retries == [0, 1]


# -- fault schedule determinism ----------------------------------------------

def test_fault_schedule_is_deterministic():
    x = _x()
    pool = S.array_chunks(x, CHUNK)
    plan = FaultPlan(seed=SEED, transient_rate=0.2, corrupt_rate=0.2,
                     slow_rate=0.2, slow_s=0.0)

    def drive(it):
        for _ in range(3):
            gen = it()
            while True:
                try:
                    for _ in gen:
                        pass
                    break
                except TransientFault:
                    gen = it()
        return dict(it.injected)

    a = drive(FaultyChunkIterator(pool, plan))
    b = drive(FaultyChunkIterator(pool, plan))
    assert a == b and sum(a.values()) > 0


# -- the differential guarantee ----------------------------------------------

def test_transient_faults_bit_identical_selection():
    x = _x()
    pool = S.array_chunks(x, CHUNK)
    ref = _solve(pool, x, row_fetch=S.array_row_fetch(x))
    assert ref.stats.retries == 0

    plan = FaultPlan(seed=SEED, transient_rate=0.12, row_transient_rate=0.1,
                     slow_rate=0.05, slow_s=0.0)
    runs = []
    for _ in range(2):                    # run twice: run-to-run determinism
        fpool = FaultyChunkIterator(pool, plan)
        ffetch = faulty_row_fetch(S.array_row_fetch(x), plan)
        out = _solve(fpool, x, row_fetch=ffetch)
        assert bool(jnp.all(out.indices == ref.indices))
        assert bool(jnp.all(out.mask == ref.mask))
        assert bool(jnp.all(out.weights == ref.weights))
        ninj = sum(fpool.injected.values()) + sum(ffetch.injected.values())
        assert ninj > 0 and out.stats.retries > 0
        assert out.stats.quarantined == 0
        runs.append((ninj, out.stats.retries, dict(fpool.injected)))
    assert runs[0] == runs[1]


def test_transient_chunk_corruption_detected_and_cleared():
    # Full-coverage cache: every chunk re-read has an exact-norm sidecar
    # to disagree with (detection is scoped to sidecar-covered data —
    # DESIGN.md §8).  Transient raises force pass retries whose re-reads
    # carry injected corruption; the engine must detect it against the
    # sidecars, clear it by re-reading, and select identically.
    x = _x()
    pool = S.array_chunks(x, CHUNK)
    pol = RetryPolicy(max_retries=16, backoff_s=0.0, sleep=lambda s: None)
    ref = _solve(pool, x, row_fetch=S.array_row_fetch(x),
                 cache_bytes=1 << 20, retry=pol)
    plan = FaultPlan(seed=SEED, transient_rate=0.15, corrupt_rate=0.15)
    fpool = FaultyChunkIterator(pool, plan)
    out = _solve(fpool, x, row_fetch=S.array_row_fetch(x),
                 cache_bytes=1 << 20, retry=pol)
    assert bool(jnp.all(out.indices == ref.indices))
    assert bool(jnp.all(out.mask == ref.mask))
    if fpool.injected["corrupt"]:
        # Detected against the sidecars and cleared by re-reads — never
        # quarantined, never silently selected.
        assert out.stats.retries > 0
    assert out.stats.quarantined == 0


def test_persistent_corruption_quarantined_never_selected():
    # Warm-cache zero-pass bootstrap: every candidate row's content
    # reaches the solver through checked_fetch only (a loader pass would
    # supply the poisoned rows clean and there would be nothing to
    # detect).  Poison two rows the fault-free solve *would* select, plus
    # one it would not — persistent disagreement with the sidecars must
    # quarantine all of them out of candidacy, fail-closed.
    x = _x()
    pool = S.array_chunks(x, CHUNK)

    def warm_solve(fetch):
        cache = S.ChunkCache(1 << 20, D)
        target, n = S.streaming_target(pool, cache=cache)
        assert n == N and cache.complete == N // CHUNK
        # buffer >= pool so the bootstrap refill covers every candidate
        # (a smaller buffer caps refill candidates and falls back to a
        # loader pass, which would hand the solver clean rows directly).
        return S.omp_select_streaming(pool, target, K, buffer_size=N,
                                      cache=cache, row_fetch=fetch,
                                      retry=FAST)

    ref = warm_solve(S.array_row_fetch(x))
    assert ref.stats.passes == 0          # bootstrap: loader never read
    picked = np.asarray(ref.indices)[np.asarray(ref.mask)]
    bad_ids = (int(picked[0]), int(picked[-1]), 3)
    plan = FaultPlan(seed=SEED, corrupt_ids=bad_ids)
    ffetch = faulty_row_fetch(S.array_row_fetch(x), plan)
    out = warm_solve(ffetch)
    sel = set(np.asarray(out.indices)[np.asarray(out.mask)].tolist())
    assert ffetch.injected["row_corrupt"] > 0
    assert not (set(bad_ids) & sel)
    assert out.stats.quarantined > 0
    assert "quarantined=" in out.stats.summary()


# -- checkpoint / resume -----------------------------------------------------

def test_kill_and_resume_bit_identical(tmp_path):
    x = _x()
    pool = S.array_chunks(x, CHUNK)
    ref = _solve(pool, x, cache_bytes=0)

    td = str(tmp_path / "ckpt")
    dpool = FaultyChunkIterator(
        pool, FaultPlan(seed=SEED, die_after_chunks=10))
    with pytest.raises((StreamDied, RetryExhausted)):
        _solve(dpool, x, cache_bytes=0, checkpoint_dir=td,
               checkpoint_every=1)
    assert os.listdir(td)                 # the kill left checkpoints

    res = _solve(pool, x, cache_bytes=0, checkpoint_dir=td,
                 checkpoint_every=1)
    assert res.stats.resumes == 1
    assert bool(jnp.all(res.indices == ref.indices))
    assert bool(jnp.all(res.mask == ref.mask))
    assert bool(jnp.all(res.weights == ref.weights))
    assert res.err == ref.err
    assert "resumes=1" in res.stats.summary()


def test_resume_with_arena_bit_identical(tmp_path):
    x = _x()
    pool = S.array_chunks(x, CHUNK)
    fetch = S.array_row_fetch(x)
    ref = _solve(pool, x, row_fetch=fetch)

    td = str(tmp_path / "ckpt")
    dpool = FaultyChunkIterator(
        pool, FaultPlan(seed=SEED, die_after_chunks=12))
    with pytest.raises((StreamDied, RetryExhausted)):
        _solve(dpool, x, row_fetch=fetch, checkpoint_dir=td,
               checkpoint_every=1)
    res = _solve(pool, x, row_fetch=fetch, checkpoint_dir=td,
                 checkpoint_every=1)
    assert res.stats.resumes == 1
    assert bool(jnp.all(res.indices == ref.indices))
    assert bool(jnp.all(res.mask == ref.mask))
    assert bool(jnp.all(res.weights == ref.weights))


def test_incompatible_checkpoint_refused(tmp_path):
    x = _x()
    pool = S.array_chunks(x, CHUNK)
    td = str(tmp_path / "ckpt")
    _solve(pool, x, cache_bytes=0, checkpoint_dir=td, checkpoint_every=1)
    with pytest.raises(ValueError, match="incompatible"):
        S.omp_select_streaming(pool, _target(x), K + 8, buffer_size=BUF,
                               cache_bytes=0, retry=FAST,
                               checkpoint_dir=td)
    # resume=False ignores the stale state and solves fresh.
    out = S.omp_select_streaming(pool, _target(x), K + 8, buffer_size=BUF,
                                 cache_bytes=0, retry=FAST,
                                 checkpoint_dir=td, resume=False)
    assert int(jnp.sum(out.mask)) == K + 8


# -- satellite bugfixes ------------------------------------------------------

def test_pass_budget_error_message_carries_stats_summary():
    x = _x()
    pool = S.array_chunks(x, CHUNK)
    with pytest.raises(S.StreamingPassBudgetError) as ei:
        S.omp_select_streaming(pool, _target(x), K, buffer_size=BUF,
                               cache_bytes=0, max_passes=1)
    msg = str(ei.value)
    assert "Solver state at failure" in msg
    assert "passes=1" in msg and "rounds=" in msg


def test_select_validates_stream_cache_bytes():
    import jax
    x = _x()
    with pytest.raises(ValueError, match="stream_cache_bytes"):
        sel_lib.select("gradmatch-stream", jax.random.PRNGKey(0),
                       jnp.asarray(x), K, stream_cache_bytes=0)


def test_truncated_memmap_detected_at_pool_open(tmp_path):
    path = str(tmp_path / "pool.bin")
    x = _x()
    x.tofile(path)
    mm = np.memmap(path, dtype=np.float32, mode="r", shape=(N, D))
    ChunkedPool(mm, chunk_size=CHUNK)     # intact file: fine
    os.truncate(path, x.nbytes // 2)      # lose the tail under the map
    with pytest.raises(ValueError, match="truncated"):
        ChunkedPool(mm, chunk_size=CHUNK)


# -- circuit breaker ---------------------------------------------------------

def test_circuit_breaker_lifecycle():
    t = [0.0]
    br = CircuitBreaker(failure_threshold=2, cooldown_s=5.0,
                        clock=lambda: t[0])
    br.allow()
    br.record_failure()
    br.allow()                            # 1 failure: still closed
    br.record_failure()                   # threshold: opens
    assert br.state == "open" and br.trips == 1
    with pytest.raises(CircuitOpen, match="circuit open"):
        br.allow()
    with pytest.raises(CircuitOpen):      # peek agrees, mutates nothing
        br.peek()
    t[0] = 6.0                            # past cooldown
    br.peek()                             # peek never consumes the trial
    br.allow()                            # half-open: one trial admitted
    assert br.state == "half-open"
    with pytest.raises(CircuitOpen, match="half-open"):
        br.allow()
    br.record_failure()                   # trial failed: re-open
    assert br.state == "open" and br.trips == 2
    t[0] = 12.0
    br.allow()
    br.record_success()                   # trial succeeded: closed again
    assert br.state == "closed" and br.failures == 0
    br.allow()


# -- degradation primitives --------------------------------------------------

def test_stochastic_fallback_from_warm_cache():
    x = _x()
    pool = S.array_chunks(x, CHUNK)
    cache = S.ChunkCache(1 << 20, D)
    target, n = S.streaming_target(pool, cache=cache)
    assert n == N
    out = stochastic_fallback(cache, target, K, seed=SEED)
    sel = np.asarray(out.indices)[np.asarray(out.mask)]
    assert len(sel) == K and len(set(sel.tolist())) == K
    assert sel.min() >= 0 and sel.max() < N
    out2 = stochastic_fallback(cache, target, K, seed=SEED)
    assert bool(jnp.all(out.indices == out2.indices))
    # no arena -> no fallback (the ladder's next stop is failure)
    assert stochastic_fallback(S.ChunkCache(0, D), target, K) is None


def test_die_once_stream_revives():
    x = _x()
    pool = S.array_chunks(x, CHUNK)
    it = FaultyChunkIterator(
        pool, FaultPlan(seed=SEED, die_after_chunks=2, die_once=True))
    with pytest.raises(StreamDied):
        list(it())
    assert len(list(it())) == N // CHUNK  # healthy after the one death


def test_slow_chunks_call_sleeper():
    x = _x()
    pool = S.array_chunks(x, CHUNK)
    naps = []
    it = FaultyChunkIterator(
        pool, FaultPlan(seed=SEED, slow_rate=1.0, slow_s=0.01),
        sleeper=naps.append)
    list(it())
    assert naps == [0.01] * (N // CHUNK)
