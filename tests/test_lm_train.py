"""LM training-step invariants: microbatch accumulation, compression,
weighted objective, smoke-train convergence, xLSTM equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import lm as lm_lib
from repro.optim import OptState, sgd
from repro.train.compression import init_state
from repro.train.steps import lm_train_step_fn, make_lm_train_step


def _setup(arch="starcoder2-3b", b=8, s=16):
    cfg = get_smoke_config(arch)
    params = lm_lib.init_lm(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                     cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                      cfg.vocab_size),
        "weights": jax.nn.softmax(
            jax.random.normal(jax.random.PRNGKey(3), (b,))),
    }
    return cfg, params, batch


def test_microbatch_accumulation_exact():
    """grad(sum_mb) == grad(full batch): microbatching is a pure memory
    lever, not an approximation (weights are global slices)."""
    cfg, params, batch = _setup()
    opt = sgd(0.1)
    s1 = lm_train_step_fn(cfg, opt, microbatches=1)
    s4 = lm_train_step_fn(cfg, opt, microbatches=4)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p4, _, m4 = jax.jit(s4)(params, opt.init(params), batch)
    l1 = jax.tree_util.tree_leaves(p1)
    l4 = jax.tree_util.tree_leaves(p4)
    for a, b_ in zip(l1, l4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_compressed_step_runs():
    cfg, params, batch = _setup()
    opt = sgd(0.05, momentum=0.9)
    step = make_lm_train_step(cfg, opt, compress_frac=0.05)
    cstate = init_state(params)
    p, o, cstate, m = step(params, opt.init(params), cstate, batch)
    assert np.isfinite(float(m["loss"]))


def test_smoke_lm_training_reduces_loss():
    """~50 steps on the structured token stream: loss must drop — the
    synthetic pipeline carries learnable signal."""
    from repro.data.tokens import TokenStream
    cfg = get_smoke_config("gemma-2b")
    params = lm_lib.init_lm(cfg, jax.random.PRNGKey(0))
    opt = sgd(0.3, momentum=0.9)
    step = jax.jit(lm_train_step_fn(cfg, opt))
    opt_state = opt.init(params)
    stream = TokenStream(seed=0, batch_per_shard=8, seq_len=32,
                         vocab=cfg.vocab_size)
    losses = []
    for i in range(50):
        params, opt_state, m = step(params, opt_state, stream.batch(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2, (
        losses[:5], losses[-5:])


def test_selection_proxy_matches_autodiff():
    """lm.selection_proxy (closed-form head-input gradient) == autodiff
    d(mean-CE)/d(hidden) — the paper's last-layer trick is exact."""
    cfg = get_smoke_config("starcoder2-3b")
    params = lm_lib.init_lm(cfg, jax.random.PRNGKey(0))
    b, s = 3, 8
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                     cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                      cfg.vocab_size),
    }
    proxy = lm_lib.selection_proxy(cfg, params, batch)
    assert proxy.shape == (b, cfg.d_model)

    from repro.models import common
    h, _, _ = lm_lib.forward(cfg, params, batch["tokens"], mode="train")
    # the paper's "last-layer gradient" = dL/d(head input), i.e. the
    # POST-norm hidden feeding the unembedding matmul
    hn = common.norm_apply(cfg, params["final_norm"], h).astype(jnp.float32)

    w_head = (params["embed"].T if cfg.tie_embeddings
              else params["lm_head"])

    def sum_ce(hh):
        logits = hh.astype(h.dtype) @ w_head
        logits = common.softcap(logits, cfg.logit_softcap)
        ce = lm_lib.token_ce(cfg, logits, batch["targets"])
        return jnp.sum(ce)

    g = jax.grad(sum_ce)(hn)                 # (b, s, d)
    want = jnp.mean(g, axis=1)               # mean over tokens
    np.testing.assert_allclose(np.asarray(proxy), np.asarray(want),
                               rtol=5e-2, atol=5e-3)


@given(t=st.sampled_from([32, 64]), chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 20))
@settings(max_examples=8, deadline=None)
def test_mlstm_parallel_equals_serial(t, chunk, seed):
    from repro.models.xlstm import (_mlstm_chunk_scan,
                                    _mlstm_chunkwise_parallel)
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    b, h, dk, dv = 2, 2, 8, 16
    q = jax.random.normal(ks[0], (b, t, h, dk))
    k = jax.random.normal(ks[1], (b, t, h, dk))
    v = jax.random.normal(ks[2], (b, t, h, dv))
    ig = jax.random.normal(ks[3], (b, t, h)) * 2
    fg = jax.random.normal(ks[4], (b, t, h)) * 2 + 1
    c0 = jax.random.normal(ks[5], (b, h, dk, dv)) * 0.1
    n0 = jnp.abs(jax.random.normal(ks[5], (b, h, dk))) * 0.1
    m0 = jnp.zeros((b, h))
    h1, s1 = _mlstm_chunk_scan(q, k, v, ig, fg, chunk, (c0, n0, m0))
    h2, s2 = _mlstm_chunkwise_parallel(q, k, v, ig, fg, chunk,
                                       (c0, n0, m0))
    np.testing.assert_allclose(h1, h2, rtol=5e-4, atol=5e-5)
    for a, b_ in zip(s1, s2):
        np.testing.assert_allclose(a, b_, rtol=5e-4, atol=5e-5)


def test_blockwise_attention_matches_dense():
    from repro.models import attention, common
    cfg = get_smoke_config("gemma2-9b").replace(
        flash_threshold=1, flash_block_q=16, flash_block_kv=16,
        n_heads=4, n_kv_heads=2, head_dim=16)
    b, s = 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, 4, 16))
    k = jax.random.normal(ks[1], (b, s, 2, 16))
    v = jax.random.normal(ks[2], (b, s, 2, 16))
    for causal, window in [(True, None), (False, None), (True, 24)]:
        blk = attention._attend_blockwise(cfg, q, k, v, causal=causal,
                                          window=window)
        if not causal:
            mask = None
        elif window is not None:
            mask = common.window_mask(s, s, 0, window)
        else:
            mask = common.causal_mask(s, s, 0)
        dense = attention._attend(cfg, q, k, v, mask)
        np.testing.assert_allclose(np.asarray(blk, np.float32),
                                   np.asarray(dense, np.float32),
                                   rtol=2e-3, atol=2e-3)
