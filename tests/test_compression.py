"""EF-TopK gradient compression: losslessness of the feedback loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim import apply_updates, sgd
from repro.train.compression import (compress_with_feedback, init_state,
                                     topk_sparsify)


def test_topk_keeps_largest():
    x = jnp.array([[0.1, -5.0], [3.0, 0.01]])
    dense, vals, idx = topk_sparsify(x, 0.5)
    kept = np.asarray(dense).ravel()
    assert kept[1] == -5.0 and kept[2] == 3.0
    assert kept[0] == 0.0 and kept[3] == 0.0


@given(seed=st.integers(0, 50), frac=st.sampled_from([0.1, 0.25, 0.5]))
@settings(max_examples=10, deadline=None)
def test_feedback_conserves_mass(seed, frac):
    """compressed + residual == grad + old residual (nothing is lost)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (32,))}
    st0 = init_state(g)
    comp, st1 = compress_with_feedback(g, st0, frac)
    np.testing.assert_allclose(
        np.asarray(comp["w"] + st1.residual["w"]),
        np.asarray(g["w"] + st0.residual["w"]), rtol=1e-6, atol=1e-7)


def test_compressed_sgd_still_converges():
    """EF-TopK at 10% density converges on a quadratic (delayed, not
    destroyed, gradient information).  Plain SGD: naive momentum on top of
    error feedback amplifies the delayed bursts (the reason DGC uses
    momentum *correction*) — documented in train/compression.py."""
    opt = sgd(0.05)
    params = {"x": jnp.zeros((64,))}
    target = jax.random.normal(jax.random.PRNGKey(1), (64,))
    state = opt.init(params)
    cstate = init_state(params)

    @jax.jit
    def step(params, state, cstate):
        g = jax.grad(lambda p: jnp.sum((p["x"] - target) ** 2))(params)
        g, cstate = compress_with_feedback(g, cstate, 0.1)
        u, state = opt.update(g, state, params)
        return apply_updates(params, u), state, cstate

    for _ in range(500):
        params, state, cstate = step(params, state, cstate)
    err = float(jnp.max(jnp.abs(params["x"] - target)))
    assert err < 0.05, err


def test_density_bound():
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (1000,))}
    comp, _ = compress_with_feedback(g, init_state(g), 0.01)
    nnz = int(jnp.sum(comp["w"] != 0))
    assert nnz <= 10
