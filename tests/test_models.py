"""Per-arch smoke tests (reduced configs) + decode/prefill consistency.

Every assigned architecture instantiates its REDUCED config and runs one
forward/train step on CPU asserting output shapes + no NaNs (the full
configs are exercised via the dry-run only).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, applicable_shapes, get_config, \
    get_smoke_config
from repro.models import lm as lm_lib
from repro.optim import sgd
from repro.train.steps import lm_train_step_fn

B, S = 2, 16


def _batch(cfg, b=B, s=S):
    batch = {}
    if cfg.family == "audio":
        batch["embeds"] = jax.random.normal(
            jax.random.PRNGKey(9), (b, s, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(
            jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.vision.n_tokens,
                                    cfg.vision.d_embed), jnp.bfloat16)
    batch["targets"] = jax.random.randint(
        jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    params = lm_lib.init_lm(cfg, jax.random.PRNGKey(0))
    loss, metrics = lm_lib.lm_loss(cfg, params, _batch(cfg))
    assert np.isfinite(float(loss)), arch
    # uniform-ish CE at init: ln(V) +- 2
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 2.5, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params = lm_lib.init_lm(cfg, jax.random.PRNGKey(0))
    # recurrent archs (sLSTM especially) are step-size sensitive
    lr = 1e-3 if arch in ("xlstm-1.3b", "zamba2-7b") else 0.05
    opt = sgd(lr, momentum=0.9)
    step = jax.jit(lm_train_step_fn(cfg, opt))
    opt_state = opt.init(params)
    batch = _batch(cfg)
    losses = []
    for _ in range(6):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), (arch, losses)
    assert min(losses[1:]) < losses[0], f"{arch}: loss never decreased " \
        f"{losses}"


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not get_config(a).encoder_only])
def test_decode_matches_prefill(arch):
    """Greedy continuation: decode(prefill(x[:t])) logits == the full
    forward's logits at position t (teacher forcing) — the KV-cache /
    recurrent-state decode path is exact, not approximate."""
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # GShard capacity dropping makes train-mode forward lossy at tiny
        # batch; open the capacity so the comparison is exact routing.
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    params = lm_lib.init_lm(cfg, jax.random.PRNGKey(0))
    s_tot = 12
    batch = _batch(cfg, b=2, s=s_tot)
    toks = batch["tokens"]

    # full forward logits (teacher forcing)
    h, _, _ = lm_lib.forward(cfg, params, toks,
                             vision=batch.get("vision"), mode="train")
    full_logits = lm_lib._head_out(cfg, params, h)
    full_logits = lm_lib.mask_padded_logits(cfg, full_logits)

    # prefill on the first s0 tokens, decode the rest one-by-one
    s0 = 6
    lg, pstate = lm_lib.prefill_step(cfg, params, toks[:, :s0],
                                     vision=batch.get("vision"))
    from repro.launch.serve import _seat
    state = _seat(lm_lib.init_decode_state(cfg, 2, s_tot), pstate)
    np.testing.assert_allclose(
        np.asarray(lg, np.float32),
        np.asarray(full_logits[:, s0 - 1], np.float32),
        rtol=2e-2, atol=2e-2)

    for t in range(s0, s_tot):
        lg, state = lm_lib.decode_step(cfg, params, state, toks[:, t:t + 1],
                                       jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(lg, np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=5e-2, atol=5e-2, err_msg=f"{arch} pos {t}")


def test_weighted_loss_is_weighted_sum():
    """lm_loss with weights w == sum_i w_i * per-seq CE — the exact
    objective of paper Alg. 1 line 9."""
    cfg = get_smoke_config("starcoder2-3b")
    params = lm_lib.init_lm(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, b=4)
    losses = []
    for i in range(4):
        one = {k: v[i:i + 1] for k, v in batch.items()}
        _, m = lm_lib.lm_loss(cfg, params, one)
        losses.append(float(m["ce"]))
    w = jnp.array([0.4, 0.3, 0.2, 0.1])
    loss, _ = lm_lib.lm_loss(cfg, params, {**batch, "weights": w})
    np.testing.assert_allclose(float(loss),
                               float(jnp.sum(w * jnp.array(losses))),
                               rtol=2e-3)


def test_all_cells_enumeration():
    """40 assigned cells; skips per DESIGN.md §5 (encoder decode, 500k on
    pure full-attention archs)."""
    from repro.configs import all_cells
    cells = all_cells()
    # 10 archs x 4 shapes = 40 raw; hubert loses decode_32k+long_500k,
    # 6 full-attn archs lose long_500k; gemma2 (local+global) keeps it.
    assert ("hubert-xlarge", "train_4k") in cells
    assert ("hubert-xlarge", "decode_32k") not in cells
    assert ("xlstm-1.3b", "long_500k") in cells
    assert ("zamba2-7b", "long_500k") in cells
    assert ("gemma2-9b", "long_500k") in cells
    assert ("gemma-2b", "long_500k") not in cells
    assert len(cells) == 32


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_plausible(arch):
    """Full configs carry the published parameter scale (sanity vs name)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "hubert-xlarge": (0.9e9, 1.3e9),
        "xlstm-1.3b": (1.0e9, 1.8e9),
        "gemma-2b": (2.0e9, 3.3e9),
        "gemma2-9b": (8e9, 11e9),
        "starcoder2-3b": (2.7e9, 3.8e9),
        "codeqwen1.5-7b": (6.5e9, 8.5e9),   # padded 92416-vocab embeddings
        # the assignment's dims (48L x 64e x d_ff 1408) arithmetically give
        # ~29B total / ~3B active; the published -16B name corresponds to a
        # shallower variant the assignment overrides.
        "moonshot-v1-16b-a3b": (25e9, 33e9),
        "qwen3-moe-30b-a3b": (26e9, 33e9),
        "zamba2-7b": (5e9, 8.5e9),
        "llama-3.2-vision-90b": (78e9, 95e9),
    }[arch]
    assert expected[0] < n < expected[1], (arch, n)
