"""Differential parity harness: incremental vs dense vs streaming OMP.

The dense re-solve-from-scratch solver is the oracle (DESIGN.md §2); the
incremental production solver and the streaming block-OMP (DESIGN.md §4)
must select identical indices/masks and matching weights across a grid of
(n, d, k, lam) including degenerate pools — duplicate rows, zero-gradient
rows, k >= n, all-masked ``valid``.  Randomness is seeded ``numpy`` only
(no hypothesis — the container cannot install it); streaming runs with a
small buffer and a non-divisor chunk size so the multi-pass machinery and
ragged-chunk padding are actually exercised.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import streaming as stream_lib
from repro.core.omp import omp_select, omp_select_dense

STREAM = dict(buffer_size=16, chunk_topm=8)
CHUNK = 48   # deliberately not a divisor of the pool sizes below


def _pool(seed, n, d):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _run_all_methods(g, target, k, lam, valid=None, positive=True,
                     eps=1e-10):
    g = jnp.asarray(g)
    target = jnp.asarray(target, jnp.float32)
    v = None if valid is None else jnp.asarray(valid)
    inc = omp_select(g, target, k=k, lam=lam, eps=eps, valid=v,
                     positive=positive)
    dense = omp_select_dense(g, target, k=k, lam=lam, eps=eps, valid=v,
                             positive=positive)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(np.asarray(g), CHUNK, valid=valid),
        target, k, lam=lam, eps=eps, positive=positive, **STREAM)
    return inc, dense, (out.indices, out.weights, out.mask, out.err)


def _assert_parity(a, b, what):
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]),
                                  err_msg=f"{what}: indices differ")
    np.testing.assert_array_equal(np.asarray(a[2]), np.asarray(b[2]),
                                  err_msg=f"{what}: masks differ")
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]),
                               rtol=1e-4, atol=1e-5,
                               err_msg=f"{what}: weights differ")
    np.testing.assert_allclose(float(a[3]), float(b[3]), rtol=1e-4,
                               atol=1e-5, err_msg=f"{what}: err differs")


GRID = [
    # (seed, n, d, k)  — wide + narrow regimes, k crossing block boundaries
    (0, 96, 12, 16),
    (1, 160, 48, 24),
    # narrow proxies, k > d (k kept below the round where an 8-dim residual
    # reaches the f32 noise floor — beyond it every solver ranks noise)
    (2, 200, 8, 16),
    (3, 64, 32, 96),     # k > n
]


@pytest.mark.parametrize("seed,n,d,k", GRID)
@pytest.mark.parametrize("lam", [1e-6, 0.3])
def test_three_way_parity_random_pools(seed, n, d, k, lam):
    g = _pool(seed, n, d)
    target = g.sum(axis=0)
    inc, dense, stream = _run_all_methods(g, target, k, lam)
    _assert_parity(inc, dense, "incremental vs dense")
    _assert_parity(stream, dense, "streaming vs dense")


def test_parity_duplicate_rows():
    """Exactly tied scores: lowest-index tie-breaking must agree."""
    g = _pool(10, 80, 12)
    g[1::2] = g[::2]                       # every row duplicated
    target = g.sum(axis=0)
    inc, dense, stream = _run_all_methods(g, target, k=24, lam=0.2)
    _assert_parity(inc, dense, "incremental vs dense (duplicates)")
    _assert_parity(stream, dense, "streaming vs dense (duplicates)")


def test_parity_zero_gradient_rows():
    g = _pool(11, 96, 16)
    g[20:60] = 0.0
    target = g.sum(axis=0)
    inc, dense, stream = _run_all_methods(g, target, k=20, lam=0.1)
    _assert_parity(inc, dense, "incremental vs dense (zero rows)")
    _assert_parity(stream, dense, "streaming vs dense (zero rows)")
    # zero rows are never useful picks while informative rows remain
    sel = np.asarray(stream[0])[np.asarray(stream[2])]
    assert not np.any((sel >= 20) & (sel < 60))


def test_parity_k_exceeds_valid_pool():
    """k >= #valid candidates: the taken-mask tail must agree exactly."""
    g = _pool(12, 72, 10)
    valid = np.arange(72) < 9
    target = (g * valid[:, None]).sum(axis=0)
    inc, dense, stream = _run_all_methods(g, target, k=32, lam=0.2,
                                          valid=valid)
    _assert_parity(inc, dense, "incremental vs dense (k >= n_valid)")
    _assert_parity(stream, dense, "streaming vs dense (k >= n_valid)")


def test_parity_all_masked_valid():
    """Fully-masked pool: zero target -> immediate eps stop, empty subset."""
    g = _pool(13, 64, 8)
    valid = np.zeros((64,), bool)
    target = (g * valid[:, None]).sum(axis=0)
    inc, dense, stream = _run_all_methods(g, target, k=8, lam=0.2,
                                          valid=valid)
    _assert_parity(inc, dense, "incremental vs dense (all masked)")
    _assert_parity(stream, dense, "streaming vs dense (all masked)")
    assert int(np.asarray(stream[2]).sum()) == 0


def test_parity_random_valid_mask():
    rng = np.random.default_rng(14)
    g = _pool(14, 120, 24)
    valid = rng.random(120) < 0.4
    target = (g * valid[:, None]).sum(axis=0)
    inc, dense, stream = _run_all_methods(g, target, k=16, lam=0.2,
                                          valid=valid)
    _assert_parity(inc, dense, "incremental vs dense (valid mask)")
    _assert_parity(stream, dense, "streaming vs dense (valid mask)")
    sel = np.asarray(stream[0])[np.asarray(stream[2])]
    assert valid[sel].all()


def test_parity_absolute_scores():
    g = _pool(15, 140, 20)
    target = -(g[:40].sum(axis=0))         # anti-aligned target
    inc, dense, stream = _run_all_methods(g, target, k=12, lam=0.1,
                                          positive=False)
    _assert_parity(inc, dense, "incremental vs dense (absolute)")
    _assert_parity(stream, dense, "streaming vs dense (absolute)")


def test_parity_eps_stop():
    """Exact 2-row reconstruction: all solvers stop at the same round."""
    g = _pool(16, 50, 40)
    target = g[7] * 2.0 + g[31] * 1.0
    inc, dense, stream = _run_all_methods(g, target, k=10, lam=1e-8,
                                          eps=1e-6)
    _assert_parity(inc, dense, "incremental vs dense (eps stop)")
    _assert_parity(stream, dense, "streaming vs dense (eps stop)")
    assert int(np.asarray(stream[2]).sum()) == 2


# ---------------------------------------------------------------------------
# scatter-sentinel regression (PR 1 fix): candidate n-1 in a late round
# ---------------------------------------------------------------------------

def _lastrow_pool(n=33, d=6):
    """Pool where candidate n-1 is the best pick in round 2, not round 1.

    Row 0 dominates the target; once it is taken and reweighted, the
    residual is ~e1 and row n-1 (= e1) becomes the argmax.  The old
    in-bounds sentinel (n-1) spuriously marked row n-1 taken via the
    unused slots' duplicate writes, making it unselectable.
    """
    rng = np.random.default_rng(99)
    g = 0.01 * rng.standard_normal((n, d)).astype(np.float32)
    g[0, 0] = 10.0
    g[n - 1] = 0.0
    g[n - 1, 1] = 1.0
    target = np.zeros((d,), np.float32)
    target[0] = 20.0
    target[1] = 3.0
    return g, target


@pytest.mark.parametrize("method", ["incremental", "dense"])
def test_last_candidate_selectable_late_round(method):
    g, target = _lastrow_pool()
    n = g.shape[0]
    idx, w, mask, _ = omp_select(jnp.asarray(g), jnp.asarray(target), k=4,
                                 lam=1e-6, method=method)
    sel = np.asarray(idx)[np.asarray(mask)]
    assert n - 1 in sel.tolist(), sel
    assert sel.tolist()[0] == 0            # round 1 pick is row 0
    assert len(sel) == len(set(sel.tolist()))


def test_last_candidate_selectable_late_round_streaming():
    g, target = _lastrow_pool()
    n = g.shape[0]
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 8), jnp.asarray(target), 4, lam=1e-6,
        buffer_size=4, chunk_topm=2)
    sel = np.asarray(out.indices)[np.asarray(out.mask)]
    assert n - 1 in sel.tolist(), sel
    assert len(sel) == len(set(sel.tolist()))


def test_greedy_sentinel_fix_craig_glister():
    """The same in-bounds-sentinel race existed in CRAIG/GLISTER's greedy
    loops: a selection containing candidate n-1 must never duplicate."""
    from repro.core.craig import craig
    from repro.core.glister import glister

    g, target = _lastrow_pool(n=17, d=6)
    sel = craig(jnp.asarray(g), 8)
    got = np.asarray(sel.indices)[np.asarray(sel.mask)]
    assert len(got) == len(set(got.tolist())), got
    sel = glister(jnp.asarray(g), jnp.asarray(target), 8)
    got = np.asarray(sel.indices)[np.asarray(sel.mask)]
    assert len(got) == len(set(got.tolist())), got


# ---------------------------------------------------------------------------
# multi-round-per-pass engine grid (PR 5): cache + repair tiers must stay
# index-exact across buffer/chunk/cache configurations
# ---------------------------------------------------------------------------

MR_GRID = [
    # (n, d, k, buffer, chunk, cache_bytes) — cache ample / LRU-bounded /
    # thrashing (smaller than one chunk), buffers from tiny to pool-sized
    (256, 16, 48, 32, 96, 1 << 20),
    (256, 16, 48, 64, 64, 6000),          # ~1-2 chunk slots: evictions
    (320, 24, 40, 16, 100, 64),           # thrash: interval rung disabled
    (192, 12, 32, 256, 48, 1 << 20),      # buffer swallows the pool
]


@pytest.mark.parametrize("n,d,k,buf,chunk,cbytes", MR_GRID)
@pytest.mark.parametrize("variant", ["plain", "dups", "masked", "kbig"])
def test_multiround_grid_parity(n, d, k, buf, chunk, cbytes, variant):
    g = _pool(100 + n + k, n, d)
    valid = None
    if variant == "dups":
        g[1::2] = g[::2]
    elif variant == "masked":
        valid = np.random.default_rng(n).random(n) < 0.5
    elif variant == "kbig":
        valid = np.arange(n) < (k // 2)       # k exceeds the valid pool
    vm = None if valid is None else valid[:, None]
    target = (g if vm is None else g * vm).sum(axis=0)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, chunk, valid=valid),
        jnp.asarray(target), k, buffer_size=buf, cache_bytes=cbytes,
        row_fetch=stream_lib.array_row_fetch(g))
    v = None if valid is None else jnp.asarray(valid)
    ref = omp_select(jnp.asarray(g), jnp.asarray(target), k=k, valid=v)
    _assert_parity((out.indices, out.weights, out.mask, out.err), ref,
                   f"multiround[{variant}] vs incremental")
    assert out.stats.rounds <= k
    if variant == "plain" and cbytes >= (1 << 20):
        # ample cache + repair tier: the pass count must be amortized
        assert out.stats.passes <= max(k // 8 + 2, 2), out.stats.summary()
