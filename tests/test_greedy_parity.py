"""Differential parity harness for the greedy engine (DESIGN.md §5).

The naive dense greedy (``fl_greedy(method="dense")``, the seed CRAIG
formulation) is the oracle; the certified lazy engine must select
*index-identical* subsets across an (n, k, B) grid including degenerate
pools — duplicate rows, all-equal similarities (pure tie-breaking),
masked pools, k >= n — for both the resident-similarity and the
tile-on-the-fly scans.  Seeded ``numpy`` randomness only, mirroring
``test_omp_parity.py``.

Also covered: the submodularity certificate (accepted per-round gains are
non-increasing), stochastic-greedy seeded determinism, the pmap-sharded
gain scan, and the CRAIG/GLISTER wrappers on top of the engine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import greedy as greedy_lib
from repro.core.craig import craig, pairwise_sim
from repro.core.glister import glister


def _pool(seed, n, d):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))


def _assert_index_parity(a, b, what):
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices),
                                  err_msg=f"{what}: indices differ")
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask),
                                  err_msg=f"{what}: masks differ")


GRID = [
    # (seed, n, d, k, B) — B crossing and not crossing n, k near and past n
    (0, 96, 12, 16, 8),
    (1, 160, 24, 40, 16),
    (2, 200, 8, 24, 64),
    (3, 64, 16, 96, 32),     # k > n: the exhausted-pool tail must agree
    (4, 50, 6, 50, 4),       # k == n, tiny refresh block
]


@pytest.mark.parametrize("seed,n,d,k,B", GRID)
def test_lazy_matches_dense_random_pools(seed, n, d, k, B):
    g = _pool(seed, n, d)
    dense = greedy_lib.fl_greedy(g, k, method="dense")
    lazy = greedy_lib.fl_greedy(g, k, method="lazy", block=B)
    _assert_index_parity(dense, lazy, f"lazy vs dense {(n, d, k, B)}")
    np.testing.assert_allclose(np.asarray(lazy.cover),
                               np.asarray(dense.cover), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("seed,n,d,k,B", GRID)
def test_lazy_otf_matches_dense(seed, n, d, k, B):
    """Tile-on-the-fly scan (no resident similarity) vs the dense oracle,
    under the same explicit L_max offset."""
    g = _pool(seed, n, d)
    lm = greedy_lib.default_l_max(g)
    dense = greedy_lib.fl_greedy(g, k, method="dense", l_max=lm)
    otf = greedy_lib.fl_greedy(g, k, method="lazy", block=B, l_max=lm,
                               on_the_fly=True)
    _assert_index_parity(dense, otf, f"otf-lazy vs dense {(n, d, k, B)}")


def test_lazy_matches_dense_duplicate_rows():
    """Exactly tied gains: lowest-index tie-breaking must agree."""
    rng = np.random.default_rng(10)
    g = rng.standard_normal((80, 12)).astype(np.float32)
    g[1::2] = g[::2]                       # every row duplicated
    for B in (4, 16, 80):
        dense = greedy_lib.fl_greedy(jnp.asarray(g), 24, method="dense")
        lazy = greedy_lib.fl_greedy(jnp.asarray(g), 24, method="lazy",
                                    block=B)
        _assert_index_parity(dense, lazy, f"duplicates B={B}")
        sel = np.asarray(lazy.indices)[np.asarray(lazy.mask)]
        assert len(sel) == len(set(sel.tolist()))


def test_lazy_matches_dense_all_equal_similarity():
    """Identical rows -> every pairwise distance 0 -> all gains exactly
    equal every round: certification can never fire and the rescan path
    must reproduce jnp.argmax order (0, 1, 2, ...)."""
    rng = np.random.default_rng(11)
    g = np.tile(rng.standard_normal((1, 8)).astype(np.float32), (50, 1))
    dense = greedy_lib.fl_greedy(jnp.asarray(g), 10, method="dense")
    lazy = greedy_lib.fl_greedy(jnp.asarray(g), 10, method="lazy", block=4)
    _assert_index_parity(dense, lazy, "all-equal similarity")
    np.testing.assert_array_equal(np.asarray(lazy.indices), np.arange(10))
    assert lazy.stats.certified_rounds == 0     # ties always fail closed


def test_lazy_matches_dense_masked_pool():
    rng = np.random.default_rng(12)
    g = _pool(12, 120, 16)
    valid = jnp.asarray(rng.random(120) < 0.4)
    dense = greedy_lib.fl_greedy(g, 20, method="dense", valid=valid)
    lazy = greedy_lib.fl_greedy(g, 20, method="lazy", valid=valid, block=16)
    _assert_index_parity(dense, lazy, "masked pool")
    sel = np.asarray(lazy.indices)[np.asarray(lazy.mask)]
    assert np.asarray(valid)[sel].all()


def test_k_exceeds_valid_pool_masks_tail():
    """k >= #valid: both tiers stop growing instead of duplicating (the
    seed greedy re-selected candidate 0 forever)."""
    g = _pool(13, 40, 8)
    valid = jnp.asarray(np.arange(40) < 7)
    for method in ("dense", "lazy"):
        res = greedy_lib.fl_greedy(g, 16, method=method, valid=valid,
                                   block=8)
        assert int(np.asarray(res.mask).sum()) == 7, method
        sel = np.asarray(res.indices)[np.asarray(res.mask)]
        assert len(sel) == len(set(sel.tolist()))
        assert (np.asarray(res.indices)[~np.asarray(res.mask)] == -1).all()


@pytest.mark.parametrize("seed,n,d,k,B", GRID[:3])
def test_accepted_gains_nonincreasing(seed, n, d, k, B):
    """Submodularity certificate: the gain accepted in round t+1 can never
    exceed the gain accepted in round t (coverage only grows)."""
    g = _pool(seed, n, d)
    for method in ("dense", "lazy"):
        res = greedy_lib.fl_greedy(g, k, method=method, block=B)
        gains = np.asarray(res.gains)[np.asarray(res.mask)]
        assert (np.diff(gains) <= 1e-4 * (1 + np.abs(gains[:-1]))).all(), \
            (method, gains)


def test_lazy_certifies_most_rounds_on_random_pools():
    """The engine only pays for rescans when certification fails; on an
    i.i.d. pool the overwhelming majority of rounds must certify (this is
    the entire performance claim — see BENCH_selection.json)."""
    g = _pool(21, 512, 32)
    res = greedy_lib.fl_greedy(g, 128, method="lazy", block=32)
    s = res.stats
    assert s.rounds == 128
    assert s.certified_rounds >= 0.8 * (s.rounds - 1), s
    assert s.rescans <= 0.2 * s.rounds + 1, s


def test_stochastic_seeded_determinism():
    g = _pool(14, 200, 16)
    key = jax.random.PRNGKey(5)
    a = greedy_lib.fl_greedy(g, 24, method="stochastic", key=key, sample=16)
    b = greedy_lib.fl_greedy(g, 24, method="stochastic", key=key, sample=16)
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))
    c = greedy_lib.fl_greedy(g, 24, method="stochastic",
                             key=jax.random.PRNGKey(6), sample=16)
    sel = np.asarray(c.indices)[np.asarray(c.mask)]
    assert len(sel) == len(set(sel.tolist()))       # never duplicates
    # full-pool sample degenerates to the exact greedy
    d = greedy_lib.fl_greedy(g, 24, method="stochastic", key=key,
                             sample=200)
    e = greedy_lib.fl_greedy(g, 24, method="dense")
    _assert_index_parity(e, d, "stochastic sample=n vs dense")


def test_pmap_gain_scan_matches_dense():
    """The pmap-sharded per-round gain scan (core/distributed.py) elects
    the same medoids as the dense oracle under a shared L_max."""
    from repro.core.distributed import fl_greedy_pmap

    g = _pool(15, 96, 12)
    lm = greedy_lib.default_l_max(g)
    dense = greedy_lib.fl_greedy(g, 12, method="dense", l_max=lm)
    pm = fl_greedy_pmap(g, 12, l_max=lm)
    _assert_index_parity(dense, pm, "pmap scan vs dense")


# ---------------------------------------------------------------------------
# the CRAIG / GLISTER wrappers on top of the engine
# ---------------------------------------------------------------------------

def test_craig_lazy_full_result_parity():
    """craig(method='lazy') must reproduce craig(method='dense') exactly:
    indices, weights, and the facility-location objective."""
    g = _pool(16, 150, 20)
    a = craig(g, 30, method="dense")
    b = craig(g, 30, method="lazy")
    _assert_index_parity(a, b, "craig lazy vs dense")
    np.testing.assert_allclose(np.asarray(a.weights), np.asarray(b.weights),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(a.err), float(b.err), rtol=1e-5)


def test_craig_objective_excludes_invalid_rows():
    """Zeroed-out rows demand no coverage: the returned objective must not
    count them (the seed implementation charged max(sim) per invalid
    row)."""
    g = _pool(17, 60, 8)
    valid = jnp.asarray(np.arange(60) < 40)
    sel = craig(g, 10, valid=valid, l_max=10.0)
    # All-valid run over just the valid rows gives the same deficit.
    sel_sub = craig(g[:40], 10, l_max=10.0)
    np.testing.assert_allclose(float(sel.err), float(sel_sub.err),
                               rtol=1e-4)


def test_pairwise_sim_explicit_l_max_offsets_consistently():
    g = _pool(18, 30, 6)
    base = pairwise_sim(g)
    shifted = pairwise_sim(g, l_max=7.5)
    dist = jnp.max(base) - base
    np.testing.assert_allclose(np.asarray(shifted), np.asarray(7.5 - dist),
                               rtol=1e-5, atol=1e-5)


def test_craig_otf_never_needs_resident_sim():
    """on_the_fly=True runs end-to-end from grads alone (pool sizes where
    the (n, n) matrix would not fit) and matches the dense oracle under
    the same offset."""
    g = _pool(19, 180, 24)
    lm = float(greedy_lib.default_l_max(g))
    a = craig(g, 20, method="dense", l_max=lm)
    b = craig(g, 20, method="lazy", l_max=lm, on_the_fly=True)
    _assert_index_parity(a, b, "craig otf vs dense")
    np.testing.assert_allclose(np.asarray(a.weights), np.asarray(b.weights),
                               rtol=1e-4, atol=1e-5)


def test_glister_on_engine_unchanged_semantics():
    """GLISTER through modular_greedy: uniform weights, no duplicates, and
    the first pick is the plain argmax of g @ v."""
    g = _pool(20, 64, 12)
    tgt = jnp.sum(g, axis=0)
    sel = glister(g, tgt, 8)
    idx = np.asarray(sel.indices)[np.asarray(sel.mask)]
    assert len(idx) == len(set(idx.tolist())) == 8
    assert idx[0] == int(jnp.argmax(g @ tgt))
    w = np.asarray(sel.weights)[np.asarray(sel.mask)]
    np.testing.assert_allclose(w, np.full(8, 1 / 8), rtol=1e-5)


def test_glister_k_exceeds_valid_pool():
    g = _pool(22, 20, 6)
    valid = jnp.asarray(np.arange(20) < 5)
    sel = glister(g, jnp.sum(g, axis=0), 12, valid=valid)
    assert int(np.asarray(sel.mask).sum()) == 5
    idx = np.asarray(sel.indices)[np.asarray(sel.mask)]
    assert np.asarray(valid)[idx].all()
