"""Sharding rules: divisibility fallbacks + spec coverage (no devices
needed — specs are pure functions of shapes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_smoke_config
from repro.distributed.sharding import fit_spec, param_specs
from repro.models import lm as lm_lib


class FakeMesh:
    """Duck-typed mesh: fit_spec only reads .axis_names and .shape."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


MESH = FakeMesh(pod=2, data=16, model=16)


@given(dim0=st.integers(1, 64), dim1=st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_fit_spec_always_divides(dim0, dim1):
    spec = fit_spec((dim0, dim1), P(("pod", "data"), "model"), MESH)
    for d, entry in zip((dim0, dim1), tuple(spec)):
        if entry is None:
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([MESH.shape[n] for n in names]))
        assert d % size == 0


def test_fit_spec_truncates_composite_left_to_right():
    # 16 divides 'pod'*? no: pod*data=32 > 16 -> truncate to ('pod',)? 16%2==0
    spec = fit_spec((16,), P(("pod", "data")), MESH)
    assert tuple(spec) == ("pod",)
    # 32 takes the full composite
    spec = fit_spec((32,), P(("pod", "data")), MESH)
    assert tuple(spec) == (("pod", "data"),)


def test_fit_spec_drops_unknown_axes():
    mesh = FakeMesh(data=4)
    spec = fit_spec((8, 8), P("model", "data"), mesh)
    assert tuple(spec) == (None, "data")


def test_fit_spec_single_kv_head_drops_model():
    # MQA: 1 kv head can't shard over 16-way model axis
    spec = fit_spec((32, 1), P(None, "model"), MESH)
    assert tuple(spec) == (None, None)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_all_leaves(arch):
    """Every parameter leaf gets a spec whose entries divide its dims
    (after fit) — the single mechanism that makes all archs lower."""
    cfg = get_smoke_config(arch)
    params = jax.eval_shape(
        lambda: lm_lib.init_lm(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, params, fsdp=True)
    n = len(jax.tree_util.tree_leaves(params))
    m = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, P)))
    assert n == m


def test_stacked_block_params_shift_right():
    cfg = get_smoke_config("gemma-2b")
    params = jax.eval_shape(
        lambda: lm_lib.init_lm(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, params, fsdp=False)
    # stacked (L, d, q_dim) attention wq: leading superblock dim unsharded
    wq_spec = specs["blocks"]["sub0"]["attn"]["wq"]
    assert tuple(wq_spec)[0] is None
    assert "model" in tuple(wq_spec)


def test_moe_experts_over_model_axis():
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    params = jax.eval_shape(
        lambda: lm_lib.init_lm(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, params, fsdp=False)
    up = specs["blocks"]["sub0"]["mlp"]["w_up"]
    # stacked (L, E, d, ff): experts (dim 1 after shift) over 'model' (EP)
    assert tuple(up)[1] == "model"
