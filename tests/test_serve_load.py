"""Overload-resilience tests (DESIGN.md §10): open-loop load, priority +
weighted fairness, brownout ladder, async pool admission, chaos.

Everything runs on an injected ``SimClock`` with a deterministic
``step_cost``, so arrival schedules, shed decisions, deadlines and the
fairness rotation replay bit-identically — no wall-clock flake.

The correctness spine mirrors test_serve.py: whenever a rung claims
certification (``certified`` from a shared session, ``prefix-shared``
prefixes), its indices are compared to the unloaded one-shot
``omp_select`` over the same pool — the brownout ladder is only allowed
to trade *weights and latency*, never certified indices.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import streaming as stream_lib
from repro.core.omp import omp_select
from repro.data.loader import ChunkedPool
from repro.resilience import FaultPlan, FaultyChunkIterator, RetryPolicy
from repro.serve import (LoadSpec, OverloadController, QueueFull,
                         SelectionService, SimClock, make_arrivals,
                         run_load)

_FAST_RETRY = RetryPolicy(max_retries=6, backoff_s=0.0,
                          sleep=lambda s: None)


def _pool(seed, n, d):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _svc(clock=None, **kw):
    kw.setdefault("max_batch", 8)
    clock_kw = {} if clock is None else {"clock": clock.now}
    return SelectionService(**clock_kw, **kw)


def _flat_cost(out):
    return 0.01


# ---------------------------------------------------------------------------
# submit-time validation (satellite: fail fast on expired deadlines)
# ---------------------------------------------------------------------------

def test_expired_deadline_rejected_at_submit():
    svc = _svc()
    p = svc.register_pool(_pool(0, 64, 8))
    for bad in (0.0, -1.0, -0.001):
        with pytest.raises(ValueError, match="deadline_s must be > 0"):
            svc.submit(p, k=4, deadline_s=bad)
    # Nothing queued, nothing charged: the rejection is free.
    assert svc.scheduler.pending() == 0
    assert svc.scheduler.counters["admitted"] == 0
    t = svc.submit(p, k=4, deadline_s=10.0)    # positive is fine
    svc.drain()
    assert t.status == "done"


def test_unknown_priority_rejected():
    svc = _svc()
    p = svc.register_pool(_pool(0, 64, 8))
    with pytest.raises(ValueError, match="unknown priority"):
        svc.submit(p, k=4, priority="platinum")


# ---------------------------------------------------------------------------
# overload controller
# ---------------------------------------------------------------------------

def test_overload_controller_hysteresis():
    oc = OverloadController(max_queue=10, brownout_at=0.5,
                            overload_at=0.8, recover_at=0.2)
    assert oc.observe(0) == 0
    assert oc.observe(5) == 1          # brownout threshold
    assert oc.observe(4) == 1          # hysteresis band: stays brown
    assert oc.observe(8) == 2          # overload
    assert oc.observe(6) == 2          # still above brownout_at: stays 2
    assert oc.observe(3) == 1          # below brownout_at: partial recovery
    assert oc.observe(2) == 0          # full recovery
    assert oc.transitions == 4
    assert oc.should_shed("interactive") is False
    oc.observe(9)
    assert oc.should_shed("best-effort") and oc.should_shed("batch")
    assert not oc.should_shed("interactive")
    with pytest.raises(ValueError):
        OverloadController(brownout_at=0.9, overload_at=0.5)


def test_shed_is_labelled_and_never_charged():
    svc = _svc(max_queue=8, brownout_at=0.25, overload_at=0.9,
               recover_at=0.0)
    p = svc.register_pool(_pool(1, 64, 8))
    svc.admission.set_budget("bg", budget_units=1e9)
    for _ in range(3):                  # raise depth past 0.25 * 8
        svc.submit(p, k=4, tenant="fg")
    shed = svc.submit(p, k=4, tenant="bg", priority="best-effort")
    assert shed.status == "shed" and shed.degradation == "shed"
    assert "shed at submit" in shed.error
    # Never admitted to the queue, never charged to the tenant.
    assert svc.scheduler.pending() == 3
    assert svc.admission.stats()["bg"]["inflight"] == 0
    assert svc.admission.stats()["bg"]["used_units"] == 0.0
    c = svc.scheduler.counters
    assert c["shed"] == 1
    assert c["admitted"] == (c["completed"] + c["shed"] + c["failed"]
                             + svc.scheduler.pending())
    done = svc.drain()
    assert all(t.status == "done" for t in done)   # interactive untouched
    c = svc.scheduler.counters
    assert c["admitted"] == c["completed"] + c["shed"] + c["failed"]


# ---------------------------------------------------------------------------
# strict priority + weighted fairness
# ---------------------------------------------------------------------------

def test_strict_priority_order():
    svc = _svc(max_batch=1, overload=False)
    p = svc.register_pool(_pool(2, 64, 8))
    order = []
    for prio in ("best-effort", "best-effort", "batch", "interactive",
                 "batch", "interactive"):
        order.append(svc.submit(p, k=4, priority=prio))
    served = []
    while svc.scheduler.pending():
        for t in svc.drain_step():
            served.append(t.request.priority)
    assert served == ["interactive", "interactive", "batch", "batch",
                      "best-effort", "best-effort"]


def test_weighted_fair_drain_across_tenants():
    # Two tenants on distinct pools (so micro-batching cannot merge
    # them), weight 2 vs 1: the heavier tenant drains ~2x the turns.
    svc = _svc(max_batch=1, overload=False)
    pa = svc.register_pool(_pool(3, 64, 8), pool_id="pa")
    pb = svc.register_pool(_pool(4, 64, 8), pool_id="pb")
    svc.admission.set_weight("heavy", 2.0)
    for _ in range(8):
        svc.submit(pa, k=4, tenant="light")
        svc.submit(pb, k=4, tenant="heavy")
    served = []
    for _ in range(9):
        for t in svc.drain_step():
            served.append(t.request.tenant)
    counts = {tn: served.count(tn) for tn in set(served)}
    assert counts["heavy"] == 2 * counts["light"]
    svc.drain()   # rest completes; no leaks
    assert all(s["inflight"] == 0 for s in svc.admission.stats().values())


def test_equal_weights_alternate():
    svc = _svc(max_batch=1, overload=False)
    pa = svc.register_pool(_pool(5, 64, 8), pool_id="pa")
    pb = svc.register_pool(_pool(6, 64, 8), pool_id="pb")
    for _ in range(4):
        svc.submit(pa, k=4, tenant="a")
        svc.submit(pb, k=4, tenant="b")
    served = [svc.drain_step()[0].request.tenant for _ in range(8)]
    # Deficit round robin with equal weights = strict alternation, not
    # FIFO's a,a,a,a,b,b,b,b.
    assert served in (["a", "b"] * 4, ["b", "a"] * 4)


# ---------------------------------------------------------------------------
# brownout ladder: shared cross-k anytime sessions
# ---------------------------------------------------------------------------

def test_cross_k_shared_session_bit_exact_prefixes():
    # brownout_at=0 pins the controller at level >= 1: every same-pool
    # default-target gradmatch group shares one anytime session.
    svc = _svc(brownout_at=0.0, overload_at=0.9, recover_at=0.0)
    g = _pool(7, 192, 16)
    p = svc.register_pool(g)
    ts = {k: svc.submit(p, k=k) for k in (6, 12, 18)}
    svc.drain()
    assert svc.scheduler.shared_solves == 1
    gj = jnp.asarray(g)
    tgt = jnp.sum(gj, axis=0)
    for k, t in ts.items():
        assert t.status == "done"
        assert t.batched_with == 3
        want_idx, _, want_mask, _ = omp_select(gj, tgt, k)
        np.testing.assert_array_equal(np.asarray(t.result.indices),
                                      np.asarray(want_idx),
                                      err_msg=f"k={k} indices")
        np.testing.assert_array_equal(np.asarray(t.result.mask),
                                      np.asarray(want_mask))
    assert ts[18].degradation == "certified"      # deepest k: the solve
    assert ts[6].degradation == "prefix-shared"
    assert ts[12].degradation == "prefix-shared"
    # The state was parked: a later request is answered from the stored
    # session without a second solve.
    assert svc.sessions.stats()["puts"] >= 1
    t2 = svc.submit(p, k=12)
    svc.drain()
    assert t2.status == "done" and t2.degradation == "prefix-shared"
    assert svc.scheduler.shared_solves == 1       # no new solve


def test_overload_stochastic_rung_for_non_interactive():
    svc = _svc(max_queue=4, brownout_at=0.25, overload_at=0.5,
               recover_at=0.0)
    g = _pool(8, 512, 16)
    p = svc.register_pool(g)
    t1 = svc.submit(p, k=8, priority="batch")      # depth 0: level 0
    t2 = svc.submit(p, k=8, priority="batch")      # depth 1: level 1
    t3 = svc.submit(p, k=8, priority="batch")      # depth 2: level 2, shed
    assert t3.status == "shed"
    svc.drain()                                    # drains at level 2
    for t in (t1, t2):
        assert t.status == "done"
        assert t.degradation == "stochastic"
        idx = np.asarray(t.result.indices)
        assert ((idx >= 0) & (idx < 512))[np.asarray(t.result.mask)].all()
    # Interactive traffic is never downgraded to the stochastic rung.
    svc2 = _svc(max_queue=4, brownout_at=0.25, overload_at=0.5,
                recover_at=0.0)
    p2 = svc2.register_pool(g)
    u1 = svc2.submit(p2, k=8)
    u2 = svc2.submit(p2, k=8)
    svc2.drain()
    assert {u1.degradation, u2.degradation} <= {"certified",
                                                "prefix-shared"}


# ---------------------------------------------------------------------------
# async (deferred-warm) pool admission
# ---------------------------------------------------------------------------

def test_deferred_warm_matches_sync_admission():
    g = _pool(9, 256, 12)
    pool = ChunkedPool(g, chunk_size=64)
    svc = _svc()
    pid = svc.register_chunked_pool(pool, warm="deferred")
    entry = svc.registry.get(pid)
    assert entry.warm_state == "warming" and entry.target_sum is None
    while not svc.registry.step_warm(pid, max_chunks=1):
        pass
    entry = svc.registry.get(pid)
    assert entry.warm_state == "warm"
    want, n = stream_lib.streaming_target(
        stream_lib.chunked_pool_iter(ChunkedPool(g, chunk_size=64)))
    assert n == 256
    np.testing.assert_allclose(np.asarray(entry.target_sum),
                               np.asarray(want), rtol=1e-5, atol=1e-4)
    # Same fingerprint as a sync registration of the same content — the
    # dedupe works across warm modes.
    svc2 = _svc()
    pid2 = svc2.register_chunked_pool(ChunkedPool(g, chunk_size=64))
    assert svc2.registry.get(pid2).fingerprint == entry.fingerprint
    # And it serves the same certified selection.
    res = svc.select(pid, k=10)
    ref = svc2.select(pid2, k=10)
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  np.asarray(ref.indices))


def test_deferred_warm_does_not_head_of_line_block():
    clock = SimClock()
    svc = _svc(clock=clock)
    g_arr = _pool(10, 128, 8)
    pa = svc.register_pool(g_arr)
    g_ch = _pool(11, 512, 8)
    pc = svc.register_chunked_pool(ChunkedPool(g_ch, chunk_size=64),
                                   warm="deferred")
    svc.scheduler.warm_chunks = 1
    t_ch = svc.submit(pc, k=6)          # queued first, pool still warming
    t_arr = svc.submit(pa, k=6)
    first = svc.drain_step()
    # The warming pool must not block the array pool's request.
    assert [t.ticket_id for t in first] == [t_arr.ticket_id]
    assert t_arr.status == "done"
    assert t_ch.status == "queued"
    svc.drain()                         # warm advances, then serves
    assert t_ch.status == "done" and t_ch.degradation == "certified"
    gj = jnp.asarray(g_ch)
    want_idx, _, _, _ = omp_select(gj, jnp.sum(gj, axis=0), 6)
    np.testing.assert_array_equal(np.asarray(t_ch.result.indices),
                                  np.asarray(want_idx))


def test_deferred_warm_deadline_expires_while_warming():
    clock = SimClock()
    svc = _svc(clock=clock)
    g = _pool(12, 512, 8)
    pid = svc.register_chunked_pool(ChunkedPool(g, chunk_size=64),
                                    warm="deferred")
    svc.scheduler.warm_chunks = 1       # 8 chunks: warm takes 8 steps
    t_plain = svc.submit(pid, k=6, deadline_s=0.5)
    tgt = np.asarray(jnp.sum(jnp.asarray(g), axis=0))
    t_tgt = svc.submit(pid, k=6, deadline_s=0.5, target=tgt)
    clock.advance(1.0)                  # both deadlines now expired
    out = svc.drain_step()              # one warm step + expiry sweep
    assert {t.ticket_id for t in out} == {t_plain.ticket_id,
                                          t_tgt.ticket_id}
    # No default target exists yet -> timeout; an explicit target can be
    # served from the partially warmed cache's stochastic rung.
    assert t_plain.status == "failed"
    assert t_plain.degradation == "timeout"
    assert "warming" in t_plain.error
    assert t_tgt.status == "done" and t_tgt.degradation == "stochastic"
    assert all(s["inflight"] == 0 for s in svc.admission.stats().values())


def test_deferred_warm_needs_n_for_factories():
    g = _pool(13, 128, 8)

    def factory():
        yield g[:64], None
        yield g[64:], None

    svc = _svc()
    with pytest.raises(ValueError, match="needs n="):
        svc.register_chunked_pool(lambda: factory(), warm="deferred")
    pid = svc.register_chunked_pool(lambda: factory(), warm="deferred",
                                    n=128)
    while not svc.registry.step_warm(pid):
        pass
    assert svc.registry.get(pid).warm_state == "warm"
    res = svc.select(pid, k=5)
    gj = jnp.asarray(g)
    want_idx, _, _, _ = omp_select(gj, jnp.sum(gj, axis=0), 5)
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  np.asarray(want_idx))


def test_deferred_warm_wrong_n_fails_requests_not_queue():
    g = _pool(14, 128, 8)

    def factory():
        yield g[:64], None
        yield g[64:], None

    svc = _svc()
    pa = svc.register_pool(_pool(15, 64, 8))
    pid = svc.register_chunked_pool(lambda: factory(), warm="deferred",
                                    n=999)   # lie about the row count
    t_bad = svc.submit(pid, k=5)
    t_ok = svc.submit(pa, k=5)
    done = svc.drain()
    assert len(done) == 2
    assert t_ok.status == "done"
    assert t_bad.status == "failed"
    assert "warm failed" in t_bad.error
    assert svc.registry.get(pid).warm_state == "failed"


# ---------------------------------------------------------------------------
# breaker + fairness interaction (satellite)
# ---------------------------------------------------------------------------

def test_poisoned_tenant_does_not_starve_healthy_tenants():
    g_bad = _pool(16, 128, 8)
    inner = stream_lib.chunked_pool_iter(ChunkedPool(g_bad, chunk_size=32))
    # 4 admission chunks pass cleanly; the first serve pass dies.
    faulty = FaultyChunkIterator(inner,
                                 FaultPlan(die_after_chunks=5, seed=0))
    svc = _svc(max_batch=1, overload=False, degrade=False,
               breaker_threshold=2)
    p_bad = svc.register_chunked_pool(faulty)
    p_ok = svc.register_pool(_pool(17, 64, 8))
    svc.admission.set_budget("victim", budget_units=1e9)
    tickets = []
    for _ in range(3):
        tickets.append(svc.submit(p_bad, k=5, tenant="victim"))
        tickets.append(svc.submit(p_ok, k=5, tenant="healthy"))
    done = svc.drain()
    assert len(done) == 6
    by_tenant = {}
    for t in tickets:
        by_tenant.setdefault(t.request.tenant, []).append(t.status)
    # Deficit-fair drain kept serving the healthy tenant while the
    # poisoned pool failed and its breaker opened.
    assert by_tenant["healthy"] == ["done"] * 3
    assert by_tenant["victim"] == ["failed"] * 3
    assert svc.breakers.get(p_bad).state == "open"
    # No budget leak on the failing tenant: every failure refunded.
    stats = svc.admission.stats()
    assert stats["victim"]["used_units"] == 0.0
    assert stats["victim"]["inflight"] == 0
    assert stats["healthy"]["inflight"] == 0


# ---------------------------------------------------------------------------
# session store stats (satellite)
# ---------------------------------------------------------------------------

def test_session_store_stats_counters_surfaced():
    svc = _svc()
    p = svc.register_pool(_pool(18, 96, 8))
    sid, _ = svc.open_session(p, k=6)
    svc.extend_session(sid, 10)         # get -> hit
    svc.close_session(sid)
    from repro.serve import SessionGone
    with pytest.raises(SessionGone):
        svc.extend_session(sid, 12)     # get -> miss
    s = svc.stats()["sessions"]
    assert s["puts"] >= 1
    assert s["hits"] >= 1
    assert s["misses"] >= 1
    assert {"evictions", "expirations", "sessions"} <= set(s)


# ---------------------------------------------------------------------------
# open-loop load harness
# ---------------------------------------------------------------------------

def test_make_arrivals_deterministic_and_sorted():
    spec = LoadSpec(seed=3, requests=40, rate_rps=50.0,
                    pools=("p1", "p2"), ks=(4, 8, 12),
                    tenants=("a", "b"), tenant_weights=(3, 1),
                    priorities=("interactive", "best-effort"),
                    priority_weights=(1, 1))
    a1 = make_arrivals(spec)
    a2 = make_arrivals(spec)
    assert a1 == a2
    assert [a.t for a in a1] == sorted(a.t for a in a1)
    assert len(a1) == 40
    assert make_arrivals(LoadSpec(seed=4, requests=40, rate_rps=50.0,
                                  pools=("p1",))) != a1
    tenants = [a.request.tenant for a in a1]
    assert tenants.count("a") > tenants.count("b")   # weighted mix


def test_run_load_invariants_and_determinism():
    def once():
        clock = SimClock()
        svc = _svc(clock=clock, max_queue=16, brownout_at=0.4,
                   overload_at=0.8, recover_at=0.1)
        p = svc.register_pool(_pool(19, 128, 8))
        spec = LoadSpec(seed=5, requests=30, rate_rps=1000.0,
                        pools=(p,), ks=(4, 8),
                        tenants=("a", "b"),
                        priorities=("interactive", "best-effort"),
                        priority_weights=(2, 1))
        rep = run_load(svc, make_arrivals(spec), clock,
                       step_cost=_flat_cost)
        return rep

    r1, r2 = once(), once()
    assert r1.violations == []
    assert r1.completed + r1.shed + r1.failed == len(r1.records)
    assert r1.completed > 0
    # Deterministic replay: same outcome counts, same rung histogram.
    assert (r1.completed, r1.shed, r1.failed, r1.rejected) == \
        (r2.completed, r2.shed, r2.failed, r2.rejected)
    assert r1.rungs == r2.rungs
    # Every response is labelled with its rung.
    assert all(t.degradation != "none"
               for t in (r["ticket"] for r in r1.records))
    assert r1.p99_ms >= r1.p50_ms >= 0.0


def test_run_load_rejections_do_not_break_accounting():
    clock = SimClock()
    svc = _svc(clock=clock, max_queue=4, overload=False)
    p = svc.register_pool(_pool(20, 64, 8))
    spec = LoadSpec(seed=6, requests=20, rate_rps=1e6, pools=(p,),
                    ks=(4,))
    rep = run_load(svc, make_arrivals(spec), clock, step_cost=_flat_cost)
    assert rep.rejected > 0             # QueueFull raised mid-burst
    assert rep.violations == []
    assert rep.completed + rep.rejected + rep.shed + rep.failed == 20


def test_run_load_under_faults_no_wedge_no_leak():
    clock = SimClock()
    svc = _svc(clock=clock, max_queue=32, retry_policy=_FAST_RETRY,
               brownout_at=0.4, overload_at=0.8, recover_at=0.1)
    g = _pool(21, 256, 8)
    inner = stream_lib.chunked_pool_iter(ChunkedPool(g, chunk_size=64))
    faulty = FaultyChunkIterator(
        inner, FaultPlan(transient_rate=0.2, seed=2))
    p_ch = svc.register_chunked_pool(faulty)
    p_arr = svc.register_pool(_pool(22, 128, 8))
    spec = LoadSpec(seed=7, requests=24, rate_rps=1000.0,
                    pools=(p_arr, p_ch), pool_weights=(2, 1),
                    ks=(4, 6), tenants=("a", "b"),
                    priorities=("interactive", "batch"))
    rep = run_load(svc, make_arrivals(spec), clock, step_cost=_flat_cost)
    assert rep.violations == []
    assert svc.scheduler.pending() == 0
    assert rep.completed > 0
    assert faulty.injected["transient"] > 0     # chaos actually fired
    # Certified answers under concurrent faults + overload must equal
    # the unloaded solve.
    gj = jnp.asarray(g)
    want = {k: np.asarray(omp_select(gj, jnp.sum(gj, axis=0), k)[0])
            for k in (4, 6)}
    checked = 0
    for r in rep.records:
        t = r["ticket"]
        if (t.request.pool_id == p_ch and t.status == "done"
                and t.degradation == "certified"):
            np.testing.assert_array_equal(
                np.asarray(t.result.indices), want[t.request.k])
            checked += 1
    assert checked > 0


def test_run_load_fairness_ratio_reported():
    clock = SimClock()
    svc = _svc(clock=clock, max_queue=64, overload=False, max_batch=1,
               max_inflight_per_tenant=64)
    pa = svc.register_pool(_pool(23, 64, 8), pool_id="pa")
    pb = svc.register_pool(_pool(24, 64, 8), pool_id="pb")
    spec = LoadSpec(seed=8, requests=24, rate_rps=1e6,
                    pools=("pa", "pb"), ks=(4,), tenants=("a", "b"))
    arr = [a if a.request.tenant == "a" else a for a in
           make_arrivals(spec)]
    # Pin pool to tenant so fairness is visible in served units.
    from repro.serve import Arrival, SelectRequest
    arr = [Arrival(t=a.t, request=SelectRequest(
        pool_id="pa" if a.request.tenant == "a" else "pb",
        k=a.request.k, tenant=a.request.tenant, seed=a.request.seed))
        for a in arr]
    rep = run_load(svc, arr, clock, step_cost=_flat_cost)
    assert rep.violations == []
    assert rep.fairness_ratio is not None
    assert 0.0 < rep.fairness_ratio <= 1.0
    assert set(rep.tenant_served_units) == {"a", "b"}
