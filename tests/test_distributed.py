"""Distributed selection: sharded OMP == dense OMP.

The multi-device path runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (conftest keeps the
main test process on 1 real device, per the dry-run contract)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if not hasattr(jax.sharding, "AxisType"):  # repro.launch.mesh needs it
    pytest.skip("requires jax.sharding.AxisType (newer jax)",
                allow_module_level=True)

from repro.core.distributed import sharded_gradmatch_pb, sharded_omp_select
from repro.core.omp import omp_select
from repro.launch.mesh import make_host_mesh


def test_sharded_omp_single_device_matches_dense():
    """data=1 mesh: the shard_map path must agree exactly with the dense
    solver (same math, one shard)."""
    mesh = make_host_mesh(data=1, model=1)
    g = jax.random.normal(jax.random.PRNGKey(0), (96, 32))
    t = jnp.sum(g[:9], axis=0)
    i1, w1, m1, e1 = omp_select(g, t, k=9, lam=0.3)
    sel = sharded_omp_select(mesh, g, t, k=9, lam=0.3)
    np.testing.assert_array_equal(np.sort(np.asarray(i1)),
                                  np.sort(np.asarray(sel.indices)))
    np.testing.assert_allclose(float(e1), float(sel.err), rtol=1e-5)


def test_sharded_gradmatch_pb_single_device():
    mesh = make_host_mesh(data=1, model=1)
    g = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    sel = sharded_gradmatch_pb(mesh, g, batch_size=4, k_batches=4)
    assert int(jnp.sum(sel.mask)) == 4
    assert abs(float(jnp.sum(sel.weights)) - 1.0) < 1e-4


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import AxisType
    from repro.core.distributed import sharded_omp_select, shard_rows
    from repro.core.omp import omp_select
    mesh = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
    g = jax.random.normal(jax.random.PRNGKey(0), (128, 48))
    t = jnp.sum(g[:12], axis=0)
    i1, w1, m1, e1 = omp_select(g, t, k=12, lam=0.3)
    sel = sharded_omp_select(mesh, shard_rows(mesh, g), t, k=12, lam=0.3)
    assert sorted(np.asarray(i1).tolist()) == sorted(
        np.asarray(sel.indices).tolist()), (i1, sel.indices)
    np.testing.assert_allclose(float(e1), float(sel.err), rtol=1e-4)
    np.testing.assert_allclose(np.sort(np.asarray(w1)),
                               np.sort(np.asarray(sel.weights
                                                  * jnp.sum(w1))),
                               rtol=1e-3, atol=1e-5)
    print("OK8")
""")


def test_sharded_omp_8way_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK8" in r.stdout
