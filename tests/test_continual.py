"""Continual-stream selection: differential + lifecycle tests.

The contract under test (DESIGN.md §11): after every admitted batch, the
``BufferMaintainer``'s committed solution is index-exact (weights to f32
tolerance) against a **from-scratch** solve over the rows currently
surviving in the buffer; decremental downdates match from-scratch solves
on the surviving pool; a killed stream resumes **bit**-exactly.

``FAULT_SEED`` parametrizes the fault-schedule tests (CI's fault-suite
job runs this file under three seeds) — schedules are pure functions of
the seed, so failures replay byte-for-byte.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.continual import BufferMaintainer, continual_select
from repro.core import omp
from repro.core import selection as sel_lib
from repro.core.decremental import (certify_admission, omp_downdate,
                                    session_extend_traced, session_truncate)
from repro.core.gradmatch import gradmatch
from repro.core.streaming import SelectStats, StreamingPassBudgetError
from repro.resilience import RetryPolicy, TransientFault, with_retries
from repro.serve import SelectionService, SessionGone

SEED = int(os.environ.get("FAULT_SEED", "7"))


def _pool(seed, n, d, dups=True):
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((n, d)).astype(np.float32)
    if dups and n >= 8:
        g[n // 2] = g[1]            # duplicate rows: tie-breaking must
        g[n - 2] = g[1]             # not depend on arrival order
    return g


def _feed(m, g, bs):
    n = g.shape[0]
    for lo in range(0, n, bs):
        hi = min(lo + bs, n)
        m.admit(g[lo:hi], gids=np.arange(lo, hi, dtype=np.int64))
    return m


def _assert_matches_scratch(m, what):
    """Maintained slot-space solution == from-scratch solve on the
    surviving buffer rows (the tentpole differential guarantee)."""
    pool, ok = m.pool_view()
    idx, w, mask, err = m.slot_result()
    fresh = omp.omp_session_start(pool, m.target, m.k, valid=ok,
                                  lam=m.lam, eps=m.eps,
                                  positive=m.positive, block=m.block)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(fresh.indices),
                                  err_msg=f"{what}: indices diverged")
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(fresh.mask),
                                  err_msg=f"{what}: mask diverged")
    np.testing.assert_allclose(np.asarray(w), np.asarray(fresh.weights),
                               rtol=2e-4, atol=2e-5,
                               err_msg=f"{what}: weights diverged")
    np.testing.assert_allclose(float(err), float(fresh.err), rtol=1e-4,
                               err_msg=f"{what}: err diverged")


# ---------------------------------------------------------------------------
# tentpole differential: (n, k, batch_size, buffer_cap) grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,k,bs,cap", [
    (64, 8, 8, 16, 32),      # roomy buffer: mostly free evictions
    (96, 8, 12, 8, 16),      # tight buffer: committed evictions (downdates)
    (48, 24, 6, 6, 48),      # capacity covers the pool: nothing evicted
    (40, 8, 16, 8, 12),      # k >= buffer: degenerate re-pick rounds
    (64, 24, 10, 32, 24),    # wide-ish proxies, batch > capacity wave split
])
def test_differential_after_every_batch(n, d, k, bs, cap):
    g = _pool(SEED, n, d)
    tgt = jnp.sum(jnp.asarray(g), axis=0)
    m = BufferMaintainer(capacity=cap, d=d, target=tgt, k=k,
                         compress=False, seed=SEED)
    for lo in range(0, n, bs):
        hi = min(lo + bs, n)
        m.admit(g[lo:hi], gids=np.arange(lo, hi, dtype=np.int64))
        _assert_matches_scratch(m, f"n={n} k={k} bs={bs} cap={cap} @row{hi}")
    assert m.stats.admits == n
    if cap < n:
        assert m.stats.evicts > 0


def test_differential_vs_omp_select_smoke():
    """Cross-engine check at a friendly size: the maintained buffer also
    matches the one-shot ``omp_select`` (default block) on the surviving
    rows — the wording of the issue's guarantee."""
    g = _pool(3, 96, 16, dups=True)
    tgt = jnp.sum(jnp.asarray(g), axis=0)
    m = _feed(BufferMaintainer(capacity=40, d=16, target=tgt, k=12,
                               compress=False, seed=3), g, 16)
    pool, ok = m.pool_view()
    idx, w, mask, err = m.slot_result()
    i2, w2, m2, _ = omp.omp_select(pool, tgt, 12, valid=ok)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(w), np.asarray(w2),
                               rtol=2e-4, atol=2e-5)


def test_compressed_storage_still_exact():
    """compress=True solves the *stored* (bf16-rounded) rows — exactness
    is against what survives in the arena, by construction."""
    g = _pool(11, 80, 8)
    tgt = jnp.sum(jnp.asarray(g), axis=0)
    m = _feed(BufferMaintainer(capacity=24, d=8, target=tgt, k=8,
                               compress=True, seed=11), g, 10)
    pool, ok = m.pool_view()
    np.testing.assert_array_equal(
        np.asarray(pool), np.asarray(m._rows_bf.astype(jnp.float32)))
    _assert_matches_scratch(m, "compressed")


def test_invalidated_rows_leave_the_solution():
    """Masked-rows grid point: upstream retraction of committed rows goes
    through the decremental path and the invariant still holds."""
    g = _pool(SEED + 1, 64, 8, dups=False)
    tgt = jnp.sum(jnp.asarray(g), axis=0)
    m = _feed(BufferMaintainer(capacity=32, d=8, target=tgt, k=10,
                               compress=False, seed=SEED), g, 16)
    committed = [int(i) for i in np.asarray(m.result().indices) if i >= 0]
    dropped = committed[:3] + [9999]       # unknown gids are a no-op
    assert m.invalidate(dropped) == 3
    assert m.stats.downdates >= 3
    _assert_matches_scratch(m, "after invalidate")
    left = np.asarray(m.result().indices)
    assert not np.isin(left[left >= 0], committed[:3]).any()
    # non-committed invalidation is free (no replay rounds charged)
    rounds_before = m.stats.rounds
    spectator = [int(gid) for gid in m._gids[m._ok]
                 if int(gid) not in left[left >= 0]][:1]
    if spectator:
        m.invalidate(spectator)
        assert m.stats.rounds == rounds_before
        _assert_matches_scratch(m, "after free invalidate")


def test_capacity_covering_pool_matches_gradmatch():
    """buffer_cap=None == pooled gradmatch: the free-parity case."""
    g = _pool(2, 72, 12, dups=False)
    ref = gradmatch(jnp.asarray(g), 10)
    got = continual_select(g, 10, batch=24)
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_allclose(np.asarray(got.weights),
                               np.asarray(ref.weights), rtol=2e-4,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# decremental OMP: downdate + truncate differentials
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("which", ["last", "middle", "first"])
def test_downdate_matches_scratch_on_surviving_rows(which):
    n, d, k = 96, 16, 12
    g = jnp.asarray(_pool(5, n, d))
    tgt = jnp.sum(g, axis=0)
    sess = omp.omp_session_start(g, tgt, k)
    ind = np.asarray(sess.indices)
    pick = {"last": ind[k - 1], "middle": ind[k // 2], "first": ind[0]}[which]
    down, info = omp_downdate(g, sess, int(pick))
    assert info.replayed == {"last": 0, "middle": k - 1 - k // 2,
                             "first": k - 1}[which]
    assert info.resolved == (which == "first")
    surviving = jnp.ones((n,), bool).at[int(pick)].set(False)
    # downdate leaves a (k-1)-round solution over the surviving rows ...
    ref = omp.omp_session_start(g, tgt, k - 1, valid=surviving)
    np.testing.assert_array_equal(np.asarray(down.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_allclose(np.asarray(down.weights),
                               np.asarray(ref.weights), rtol=2e-4,
                               atol=2e-5)
    # ... and a follow-up extend matches the from-scratch omp_select at k
    ext = omp.omp_session_extend(g, down, k)
    i2, w2, m2, _ = omp.omp_select(g, tgt, k, valid=surviving)
    np.testing.assert_array_equal(np.asarray(ext.indices), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(ext.weights), np.asarray(w2),
                               rtol=2e-4, atol=2e-5)


def test_downdate_rejects_non_committed():
    g = jnp.asarray(_pool(6, 32, 8, dups=False))
    sess = omp.omp_session_start(g, jnp.sum(g, 0), 4)
    loser = next(i for i in range(32)
                 if i not in np.asarray(sess.indices).tolist())
    with pytest.raises(ValueError, match="not committed"):
        omp_downdate(g, sess, loser)


@pytest.mark.parametrize("t", [0, 1, 5, 9])
def test_truncate_matches_fresh_prefix(t):
    n, d, k = 64, 8, 9
    g = jnp.asarray(_pool(8, n, d))
    tgt = jnp.sum(g, axis=0)
    sess = omp.omp_session_start(g, tgt, k)
    cut = session_truncate(sess, t)
    fresh = omp.omp_session_start(g, tgt, t) if t else None
    assert cut.k == t
    if t:
        np.testing.assert_array_equal(np.asarray(cut.indices),
                                      np.asarray(fresh.indices))
        np.testing.assert_allclose(np.asarray(cut.weights),
                                   np.asarray(fresh.weights), rtol=2e-4,
                                   atol=2e-5)
    # re-extending recovers the original solve
    back = omp.omp_session_extend(g, cut, k)
    np.testing.assert_array_equal(np.asarray(back.indices),
                                  np.asarray(sess.indices))


def test_traced_extend_matches_block_extend():
    n, d, k = 48, 8, 10
    g = jnp.asarray(_pool(9, n, d))
    tgt = jnp.sum(g, axis=0)
    blocked = omp.omp_session_start(g, tgt, k)
    base = session_truncate(blocked, 0)
    traced, trace = session_extend_traced(g, base, k)
    np.testing.assert_array_equal(np.asarray(traced.indices),
                                  np.asarray(blocked.indices))
    np.testing.assert_array_equal(np.asarray(traced.st.weights),
                                  np.asarray(blocked.st.weights))
    assert trace.resid.shape == (k, d) and trace.win.shape == (k,)
    assert np.isfinite(trace.win).all()
    # the recorded winner gains dominate a zero newcomer (certified keep)
    assert certify_admission(np.zeros((3, d), np.float32), trace, k) == k
    # a newcomer equal to round 0's winner cannot be certified past it
    hot = np.asarray(g)[int(np.asarray(traced.indices)[0])][None, :]
    assert certify_admission(hot, trace, k) == 0


# ---------------------------------------------------------------------------
# kill / resume
# ---------------------------------------------------------------------------

def test_kill_resume_bit_exact(tmp_path):
    n, d, k, bs, cap = 96, 8, 10, 8, 20
    g = _pool(SEED + 2, n, d)
    tgt = jnp.sum(jnp.asarray(g), axis=0)

    never_killed = _feed(BufferMaintainer(capacity=cap, d=d, target=tgt,
                                          k=k, compress=True, seed=SEED),
                         g, bs)

    ckpt = str(tmp_path / "stream")
    m = BufferMaintainer(capacity=cap, d=d, target=tgt, k=k, compress=True,
                         seed=SEED, checkpoint_dir=ckpt)
    kill_after = 5
    for i, lo in enumerate(range(0, n, bs)):
        if i == kill_after:
            break
        m.admit(g[lo:lo + bs], gids=np.arange(lo, lo + bs, dtype=np.int64))
    del m                                             # "killed" here

    res = BufferMaintainer.restore(ckpt)
    assert res is not None and res.batches == kill_after
    assert res.stats.resumes == 1
    for i, lo in enumerate(range(0, n, bs)):
        if i < kill_after:
            continue
        hi = min(lo + bs, n)
        res.admit(g[lo:hi], gids=np.arange(lo, hi, dtype=np.int64))

    for a, b in zip(never_killed.slot_result(), res.slot_result()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(never_killed._pool),
                                  np.asarray(res._pool))
    np.testing.assert_array_equal(never_killed._gids, res._gids)
    np.testing.assert_array_equal(
        never_killed._trace.win, res._trace.win)


def test_restore_empty_dir_returns_none(tmp_path):
    assert BufferMaintainer.restore(str(tmp_path / "nothing")) is None


# ---------------------------------------------------------------------------
# stats counters (satellite: SelectStats surface)
# ---------------------------------------------------------------------------

def test_counters_surface_in_summary():
    s = SelectStats()
    assert "admits=" not in s.summary()       # quiet until continual runs
    s.admits, s.evicts, s.downdates, s.resolves = 40, 7, 3, 1
    out = s.summary()
    assert "admits=40 evicts=7 downdates=3 resolves=1" in out
    # ... and StreamingPassBudgetError messages carry them for free
    err = StreamingPassBudgetError(2, s)
    assert "downdates=3" in str(err)


def test_maintainer_counters_account():
    g = _pool(13, 80, 8)
    tgt = jnp.sum(jnp.asarray(g), axis=0)
    m = _feed(BufferMaintainer(capacity=16, d=8, target=tgt, k=10,
                               compress=False, seed=13), g, 10)
    assert m.stats.admits == 80
    assert m.stats.evicts >= 80 - 16          # everything beyond capacity
    assert m.stats.downdates > 0              # tight buffer forces them
    assert m.result().stats is m.stats
    assert "admits=80" in m.stats.summary()


def test_memory_stays_flat():
    g = _pool(17, 60, 8, dups=False)
    tgt = jnp.sum(jnp.asarray(g), axis=0)
    m = BufferMaintainer(capacity=12, d=8, target=tgt, k=6, compress=True)
    sizes = []
    for lo in range(0, 60, 6):
        m.admit(g[lo:lo + 6])
        sizes.append(m.memory_bytes())
    assert len(set(sizes)) == 1, f"memory grew: {sizes}"


# ---------------------------------------------------------------------------
# selection.select dispatch + kwarg validation (satellite S1)
# ---------------------------------------------------------------------------

def test_select_dispatch_continual():
    g = jnp.asarray(_pool(1, 48, 8, dups=False))
    sel = sel_lib.select("gradmatch-continual", jax.random.PRNGKey(0), g,
                         k=8, buffer_cap=24, continual_batch=16)
    idx = np.asarray(sel.indices)
    msk = np.asarray(sel.mask)
    assert ((idx[msk] >= 0) & (idx[msk] < 48)).all()
    assert abs(float(np.asarray(sel.weights)[msk].sum()) - 1.0) < 1e-4
    assert sel.stats is not None and sel.stats.evicts > 0


def test_select_rejects_unknown_strategy():
    g = jnp.asarray(_pool(1, 16, 4, dups=False))
    with pytest.raises(ValueError, match="unknown strategy"):
        sel_lib.select("gradmatch-typo", jax.random.PRNGKey(0), g, k=4)


@pytest.mark.parametrize("strategy", ["gradmatch", "craig-lazy", "random"])
def test_select_rejects_partitions_on_wrong_strategy(strategy):
    g = jnp.asarray(_pool(1, 16, 4, dups=False))
    with pytest.raises(ValueError, match="silently ignored"):
        sel_lib.select(strategy, jax.random.PRNGKey(0), g, k=4,
                       partitions=2)


def test_select_rejects_bad_partition_count():
    g = jnp.asarray(_pool(1, 16, 4, dups=False))
    with pytest.raises(ValueError, match="partitions must be >= 1"):
        sel_lib.select("gradmatch-partitioned", jax.random.PRNGKey(0), g,
                       k=4, partitions=0)


def test_select_accepts_explicit_partitions():
    g = jnp.asarray(_pool(1, 32, 8, dups=False))
    sel = sel_lib.select("gradmatch-partitioned", jax.random.PRNGKey(0), g,
                         k=8, partitions=2)
    assert int(np.asarray(sel.mask).sum()) >= 1


@pytest.mark.parametrize("kw", [{"buffer_cap": 8}, {"continual_batch": 8}])
def test_select_rejects_continual_kwargs_elsewhere(kw):
    g = jnp.asarray(_pool(1, 16, 4, dups=False))
    with pytest.raises(ValueError, match="gradmatch-continual"):
        sel_lib.select("gradmatch", jax.random.PRNGKey(0), g, k=4, **kw)


@pytest.mark.parametrize("kw", [{"buffer_cap": 0}, {"continual_batch": -1}])
def test_select_rejects_nonpositive_continual_kwargs(kw):
    g = jnp.asarray(_pool(1, 16, 4, dups=False))
    with pytest.raises(ValueError, match="must be >= 1"):
        sel_lib.select("gradmatch-continual", jax.random.PRNGKey(0), g,
                       k=4, **kw)


# ---------------------------------------------------------------------------
# serve stream sessions
# ---------------------------------------------------------------------------

def test_serve_stream_lifecycle():
    rng = np.random.default_rng(SEED)
    svc = SelectionService()
    tgt = rng.standard_normal(8).astype(np.float32)
    sid = svc.open_stream(d=8, k=6, target=tgt, capacity=24, tenant="t1")
    res = None
    for _ in range(6):
        res = svc.push_stream(sid,
                              rng.standard_normal((8, 8)).astype(np.float32))
    assert res.stats.admits == 48
    st = svc.stats()
    assert st["streams"]["sessions"] == 1 and st["streams"]["hits"] == 6
    assert svc.stats()["tenants"]["t1"]["admitted"] == 7   # open + 6 pushes
    # result endpoint does not admit anything
    again = svc.stream_result(sid)
    assert again.stats.admits == 48
    assert svc.close_stream(sid)
    with pytest.raises(SessionGone):
        svc.push_stream(sid, rng.standard_normal((4, 8)))


def test_serve_stream_refunds_failed_push():
    svc = SelectionService(default_budget_units=1e6)
    sid = svc.open_stream(d=8, k=4, target=np.ones(8, np.float32),
                          capacity=16, tenant="t2")
    used = svc.stats()["tenants"]["t2"]["used_units"]
    with pytest.raises(ValueError, match="incompatible"):
        svc.push_stream(sid, np.ones((4, 5), np.float32))   # wrong d
    assert svc.stats()["tenants"]["t2"]["used_units"] == used
    assert svc.stats()["tenants"]["t2"]["inflight"] == 0


def test_serve_stream_checkpoint_resume(tmp_path):
    rng = np.random.default_rng(SEED)
    batches = [rng.standard_normal((6, 8)).astype(np.float32)
               for _ in range(8)]
    tgt = np.sum(np.concatenate(batches), axis=0)
    ckpt = str(tmp_path / "svc-stream")

    ref = BufferMaintainer(capacity=16, d=8, target=tgt, k=6,
                           compress=True, seed=0)
    gid = 0
    for b in batches:
        ref.admit(b, gids=np.arange(gid, gid + 6, dtype=np.int64))
        gid += 6

    svc = SelectionService()
    sid = svc.open_stream(d=8, k=6, target=tgt, capacity=16, seed=0,
                          checkpoint_dir=ckpt)
    gid = 0
    for b in batches[:4]:
        svc.push_stream(sid, b, gids=np.arange(gid, gid + 6,
                                               dtype=np.int64))
        gid += 6
    svc.close_stream(sid)                       # "killed" mid-stream

    sid2 = svc.open_stream(d=8, k=6, target=tgt, capacity=16, seed=0,
                           checkpoint_dir=ckpt)   # resumes from snapshot
    res = None
    for b in batches[4:]:
        res = svc.push_stream(sid2, b, gids=np.arange(gid, gid + 6,
                                                      dtype=np.int64))
        gid += 6
    assert res.stats.resumes == 1
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  np.asarray(ref.result().indices))
    m2 = svc.streams.get(sid2).maintainer
    for a, b in zip(ref.slot_result(), m2.slot_result()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fault-suite coverage (FAULT_SEED drives the schedule)
# ---------------------------------------------------------------------------

def test_flaky_delivery_matches_fault_free():
    """Transient batch-delivery faults retried away leave the eviction
    schedule and the maintained solution bit-identical to the fault-free
    stream — the admission RNG is keyed on (seed, batch counter), never
    on wall-clock or attempt counts."""
    rng = np.random.default_rng(SEED)
    batches = [rng.standard_normal((8, 8)).astype(np.float32)
               for _ in range(10)]
    tgt = np.sum(np.concatenate(batches), axis=0)
    policy = RetryPolicy(max_retries=3, backoff_s=0.0,
                         sleep=lambda s: None)

    frng = np.random.default_rng((SEED, 1234))
    fault_batches = set(frng.choice(len(batches), size=3, replace=False))

    def run(faulty):
        m = BufferMaintainer(capacity=20, d=8, target=tgt, k=6,
                             compress=True, seed=SEED)
        injected = 0
        for i, b in enumerate(batches):
            state = {"tries": 0}

            def deliver():
                state["tries"] += 1
                if faulty and state["tries"] == 1 and i in fault_batches:
                    raise TransientFault(f"flaky delivery, batch {i}")
                return b

            rows = with_retries(deliver, policy)
            injected += state["tries"] - 1
            m.admit(rows, gids=np.arange(i * 8, i * 8 + 8,
                                         dtype=np.int64))
        return m, injected

    clean, _ = run(False)
    dirty, injected = run(True)
    assert injected > 0, "fault schedule injected nothing at this seed"
    for a, b in zip(clean.slot_result(), dirty.slot_result()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(clean._pool),
                                  np.asarray(dirty._pool))
    assert clean.stats.evicts == dirty.stats.evicts
    assert clean.stats.downdates == dirty.stats.downdates
