"""Elastic scaling: checkpoint from one topology restores onto another."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

if not hasattr(jax.sharding, "AxisType"):  # repro.launch.mesh needs it
    pytest.skip("requires jax.sharding.AxisType (newer jax)",
                allow_module_level=True)

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_smoke_config
from repro.launch.elastic import rendezvous, reshard_like
from repro.models import lm as lm_lib


def test_rendezvous_roundtrip(tmp_path):
    """Save under topology A, restore under topology B, forward output
    identical — the reshard is value-preserving."""
    cfg = get_smoke_config("starcoder2-3b")
    params = lm_lib.init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    h0, _, _ = lm_lib.forward(cfg, params, toks, mode="train")

    save_checkpoint(str(tmp_path), 1, {"params": params})
    snap = load_checkpoint(str(tmp_path))

    # "new cluster": 1-device mesh (the only topology on this container;
    # the 512-way version is exercised by the dry-run artifacts)
    mesh, params2 = rendezvous(cfg, snap["params"], data=1, model=1,
                               fsdp=True)
    h1, _, _ = lm_lib.forward(cfg, params2, toks, mode="train")
    np.testing.assert_allclose(np.asarray(h0, np.float32),
                               np.asarray(h1, np.float32), rtol=1e-5)


def test_reshard_like_moves_leaves():
    dev = jax.devices()[0]
    tree = {"a": np.ones((4, 4), np.float32)}
    sh = {"a": jax.sharding.SingleDeviceSharding(dev)}
    out = reshard_like(tree, sh)
    assert isinstance(out["a"], jax.Array)
    np.testing.assert_array_equal(np.asarray(out["a"]), tree["a"])
