"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single CPU device; multi-device behavior is tested via subprocesses
(test_distributed.py) and the dry-run (launch/dryrun.py)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="module")
def _bounded_compile_cache():
    """Drop compiled XLA programs between test modules.

    The suite compiles thousands of distinct (function, shape) programs;
    XLA:CPU keeps every live executable mapped and segfaults inside
    ``backend_compile`` once enough of them accumulate in one process
    (observed deterministically at the suite's tail on jaxlib 0.4.36).
    Modules are independent — each recompiles its own shapes on entry —
    so clearing per module bounds the live-executable count without
    changing any test's behavior."""
    yield
    jax.clear_caches()
