"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the real
single CPU device; multi-device behavior is tested via subprocesses
(test_distributed.py) and the dry-run (launch/dryrun.py)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
