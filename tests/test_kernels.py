"""Pallas kernel sweeps: every kernel, shapes x dtypes, vs ref.py oracles.

Kernels execute through the Pallas interpreter on CPU (interpret=True runs
the kernel body in Python) — the BlockSpec tiling, grid logic, padding and
accumulation schedules are all exercised; only the Mosaic codegen is not.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.corr import corr, corr_argmax
from repro.kernels.fl_gain import fl_gain_argmax, fl_gain_argmax_otf
from repro.kernels.lastlayer_grad import hidden_grad_fused, lastlayer_grad
from repro.kernels.sqdist import sqdist


def _key(*xs):
    k = jax.random.PRNGKey(42)
    for x in xs:
        k = jax.random.fold_in(k, x)
    return k


# ---------------------------------------------------------------------------
# corr: OMP residual correlation  G @ r
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 128, 300])
@pytest.mark.parametrize("d", [1, 64, 512, 700])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_corr_matches_ref(n, d, dtype):
    g = jax.random.normal(_key(n, d, 0), (n, d), dtype)
    r = jax.random.normal(_key(n, d, 1), (d,), dtype)
    got = corr(g, r, interpret=True)
    want = ref.corr_ref(g, r)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


# ---------------------------------------------------------------------------
# corr_argmax: fused OMP scoring  argmax of  base - C @ w  (masked)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 7, 128, 300])
@pytest.mark.parametrize("kc", [1, 64, 512, 700])
@pytest.mark.parametrize("absolute", [False, True])
def test_corr_argmax_matches_ref(n, kc, absolute):
    c = jax.random.normal(_key(n, kc, 20), (n, kc))
    w = jax.random.normal(_key(n, kc, 21), (kc,))
    base = jax.random.normal(_key(n, kc, 22), (n,)) * 3
    mask = jax.random.bernoulli(_key(n, kc, 23), 0.7, (n,))
    gi, gv = corr_argmax(c, w, base, mask, absolute=absolute,
                         interpret=True)
    ri, rv = ref.corr_argmax_ref(c, w, base, mask, absolute=absolute)
    assert int(gi) == int(ri)
    if np.isfinite(float(rv)):
        np.testing.assert_allclose(float(gv), float(rv), rtol=1e-4,
                                   atol=1e-4)
    else:
        assert float(gv) == float(rv)  # both -inf (mask emptied the pool)


def test_corr_argmax_tie_breaks_to_lowest_index():
    """Constant scores across rows (and across row tiles): both the kernel
    and the ref must return the first unmasked index."""
    n, kc = 400, 8
    c = jnp.zeros((n, kc))
    w = jnp.zeros((kc,))
    base = jnp.full((n,), 1.5)
    mask = jnp.ones((n,), bool).at[0].set(False).at[1].set(False)
    gi, gv = corr_argmax(c, w, base, mask, interpret=True)
    ri, rv = ref.corr_argmax_ref(c, w, base, mask)
    assert int(gi) == int(ri) == 2
    # tie inside a later row tile only
    base2 = base.at[200].set(9.0).at[333].set(9.0)
    gi2, _ = corr_argmax(c, w, base2, mask, interpret=True)
    ri2, _ = ref.corr_argmax_ref(c, w, base2, mask)
    assert int(gi2) == int(ri2) == 200


def test_corr_argmax_all_masked():
    """An all-False mask yields (0, -inf) — the OMP body relies on this
    being in-range (the eps-stop gates the actual selection)."""
    n, kc = 260, 16
    c = jax.random.normal(_key(n, kc, 24), (n, kc))
    w = jax.random.normal(_key(n, kc, 25), (kc,))
    base = jax.random.normal(_key(n, kc, 26), (n,))
    mask = jnp.zeros((n,), bool)
    gi, gv = corr_argmax(c, w, base, mask, interpret=True)
    ri, rv = ref.corr_argmax_ref(c, w, base, mask)
    assert int(gi) == int(ri) == 0
    assert float(gv) == float(rv) == -np.inf


def test_corr_argmax_residual_form_matches_corr():
    """The narrow-regime call (G, -r, 0) must equal argmax of corr(G, r)."""
    g = jax.random.normal(_key(64, 96, 27), (64, 96))
    r = jax.random.normal(_key(64, 96, 28), (96,))
    mask = jnp.ones((64,), bool)
    gi, gv = corr_argmax(g, -r, jnp.zeros((64,)), mask, interpret=True)
    scores = ref.corr_ref(g, r)
    assert int(gi) == int(jnp.argmax(scores))
    np.testing.assert_allclose(float(gv), float(jnp.max(scores)), rtol=1e-4)


# ---------------------------------------------------------------------------
# sqdist: pairwise squared distances (CRAIG similarity ground set)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [(1, 1), (9, 17), (100, 90), (128, 128)])
@pytest.mark.parametrize("d", [3, 130])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sqdist_matches_ref(n, m, d, dtype):
    a = jax.random.normal(_key(n, d, 2), (n, d), dtype)
    b = jax.random.normal(_key(m, d, 3), (m, d), dtype)
    got = sqdist(a, b, interpret=True)
    want = ref.sqdist_ref(a, b)
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


def test_sqdist_self_diagonal_zero():
    a = jax.random.normal(_key(50, 64, 4), (50, 64))
    d = sqdist(a, a, interpret=True)
    np.testing.assert_allclose(jnp.diag(d), np.zeros(50), atol=1e-3)


# ---------------------------------------------------------------------------
# fl_gain_argmax: fused facility-location gain scan (CRAIG greedy)
# ---------------------------------------------------------------------------

def _fl_case(seed, n, d):
    g = jax.random.normal(_key(seed, n, d), (n, d))
    sq = jnp.sum(g**2, axis=1)
    dist = jnp.sqrt(jnp.maximum(
        sq[:, None] + sq[None, :] - 2.0 * g @ g.T, 0.0))
    lm = jnp.max(dist)
    sim = lm - dist
    cover = jnp.abs(jax.random.normal(_key(seed, n, d + 1), (n,)))
    mask = jax.random.bernoulli(_key(seed, n, d + 2), 0.7, (n,))
    return g, sim, lm, cover, mask


@pytest.mark.parametrize("n", [1, 9, 128, 300])
@pytest.mark.parametrize("d", [4, 70])
def test_fl_gain_argmax_matches_ref(n, d):
    _, sim, _, cover, mask = _fl_case(30, n, d)
    gg, gi, gv = fl_gain_argmax(sim, cover, mask, interpret=True)
    rg, ri, rv = ref.fl_gain_argmax_ref(sim, cover, mask)
    np.testing.assert_allclose(gg, rg, rtol=1e-4, atol=1e-4)
    if np.isfinite(float(rv)):
        assert int(gi) == int(ri)
        np.testing.assert_allclose(float(gv), float(rv), rtol=1e-4,
                                   atol=1e-4)
    else:
        assert int(gi) == int(ri) == 0 and float(gv) == float(rv)


@pytest.mark.parametrize("n", [1, 9, 150, 260])
@pytest.mark.parametrize("d", [3, 64, 600])
def test_fl_gain_argmax_otf_matches_resident(n, d):
    """The on-the-fly kernel (similarity reconstructed from grads inside
    the loop) must agree with the resident ref to float tolerance."""
    g, sim, lm, cover, mask = _fl_case(31, n, d)
    rok = jnp.ones((n,), bool)
    rg, ri, _ = ref.fl_gain_argmax_ref(sim, cover, mask)
    og, oi, _ = ref.fl_gain_argmax_otf_ref(g, cover, rok, mask, lm,
                                           block=64)
    np.testing.assert_allclose(og, rg, rtol=1e-3, atol=1e-3)
    kg, ki, _ = fl_gain_argmax_otf(g, cover, rok, mask, lm, interpret=True)
    np.testing.assert_allclose(kg, rg, rtol=1e-3, atol=1e-3)
    if np.isfinite(float(np.max(np.where(np.asarray(mask), rg, -np.inf)))):
        assert int(oi) == int(ri)
        assert int(ki) == int(ri)


def test_fl_gain_argmax_tie_breaks_to_lowest_index():
    """All-equal similarity (duplicate candidates): both the kernel and
    the ref must return the first unmasked column, across column tiles."""
    n = 300
    sim = jnp.ones((n, n))
    cover = jnp.zeros((n,))
    mask = jnp.ones((n,), bool).at[0].set(False)
    ki = int(fl_gain_argmax(sim, cover, mask, interpret=True)[1])
    ri = int(ref.fl_gain_argmax_ref(sim, cover, mask)[1])
    assert ki == ri == 1
    # tie inside a later column tile only
    sim2 = sim.at[:, 200].set(2.0).at[:, 260].set(2.0)
    ki2 = int(fl_gain_argmax(sim2, cover, mask, interpret=True)[1])
    ri2 = int(ref.fl_gain_argmax_ref(sim2, cover, mask)[1])
    assert ki2 == ri2 == 200


def test_fl_gain_argmax_all_masked():
    n = 140
    _, sim, _, cover, _ = _fl_case(32, n, 8)
    mask = jnp.zeros((n,), bool)
    kg, ki, kv = fl_gain_argmax(sim, cover, mask, interpret=True)
    rg, ri, rv = ref.fl_gain_argmax_ref(sim, cover, mask)
    assert int(ki) == int(ri) == 0
    assert float(kv) == float(rv) == -np.inf
    np.testing.assert_allclose(kg, rg, rtol=1e-4, atol=1e-4)


def test_fl_gain_otf_invalid_rows_demand_no_coverage():
    """row_ok=False rows must contribute exactly 0 gain — the on-the-fly
    equivalent of zeroing similarity rows."""
    n, d = 60, 8
    g, sim, lm, cover, _ = _fl_case(33, n, d)
    rok = jnp.asarray(np.arange(n) < 40)
    mask = jnp.ones((n,), bool)
    og, _, _ = ref.fl_gain_argmax_otf_ref(g, cover, rok, mask, lm, block=16)
    sim_z = sim * rok[:, None].astype(sim.dtype)
    rg, _, _ = ref.fl_gain_argmax_ref(sim_z, cover, mask)
    np.testing.assert_allclose(og, rg, rtol=1e-3, atol=1e-3)
    kg, _, _ = fl_gain_argmax_otf(g, cover, rok, mask, lm, interpret=True)
    np.testing.assert_allclose(kg, rg, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# lastlayer_grad: fused classification-head proxy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 50, 128, 200])
@pytest.mark.parametrize("c", [2, 10, 100])
@pytest.mark.parametrize("dh", [8, 64])
def test_lastlayer_grad_matches_ref(n, c, dh):
    h = jax.random.normal(_key(n, c, 5), (n, dh))
    z = jax.random.normal(_key(n, c, 6), (n, c)) * 3
    y = jax.random.randint(_key(n, c, 7), (n,), 0, c)
    resid, hgrad = lastlayer_grad(h, z, y, interpret=True)
    eresid, ehgrad = ref.lastlayer_grad_ref(h, z, y)
    np.testing.assert_allclose(resid, eresid, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(hgrad, ehgrad, rtol=1e-4, atol=1e-5)


def test_lastlayer_grad_rows_sum_to_zero():
    """softmax(z) - onehot(y) rows sum to 0 — exactness of the fused path."""
    z = jax.random.normal(_key(64, 10, 8), (64, 10))
    y = jnp.zeros((64,), jnp.int32)
    resid, _ = lastlayer_grad(jnp.ones((64, 4)), z, y, interpret=True)
    np.testing.assert_allclose(jnp.sum(resid, -1), np.zeros(64), atol=1e-5)


# ---------------------------------------------------------------------------
# hidden_grad_fused: flash-style (softmax(Z)-Y) @ W^T for LM heads
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 60, 128])
@pytest.mark.parametrize("v", [16, 100, 513, 1024])
@pytest.mark.parametrize("dh", [32, 512, 600])
def test_hidden_grad_fused_matches_ref(n, v, dh):
    z = jax.random.normal(_key(n, v, 9), (n, v)) * 2
    y = jax.random.randint(_key(n, v, 10), (n,), 0, v)
    w = jax.random.normal(_key(n, v, 11), (dh, v)) / np.sqrt(v)
    got = hidden_grad_fused(z, y, w, interpret=True)
    resid, _ = ref.lastlayer_grad_ref(jnp.zeros((n, 1)), z, y)
    want = resid @ w.T
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_hidden_grad_fused_bf16_logits():
    n, v, dh = 32, 640, 128
    z = (jax.random.normal(_key(0, 0, 12), (n, v)) * 2).astype(jnp.bfloat16)
    y = jax.random.randint(_key(0, 0, 13), (n,), 0, v)
    w = jax.random.normal(_key(0, 0, 14), (dh, v)).astype(jnp.bfloat16)
    got = hidden_grad_fused(z, y, w, interpret=True)
    resid, _ = ref.lastlayer_grad_ref(jnp.zeros((n, 1)), z, y)
    want = resid @ w.T.astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# bound_max: fused compressed-cache interval scan (streaming OMP, §7)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(64, 32), (200, 48), (130, 520)])
@pytest.mark.parametrize("absolute", [False, True])
def test_bound_max_matches_ref(n, d, absolute):
    from repro.kernels.corr import bound_max

    rng = np.random.default_rng(n + d)
    rows_f = rng.standard_normal((n, d)).astype(np.float32)
    rows = jnp.asarray(rows_f).astype(jnp.bfloat16)
    norms = jnp.sqrt(jnp.sum(jnp.asarray(rows_f) ** 2, axis=1))
    errn = jnp.sqrt(jnp.sum(
        (jnp.asarray(rows_f) - rows.astype(jnp.float32)) ** 2, axis=1))
    r = jnp.asarray(rng.standard_normal((d,)).astype(np.float32))
    mask = jnp.asarray(rng.random(n) < 0.7)
    acc = jnp.float32(1e-5)
    # a mid-range threshold so the offender count is non-trivial
    thresh = jnp.float32(0.5)
    gv, gi, gc = bound_max(rows, norms, errn, r, acc, thresh, mask,
                           absolute=absolute, interpret=True)
    rv, ri, rc = ref.bound_max_ref(rows, norms, errn, r, acc, thresh,
                                   mask, absolute=absolute)
    np.testing.assert_allclose(float(gv), float(rv), rtol=1e-6)
    assert int(gi) == int(ri)
    assert int(gc) == int(rc)


def test_bound_max_upper_bounds_exact_scores():
    """The certified invariant: u_i from the bf16 rows + sidecars must
    upper-bound the exact f32 score of every row."""
    from repro.kernels.corr import bound_max

    rng = np.random.default_rng(7)
    n, d = 256, 64
    rows_f = rng.standard_normal((n, d)).astype(np.float32)
    rows = jnp.asarray(rows_f).astype(jnp.bfloat16)
    norms = jnp.sqrt(jnp.sum(jnp.asarray(rows_f) ** 2, axis=1))
    errn = jnp.sqrt(jnp.sum(
        (jnp.asarray(rows_f) - rows.astype(jnp.float32)) ** 2, axis=1))
    r = jnp.asarray(rng.standard_normal((d,)).astype(np.float32))
    acc = jnp.float32(d * 2.0 ** -23 * 1.25)
    exact = np.asarray(jnp.asarray(rows_f) @ r)
    for i in range(0, n, 37):       # spot-check single-row masks
        mask = jnp.zeros((n,), bool).at[i].set(True)
        uv, ui, _ = bound_max(rows, norms, errn, r, acc,
                              jnp.float32(np.inf), mask, interpret=True)
        assert int(ui) == i
        assert float(uv) >= exact[i] - 1e-12, (i, float(uv), exact[i])


def test_bound_max_all_masked_and_ties():
    from repro.kernels.corr import bound_max

    n, d = 64, 32
    rows = jnp.ones((n, d), jnp.bfloat16)
    norms = jnp.full((n,), float(np.sqrt(d)))
    errn = jnp.zeros((n,))
    r = jnp.ones((d,))
    none = jnp.zeros((n,), bool)
    v, i, c = bound_max(rows, norms, errn, r, jnp.float32(0.0),
                        jnp.float32(0.0), none, interpret=True)
    rv, ri, rc = ref.bound_max_ref(rows, norms, errn, r,
                                   jnp.float32(0.0), jnp.float32(0.0),
                                   none)
    assert float(v) == float(rv) == -np.inf
    assert int(i) == int(ri) == 0
    assert int(c) == int(rc) == 0
    # exact ties across all rows resolve to the lowest index
    allm = jnp.ones((n,), bool)
    v, i, c = bound_max(rows, norms, errn, r, jnp.float32(0.0),
                        jnp.float32(0.0), allm, interpret=True)
    assert int(i) == 0 and int(c) == n
