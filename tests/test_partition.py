"""Partition-and-merge sharded selection (core/partition.py, DESIGN.md §9)
plus the per-class budget-split fix (omp.split_budget / gradmatch_per_class).

Layers:

* **split_budget units** — exact budget accounting: sum == min(k, total),
  quotas capped at partition size, remainder to the largest partitions,
  capped-off surplus rebalanced.
* **per-class budget grid** — the bugfix contract: ``gradmatch_per_class``
  returns exactly ``min(k, n_valid)`` rows at every grid point (k % C != 0,
  a class smaller than its quota, a single populated class, k >= n_valid,
  out-of-range labels), with a true (non-placeholder) global ``err``.
* **partition-merge differential parity** — P=1 is set-exact vs the single
  solver; P in {2, 4} stays within an objective tolerance of it; the class
  kind is index-exact vs ``gradmatch_per_class``; the streaming path is
  bit-exact vs in-memory contiguous partitioning; the pmap dispatch path
  matches the vmap path on one device.
* **stats propagation** — PartitionStats accounting, streaming SelectStats
  aggregation, and ``expand_batch_selection`` carrying stats through.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gradmatch as gm_lib
from repro.core import partition as part_lib
from repro.core import selection as sel_lib
from repro.core import streaming as stream_lib
from repro.core.gradmatch import SelectionResult
from repro.core.omp import matching_error, omp_select, split_budget


def _pool(seed, n, d):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _selected(res):
    m = np.asarray(res.mask)
    return np.asarray(res.indices)[m]


def _check_result(res, n):
    idx = np.asarray(res.indices)
    w = np.asarray(res.weights)
    m = np.asarray(res.mask)
    assert np.all(w >= 0) and np.all(w[~m] == 0)
    if m.any():
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)
    assert np.all((idx[m] >= 0) & (idx[m] < n))
    assert np.all(idx[~m] == -1)
    sel = idx[m]
    assert len(np.unique(sel)) == len(sel), "duplicate selections"
    assert np.isfinite(float(res.err))


# ---------------------------------------------------------------------------
# split_budget units
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes,k,want", [
    ([5, 3, 2], 7, [3, 2, 2]),      # remainder to the largest first
    ([10, 1, 1], 9, [7, 1, 1]),     # cap at size, surplus rebalanced
    ([2, 3], 99, [2, 3]),           # k beyond the pool: everything
    ([0, 4, 0], 3, [0, 3, 0]),      # empty partitions get nothing
    ([4, 4], 0, [0, 0]),            # zero budget
    ([3, 5], 8, [3, 5]),            # exact fill
    ([0, 0, 0], 5, [0, 0, 0]),      # every class empty: nothing to place
    ([5] * 7, 3, [1, 1, 1, 0, 0, 0, 0]),   # C > k starvation: equal sizes
                                    # tie-break by class id, 4 starve
    ([1, 9, 1], 2, [1, 1, 0]),      # C > k: one each to largest-first
])
def test_split_budget_cases(sizes, k, want):
    got = split_budget(k, np.asarray(sizes, np.int64))
    np.testing.assert_array_equal(got, np.asarray(want, np.int64))


def test_split_budget_rejects_bad_sizes():
    with pytest.raises(ValueError, match="non-empty"):
        split_budget(4, np.asarray([], np.int64))
    with pytest.raises(ValueError, match="negative"):
        split_budget(4, np.asarray([3, -1], np.int64))


def test_split_budget_starvation_sums_exactly():
    # C > k never over- or under-places: the starved classes are exactly
    # the smallest (ties broken by id), and the quota still sums to k.
    sizes = np.asarray([2, 7, 1, 7, 3], np.int64)
    q = split_budget(3, sizes)
    assert q.sum() == 3
    assert int((q == 0).sum()) == 2
    np.testing.assert_array_equal(q, [0, 1, 0, 1, 1])


def test_per_class_all_rows_invalid():
    # Every label out of range: no class has members, the selection is
    # empty rather than an error (the trainer sees an all-masked result).
    g = _pool(9, 20, 8)
    labels = np.full(20, -1, np.int64)
    res = gm_lib.gradmatch_per_class(jnp.asarray(g), jnp.asarray(labels),
                                     4, 6)
    assert int(np.asarray(res.mask).sum()) == 0


@pytest.mark.parametrize("seed", range(5))
def test_split_budget_invariants_random(seed):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(0, 40, size=rng.integers(1, 9))
    k = int(rng.integers(0, 80))
    q = split_budget(k, sizes)
    assert q.sum() == min(k, sizes.sum())
    assert np.all(q <= sizes) and np.all(q >= 0)
    # Largest partitions never end up with smaller quotas than smaller
    # ones unless capped by their own size.
    for i in range(len(sizes)):
        for j in range(len(sizes)):
            if sizes[i] > sizes[j] and q[i] < q[j]:
                assert q[i] == sizes[i], (sizes, k, q)


# ---------------------------------------------------------------------------
# per-class budget split (the bugfix)
# ---------------------------------------------------------------------------

PER_CLASS_GRID = [
    # (seed, n, num_classes, k, label_fn) — label_fn(n) -> (n,) labels
    (0, 50, 4, 10, lambda n: np.arange(n) % 4),            # k % C != 0
    (1, 40, 3, 24, lambda n: np.repeat([0, 1, 2], [20, 3, 17])),  # tiny class
    (2, 30, 3, 5, lambda n: np.zeros(n, np.int64)),        # one populated class
    (3, 18, 4, 50, lambda n: np.arange(n) % 4),            # k >= n
    (4, 44, 4, 13, lambda n: np.where(np.arange(n) % 11 == 0, -1,
                                      np.arange(n) % 4)),  # invalid labels
]


@pytest.mark.parametrize("seed,n,C,k,label_fn", PER_CLASS_GRID)
def test_per_class_budget_exact(seed, n, C, k, label_fn):
    g = _pool(seed, n, 8)
    labels = np.asarray(label_fn(n), np.int64)
    n_valid = int(((labels >= 0) & (labels < C)).sum())
    res = gm_lib.gradmatch_per_class(jnp.asarray(g), jnp.asarray(labels), C, k)
    _check_result(res, n)
    sel = _selected(res)
    assert len(sel) == min(k, n_valid), \
        f"budget split lost rows: {len(sel)} != min({k}, {n_valid})"
    assert np.all((labels[sel] >= 0) & (labels[sel] < C))
    # Per-class counts follow split_budget exactly.
    sizes = np.bincount(labels[(labels >= 0) & (labels < C)], minlength=C)
    quotas = split_budget(k, sizes)
    counts = np.bincount(labels[sel], minlength=C)
    np.testing.assert_array_equal(counts, quotas)


def test_per_class_err_is_true_objective():
    g = _pool(7, 60, 8)
    labels = np.arange(60) % 3
    res = gm_lib.gradmatch_per_class(jnp.asarray(g), jnp.asarray(labels), 3,
                                     12)
    # The old code hardcoded 0.0; random data with lam > 0 makes a zero
    # objective impossible.
    assert float(res.err) > 0.0
    # err is computed on the *unnormalized* per-class weights; recover
    # them from the normalized result and check the objective matches.
    w = np.asarray(res.weights)
    m = np.asarray(res.mask)
    target = jnp.asarray(g).sum(axis=0)
    best = None
    for scale in np.linspace(0.5, 3.0, 200):
        e = float(matching_error(jnp.asarray(g), target, res.indices,
                                 jnp.asarray(w * scale), res.mask, lam=0.5))
        best = e if best is None else min(best, e)
    # The true err must be attainable by *some* rescale of the normalized
    # weights (it was produced from them) — a hardcoded 0.0 is not.
    assert float(res.err) <= best + 1e-3
    assert m.sum() == 12


def test_select_dispatch_uses_fixed_split():
    g = _pool(9, 50, 8)
    labels = jnp.asarray(np.arange(50) % 4)
    res = sel_lib.select("gradmatch", jax.random.PRNGKey(0), jnp.asarray(g),
                         10, labels=labels, num_classes=4)
    assert int(np.asarray(res.mask).sum()) == 10   # 10 % 4 != 0


# ---------------------------------------------------------------------------
# partition plans
# ---------------------------------------------------------------------------

def test_make_plan_kinds():
    labels = np.arange(30) % 3
    plan = part_lib.make_plan(30, labels=labels, num_classes=3)
    assert plan.kind == "class" and plan.num_parts == 3
    np.testing.assert_array_equal(plan.sizes, [10, 10, 10])

    plan = part_lib.make_plan(100, partitions=4)
    assert plan.kind == "hash" and plan.num_parts == 4
    assert plan.sizes.sum() == 100
    # deterministic assignment
    plan2 = part_lib.make_plan(100, partitions=4)
    np.testing.assert_array_equal(plan.assign, plan2.assign)

    plan = part_lib.make_plan(103, partitions=4, kind="contiguous")
    assert plan.bounds[0] == 0 and plan.bounds[-1] == 103
    assert plan.sizes.sum() == 103

    valid = np.ones(40, bool)
    valid[::5] = False
    plan = part_lib.make_plan(40, partitions=2, kind="hash", valid=valid)
    assert plan.sizes.sum() == int(valid.sum())

    with pytest.raises(ValueError, match="unknown partition kind"):
        part_lib.make_plan(10, kind="banana")
    with pytest.raises(ValueError, match="needs labels"):
        part_lib.make_plan(10, kind="class")


def test_subrange_chunks_and_offset_fetch():
    g = _pool(11, 100, 4)
    it = stream_lib.array_chunks(g, 16)
    # Subranges that straddle chunk boundaries re-tile the exact rows.
    for lo, hi in [(0, 100), (10, 90), (17, 33), (95, 100)]:
        sub = stream_lib.subrange_chunks(it, lo, hi)
        rows = np.concatenate([np.asarray(c) for c, _ in sub()])
        np.testing.assert_array_equal(rows, g[lo:hi])
    fetch = stream_lib.offset_row_fetch(stream_lib.array_row_fetch(g), 20)
    np.testing.assert_array_equal(np.asarray(fetch(np.array([0, 5, 9]))),
                                  g[[20, 25, 29]])


# ---------------------------------------------------------------------------
# partition-merge differential parity
# ---------------------------------------------------------------------------

def test_single_partition_matches_single_solver():
    g = _pool(13, 300, 8)
    single = gm_lib.gradmatch(jnp.asarray(g), 20)
    for kind in ("hash", "contiguous"):
        res = part_lib.gradmatch_partitioned(g, 20, partitions=1, kind=kind)
        _check_result(res, 300)
        np.testing.assert_array_equal(np.sort(_selected(res)),
                                      np.sort(_selected(single)),
                                      err_msg=f"P=1 {kind} != single solver")


@pytest.mark.parametrize("partitions", [2, 4])
@pytest.mark.parametrize("kind", ["hash", "contiguous"])
def test_partitioned_objective_near_single_solver(partitions, kind):
    g = _pool(17, 400, 8)
    k = 24
    single = gm_lib.gradmatch(jnp.asarray(g), k)
    res = part_lib.gradmatch_partitioned(g, k, partitions=partitions,
                                         kind=kind)
    _check_result(res, 400)
    assert res.stats.num_parts == partitions
    assert res.stats.union_size >= res.stats.merged
    assert res.stats.merged == int(np.asarray(res.mask).sum())
    tnorm = float(jnp.sum(jnp.asarray(g).sum(axis=0) ** 2))
    gap = (float(res.err) - float(single.err)) / tnorm
    assert gap <= 0.05, (
        f"P={partitions} {kind}: objective gap {gap:.4f} vs single solver")


def test_class_partitioning_matches_gradmatch_per_class():
    g = _pool(19, 120, 8)
    labels = np.arange(120) % 4
    per_class = gm_lib.gradmatch_per_class(jnp.asarray(g),
                                           jnp.asarray(labels), 4, 20)
    res = part_lib.gradmatch_partitioned(g, 20, labels=labels, num_classes=4)
    assert res.stats.kind == "class"
    np.testing.assert_array_equal(np.sort(_selected(res)),
                                  np.sort(_selected(per_class)))


def test_explicit_target_and_valid():
    g = _pool(23, 200, 8)
    target = _pool(24, 1, 8)[0] * 5
    valid = np.ones(200, bool)
    valid[::7] = False
    res = part_lib.gradmatch_partitioned(g, 16, partitions=3, target=target,
                                         valid=valid)
    _check_result(res, 200)
    sel = _selected(res)
    assert valid[sel].all(), "selected a masked row"


def test_pmap_path_matches_vmap_path():
    g = _pool(29, 200, 8)
    a = part_lib.gradmatch_partitioned(g, 16, partitions=4, use_pmap=False)
    b = part_lib.gradmatch_partitioned(g, 16, partitions=4, use_pmap=True)
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))
    np.testing.assert_allclose(np.asarray(a.weights), np.asarray(b.weights),
                               rtol=1e-5, atol=1e-7)


def test_selection_dispatch_partitioned():
    g = _pool(31, 160, 8)
    labels = jnp.asarray(np.arange(160) % 4)
    key = jax.random.PRNGKey(0)
    # per-class route mirrors "gradmatch"'s per-class criteria
    res = sel_lib.select("gradmatch-partitioned", key, jnp.asarray(g), 16,
                         labels=labels, num_classes=4)
    assert res.stats.kind == "class"
    # explicit validation target switches to hashed partitions
    tgt = jnp.asarray(_pool(32, 1, 8)[0])
    res = sel_lib.select("gradmatch-partitioned", key, jnp.asarray(g), 16,
                         labels=labels, num_classes=4, val_target=tgt,
                         partitions=3)
    assert res.stats.kind == "hash" and res.stats.num_parts == 3
    _check_result(res, 160)


# ---------------------------------------------------------------------------
# out-of-core streaming path
# ---------------------------------------------------------------------------

def test_stream_matches_inmemory_contiguous():
    g = _pool(37, 500, 8)
    mem = part_lib.gradmatch_partitioned(g, 24, partitions=4,
                                         kind="contiguous")
    st = part_lib.gradmatch_partitioned_stream(pool=g, k=24, partitions=4,
                                               chunk_size=64)
    np.testing.assert_array_equal(np.asarray(st.indices),
                                  np.asarray(mem.indices))
    np.testing.assert_array_equal(np.asarray(st.mask), np.asarray(mem.mask))
    np.testing.assert_allclose(np.asarray(st.weights),
                               np.asarray(mem.weights), rtol=1e-5, atol=1e-7)
    assert st.stats.stream is not None
    assert st.stats.stream.pool_size == 500
    # Aggregated engine rounds across partitions place the whole budget.
    assert st.stats.stream.rounds == sum(st.stats.quotas) == 24
    assert st.stats.stream.chunks > 0


def test_stream_explicit_target_matches_inmemory():
    g = _pool(41, 300, 8)
    target = _pool(42, 1, 8)[0] * 3
    mem = part_lib.gradmatch_partitioned(g, 16, partitions=3,
                                         kind="contiguous", target=target)
    st = part_lib.gradmatch_partitioned_stream(pool=g, k=16, partitions=3,
                                               target=target, chunk_size=50)
    np.testing.assert_array_equal(np.asarray(st.indices),
                                  np.asarray(mem.indices))


def test_stream_factory_without_row_fetch():
    g = _pool(43, 260, 8)
    def factory():
        for i in range(0, 260, 64):
            c = g[i:i + 64]
            yield c, np.ones(c.shape[0], bool)
    with_fetch = part_lib.gradmatch_partitioned_stream(pool=g, k=16,
                                                       partitions=2)
    no_fetch = part_lib.gradmatch_partitioned_stream(pool_iter=factory, k=16,
                                                     partitions=2)
    # The union gather falls back to one loader scan; selection identical.
    np.testing.assert_array_equal(np.asarray(no_fetch.indices),
                                  np.asarray(with_fetch.indices))


# ---------------------------------------------------------------------------
# stats propagation
# ---------------------------------------------------------------------------

def test_expand_batch_selection_keeps_stats():
    sentinel = part_lib.PartitionStats(2, "hash", (2, 2), 4, 4)
    sel = SelectionResult(jnp.asarray([1, 0], jnp.int32),
                          jnp.asarray([0.5, 0.5], jnp.float32),
                          jnp.ones((2,), bool), jnp.float32(0.1), sentinel)
    ex = gm_lib.expand_batch_selection(sel, batch_size=4, n_examples=8)
    assert ex.stats is sentinel
    assert int(np.asarray(ex.mask).sum()) == 8


def test_expand_if_pb_keeps_stream_stats():
    g = _pool(47, 96, 8)
    sel = sel_lib.select("gradmatch-pb", jax.random.PRNGKey(0),
                         jnp.asarray(g), 32, batch_size=8)
    ex = sel_lib.expand_if_pb("gradmatch-pb", sel, 8, 96)
    assert ex.stats is sel.stats   # None in, None out — but not dropped


# ---------------------------------------------------------------------------
# craig-lazy-otf dispatch
# ---------------------------------------------------------------------------

def test_craig_lazy_otf_matches_craig_lazy():
    g = _pool(53, 96, 8)
    key = jax.random.PRNGKey(0)
    lazy = sel_lib.select("craig-lazy", key, jnp.asarray(g), 12)
    otf = sel_lib.select("craig-lazy-otf", key, jnp.asarray(g), 12)
    np.testing.assert_array_equal(np.asarray(otf.indices),
                                  np.asarray(lazy.indices))
    np.testing.assert_array_equal(np.asarray(otf.mask),
                                  np.asarray(lazy.mask))
