"""Optimizers + schedules (from-scratch implementations)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, apply_updates, cosine_annealing,
                         cosine_with_warmup, constant, global_norm, sgd)


def _quadratic(a=3.0):
    def loss(p):
        return jnp.sum((p["x"] - a) ** 2) + jnp.sum((p["y"] + 1.0) ** 2)
    return loss


def _run(opt, steps=200, dtype=jnp.float32):
    loss = _quadratic()
    params = {"x": jnp.zeros((4,), dtype), "y": jnp.ones((2,), dtype)}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        g = jax.grad(loss)(params)
        u, state = opt.update(g, state, params)
        return apply_updates(params, u), state

    for _ in range(steps):
        params, state = step(params, state)
    return params, float(loss(params))


def test_sgd_momentum_converges():
    _, l = _run(sgd(0.05, momentum=0.9))
    assert l < 1e-4


def test_sgd_plain_converges():
    _, l = _run(sgd(0.1))
    assert l < 1e-3


def test_nesterov_converges():
    _, l = _run(sgd(0.05, momentum=0.9, nesterov=True))
    assert l < 1e-4


def test_adamw_converges():
    _, l = _run(adamw(0.05, weight_decay=0.0))
    assert l < 1e-3


def test_bf16_params_f32_master():
    """bf16 params train with f32 momentum (mixed-precision master)."""
    opt = sgd(0.05, momentum=0.9)
    params, l = _run(opt, dtype=jnp.bfloat16)
    assert params["x"].dtype == jnp.bfloat16
    assert l < 0.05  # bf16 resolution-limited
    state = opt.init({"x": jnp.zeros((4,), jnp.bfloat16),
                      "y": jnp.zeros((2,), jnp.bfloat16)})
    assert state.slots["x"].dtype == jnp.float32


def test_weight_decay_shrinks():
    opt = sgd(0.1, weight_decay=0.5)
    p = {"w": jnp.ones((4,))}
    s = opt.init(p)
    g = {"w": jnp.zeros((4,))}
    u, s = opt.update(g, s, p)
    p2 = apply_updates(p, u)
    assert float(p2["w"][0]) < 1.0


def test_clip_norm():
    opt = sgd(1.0, clip_norm=1.0)
    p = {"w": jnp.zeros((4,))}
    s = opt.init(p)
    g = {"w": jnp.full((4,), 100.0)}
    u, _ = opt.update(g, s, p)
    np.testing.assert_allclose(global_norm(u), 1.0, rtol=1e-5)


def test_cosine_annealing_endpoints():
    f = cosine_annealing(0.01, 100)
    assert abs(float(f(jnp.int32(0))) - 0.01) < 1e-8
    assert float(f(jnp.int32(100))) < 1e-8
    assert 0 < float(f(jnp.int32(50))) < 0.01


def test_cosine_with_warmup():
    f = cosine_with_warmup(0.01, 10, 110, final_scale=0.1)
    assert float(f(jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(f(jnp.int32(10))), 0.01, rtol=1e-5)
    assert float(f(jnp.int32(110))) >= 0.00099


def test_step_counter_advances():
    opt = sgd(constant(0.1))
    p = {"w": jnp.zeros((2,))}
    s = opt.init(p)
    for i in range(3):
        _, s = opt.update({"w": jnp.ones((2,))}, s, p)
    assert int(s.step) == 3
