"""Overload-resilient serving under open-loop load (DESIGN.md §10).

Drives the ``SelectionService`` through seeded Poisson arrival traces on
a virtual clock (``repro.serve.loadgen``) and records the
``selection_serve_load`` table:

* **serve-load-sequential** — the naive baseline: ``max_batch=1``, no
  overload control, every request a full per-request solve.
* **serve-load** — the real service: micro-batching + brownout ladder
  (burst traffic lands at brownout level, so same-pool differing-k
  requests share one anytime session; indices stay bit-exact prefixes).
* **serve-load-speedup** — sustained req/s ratio of the two, with the
  p99-within-SLO qualifier.  Acceptance (full scale, pool 8192): >= 10x
  sustained throughput at p99 within the SLO (25x one sequential solve).
* **serve-load-chaos** — the same harness at ~1.5x the service's
  measured capacity with a fault-injected chunked pool and a mixed
  tenant/priority population.  Asserts the robustness claims outright:
  no queue wedge, no in-flight/budget leak (``LoadReport.violations``
  empty), every response labelled with its rung, interactive p99 within
  SLO, and every *certified* answer index-identical to the unloaded
  solve over the same pool.

Latency numbers are measured wall time per drain step folded into the
virtual clock — arrival schedules replay bit-identically across runs
while p50/p99/sustained-rps stay real.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import make_recorder

TABLE = "selection_serve_load"


def _mk_pool(seed, n, d):
    rng = np.random.default_rng(seed)
    return rng.standard_normal((n, d)).astype(np.float32)


def _fresh_service(clock, pool, ks, *, max_batch, overload,
                   max_queue, retry=None):
    from repro.serve import SelectionService

    svc = SelectionService(
        max_batch=max_batch, max_queue=max_queue,
        max_inflight_per_tenant=max_queue, clock=clock.now,
        retry_policy=retry, overload=overload,
        brownout_at=0.4, overload_at=0.85, recover_at=0.1)
    pid = svc.register_pool(pool, pool_id="bench-pool")
    # Warm the jit cache off the measured trace: one solve per distinct
    # k (sequential path), one 2-wide batch (batched path), and one
    # anytime session at k_max (the brownout share path).  The warmup
    # session is closed so the measured run really solves.
    for k in ks:
        svc.select(pid, k=k)
    if max_batch > 1:
        t1 = svc.submit(pid, k=ks[0])
        t2 = svc.submit(pid, k=ks[0])
        svc.drain()
        assert t1.status == t2.status == "done"
        sid, _ = svc.open_session(pid, k=max(ks))
        svc.close_session(sid)
    return svc, pid


def _run_trace(svc, pid, clock, *, requests, rate_rps, ks, seed,
               priorities=("interactive",), priority_weights=None,
               tenants=("default",), deadline_s=None, extra_pools=()):
    from repro.serve import LoadSpec, make_arrivals, run_load

    spec = LoadSpec(
        seed=seed, requests=requests, rate_rps=rate_rps,
        pools=(pid,) + tuple(extra_pools), ks=tuple(ks),
        tenants=tuple(tenants), priorities=tuple(priorities),
        priority_weights=priority_weights, deadline_s=deadline_s)
    return run_load(svc, make_arrivals(spec), clock)


def run_load_bench(pool_n=8192, d=512, ks=(32, 64), requests=64,
                   quick=False) -> list[dict]:
    """Headline rows: sequential baseline vs the overload-aware service
    on the identical burst trace."""
    from repro.serve import SimClock

    if quick:
        pool_n, d, ks, requests = 2048, 128, (12, 24), 24
    rows: list[dict] = []
    record = make_recorder(TABLE, rows)
    pool = _mk_pool(pool_n, pool_n, d)
    burst_rate = 1e6        # all arrivals land at once: pure overload

    clock = SimClock()
    svc_seq, pid = _fresh_service(clock, pool, ks, max_batch=1,
                                  overload=False, max_queue=2 * requests)
    seq = _run_trace(svc_seq, pid, clock, requests=requests,
                     rate_rps=burst_rate, ks=ks, seed=17)
    assert seq.violations == [], seq.violations
    assert seq.completed == requests, (seq.completed, seq.failed)

    clock = SimClock()
    svc, pid = _fresh_service(clock, pool, ks, max_batch=32,
                              overload=True, max_queue=2 * requests)
    loaded = _run_trace(svc, pid, clock, requests=requests,
                        rate_rps=burst_rate, ks=ks, seed=17)
    assert loaded.violations == [], loaded.violations
    assert loaded.completed == requests, (loaded.completed, loaded.failed)

    # SLO: a generous multiple of one sequential solve — the qualifier
    # that makes "sustained req/s" an honest number (throughput at
    # unbounded latency is free).
    per_req_seq = seq.duration_s / max(seq.completed, 1)
    slo_s = 25.0 * per_req_seq
    speedup = loaded.sustained_rps / max(seq.sustained_rps, 1e-9)

    record(strategy="serve-load-sequential", pool=pool_n, d=d,
           requests=requests, completed=seq.completed,
           sustained_rps=round(seq.sustained_rps, 2),
           p50_ms=round(seq.p50_ms, 2), p99_ms=round(seq.p99_ms, 2))
    record(strategy="serve-load", pool=pool_n, d=d, requests=requests,
           completed=loaded.completed,
           sustained_rps=round(loaded.sustained_rps, 2),
           p50_ms=round(loaded.p50_ms, 2), p99_ms=round(loaded.p99_ms, 2),
           certified=loaded.rungs.get("certified", 0),
           prefix_shared=loaded.rungs.get("prefix-shared", 0),
           shared_solves=svc.scheduler.stats()["shared_solves"])
    accept = {} if quick else {"acceptance": 10.0}
    record(strategy="serve-load-speedup", pool=pool_n, d=d,
           requests=requests, speedup=round(speedup, 2),
           slo_ms=round(slo_s * 1e3, 2),
           p99_within_slo=bool(loaded.p99_ms <= slo_s * 1e3), **accept)
    if not quick:
        assert loaded.p99_ms <= slo_s * 1e3, (loaded.p99_ms, slo_s)
    return rows


def run_chaos(pool_n=2048, d=128, chunk=256, ks=(16, 32), requests=36,
              transient_rate=0.15, quick=False) -> list[dict]:
    """Chaos row: ~1.5x measured capacity, fault-injected chunked pool,
    mixed tenants/priorities — the robustness acceptance claims."""
    import jax.numpy as jnp

    from repro.core import streaming as stream_lib
    from repro.core.omp import omp_select
    from repro.data.loader import ChunkedPool
    from repro.resilience import (FaultPlan, FaultyChunkIterator,
                                  RetryPolicy)
    from repro.serve import SimClock

    if quick:
        pool_n, ks, requests = 1024, (8, 16), 16
    rows: list[dict] = []
    record = make_recorder(TABLE, rows)
    pool = _mk_pool(pool_n + 1, pool_n, d)
    g_ch = _mk_pool(pool_n + 2, pool_n, d)
    # Generous budget: at 15% per chunk read a clean 8-chunk pass is only
    # ~27% likely, so ~5 restarts are *expected* — the budget bounds the
    # tail, not the mean.
    retry = RetryPolicy(max_retries=25, backoff_s=0.0,
                        sleep=lambda s: None)

    def build():
        clock = SimClock()
        svc, pid = _fresh_service(clock, pool, ks, max_batch=16,
                                  overload=True, max_queue=32,
                                  retry=retry)
        faulty = FaultyChunkIterator(
            stream_lib.chunked_pool_iter(ChunkedPool(g_ch, chunk_size=chunk)),
            FaultPlan(transient_rate=transient_rate, seed=5))
        pid_ch = svc.register_chunked_pool(faulty, pool_id="chaos-chunked")
        for k in ks:                         # jit warm for the stream path
            svc.select(pid_ch, k=k)
        return clock, svc, pid, pid_ch

    # Calibrate capacity on a clean burst, then rerun fresh at 1.5x.
    clock, svc, pid, pid_ch = build()
    cal = _run_trace(svc, pid, clock, requests=max(requests // 2, 8),
                     rate_rps=1e6, ks=ks, seed=23,
                     extra_pools=(pid_ch,))
    capacity = max(cal.sustained_rps, 1e-3)
    per_req = 1.0 / capacity
    slo_s = 60.0 * per_req

    clock, svc, pid, pid_ch = build()
    rep = _run_trace(
        svc, pid, clock, requests=requests, rate_rps=1.5 * capacity,
        ks=ks, seed=29, extra_pools=(pid_ch,),
        tenants=("team-a", "team-b"),
        priorities=("interactive", "batch", "best-effort"),
        priority_weights=(5, 3, 2),
        deadline_s={"interactive": slo_s})

    # The acceptance claims, asserted outright:
    assert rep.violations == [], rep.violations          # no wedge/leaks
    assert svc.scheduler.pending() == 0
    assert rep.completed > 0
    for r in rep.records:                                # all labelled
        t = r["ticket"]
        assert t.status in ("done", "failed", "shed"), t.status
        if t.status != "done":
            assert t.degradation in ("shed", "timeout", "failed"), \
                (t.status, t.degradation)
    itv_p99 = rep.class_p99_ms.get("interactive", 0.0)
    # Deadline admission enforces the SLO (expired work is timed out,
    # labelled, refunded); a request may still *start* just under its
    # deadline and finish after, so the latency bound allows that one
    # in-flight solve on top of the SLO itself.
    assert itv_p99 <= (slo_s + 2 * per_req) * 1e3, (itv_p99, slo_s * 1e3)
    # Certified answers under chaos == the unloaded solve, bit-exact.
    refs = {}
    gj, gcj = jnp.asarray(pool), jnp.asarray(g_ch)
    for k in ks:
        refs[(pid, k)] = np.asarray(
            omp_select(gj, jnp.sum(gj, axis=0), k)[0])
        refs[(pid_ch, k)] = np.asarray(
            omp_select(gcj, jnp.sum(gcj, axis=0), k)[0])
    certified_checked = 0
    for r in rep.records:
        t = r["ticket"]
        if t.status == "done" and t.degradation == "certified":
            np.testing.assert_array_equal(
                np.asarray(t.result.indices),
                refs[(t.request.pool_id, t.request.k)])
            certified_checked += 1

    record(strategy="serve-load-chaos", pool=pool_n, d=d,
           requests=requests, rate_x_capacity=1.5,
           transient_rate=transient_rate,
           completed=rep.completed, shed=rep.shed, failed=rep.failed,
           timeouts=rep.timeouts, rejected=rep.rejected,
           interactive_p99_ms=round(itv_p99, 2),
           slo_ms=round(slo_s * 1e3, 2),
           certified_checked=certified_checked,
           fairness_ratio=(None if rep.fairness_ratio is None
                           else round(rep.fairness_ratio, 3)),
           violations=len(rep.violations))
    return rows


def main(quick=False) -> list[dict]:
    rows = run_load_bench(quick=quick)
    rows += run_chaos(quick=quick)
    return rows


if __name__ == "__main__":
    import sys

    from benchmarks.common import persist

    out = main(quick="--quick" in sys.argv)
    persist("selection", out)
