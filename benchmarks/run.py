"""Benchmark orchestrator: one section per paper table/figure.

``python -m benchmarks.run``           — full pass (~20-30 min on CPU)
``python -m benchmarks.run --quick``   — reduced grid (~5 min)
``python -m benchmarks.run --only tradeoff,kernels``

Emits ``table,key=value,...`` CSV lines (tee-able) and finishes with a
paper-claims check summary.  The ``kernels`` and ``selection`` sections
additionally persist their result rows to ``BENCH_kernels.json`` /
``BENCH_selection.json`` at the repo root so the perf trajectory is
tracked across PRs.
"""

from __future__ import annotations

import argparse
import time
import traceback

from benchmarks.common import persist

SECTIONS = ("kernels", "grad_error", "selection", "serve_load",
            "tradeoff", "redundant", "ablations", "roofline")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)
    only = set(filter(None, args.only.split(",")))
    failures = []

    def section(name, fn, persist_as=None):
        if only and name not in only:
            return
        print(f"\n### bench:{name}", flush=True)
        t0 = time.perf_counter()
        try:
            rows = fn()
            if persist_as and rows:
                path = persist(persist_as, rows)
                print(f"### bench:{name} -> {path}", flush=True)
            print(f"### bench:{name} done in "
                  f"{time.perf_counter() - t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)

    from benchmarks import (bench_ablations, bench_grad_error,
                            bench_kernels, bench_redundant,
                            bench_selection, bench_serve_load,
                            bench_tradeoff, roofline)

    section("kernels", lambda: bench_kernels.main(quick=args.quick),
            persist_as="kernels")
    section("grad_error", lambda: bench_grad_error.main(quick=args.quick))
    section("selection", lambda: bench_selection.main(quick=args.quick),
            persist_as="selection")
    section("serve_load", lambda: bench_serve_load.main(quick=args.quick),
            persist_as="selection")
    section("tradeoff", lambda: bench_tradeoff.main(quick=args.quick))
    section("redundant", lambda: bench_redundant.main(quick=args.quick))
    section("ablations", lambda: bench_ablations.main(quick=args.quick))
    section("roofline", lambda: roofline.main([]))

    print(f"\nbench summary: {'FAILURES: ' + str(failures) if failures else 'all sections ok'}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
