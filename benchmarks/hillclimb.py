"""§Perf hillclimb probes: hypothesis -> change -> re-lower -> re-analyse.

Each probe compiles ONE production-module variant of a chosen cell and
reports (flops, weighted collective bytes, memory) so the roofline terms
before/after a change are directly comparable.  Changes are expressed as
config/sharding overrides — model code is untouched; everything goes
through the hint tables and builder arguments, which is the point of the
hint system.

Run:
  PYTHONPATH=src python -m benchmarks.hillclimb --cell gemma2b_train \
      --variant baseline|no_fsdp|...
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import time

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed import hints
from repro.distributed.sharding import logical_rules, param_shardings
from repro.launch import dryrun
from repro.launch.hlo_analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                       analytic_hbm_bytes)
from repro.launch.mesh import make_production_mesh


def _measure(cfg, shape, mesh, microbatches, rules=None, fsdp=True,
             attn_dp=False, batch_overrides=None):
    """Compile the production module with overrides; return terms."""
    import repro.distributed.sharding as sh_mod
    orig_build_train = dryrun.build_train
    orig_rules = None
    orig_param_rule = sh_mod._param_rule
    if attn_dp:
        # attention weights replicated (data-parallel attention): the
        # arch's q/kv head counts don't divide the model axis, so TP
        # attention reshards activations wholesale; attention params are
        # tiny next to FFN, so replicating them removes the resharding
        # at negligible memory cost.
        def patched_param_rule(path, ndim, fsdp_arg):
            if any(k in path for k in ("wq", "wk", "wv", "wo", "bq",
                                       "bk", "bv", "bo")):
                return (P(*("data", None)[:ndim]) if fsdp_arg else P())
            return orig_param_rule(path, ndim, fsdp_arg)
        sh_mod._param_rule = patched_param_rule
    if rules is not None:
        import repro.distributed.sharding as sh_mod
        orig_rules = sh_mod.logical_rules

        def patched_rules(mesh):
            table = orig_rules(mesh)
            table.update(rules(mesh))
            return table
        sh_mod.logical_rules = patched_rules
        dryrun.logical_rules = patched_rules

    def patched_build_train(cfg, shape, mesh, mb, fsdp_arg=True):
        return orig_build_train(cfg, shape, mesh, mb, fsdp=fsdp)

    dryrun.build_train = patched_build_train
    try:
        out = dryrun._compile_cell(cfg, shape, mesh, microbatches)
    finally:
        dryrun.build_train = orig_build_train
        sh_mod._param_rule = orig_param_rule
        if orig_rules is not None:
            sh_mod.logical_rules = orig_rules
            dryrun.logical_rules = orig_rules
    ma = out["compiled"].memory_analysis()
    coll = out["coll_weighted"]
    return {
        "compile_s": round(out["compile_s"], 1),
        "coll_gib": round(coll.total_bytes / 2**30, 2),
        "t_coll_s": round(coll.total_bytes / ICI_BW, 4),
        "coll_counts": dict(coll.counts),
        "peak_gib": round((max(ma.argument_size_in_bytes,
                               ma.output_size_in_bytes)
                           + ma.temp_size_in_bytes
                           - ma.alias_size_in_bytes) / 2**30, 2),
    }


def probe(cell: str, variant: str) -> dict:
    mesh = make_production_mesh()
    if cell == "gemma2b_train":
        cfg, shape, mb = get_config("gemma-2b"), SHAPES["train_4k"], 8
        if variant == "baseline":
            r = _measure(cfg, shape, mesh, mb)
        elif variant == "no_fsdp":
            # H1: FSDP re-gathers (2 x mb x params) dominate; a 2.6B model
            # fits TP16 replicated-over-data -> collectives collapse to
            # one grad all-reduce.
            r = _measure(cfg, shape, mesh, mb, fsdp=False)
        elif variant == "no_fsdp_mb1":
            # H2: with DP weights, microbatching no longer buys collective
            # savings; mb=1 removes the accumulation loop entirely.
            r = _measure(cfg, shape, mesh, 1, fsdp=False)
        elif variant == "attn_dp":
            # H4 (H1-H3 refuted): the traffic is attention-weight-TP vs
            # unshardeable heads (8 q / 1 kv on a 16-way axis) — GSPMD
            # reshards the (B,S,d) stream around every attention matmul.
            # Replicate attention weights (19 MB/layer), keep Megatron
            # TP for the FFN (d_ff=16384 shards cleanly).
            r = _measure(cfg, shape, mesh, mb, attn_dp=True)
        elif variant == "attn_dp_mb2":
            # H4 follow-up: with attention resharding gone, the residual
            # 35 GiB is dominated by FSDP weight re-gathers (scale with
            # microbatch count); mb=2 cuts them 4x.
            r = _measure(cfg, shape, mesh, 2, attn_dp=True)
        elif variant == "seqpar_mb2":
            # H3 (after H1/H2 refuted): the traffic is attention-layout
            # activation resharding — 8 q heads / 1 kv head cannot shard
            # on a 16-way model axis, so GSPMD reshards the (B,S,d)
            # stream around every attention op.  Sequence-parallel
            # residual (S over 'model') keeps activations sharded
            # through attention AND FFN (per-token ops); MQA KV gathers
            # are tiny.  mb=2 for activation memory.
            def rules(mesh):
                return {"residual": P("data", "model", None)}
            r = _measure(cfg, shape, mesh, 2, rules=rules)
        else:
            raise SystemExit(f"unknown variant {variant}")
    elif cell == "gemma2b_prefill":
        cfg, shape, mb = get_config("gemma-2b"), SHAPES["prefill_32k"], 1
        if variant == "baseline":
            r = _measure(cfg, shape, mesh, mb)
        elif variant == "seqpar":
            # H: MQA (kv=1) can't head-shard on a 16-way model axis; the
            # baseline reshards activations wholesale.  Sequence-parallel
            # residual stream (S over 'model') + per-layer KV all-gather
            # is cheap BECAUSE MQA KV is tiny.
            def rules(mesh):
                return {"residual": P("data", "model", None)}
            r = _measure(cfg, shape, mesh, mb, rules=rules)
        elif variant == "attn_dp":
            # same H4 as the train cell: replicated attention weights
            r = _measure(cfg, shape, mesh, mb, attn_dp=True)
        elif variant == "kv_hoist":
            # H7: the baseline's 36864 all-gathers are the hd-sharded MQA
            # KV being gathered per flash tile pair; pin K/V replicated
            # ONCE per layer before the tile loops (MQA KV is 34 MB/chip)
            # via the 'kv_full' hint.
            def rules(mesh):
                return {"kv_full": P("data", None, None, None)}
            r = _measure(cfg, shape, mesh, mb, rules=rules)
        elif variant == "qkv_hoist":
            # H8: kv_hoist killed the gathers but left 18432 per-tile
            # score all-reduces — the q head_dim is TP-sharded, so every
            # tile einsum is a sharded contraction.  Gathering Q once per
            # layer (1 GB/chip) is 16x cheaper than 1024 x 8.9 MB ARs.
            def rules(mesh):
                return {"kv_full": P("data", None, None, None),
                        "q_full": P("data", None, None, None)}
            r = _measure(cfg, shape, mesh, mb, rules=rules)
        elif variant == "all_dp":
            # H6 (H4 refuted at prefill: per-flash-tile all-reduces from
            # the hd-sharded MQA KV remained): serving a 2.6B model needs
            # no TP at all — replicate the whole trunk (5.3 GB params),
            # keep only the 256k-vocab embedding/head vocab-parallel.
            # Prefill has no gradient reduction, so DP-everything costs
            # only the CE logit reductions.
            import repro.distributed.sharding as sh_mod
            orig = sh_mod._param_rule

            def rules_all_dp(path, ndim, fsdp_arg):
                if "embed" in path or "lm_head" in path or (
                        "unit_head" in path) or "router" in path:
                    return orig(path, ndim, False)
                return P()
            sh_mod._param_rule = rules_all_dp
            try:
                r = _measure(cfg, shape, mesh, mb)
            finally:
                sh_mod._param_rule = orig
        else:
            raise SystemExit(f"unknown variant {variant}")
    elif cell == "qwen3_train":
        cfg, shape = get_config("qwen3-moe-30b-a3b"), SHAPES["train_4k"]
        if variant == "baseline":
            r = _measure(cfg, shape, mesh, 8)
        elif variant == "mb2":
            # H: FSDP re-gathers scale with microbatch count; the MoE fits
            # mb=2 activations.
            r = _measure(cfg, shape, mesh, 2)
        elif variant == "mb4":
            r = _measure(cfg, shape, mesh, 4)
        elif variant == "attn_dp_mb8":
            # qwen3 has 32 q heads (shards 16-way) but only 4 KV heads:
            # the GQA KV falls back to head_dim sharding and reshards —
            # same family of pathology as gemma-2b; attention weights are
            # ~0.6% of a 30B MoE, replicate them.
            r = _measure(cfg, shape, mesh, 8, attn_dp=True)
        else:
            raise SystemExit(f"unknown variant {variant}")
    elif cell == "xlstm_train":
        cfg, shape = get_config("xlstm-1.3b"), SHAPES["train_4k"]
        if variant == "baseline":
            r = _measure(cfg, shape, mesh, 8)
        elif variant == "state_pin":
            # H11: the worst roofline cell (frac 0.01, t_coll 72 s) — the
            # mLSTM per-chunk state tensors (B,NC,H,dk,dv) are resharded
            # between the parallel-summary, cross-chunk-scan and combine
            # phases.  Pin their layout to batch-sharded-only via the
            # 'mlstm_chunk_state' hint.
            def rules(mesh):
                return {"mlstm_chunk_state": P("data")}
            r = _measure(cfg, shape, mesh, 8, rules=rules)
        elif variant == "qk_hoist":
            # H12 (H11 refuted — the 206k all-reduces are per-chunk score
            # einsums contracting the TP-sharded dk): gather q/k once per
            # layer via 'mlstm_qk' (33 MB/chip) — the mLSTM analogue of
            # §Perf cell 2's q_full fix; v stays dv-sharded 16-way.
            def rules(mesh):
                return {"mlstm_qk": P("data", None, None, None)}
            r = _measure(cfg, shape, mesh, 8, rules=rules)
        else:
            raise SystemExit(f"unknown variant {variant}")
    else:
        raise SystemExit(f"unknown cell {cell}")
    r.update({"cell": cell, "variant": variant})
    return r


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--out")
    args = ap.parse_args(argv)
    r = probe(args.cell, args.variant)
    print(json.dumps(r))
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
