"""Shared benchmark plumbing: timing, CSV emission, JSON persistence,
standard dataset."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable

import jax

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def _row_table(row: dict) -> str | None:
    """The table a result row belongs to.

    New rows carry it explicitly (``make_recorder`` tags them); rows from
    files committed before the tag existed are inferred from their field
    signature so a merge never mistakes one section for another.
    """
    if "table" in row:
        return row["table"]
    strategy = str(row.get("strategy", ""))
    if strategy.startswith("gradmatch-stream"):
        return "selection_stream"
    if any(key in row for key in ("rescans", "sample", "on_the_fly")):
        return "selection_greedy"
    if "strategy" in row:
        return "selection_time"
    return "kernel"


def persist(name: str, rows: list[dict]) -> pathlib.Path:
    """Merge one run's result rows into ``BENCH_<name>.json`` by table.

    Rows are grouped by their recorder table (``selection_time``,
    ``selection_stream``, ...).  Tables present in this run **replace**
    their previous rows; tables absent keep the committed ones — so a
    partial run (``--quick``, ``--only selection``, or a single section
    crashing) no longer wipes the unrelated sections the parity gate
    reads its baselines from.  The file stays a flat ``rows`` list
    (sorted by table) for existing consumers; ``table_timestamps``
    records when each section was last refreshed."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    now = time.strftime("%Y-%m-%dT%H:%M:%S")
    new_tables = {_row_table(r) for r in rows}
    kept: list[dict] = []
    table_stamps: dict[str, str] = {}
    if path.exists():
        try:
            old = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            old = {}
        table_stamps = dict(old.get("table_timestamps", {}))
        kept = [r for r in old.get("rows", [])
                if _row_table(r) not in new_tables]
    merged = kept + rows
    merged.sort(key=lambda r: str(_row_table(r)))
    for t in new_tables:
        table_stamps[str(t)] = now
    payload = {
        "timestamp": now,
        "backend": jax.default_backend(),
        "table_timestamps": table_stamps,
        "rows": merged,
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


def make_recorder(table: str, rows: list[dict]) -> Callable:
    """emit() + collect into ``rows`` (the list persist() later writes).

    Each row is tagged with its ``table`` so ``persist`` can merge runs
    section-wise instead of overwriting the whole file."""
    def record(**fields):
        emit(table, **fields)
        rows.append(dict(fields, table=table))
    return record


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(table: str, **fields) -> None:
    """One CSV-ish line per result: ``table,key=value,...``."""
    print(f"{table}," + ",".join(f"{k}={v}" for k, v in fields.items()),
          flush=True)


def paper_dataset(n: int = 2048, dim: int = 32, num_classes: int = 10,
                  seed: int = 0):
    from repro.data.synthetic import make_classification, split
    ds = make_classification(jax.random.PRNGKey(seed), n=n, dim=dim,
                             num_classes=num_classes, sep=5.0)
    return split(ds, jax.random.PRNGKey(seed + 1))
