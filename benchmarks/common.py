"""Shared benchmark plumbing: timing, CSV emission, JSON persistence,
standard dataset."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Callable

import jax

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def persist(name: str, rows: list[dict]) -> pathlib.Path:
    """Write one section's result rows to ``BENCH_<name>.json`` at the repo
    root.  The file is overwritten per run and committed, so the perf
    trajectory across PRs lives in its git history (diff-able per PR)."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    payload = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "backend": jax.default_backend(),
        "rows": rows,
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


def make_recorder(table: str, rows: list[dict]) -> Callable:
    """emit() + collect into ``rows`` (the list persist() later writes)."""
    def record(**fields):
        emit(table, **fields)
        rows.append(fields)
    return record


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall seconds per call (after jit warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(table: str, **fields) -> None:
    """One CSV-ish line per result: ``table,key=value,...``."""
    print(f"{table}," + ",".join(f"{k}={v}" for k, v in fields.items()),
          flush=True)


def paper_dataset(n: int = 2048, dim: int = 32, num_classes: int = 10,
                  seed: int = 0):
    from repro.data.synthetic import make_classification, split
    ds = make_classification(jax.random.PRNGKey(seed), n=n, dim=dim,
                             num_classes=num_classes, sep=5.0)
    return split(ds, jax.random.PRNGKey(seed + 1))
