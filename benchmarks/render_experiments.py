"""Regenerate the generated sections of EXPERIMENTS.md from artifacts.

Replaces the <!-- DRYRUN_TABLE -->, <!-- ROOFLINE_TABLE --> and
<!-- PERF_LOG --> markers with rendered tables.  Idempotent: markers are
kept as section delimiters.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks import roofline as rl

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
PERF_DIR = os.path.join(ROOT, "benchmarks", "artifacts", "perf")


def dryrun_table() -> str:
    lines = ["| arch | shape | mesh | compiled | peak GiB (measured¹ / "
             "analytic) | collective GiB/step | compile s |",
             "|---|---|---|---|---|---|---|"]
    for mesh in ("16x16", "2x16x16"):
        for r in rl.load(mesh):
            if not r.get("ok"):
                lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                             f"**FAIL** | | | |")
                continue
            ag = r.get("analytic_gib")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} | ok | "
                f"{r['peak_gib']:.1f} / "
                f"{ag if ag is not None else '-'} | "
                f"{r['collective_gib']:.2f} | {r['compile_s']:.0f} |")
    lines.append("")
    lines.append("¹ CPU-measured peaks include f32 upcasts of bf16 dot "
                 "operands and ignore donation aliasing — artifacts of "
                 "the CPU backend, absent on TPU (see Methodology); the "
                 "analytic column is the TPU-true accounting.")
    return "\n".join(lines)


def roofline_table() -> str:
    rows = rl.load("16x16")
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_mem-unfused (s) |"
           " t_coll (s) | dominant | roofline frac | useful FLOP ratio |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAILED ||||||||")
            continue
        ur = (f"{r['useful_ratio']:.2f}"
              if r.get("useful_ratio") is not None else "-")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_memory_unfused_s']:.3f} | "
            f"{r['t_collective_s']:.4f} | {r['dominant']} | "
            f"{rl.fraction_of_roofline(r):.2f} | {ur} |")
    return "\n".join(out)


def perf_log() -> str:
    out = []
    for path in sorted(glob.glob(os.path.join(PERF_DIR, "*.jsonl"))):
        cell = os.path.basename(path)[:-6]
        out.append(f"\n**{cell}** (probes, chronological):\n")
        out.append("| variant | collective GiB/step | t_coll (s) | "
                   "peak GiB | compile s |")
        out.append("|---|---|---|---|---|")
        for line in open(path):
            r = json.loads(line)
            out.append(f"| {r['variant']} | {r['coll_gib']} | "
                       f"{r['t_coll_s']} | {r['peak_gib']} | "
                       f"{r['compile_s']} |")
    return "\n".join(out)


def _replace(text: str, name: str, content: str) -> str:
    """Idempotent: rendered content lives between begin/end markers."""
    begin = f"<!-- {name} -->"
    end = f"<!-- /{name} -->"
    block = begin + "\n\n" + content + "\n\n" + end
    if end in text:
        import re as _re
        return _re.sub(_re.escape(begin) + ".*?" + _re.escape(end), block,
                       text, count=1, flags=_re.DOTALL)
    return text.replace(begin, block, 1)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    text = _replace(text, "DRYRUN_TABLE", dryrun_table())
    text = _replace(text, "ROOFLINE_TABLE", roofline_table())
    text = _replace(text, "PERF_LOG", perf_log())
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md regenerated")


if __name__ == "__main__":
    main()
