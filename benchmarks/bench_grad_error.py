"""Paper Table 9: gradient-matching error by strategy and budget.

Err(w, X) = || sum_i w_i g_i - sum_j g_j || on held-out proxy matrices,
normalized by ||target||.  The paper's ordering (GRAD-MATCH(PB) < CRAIG(PB)
<< RANDOM, GLISTER large at small budgets) is asserted by benchmarks.run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, paper_dataset
from repro.configs.paper import mlp
from repro.core import selection as sel_lib
from repro.core.gradmatch import SelectionResult
from repro.models.classifier import init_classifier
from repro.train.steps import make_proxy_fn


def _err(proxies, target, sel: SelectionResult) -> float:
    """Relative matching error at the OPTIMAL scalar rescale.

    Selection weights are normalized to sum 1 (training re-normalizes
    every mini-batch, so only the weight *direction* matters); comparing
    strategies at their best scalar multiple s* = <approx,target>/|approx|^2
    is both fair and exactly what the training dynamics see.
    """
    import numpy as np
    m = np.asarray(sel.mask)
    idx = np.asarray(sel.indices)[m]
    w = np.asarray(sel.weights)[m]
    approx = jnp.asarray((w[:, None] * np.asarray(proxies)[idx]).sum(0))
    denom = jnp.maximum(jnp.sum(approx * approx), 1e-12)
    s = jnp.sum(approx * target) / denom
    return float(jnp.linalg.norm(s * approx - target)
                 / jnp.maximum(jnp.linalg.norm(target), 1e-9))


def run(budgets=(0.05, 0.1, 0.3), quick=False) -> list[dict]:
    if quick:
        budgets = (0.1,)
    train, _ = paper_dataset(n=1024)
    model = mlp(in_dim=32, num_classes=10)
    params = init_classifier(model, jax.random.PRNGKey(3))
    _, bias = make_proxy_fn(model)(params, train.x, train.y)
    target = jnp.sum(bias, axis=0)
    n = train.n
    rows = []
    for budget in budgets:
        k = int(n * budget)
        for strategy in ("random", "glister", "craig", "craig-pb",
                         "gradmatch", "gradmatch-pb"):
            sel = sel_lib.select(strategy, jax.random.PRNGKey(0), bias, k,
                                 labels=train.y, num_classes=10,
                                 batch_size=32, per_class=False)
            sel = sel_lib.expand_if_pb(strategy, sel, 32, n)
            e = _err(bias, target, sel)
            row = dict(strategy=strategy, budget=budget,
                       rel_grad_err=round(e, 4))
            emit("grad_error", **row)
            rows.append(row)
    return rows


def main(quick=False):
    run(quick=quick)


if __name__ == "__main__":
    main()
