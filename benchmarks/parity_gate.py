"""CI parity gate (run after the differential tests, see ci.yml).

Checks, all against artifacts committed in the repo:

1. **Streaming-vs-dense smoke at pool = 16384**: the streaming block-OMP
   must select the identical subset as the dense oracle on a pool larger
   than any unit-test shape (chunked 4096 at a 512-slot buffer, so the
   multi-pass path is really exercised).
1b. **Streaming-overhead gate at pool = 8192** (PR 5): the multi-round
   engine must run within 5x of the in-memory incremental solver with
   loader passes <= k/8 + 2, and its pass count must not regress
   against the committed ``BENCH_selection.json`` row.
2. **OMP perf regression**: re-times the incremental solver at the
   committed ``BENCH_selection.json`` headline shape and fails if its
   slowdown relative to the *dense* solver (timed in the same run, on the
   same machine) regresses by more than 2x against the committed
   baseline's incremental/dense ratio.  Normalizing by the dense solver
   makes the gate machine-independent — CI runners are slower than the
   machine the baseline was committed from, but both solvers slow down
   together (a true regression to the dense path moves the ratio 15-30x).
3. **Lazy-greedy-vs-dense smoke at pool = 4096**: the certified lazy
   CRAIG tier (core/greedy.py, DESIGN.md §5) must select the identical
   subset as the dense greedy oracle beyond unit-test shapes.
4. **Greedy perf regression**: same machine-independent >2x ratio rule as
   (2), applied to the craig-lazy/craig time pair at the largest
   committed pool whose dense greedy is still CI-affordable.
5. **Fault recovery** (DESIGN.md §8): under seeded transient faults at
   15% the streaming solve must stay bit-identical to fault-free within
   1.5x its wall-clock, and a solve killed mid-stream must resume from
   its checkpoint to the same selection.
6. **Partition-and-merge** (DESIGN.md §9): P = 1 partitioned selection
   must be set-identical to the single solver, the class kind
   set-identical to ``gradmatch_per_class`` (whose budget split must
   place exactly ``min(k, n_valid)`` rows), hashed P = 4 within an
   objective tolerance of the single solver, and the streaming solve
   must scale near-linearly in P (t(P=4) <= 0.8 t(P=1), interleaved
   min-of-3).
7. **Serve under load** (DESIGN.md §10): a fixed-seed open-loop burst
   must drain through the overload-aware service at >= 5x the
   sequential baseline's sustained req/s with p99 within the SLO, both
   runs clean on the shed-accounting invariants; an undersized service
   must shed best-effort traffic — labelled, never charged.
8. **Artifact fast path** (DESIGN.md §12): at pool = 8192 / k = 512, a
   precomputed trajectory served through the scheduler must be
   bit-identical to the live anytime session engine at 3 budgets,
   objective-equal (1%) to the live certified batched path, and answer
   >= 20x faster than the live submit+drain — with the shed-style
   accounting invariant intact.

Exit code 0 = gate passed.  ``python -m benchmarks.parity_gate``
"""

from __future__ import annotations

import json
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import REPO_ROOT, time_fn

REGRESSION_FACTOR = 2.0


def check_streaming_parity(n=16384, d=64, k=128) -> bool:
    from repro.core import streaming as stream_lib
    from repro.core.omp import omp_select, omp_select_dense

    g = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (n, d)),
                   np.float32)
    target = jnp.sum(jnp.asarray(g), axis=0)
    dense = omp_select_dense(jnp.asarray(g), target, k=k)
    inc = omp_select(jnp.asarray(g), target, k=k)
    out = stream_lib.omp_select_streaming(
        stream_lib.array_chunks(g, 4096), target, k, buffer_size=512,
        row_fetch=stream_lib.array_row_fetch(g))
    ok = True
    for name, got in (("incremental", inc),
                      ("streaming", (out.indices, out.weights, out.mask,
                                     out.err))):
        same_idx = np.array_equal(np.asarray(got[0]), np.asarray(dense[0]))
        same_mask = np.array_equal(np.asarray(got[2]), np.asarray(dense[2]))
        w_ok = np.allclose(np.asarray(got[1]), np.asarray(dense[1]),
                           rtol=1e-4, atol=1e-5)
        print(f"parity_gate,check={name}-vs-dense,pool={n},k={k},"
              f"indices={same_idx},mask={same_mask},weights={w_ok}",
              flush=True)
        ok &= same_idx and same_mask and w_ok
    print(f"parity_gate,check=stream-passes,passes={out.stats.passes},"
          f"certified={out.stats.certified_rounds}", flush=True)
    return ok


def check_streaming_overhead(n=8192, d=64, k=512, chunk=4096,
                             buffer_size=512) -> bool:
    """PR-5 gate: the multi-round streaming engine (compressed cache +
    certified buffer rounds, DESIGN.md §7) must run within 5x of the
    in-memory incremental solver at the bench shape with its loader pass
    count amortized to <= k/8 + 2 — versus one pass per round (~k)
    before the rebuild.  Also fails on a pass-count regression against
    the committed ``BENCH_selection.json`` row (median-of-3 timings keep
    the ratio robust to CI load spikes)."""
    from repro.core import streaming as stream_lib
    from repro.core.omp import omp_select

    g = np.asarray(jax.random.normal(jax.random.PRNGKey(n), (n, d)),
                   np.float32)
    target = jnp.sum(jnp.asarray(g), axis=0)
    chunks = stream_lib.array_chunks(g, chunk)
    fetch = stream_lib.array_row_fetch(g)

    def stream_once():
        out = stream_lib.omp_select_streaming(
            chunks, target, k, buffer_size=buffer_size, row_fetch=fetch)
        jax.block_until_ready(out.weights)
        return out

    def inmem():
        return omp_select(jnp.asarray(g), target, k=k)[1]

    out = stream_once()                          # warm + stats
    jax.block_until_ready(inmem())               # warm
    # Interleaved min-of-5: CI runners see load spikes lasting seconds,
    # which a sequential median cannot cancel — pairing the two solvers
    # back-to-back and taking each side's fastest observation does.
    import time as _time
    ts, ti = [], []
    for _ in range(5):
        t0 = _time.perf_counter()
        jax.block_until_ready(stream_once().weights)
        ts.append(_time.perf_counter() - t0)
        t0 = _time.perf_counter()
        jax.block_until_ready(inmem())
        ti.append(_time.perf_counter() - t0)
    t_stream, t_inmem = min(ts), min(ti)
    ratio = t_stream / max(t_inmem, 1e-9)
    budget = k // 8 + 2
    s = out.stats
    ok = ratio <= 5.0 and s.passes <= budget
    base = None
    path = REPO_ROOT / "BENCH_selection.json"
    if path.exists():
        for r in json.loads(path.read_text())["rows"]:
            if (r.get("strategy") == "gradmatch-stream"
                    and r.get("pool") == n and "passes" in r):
                base = r["passes"]
    pass_ok = True
    if base is not None:
        pass_ok = s.passes <= max(2 * base, base + 2)
        ok &= pass_ok
    print(f"parity_gate,check=stream-overhead,pool={n},k={k},"
          f"stream_ms={t_stream * 1e3:.2f},inmem_ms={t_inmem * 1e3:.2f},"
          f"ratio={ratio:.2f},limit=5.0,passes={s.passes},"
          f"pass_budget={budget},baseline_passes={base},"
          f"pass_ok={pass_ok},certified={s.certified_rounds},"
          f"refills={s.refills},repairs={s.repairs},"
          f"cache_hit_rate={s.cache_hit_rate:.2f},ok={ok}", flush=True)
    return ok


def check_incremental_regression() -> bool:
    from repro.core import selection as sel_lib

    path = REPO_ROOT / "BENCH_selection.json"
    if not path.exists():
        print("parity_gate,check=regression,skipped=no-baseline", flush=True)
        return True
    rows = json.loads(path.read_text())["rows"]
    by_pool = {}
    for r in rows:
        if "ms" in r and r.get("strategy") in ("gradmatch",
                                               "gradmatch-dense"):
            by_pool.setdefault(r["pool"], {})[r["strategy"]] = r
    pools = [p for p, d in by_pool.items() if len(d) == 2]
    if not pools:
        print("parity_gate,check=regression,skipped=no-baseline-pair",
              flush=True)
        return True
    n = max(pools)
    inc_row, dense_row = by_pool[n]["gradmatch"], by_pool[n]["gradmatch-dense"]
    k = inc_row["k"]
    base_ratio = float(inc_row["ms"]) / float(dense_row["ms"])
    g = jax.random.normal(jax.random.PRNGKey(n), (n, 64))
    labels = jnp.arange(n) % 10

    def once(method):
        return sel_lib.select("gradmatch", jax.random.PRNGKey(0), g, k,
                              labels=labels, num_classes=10,
                              per_class=False, omp_method=method).weights

    ms_inc = time_fn(lambda: once("incremental"), warmup=1, iters=3) * 1e3
    ms_dense = time_fn(lambda: once("dense"), warmup=1, iters=2) * 1e3
    ratio = ms_inc / ms_dense
    ok = ratio <= REGRESSION_FACTOR * base_ratio
    print(f"parity_gate,check=regression,pool={n},k={k},"
          f"inc_ms={ms_inc:.2f},dense_ms={ms_dense:.2f},"
          f"ratio={ratio:.4f},baseline_ratio={base_ratio:.4f},"
          f"limit={REGRESSION_FACTOR}x,ok={ok}", flush=True)
    return ok


def check_greedy_parity(n=4096, d=64, k=128) -> bool:
    from repro.core import greedy as greedy_lib

    g = jax.random.normal(jax.random.PRNGKey(11), (n, d))
    dense = greedy_lib.fl_greedy(g, k, method="dense")
    lazy = greedy_lib.fl_greedy(g, k, method="lazy", block=64)
    same_idx = np.array_equal(np.asarray(lazy.indices),
                              np.asarray(dense.indices))
    same_mask = np.array_equal(np.asarray(lazy.mask),
                               np.asarray(dense.mask))
    s = lazy.stats
    print(f"parity_gate,check=craig-lazy-vs-dense,pool={n},k={k},"
          f"indices={same_idx},mask={same_mask},rescans={s.rescans},"
          f"certified={s.certified_rounds}", flush=True)
    return same_idx and same_mask


def check_greedy_regression(dense_budget_ms=15000.0) -> bool:
    """Re-time craig-lazy against the dense greedy at the largest
    committed pool whose baseline dense time fits the CI budget — the
    8192 pool's ~2-minute dense greedy is excluded (its lazy parity
    coverage is the pool-4096 smoke above plus the full-bench ratio
    recorded in BENCH_selection.json, not a per-CI re-run)."""
    from repro.core import selection as sel_lib

    path = REPO_ROOT / "BENCH_selection.json"
    if not path.exists():
        print("parity_gate,check=greedy-regression,skipped=no-baseline",
              flush=True)
        return True
    rows = json.loads(path.read_text())["rows"]
    # Key on (pool, k): craig-lazy is recorded at several k per pool
    # (run() and run_greedy()); the ratio is only meaningful for rows
    # timed at the identical workload.
    by_pool = {}
    for r in rows:
        if "ms" in r and r.get("strategy") in ("craig", "craig-lazy"):
            by_pool.setdefault((r["pool"], r["k"]), {})[r["strategy"]] = r
    pools = [p for p, d in by_pool.items()
             if len(d) == 2 and float(d["craig"]["ms"]) <= dense_budget_ms]
    if not pools:
        print("parity_gate,check=greedy-regression,skipped=no-baseline-pair",
              flush=True)
        return True
    n, k = max(pools)
    lazy_row = by_pool[(n, k)]["craig-lazy"]
    dense_row = by_pool[(n, k)]["craig"]
    base_ratio = float(lazy_row["ms"]) / float(dense_row["ms"])
    g = jax.random.normal(jax.random.PRNGKey(n), (n, 64))
    labels = jnp.arange(n) % 10

    def once(strategy):
        return sel_lib.select(strategy, jax.random.PRNGKey(0), g, k,
                              labels=labels, num_classes=10,
                              per_class=False).weights

    ms_lazy = time_fn(lambda: once("craig-lazy"), warmup=1, iters=3) * 1e3
    ms_dense = time_fn(lambda: once("craig"), warmup=1, iters=2) * 1e3
    ratio = ms_lazy / ms_dense
    ok = ratio <= REGRESSION_FACTOR * base_ratio
    print(f"parity_gate,check=greedy-regression,pool={n},k={k},"
          f"lazy_ms={ms_lazy:.2f},dense_ms={ms_dense:.2f},"
          f"ratio={ratio:.4f},baseline_ratio={base_ratio:.4f},"
          f"limit={REGRESSION_FACTOR}x,ok={ok}", flush=True)
    return ok


def check_serve_smoke() -> bool:
    """Serve-selection smoke (DESIGN.md §6): 8 queued requests over 2
    pools drain through the micro-batching scheduler, plus one anytime
    k-extension — the driver self-checks both differential claims
    (batched == per-request ``omp_select``; extension == one-shot k')
    and reports them."""
    from repro.launch import serve_selection

    report = serve_selection.main([
        "--smoke", "--requests", "8", "--pools", "2", "--tenants", "2",
        "--pool-size", "1024", "--dim", "32", "--k", "48",
        "--k-extend", "80"])
    print(f"parity_gate,check=serve-smoke,requests={report['requests']},"
          f"batches={report['batches_run']},"
          f"batched_ok={report['batched_ok']},"
          f"extension_ok={report['extension_ok']},ok={report['ok']}",
          flush=True)
    return bool(report["ok"])


def check_serve_load(n=4096, d=256, ks=(48, 96), requests=40,
                     min_speedup=5.0, slo_factor=25.0) -> bool:
    """Overload-resilience gate (DESIGN.md §10): a fixed-seed open-loop
    burst must drain through the overload-aware service at >=
    ``min_speedup`` x the sequential baseline's sustained req/s, with
    p99 within the SLO (``slo_factor`` x one sequential solve), both
    runs clean on the load harness's accounting invariants (admitted ==
    completed + shed + failed, in-flight slots returned, refunds
    exactly once).  A second deliberately-undersized run must *shed* —
    labelled, never charged — with the same invariants intact.

    Throughput is gated as a same-machine ratio (sequential and loaded
    are timed in the same process on the same trace), so the gate is
    machine-independent like the other perf checks."""
    from repro.serve import (LoadSpec, SelectionService, SimClock,
                             make_arrivals, run_load)

    pool = np.asarray(jax.random.normal(jax.random.PRNGKey(31), (n, d)),
                      np.float32)

    def build(max_batch, overload, max_queue, brownout_at=0.4):
        clock = SimClock()
        svc = SelectionService(
            max_batch=max_batch, max_queue=max_queue,
            max_inflight_per_tenant=2 * requests, clock=clock.now,
            overload=overload, brownout_at=brownout_at,
            overload_at=0.85, recover_at=0.1)
        pid = svc.register_pool(pool, pool_id="gate-pool")
        for k in ks:
            svc.select(pid, k=k)                 # jit warm, off the trace
        if max_batch > 1:
            svc.submit(pid, k=ks[0])
            svc.submit(pid, k=ks[0])
            svc.drain()
            sid, _ = svc.open_session(pid, k=max(ks))
            svc.close_session(sid)
        return clock, svc, pid

    def trace(pid, **kw):
        return make_arrivals(LoadSpec(
            seed=13, requests=requests, rate_rps=1e6, pools=(pid,),
            ks=tuple(ks), **kw))

    clock, svc, pid = build(1, False, 2 * requests)
    seq = run_load(svc, trace(pid), clock)
    # max_queue == the burst size: the whole trace drains under
    # brownout (the regime this gate is about), so every group goes
    # through the shared anytime session instead of recovering to the
    # cold-bucket batched path mid-trace.
    clock, svc, pid = build(16, True, requests)
    loaded = run_load(svc, trace(pid), clock)

    speedup = loaded.sustained_rps / max(seq.sustained_rps, 1e-9)
    per_req_seq = seq.duration_s / max(seq.completed, 1)
    slo_ms = slo_factor * per_req_seq * 1e3
    clean = (seq.violations == [] and loaded.violations == []
             and seq.completed == requests
             and loaded.completed == requests)
    speed_ok = speedup >= min_speedup
    slo_ok = loaded.p99_ms <= slo_ms

    # Undersized service: the burst must brown out and shed best-effort
    # traffic with the accounting invariants still holding.
    clock, svc, pid = build(8, True, max_queue=8, brownout_at=0.25)
    shed_rep = run_load(
        svc, trace(pid, tenants=("a", "b"),
                   priorities=("interactive", "best-effort"),
                   priority_weights=(1, 1)),
        clock)
    c = svc.scheduler.counters
    shed_ok = (shed_rep.violations == [] and shed_rep.shed > 0
               and c["admitted"] == c["completed"] + c["shed"]
               + c["failed"]
               and all(r["ticket"].degradation == "shed"
                       for r in shed_rep.records
                       if r["ticket"].status == "shed"))

    ok = clean and speed_ok and slo_ok and shed_ok
    print(f"parity_gate,check=serve-load,pool={n},requests={requests},"
          f"seq_rps={seq.sustained_rps:.2f},"
          f"loaded_rps={loaded.sustained_rps:.2f},"
          f"speedup={speedup:.2f},min={min_speedup},"
          f"p99_ms={loaded.p99_ms:.1f},slo_ms={slo_ms:.1f},"
          f"shed={shed_rep.shed},invariants_ok={clean and shed_ok},"
          f"ok={ok}", flush=True)
    return ok


def check_fault_recovery(n=4096, d=64, k=128, chunk=512, rate=0.15,
                         seed=11, overhead_budget=1.5) -> bool:
    """Fault-recovery gate (DESIGN.md §8): under seeded transient faults
    at ``rate`` (3x the 5% acceptance floor) the streaming solve must
    select bit-identically to the fault-free run within
    ``overhead_budget`` x its wall-clock (retries are zero-backoff, so
    the ratio measures re-read work, not sleeps); and a solve killed
    mid-stream must resume from its checkpoint to the same selection."""
    import shutil
    import tempfile

    from repro.core import streaming as stream_lib
    from repro.resilience import (FaultPlan, FaultyChunkIterator,
                                  RetryPolicy, faulty_row_fetch)

    g = np.asarray(jax.random.normal(jax.random.PRNGKey(17), (n, d)),
                   np.float32)
    target = jnp.sum(jnp.asarray(g), axis=0)
    chunks = stream_lib.array_chunks(g, chunk)
    fetch = stream_lib.array_row_fetch(g)
    pol = RetryPolicy(max_retries=8, backoff_s=0.0, sleep=lambda s: None)
    plan = FaultPlan(seed=seed, transient_rate=rate, row_transient_rate=rate)

    def solve(ci, rf, **kw):
        out = stream_lib.omp_select_streaming(
            ci, target, k, buffer_size=256, row_fetch=rf, retry=pol, **kw)
        jax.block_until_ready(out.weights)
        return out

    ref = solve(chunks, fetch)                       # warm + reference
    t_clean = time_fn(lambda: solve(chunks, fetch).weights,
                      warmup=0, iters=3)
    out = solve(FaultyChunkIterator(chunks, plan),
                faulty_row_fetch(fetch, plan))
    parity = bool(jnp.all(out.indices == ref.indices)) and bool(
        jnp.all(out.mask == ref.mask)) and bool(
        jnp.all(out.weights == ref.weights))
    t_fault = time_fn(
        lambda: solve(FaultyChunkIterator(chunks, plan),
                      faulty_row_fetch(fetch, plan)).weights,
        warmup=0, iters=3)
    overhead = t_fault / max(t_clean, 1e-9)

    # Kill/resume on the cacheless configuration: every commit burst
    # re-pays a loader pass there, so death at 3 passes lands mid-solve
    # (the cached solve finishes in one pass and would never be killed).
    n2, k2 = n // 4, k // 4
    g2 = g[:n2]
    t2 = jnp.sum(jnp.asarray(g2), axis=0)
    c2 = stream_lib.array_chunks(g2, chunk // 4)

    def solve2(ci, **kw):
        return stream_lib.omp_select_streaming(
            ci, t2, k2, buffer_size=64, cache_bytes=0, retry=pol, **kw)

    ref2 = solve2(c2)
    td = tempfile.mkdtemp(prefix="gate-faults-")
    try:
        dying = FaultyChunkIterator(
            c2, FaultPlan(seed=seed, die_after_chunks=3 * (n2 // (chunk
                                                                  // 4))))
        try:
            solve2(dying, checkpoint_dir=td, checkpoint_every=1)
            killed = False
        except Exception:
            killed = True
        res = solve2(c2, checkpoint_dir=td, checkpoint_every=1)
        resume_ok = (killed and res.stats.resumes == 1
                     and bool(jnp.all(res.indices == ref2.indices))
                     and bool(jnp.all(res.weights == ref2.weights)))
    finally:
        shutil.rmtree(td, ignore_errors=True)

    ok = parity and overhead <= overhead_budget and resume_ok
    print(f"parity_gate,check=fault-recovery,pool={n},k={k},rate={rate},"
          f"parity={parity},retries={out.stats.retries},"
          f"overhead={overhead:.2f},budget={overhead_budget},"
          f"resume_ok={resume_ok},ok={ok}", flush=True)
    return ok


def check_partitioned(n=4096, d=64, k=128, gap_tol=0.05,
                      scale_n=16384, scale_k=256) -> bool:
    """Partition-and-merge gate (core/partition.py, DESIGN.md §9).

    Merge parity: P = 1 must reproduce the single solver's subset exactly
    (the merge re-solves the same candidates against the same target);
    the class kind must pick the same rows as ``gradmatch_per_class``
    (same per-class solves, merge reweighted); hashed P = 4 must land
    within ``gap_tol`` of the single solver's objective, normalized by
    ||target||^2 (partitioning is a decomposition heuristic — the gate
    bounds its cost, bit-equality is not the claim).  The budget-split
    fix is asserted where it bites: k % C != 0 with a class smaller than
    its quota still yields exactly min(k, n_valid) rows.  Scaling smoke:
    the streaming solve at P = 4 must run in <= 0.8x the P = 1 time
    (total rounds drop to ~k/P; interleaved min-of-3 cancels CI load
    spikes)."""
    import time as _time

    from repro.core import gradmatch as gm_lib
    from repro.core import partition as part_lib

    g = np.asarray(jax.random.normal(jax.random.PRNGKey(23), (n, d)),
                   np.float32)
    single = gm_lib.gradmatch(jnp.asarray(g), k)
    s_idx = np.sort(np.asarray(single.indices)[np.asarray(single.mask)])

    p1 = part_lib.gradmatch_partitioned(g, k, partitions=1, kind="hash")
    p1_idx = np.sort(np.asarray(p1.indices)[np.asarray(p1.mask)])
    p1_ok = np.array_equal(p1_idx, s_idx)

    p4 = part_lib.gradmatch_partitioned(g, k, partitions=4, kind="hash")
    tnorm = float(jnp.sum(jnp.asarray(g).sum(axis=0) ** 2))
    gap = (float(p4.err) - float(single.err)) / tnorm
    gap_ok = gap <= gap_tol

    # Per-class: a 6-class pool with one class smaller than its quota and
    # k % C != 0 — the exact configuration the old split dropped rows on.
    labels = np.arange(n) % 6
    labels[labels == 5] = 0
    labels[:3] = 5                      # class 5 has 3 rows < quota
    pc = gm_lib.gradmatch_per_class(jnp.asarray(g), jnp.asarray(labels), 6,
                                    k + 3)
    pc_count = int(np.asarray(pc.mask).sum())
    split_ok = pc_count == min(k + 3, n)
    cls = part_lib.gradmatch_partitioned(g, k + 3, labels=labels,
                                         num_classes=6)
    cls_ok = np.array_equal(
        np.sort(np.asarray(cls.indices)[np.asarray(cls.mask)]),
        np.sort(np.asarray(pc.indices)[np.asarray(pc.mask)]))

    gs = np.asarray(jax.random.normal(jax.random.PRNGKey(29),
                                      (scale_n, d)), np.float32)

    def stream_at(p):
        res = part_lib.gradmatch_partitioned_stream(pool=gs, k=scale_k,
                                                    partitions=p)
        jax.block_until_ready(res.weights)
        return res

    stream_at(1), stream_at(4)                   # warm both shapes
    t1s, t4s = [], []
    for _ in range(3):
        t0 = _time.perf_counter()
        stream_at(1)
        t1s.append(_time.perf_counter() - t0)
        t0 = _time.perf_counter()
        stream_at(4)
        t4s.append(_time.perf_counter() - t0)
    t1, t4 = min(t1s), min(t4s)
    scale_ok = t4 <= 0.8 * t1

    ok = p1_ok and gap_ok and split_ok and cls_ok and scale_ok
    print(f"parity_gate,check=partitioned,pool={n},k={k},"
          f"p1_exact={p1_ok},gap={gap:.4f},gap_tol={gap_tol},"
          f"per_class_rows={pc_count},split_ok={split_ok},"
          f"class_exact={cls_ok},p1_ms={t1 * 1e3:.2f},"
          f"p4_ms={t4 * 1e3:.2f},scale={t1 / max(t4, 1e-9):.2f},"
          f"scale_ok={scale_ok},ok={ok}", flush=True)
    return ok


def check_continual(n=1024, d=32, k=24, cap=96, bs=48, down_pool=2048,
                    down_d=64, down_k=512, min_speedup=5.0) -> bool:
    """Continual-stream gate (repro.continual, DESIGN.md §11).

    Differential smoke: after streaming ``n`` rows through a
    ``cap``-slot buffer the maintained coreset must be index-identical
    (weights to f32 tolerance) to a from-scratch session solve over the
    surviving rows — the invariant tests/test_continual.py grids over,
    re-asserted here at a beyond-unit-test shape.  Decremental speedup:
    downdating the last committed pick at k = 512 must beat the
    from-scratch re-solve by >= ``min_speedup`` (interleaved min-of-3;
    the downdate is one truncation, the re-solve is 512 rounds — a
    regression here means the truncate path is silently replaying)."""
    import time as _time

    from repro.continual import BufferMaintainer
    from repro.core import omp as omp_lib
    from repro.core.decremental import omp_downdate

    g = np.asarray(jax.random.normal(jax.random.PRNGKey(31), (n, d)),
                   np.float32)
    tgt = g.sum(axis=0)
    m = BufferMaintainer(capacity=cap, d=d, target=tgt, k=k,
                         compress=False, seed=0)
    for lo in range(0, n, bs):
        m.admit(g[lo:lo + bs], gids=np.arange(lo, min(lo + bs, n)))
    pool, okmask = m.pool_view()
    idx, w, mask, _ = m.slot_result()
    fresh = omp_lib.omp_session_start(pool, m.target, k, valid=okmask,
                                      block=m.block)
    diff_ok = (np.array_equal(np.asarray(idx), np.asarray(fresh.indices))
               and np.allclose(np.asarray(w), np.asarray(fresh.weights),
                               rtol=2e-4, atol=2e-5))

    gd = jax.random.normal(jax.random.PRNGKey(37), (down_pool, down_d))
    target = jnp.sum(gd, axis=0)
    sess = omp_lib.omp_session_start(gd, target, down_k)
    last = int(np.asarray(sess.indices)[down_k - 1])

    def downdate():
        jax.block_until_ready(omp_downdate(gd, sess, last)[0].st.weights)

    def resolve():
        jax.block_until_ready(
            omp_lib.omp_session_start(gd, target, down_k).st.weights)

    downdate(), resolve()                        # warm both paths
    td, tr = [], []
    for _ in range(3):
        t0 = _time.perf_counter()
        downdate()
        td.append(_time.perf_counter() - t0)
        t0 = _time.perf_counter()
        resolve()
        tr.append(_time.perf_counter() - t0)
    speedup = min(tr) / max(min(td), 1e-9)
    speed_ok = speedup >= min_speedup

    ok = diff_ok and speed_ok
    print(f"parity_gate,check=continual,pool={n},k={k},cap={cap},"
          f"evicts={m.stats.evicts},downdates={m.stats.downdates},"
          f"diff_exact={diff_ok},down_k={down_k},"
          f"down_ms={min(td) * 1e3:.2f},resolve_ms={min(tr) * 1e3:.2f},"
          f"speedup={speedup:.2f},min_speedup={min_speedup},ok={ok}",
          flush=True)
    return ok


def check_artifacts(n=8192, d=64, k=512, min_speedup=20.0,
                    err_rtol=0.01) -> bool:
    """Artifact fast-path gate (DESIGN.md §12) at the headline serve
    shape.  Three claims, all end-to-end through the service:

    * **bit-exactness at 3 k-slices**: the artifact-served ticket
      (``degradation="artifact"``) must be bit-identical — indices,
      mask, normalized weights, err — to the live anytime session
      engine at k in {1, k/2, k}.  (The one-shot ``omp_select`` pads
      its solve to narrower prefix widths than the session engine; at
      this pool size the resulting 1-ulp score differences flip
      near-tie argmaxes, so the two *live* paths themselves diverge
      bit-wise — the artifact records the session engine, the rung
      extension serving runs on, and is gated against the certified
      batched path at the objective level instead.)
    * **objective parity vs the live certified path**: residual err
      within ``err_rtol``.
    * **>= min_speedup x**: answering from the artifact at submit must
      beat the live certified submit+drain by >= 20x.
    """
    import tempfile
    import time as _time

    from repro.artifacts import ArtifactStore, build_artifact
    from repro.core.gradmatch import _normalize
    from repro.core.omp import omp_session_start
    from repro.serve.service import SelectionService

    g = np.asarray(jax.random.normal(jax.random.PRNGKey(23), (n, d)),
                   np.float32)
    with tempfile.TemporaryDirectory() as root:
        svc = SelectionService(artifact_store=ArtifactStore(root))
        pid = svc.register_pool(g)
        entry = svc.registry.get(pid)
        tgt = np.asarray(entry.target_sum, np.float32)
        t0 = _time.perf_counter()
        build_artifact(svc.artifacts, g, tgt, k,
                       fingerprint=entry.content_digest)
        build_s = _time.perf_counter() - t0

        live = SelectionService()                 # no artifacts: live path
        live_pid = live.register_pool(g)

        exact = True
        err_ok = True
        for kq in sorted({1, k // 2, k}):
            t = svc.submit(pid, kq)
            hit = t.status == "done" and t.degradation == "artifact"
            sess = omp_session_start(g, tgt, kq)
            sw = np.asarray(_normalize(jnp.asarray(sess.weights),
                                       jnp.asarray(sess.mask)))
            bit = (hit
                   and np.array_equal(np.asarray(t.result.indices),
                                      np.asarray(sess.indices))
                   and np.array_equal(np.asarray(t.result.mask),
                                      np.asarray(sess.mask))
                   and np.array_equal(np.asarray(t.result.weights), sw)
                   and np.array_equal(np.asarray(t.result.err),
                                      np.asarray(sess.err)))
            lt = live.submit(live_pid, kq)
            live.drain()
            art_err = float(np.asarray(t.result.err))
            live_err = float(np.asarray(lt.result.err))
            erel = abs(art_err - live_err) / max(abs(live_err), 1e-9)
            print(f"parity_gate,check=artifacts,k={kq},hit={hit},"
                  f"bit_exact_vs_session={bit},err_rel={erel:.5f},"
                  f"rung={t.degradation}", flush=True)
            exact &= bit
            err_ok &= erel <= err_rtol

        def artifact_hit():
            tt = svc.submit(pid, k)
            assert tt.degradation == "artifact"

        def live_solve():
            live.submit(live_pid, k)
            live.drain()

        hit_ms = time_fn(artifact_hit, warmup=1, iters=5) * 1e3
        live_ms = time_fn(live_solve, warmup=1, iters=3) * 1e3
        speedup = live_ms / max(hit_ms, 1e-9)
        speed_ok = speedup >= min_speedup

        st = svc.stats()
        acc = svc.scheduler.counters
        acct_ok = (acc["admitted"] == acc["completed"] + acc["shed"]
                   + acc["failed"] + svc.scheduler.pending())
        ok = exact and err_ok and speed_ok and acct_ok
        print(f"parity_gate,check=artifacts,pool={n},k={k},"
              f"build_s={build_s:.1f},hit_ms={hit_ms:.3f},"
              f"live_ms={live_ms:.1f},speedup={speedup:.1f},"
              f"min={min_speedup},hits={st['registry']['artifact_hits']},"
              f"quarantined={st['registry']['artifact_quarantined']},"
              f"accounting_ok={acct_ok},ok={ok}", flush=True)
        return ok


def main() -> int:
    ok = check_streaming_parity()
    ok &= check_streaming_overhead()
    ok &= check_incremental_regression()
    ok &= check_greedy_parity()
    ok &= check_greedy_regression()
    ok &= check_serve_smoke()
    ok &= check_serve_load()
    ok &= check_fault_recovery()
    ok &= check_partitioned()
    ok &= check_continual()
    ok &= check_artifacts()
    print(f"parity_gate,{'PASS' if ok else 'FAIL'}", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
