"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads ``benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json`` (written
by launch/dryrun.py) and emits, per cell: the three roofline terms in
seconds, the dominant term, MODEL_FLOPS / HLO_FLOPS (useful-compute
ratio), and the per-device memory verdict.  ``--markdown`` renders the
EXPERIMENTS.md table.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                   "artifacts", "dryrun")

COLS = ("arch", "shape", "dominant", "t_compute_s", "t_memory_s",
        "t_collective_s", "useful_ratio", "peak_gib", "analytic_gib",
        "compile_s")


def load(mesh: str = "16x16") -> list[dict]:
    from repro.configs import SHAPES, get_config
    from repro.launch.hlo_analysis import HBM_BW, analytic_hbm_bytes
    n_chips = 512 if mesh == "2x16x16" else 256
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if not d.get("ok"):
            rows.append({"arch": d["arch"], "shape": d["shape"],
                         "ok": False})
            continue
        r = d["roofline"]
        # Analytic memory term: XLA:CPU 'bytes accessed' counts unfused
        # op-level traffic + f32 upcasts of bf16 dot operands — a 5-20x
        # overstatement of fused-TPU HBM traffic.  The analytic stream
        # model (weights/optimizer/activations/KV) is the fair memory
        # term; the measured one is kept as 'unfused upper bound'.
        cfg = get_config(d["arch"])
        ab = analytic_hbm_bytes(cfg, SHAPES[d["shape"]], n_chips,
                                16, d.get("microbatches", 1))
        t_mem = ab / HBM_BW
        terms = {"compute": r["t_compute_s"], "memory": t_mem,
                 "collective": r["t_collective_s"]}
        dominant = max(terms, key=terms.get)
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "ok": True,
            "dominant": dominant,
            "t_compute_s": r["t_compute_s"],
            "t_memory_s": t_mem,
            "t_memory_unfused_s": r["t_memory_s"],
            "t_collective_s": r["t_collective_s"],
            "useful_ratio": d.get("useful_flops_ratio"),
            "peak_gib": d["memory"]["peak_device_bytes"] / 2**30,
            "analytic_gib": d["memory"].get("analytic", {}).get(
                "per_chip_total_gib"),
            "compile_s": d["compile_s"],
            "collective_gib": d["collectives"]["total_bytes"] / 2**30,
            "kind": d["kind"],
        })
    return rows


def fraction_of_roofline(row: dict) -> float:
    """Achievable fraction = compute term / max(all three terms): if the
    dominant term were perfectly overlapped down to the compute term the
    step would be compute-bound (1.0)."""
    tmax = max(row["t_compute_s"], row["t_memory_s"],
               row["t_collective_s"])
    return row["t_compute_s"] / tmax if tmax else 0.0


def markdown(rows: list[dict]) -> str:
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
           "dominant | roofline frac | useful FLOP ratio | peak GiB "
           "(measured / analytic) |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | FAILED ||||||||")
            continue
        ur = (f"{r['useful_ratio']:.2f}"
              if r.get("useful_ratio") is not None else "-")
        ag = (f"{r['analytic_gib']:.1f}"
              if r.get("analytic_gib") is not None else "-")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{r['dominant']} | {fraction_of_roofline(r):.2f} | {ur} | "
            f"{r['peak_gib']:.1f} / {ag} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)
    rows = load(args.mesh)
    if args.markdown:
        print(markdown(rows))
        return
    for r in rows:
        if not r.get("ok"):
            print(f"roofline,arch={r['arch']},shape={r['shape']},ok=False")
            continue
        print(f"roofline,arch={r['arch']},shape={r['shape']},"
              f"dominant={r['dominant']},"
              f"frac={fraction_of_roofline(r):.3f},"
              f"t_comp={r['t_compute_s']:.4f},t_mem={r['t_memory_s']:.4f},"
              f"t_coll={r['t_collective_s']:.4f}")


if __name__ == "__main__":
    main()
