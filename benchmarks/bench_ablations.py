"""Paper Fig. 4 ablations: R (selection interval), lambda, kappa, and the
class-imbalance robustness sweep (Fig. 4e)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, paper_dataset
from repro.configs.paper import PaperHParams, mlp
from repro.core import selection as sel_lib
from repro.core.gradmatch import gradmatch
from repro.data.synthetic import make_imbalanced
from repro.train.trainer import AdaptiveTrainer, TrainerConfig

MODEL = mlp(in_dim=32, num_classes=10)


def sweep_r(train, val, rs=(5, 10, 20), epochs=40, quick=False):
    if quick:
        rs, epochs = (5, 20), 20
    for r in rs:
        tc = TrainerConfig(strategy="gradmatch-pb", budget=0.1,
                           epochs=epochs, batch_size=64,
                           hp=PaperHParams(select_every=r))
        rep = AdaptiveTrainer(MODEL, tc, train, val).run()
        emit("ablation_R", R=r, acc=round(rep.final_acc, 4),
             sel_rounds=rep.selection_rounds,
             sel_seconds=round(rep.selection_seconds, 2))


def sweep_lambda(train, val, lams=(0.0, 0.5, 5.0, 50.0)):
    """Fig. 4g mechanism, measured directly on the matching error."""
    from repro.models.classifier import init_classifier
    from repro.train.steps import make_proxy_fn
    params = init_classifier(MODEL, jax.random.PRNGKey(0))
    _, bias = make_proxy_fn(MODEL)(params, train.x, train.y)
    target = jnp.sum(bias, axis=0)
    for lam in lams:
        sel = gradmatch(bias, k=100, lam=lam)
        wnorm = float(jnp.sum(sel.weights ** 2))
        emit("ablation_lambda", lam=lam, err=round(float(sel.err), 4),
             w_sq_norm=round(wnorm, 5))


def sweep_kappa(train, val, kappas=(0.25, 0.5, 0.75), epochs=40,
                quick=False):
    if quick:
        kappas, epochs = (0.5,), 20
    for kappa in kappas:
        tc = TrainerConfig(strategy="gradmatch-pb", budget=0.1,
                           epochs=epochs, batch_size=64, warm_start=True,
                           hp=PaperHParams(select_every=10, kappa=kappa))
        rep = AdaptiveTrainer(MODEL, tc, train, val).run()
        emit("ablation_kappa", kappa=kappa, acc=round(rep.final_acc, 4),
             work=int(rep.work_units))


def imbalance(quick=False, epochs=40):
    """Fig. 3f/4e: isValid=True (validation-gradient matching) vs
    training-gradient matching vs random under class imbalance."""
    if quick:
        epochs = 20
    train, val = make_imbalanced(jax.random.PRNGKey(5), n=4096, dim=32,
                                 num_classes=10, sep=5.0)
    for strategy, is_valid in (("gradmatch", True), ("gradmatch", False),
                               ("random", False), ("full", False)):
        tc = TrainerConfig(strategy=strategy, budget=0.3, epochs=epochs,
                           batch_size=64, is_valid=is_valid,
                           hp=PaperHParams(select_every=10))
        rep = AdaptiveTrainer(MODEL, tc, train, val).run()
        emit("imbalance", strategy=strategy
             + ("-val" if is_valid else ""),
             acc=round(rep.final_acc, 4))


def main(quick=False):
    train, val = paper_dataset(n=2048)
    sweep_r(train, val, quick=quick)
    sweep_lambda(train, val)
    sweep_kappa(train, val, quick=quick)
    imbalance(quick=quick)


if __name__ == "__main__":
    main()
