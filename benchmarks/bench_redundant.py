"""Paper Table 10: redundant points — examples never selected across all
selection rounds of a training run (information redundancy of the data)."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, paper_dataset
from repro.configs.paper import PaperHParams, mlp
from repro.core import selection as sel_lib
from repro.models.classifier import init_classifier
from repro.optim import sgd
from repro.train.steps import make_classifier_step, make_proxy_fn


def run(budgets=(0.05, 0.1, 0.3), epochs=30, quick=False) -> list[dict]:
    if quick:
        budgets, epochs = (0.1,), 12
    train, _ = paper_dataset(n=1024)
    model = mlp(in_dim=32, num_classes=10)
    rows = []
    for strategy in ("gradmatch", "gradmatch-pb", "craig-pb", "glister"):
        for budget in budgets:
            params = init_classifier(model, jax.random.PRNGKey(0))
            opt = sgd(0.01, momentum=0.9)
            step = make_classifier_step(model, opt)
            proxy = make_proxy_fn(model)
            opt_state = opt.init(params)
            ever = np.zeros(train.n, bool)
            k = int(train.n * budget)
            for epoch in range(epochs):
                if epoch % 5 == 0:
                    _, bias = proxy(params, train.x, train.y)
                    sel = sel_lib.select(strategy, jax.random.PRNGKey(epoch),
                                         bias, k, labels=train.y,
                                         num_classes=10, batch_size=32,
                                         per_class=False)
                    sel = sel_lib.expand_if_pb(strategy, sel, 32, train.n)
                    m = np.asarray(sel.mask)
                    ever[np.asarray(sel.indices)[m]] = True
                # one cheap epoch on the subset keeps the model moving
                idx = np.asarray(sel.indices)[np.asarray(sel.mask)]
                batch = {"x": train.x[idx[:64]], "y": train.y[idx[:64]]}
                params, opt_state, _ = step(params, opt_state, batch)
            redundant = 100.0 * float((~ever).mean())
            row = dict(strategy=strategy, budget=budget,
                       redundant_pct=round(redundant, 2))
            emit("redundant", **row)
            rows.append(row)
    return rows


def main(quick=False):
    run(quick=quick)


if __name__ == "__main__":
    main()
