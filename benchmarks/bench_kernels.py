"""Kernel microbench: Pallas (interpret) correctness + jnp-ref timing.

On this CPU container the Pallas interpreter is not a performance path —
the numbers that matter are (a) allclose vs the oracle at benchmark shapes
and (b) the jnp reference's wall time (what the selection round costs on
the host today).  TPU timings come from running the same pallas_call
compiled on device.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import make_recorder, time_fn
from repro.kernels import ref
from repro.kernels.corr import corr, corr_argmax
from repro.kernels.lastlayer_grad import hidden_grad_fused, lastlayer_grad
from repro.kernels.sqdist import sqdist


def run(quick=False) -> list[dict]:
    rows = []
    record = make_recorder("kernel", rows)

    n, d, v, dh = (2048, 512, 1024, 256) if quick else (8192, 1024, 4096,
                                                        512)
    k = jax.random.PRNGKey(0)
    g = jax.random.normal(k, (n, d))
    r = jax.random.normal(jax.random.fold_in(k, 1), (d,))
    t = time_fn(jax.jit(ref.corr_ref), g, r)
    err = float(jnp.max(jnp.abs(corr(g, r, interpret=True)
                                - ref.corr_ref(g, r))))
    record(name="corr", n=n, d=d, ref_ms=round(t * 1e3, 2),
           max_abs_err=f"{err:.2e}")

    # fused OMP scores-and-argmax (incremental solver inner loop)
    kc = 512 if quick else 1024
    cc = jax.random.normal(jax.random.fold_in(k, 7), (n, kc))
    w = jnp.abs(jax.random.normal(jax.random.fold_in(k, 8), (kc,)))
    base = jax.random.normal(jax.random.fold_in(k, 9), (n,))
    mask = jnp.arange(n) % 7 != 0
    t = time_fn(jax.jit(ref.corr_argmax_ref), cc, w, base, mask)
    gi, gv = corr_argmax(cc, w, base, mask, interpret=True)
    ri, rv = ref.corr_argmax_ref(cc, w, base, mask)
    err = abs(float(gv) - float(rv)) + float(int(gi) != int(ri))
    record(name="corr_argmax", n=n, k=kc, ref_ms=round(t * 1e3, 2),
           max_abs_err=f"{err:.2e}")

    a = jax.random.normal(k, (1024, d))
    t = time_fn(jax.jit(ref.sqdist_ref), a, a)
    err = float(jnp.max(jnp.abs(sqdist(a, a, interpret=True)
                                - ref.sqdist_ref(a, a))))
    record(name="sqdist", n=1024, d=d, ref_ms=round(t * 1e3, 2),
           max_abs_err=f"{err:.2e}")

    # fused facility-location gain scan (CRAIG greedy rescan, DESIGN.md §5)
    from repro.kernels.fl_gain import fl_gain_argmax, fl_gain_argmax_otf

    nf, df = 1024, 64
    gf = jax.random.normal(jax.random.fold_in(k, 10), (nf, df))
    sq = jnp.sum(gf**2, axis=1)
    dist = jnp.sqrt(jnp.maximum(sq[:, None] + sq[None, :]
                                - 2.0 * gf @ gf.T, 0.0))
    lm = jnp.max(dist)
    sim = lm - dist
    cover = jnp.abs(jax.random.normal(jax.random.fold_in(k, 11), (nf,)))
    fmask = jnp.arange(nf) % 5 != 0
    rok = jnp.ones((nf,), bool)
    t = time_fn(jax.jit(ref.fl_gain_argmax_ref), sim, cover, fmask)
    kg, ki, _ = fl_gain_argmax(sim, cover, fmask, interpret=True)
    rg, ri, _ = ref.fl_gain_argmax_ref(sim, cover, fmask)
    err = float(jnp.max(jnp.abs(kg - rg))) + float(int(ki) != int(ri))
    record(name="fl_gain_argmax", n=nf, ref_ms=round(t * 1e3, 2),
           max_abs_err=f"{err:.2e}")
    t = time_fn(jax.jit(ref.fl_gain_argmax_otf_ref), gf, cover, rok,
                fmask, lm)
    kg, ki, _ = fl_gain_argmax_otf(gf, cover, rok, fmask, lm,
                                   interpret=True)
    err = float(jnp.max(jnp.abs(kg - rg))) + float(int(ki) != int(ri))
    record(name="fl_gain_argmax_otf", n=nf, d=df,
           ref_ms=round(t * 1e3, 2), max_abs_err=f"{err:.2e}")

    h = jax.random.normal(k, (n, dh))
    z = jax.random.normal(jax.random.fold_in(k, 2), (n, 64))
    y = jax.random.randint(jax.random.fold_in(k, 3), (n,), 0, 64)
    t = time_fn(jax.jit(ref.lastlayer_grad_ref), h, z, y)
    got = lastlayer_grad(h, z, y, interpret=True)
    want = ref.lastlayer_grad_ref(h, z, y)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(got, want))
    record(name="lastlayer_grad", n=n, C=64,
           ref_ms=round(t * 1e3, 2), max_abs_err=f"{err:.2e}")

    zz = jax.random.normal(jax.random.fold_in(k, 4), (256, v))
    yy = jax.random.randint(jax.random.fold_in(k, 5), (256,), 0, v)
    w = jax.random.normal(jax.random.fold_in(k, 6), (dh, v)) / np.sqrt(v)

    def ref_hidden(zz, yy, w):
        resid, _ = ref.lastlayer_grad_ref(jnp.zeros((zz.shape[0], 1)), zz,
                                          yy)
        return resid @ w.T

    t = time_fn(jax.jit(ref_hidden), zz, yy, w)
    err = float(jnp.max(jnp.abs(hidden_grad_fused(zz, yy, w,
                                                  interpret=True)
                                - ref_hidden(zz, yy, w))))
    record(name="hidden_grad_fused", n=256, V=v,
           ref_ms=round(t * 1e3, 2), max_abs_err=f"{err:.2e}")
    return rows


def main(quick=False) -> list[dict]:
    return run(quick=quick)


if __name__ == "__main__":
    main()
