"""Paper Fig. 1/3 + Tables 3-5: accuracy vs efficiency trade-off.

Runs the full strategy grid x budgets on the structured synthetic
classification task and reports test accuracy, work units (the
hardware-independent stand-in for the paper's wall-clock: one unit = one
example forward; training = 3 units), speedup vs FULL, and the energy
proxy.  Selection overhead is included in the work accounting exactly as
the paper includes selection time in its wall-clock.
"""

from __future__ import annotations

import jax

from benchmarks.common import emit, paper_dataset
from repro.configs.paper import PaperHParams, mlp
from repro.train.trainer import AdaptiveTrainer, TrainerConfig

STRATEGIES = ("full", "random", "glister", "craig", "craig-pb",
              "gradmatch", "gradmatch-pb")
WARM = ("gradmatch-pb", "craig-pb", "glister", "random")


def run(budgets=(0.1, 0.3), epochs=40, n=2048, quick=False) -> list[dict]:
    if quick:
        budgets, epochs, n = (0.1,), 20, 1024
    train, val = paper_dataset(n=n)
    model = mlp(in_dim=32, num_classes=10)
    hp = PaperHParams(select_every=10)
    results = []

    full_work = {}
    for budget in budgets:
        for strategy in STRATEGIES:
            for warm in ([False, True] if strategy in WARM and not quick
                         else [False]):
                if strategy == "full" and (warm or budget != budgets[0]):
                    continue
                tc = TrainerConfig(
                    strategy=strategy, budget=budget, epochs=epochs,
                    batch_size=64, warm_start=warm, hp=hp)
                rep = AdaptiveTrainer(model, tc, train, val).run()
                if strategy == "full":
                    full_work["w"] = rep.work_units
                    full_work["acc"] = rep.final_acc
                speed = full_work.get("w", rep.work_units) / rep.work_units
                rel_err = (full_work.get("acc", 1.0) - rep.final_acc) * 100
                row = dict(strategy=rep.strategy, budget=budget,
                           acc=round(rep.final_acc, 4),
                           rel_err_pct=round(rel_err, 2),
                           speedup=round(speed, 2),
                           energy_gain=round(speed, 2),
                           sel_seconds=round(rep.selection_seconds, 2))
                emit("tradeoff", **row)
                results.append(row)
    return results


def main(quick=False):
    rows = run(quick=quick)
    # paper-claim check: best gradmatch variant beats random at each budget
    by_budget = {}
    for r in rows:
        by_budget.setdefault(r["budget"], []).append(r)
    for budget, rs in by_budget.items():
        gm = max((r["acc"] for r in rs
                  if r["strategy"].startswith("gradmatch")), default=None)
        rnd = max((r["acc"] for r in rs if r["strategy"] == "random"),
                  default=None)
        if gm is not None and rnd is not None:
            emit("tradeoff_check", budget=budget, gradmatch_best=gm,
                 random=rnd, gradmatch_wins=gm >= rnd)


if __name__ == "__main__":
    main()
