"""Paper Fig. 4c / App. C.4: selection cost — PB vs non-PB vs per-class.

Wall-clock of one selection round as the candidate pool grows.  The PB
variant runs OMP on an n/B ground set, so its cost curve is ~B x flatter —
the paper's central scaling trick.  Also times the distributed
(shard_map) OMP path on the 1-device mesh for dispatch-overhead visibility.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import selection as sel_lib
from repro.core.distributed import sharded_gradmatch_pb
from repro.launch.mesh import make_host_mesh


def run(pool_sizes=(512, 2048, 8192), d=64, budget=0.1, batch=32,
        quick=False) -> list[dict]:
    if quick:
        pool_sizes = (512, 2048)
    rows = []
    mesh = make_host_mesh(1, 1)
    for n in pool_sizes:
        g = jax.random.normal(jax.random.PRNGKey(n), (n, d))
        labels = jnp.arange(n) % 10
        k = int(n * budget)
        for strategy in ("gradmatch", "gradmatch-pb", "craig", "craig-pb",
                         "glister", "random"):
            def sel_once(g=g, strategy=strategy, k=k):
                s = sel_lib.select(strategy, jax.random.PRNGKey(0), g, k,
                                   labels=labels, num_classes=10,
                                   batch_size=batch, per_class=False)
                return s.weights
            t = time_fn(sel_once, warmup=1, iters=3)
            row = dict(strategy=strategy, pool=n, k=k,
                       ms=round(t * 1e3, 2))
            emit("selection_time", **row)
            rows.append(row)
        # per-class decomposition (vmapped OMP)
        def per_class(g=g, k=k):
            return sel_lib.select("gradmatch", jax.random.PRNGKey(0), g, k,
                                  labels=labels, num_classes=10,
                                  batch_size=batch, per_class=True).weights
        t = time_fn(per_class, warmup=1, iters=3)
        emit("selection_time", strategy="gradmatch-perclass", pool=n, k=k,
             ms=round(t * 1e3, 2))
        # distributed OMP (shard_map path)
        def dist(g=g, k=k):
            return sharded_gradmatch_pb(mesh, g, batch,
                                        max(k // batch, 1)).weights
        t = time_fn(dist, warmup=1, iters=3)
        emit("selection_time", strategy="gradmatch-pb-sharded", pool=n,
             k=k, ms=round(t * 1e3, 2))
    return rows


def main(quick=False):
    run(quick=quick)


if __name__ == "__main__":
    main()
