"""Paper Fig. 4c / App. C.4: selection cost — PB vs non-PB vs per-class.

Wall-clock of one selection round as the candidate pool grows.  The PB
variant runs OMP on an n/B ground set, so its cost curve is ~B x flatter —
the paper's central scaling trick.  Also times the distributed
(shard_map) OMP path on the 1-device mesh for dispatch-overhead visibility.

The non-PB ``gradmatch`` strategy is additionally timed against the dense
reference OMP solver (``omp_method="dense"``, the seed formulation that
re-gathers the active set and rebuilds the Gram every round) and the
incremental/dense speedup is emitted per pool size — the headline number
for the incremental-Gram rewrite (DESIGN.md §2).

``run_streaming`` times the streaming block-OMP (DESIGN.md §4) against the
in-memory incremental solver at pools up to 65536, recording wall-clock
and peak-memory proxies (chunk + buffer bytes vs resident pool bytes).

``run_greedy`` times the certified lazy / stochastic CRAIG tiers
(DESIGN.md §5) at pools where the dense greedy is skipped, including a
pool-32768 run whose (n, n) similarity is never materialized.

``run_partitioned`` times partition-and-merge sharded selection
(DESIGN.md §9): near-linear partition scaling at 65536 and the flat
streaming-overhead ratio on a >= 1M-row disk-memmap pool.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import make_recorder, time_fn
from repro.core import selection as sel_lib


def run(pool_sizes=(512, 2048, 8192), d=64, budget=0.1, batch=32,
        quick=False) -> list[dict]:
    if quick:
        pool_sizes = (512, 2048)
    rows = []
    record = make_recorder("selection_time", rows)

    try:
        from repro.core.distributed import sharded_gradmatch_pb
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(1, 1)
    except Exception:   # older jax without AxisType / shard_map
        mesh = None
    for n in pool_sizes:
        g = jax.random.normal(jax.random.PRNGKey(n), (n, d))
        labels = jnp.arange(n) % 10
        k = int(n * budget)
        for strategy in ("gradmatch", "gradmatch-pb", "craig", "craig-lazy",
                         "craig-stochastic", "craig-pb", "glister",
                         "random"):
            if strategy == "craig" and n > 8192:
                # O(k·n²) dense greedy: ~2 min per call at 8192 already;
                # beyond that only the lazy/stochastic tiers are timed
                # (the parity gate asserts they select identically).
                continue
            def sel_once(g=g, strategy=strategy, k=k):
                s = sel_lib.select(strategy, jax.random.PRNGKey(0), g, k,
                                   labels=labels, num_classes=10,
                                   batch_size=batch, per_class=False)
                return s.weights
            t = time_fn(sel_once, warmup=1, iters=3)
            record(strategy=strategy, pool=n, k=k, ms=round(t * 1e3, 2))
            if strategy == "gradmatch":
                t_inc = t
        # dense reference OMP (seed solver) for the speedup headline
        def dense_once(g=g, k=k):
            return sel_lib.select("gradmatch", jax.random.PRNGKey(0), g, k,
                                  labels=labels, num_classes=10,
                                  batch_size=batch, per_class=False,
                                  omp_method="dense").weights
        t_dense = time_fn(dense_once, warmup=1, iters=3)
        record(strategy="gradmatch-dense", pool=n, k=k,
               ms=round(t_dense * 1e3, 2))
        record(strategy="gradmatch-speedup", pool=n, k=k,
               speedup=round(t_dense / max(t_inc, 1e-9), 2))
        # per-class decomposition (vmapped OMP)
        def per_class(g=g, k=k):
            return sel_lib.select("gradmatch", jax.random.PRNGKey(0), g, k,
                                  labels=labels, num_classes=10,
                                  batch_size=batch, per_class=True).weights
        t = time_fn(per_class, warmup=1, iters=3)
        record(strategy="gradmatch-perclass", pool=n, k=k,
               ms=round(t * 1e3, 2))
        if mesh is not None:
            # distributed OMP (shard_map path)
            def dist(g=g, k=k):
                return sharded_gradmatch_pb(mesh, g, batch,
                                            max(k // batch, 1)).weights
            t = time_fn(dist, warmup=1, iters=3)
            record(strategy="gradmatch-pb-sharded", pool=n, k=k,
                   ms=round(t * 1e3, 2))
    return rows


def run_streaming(pool_sizes=(8192, 32768, 65536), d=64, k=512,
                  chunk=4096, buffer_size=512, quick=False) -> list[dict]:
    """Streaming block-OMP vs in-memory incremental (core/streaming.py,
    DESIGN.md §7).

    Records wall-clock plus peak-memory proxies (one chunk + top-M
    buffer + the compressed chunk cache, independent of n, versus the
    in-memory solver's resident (n, d) pool) and the multi-round
    engine's amortization accounting: loader ``passes`` (the PR-5
    headline — ~k/B instead of ~k), ``certified_rounds``, cache
    ``refills``/``repairs``/``cache_hit_rate``.  Rows are merge-persisted
    by ``benchmarks.common.persist`` (partial runs never wipe them).
    """
    import numpy as np

    from repro.core import streaming as stream_lib
    from repro.core.omp import omp_select

    if quick:
        pool_sizes = (8192,)
        k = 128
    rows = []
    record = make_recorder("selection_stream", rows)
    for n in pool_sizes:
        g = np.asarray(jax.random.normal(jax.random.PRNGKey(n), (n, d)),
                       np.float32)
        target = jnp.sum(jnp.asarray(g), axis=0)
        chunks = stream_lib.array_chunks(g, chunk)
        fetch = stream_lib.array_row_fetch(g)

        def stream_once(chunks=chunks, target=target, k=k):
            out = stream_lib.omp_select_streaming(
                chunks, target, k, buffer_size=buffer_size,
                row_fetch=fetch)
            jax.block_until_ready(out.weights)
            return out

        out = stream_once()                      # warm + stats
        t_stream = time_fn(lambda: stream_once().weights, warmup=0, iters=3)

        def inmem_once(g=g, target=target, k=k):
            return omp_select(jnp.asarray(g), target, k=k)[1]

        t_inmem = time_fn(inmem_once, warmup=1, iters=3)
        s = out.stats
        row_bytes = stream_lib.ChunkCache(0, d).bytes_per_row
        cache_rows = min(n, stream_lib.DEFAULT_CACHE_BYTES // row_bytes)
        record(strategy="gradmatch-stream", pool=n, k=k,
               ms=round(t_stream * 1e3, 2), passes=s.passes,
               certified_rounds=s.certified_rounds, refills=s.refills,
               repairs=s.repairs, fetched_rows=s.fetched_rows,
               cache_hit_rate=round(s.cache_hit_rate, 4),
               chunk_bytes=chunk * d * 4,
               buffer_bytes=buffer_size * d * 4,
               cache_bytes=cache_rows * row_bytes,
               pool_bytes=n * d * 4)
        record(strategy="gradmatch-stream-inmem", pool=n, k=k,
               ms=round(t_inmem * 1e3, 2), pool_bytes=n * d * 4)
        record(strategy="gradmatch-stream-overhead", pool=n, k=k,
               ratio=round(t_stream / max(t_inmem, 1e-9), 2),
               passes=s.passes, pass_budget=k // 8 + 2)
    return rows


def run_greedy(pool_sizes=(8192, 32768), d=64, k=512, block=64, sample=64,
               quick=False) -> list[dict]:
    """Certified lazy / stochastic CRAIG at pools beyond the dense tier
    (core/greedy.py, DESIGN.md §5).

    Records wall-clock plus the engine's certification accounting
    (rescans vs certified rounds — the entire perf claim) and a
    similarity-memory proxy: above ``greedy._OTF_AUTO_BYTES`` the scan
    tiles s_ij from the gradients on the fly and ``sim_bytes`` drops to 0
    — the (n, n) matrix is never materialized in any memory space.
    """
    from repro.core import greedy as greedy_lib

    if quick:
        pool_sizes = (8192,)
        k = 128
    rows = []
    record = make_recorder("selection_greedy", rows)
    for n in pool_sizes:
        g = jax.random.normal(jax.random.PRNGKey(n), (n, d))
        otf = greedy_lib.auto_on_the_fly(n)
        sim_bytes = 0 if otf else n * n * 4

        def lazy_once(g=g, k=k):
            res = greedy_lib.fl_greedy(g, k, method="lazy", block=block)
            jax.block_until_ready(res.cover)
            return res

        res = lazy_once()                    # warm + certification stats
        t = time_fn(lambda: lazy_once().cover, warmup=0, iters=2)
        record(strategy="craig-lazy", pool=n, k=k, ms=round(t * 1e3, 2),
               on_the_fly=otf, sim_bytes=sim_bytes, pool_bytes=n * d * 4,
               rescans=res.stats.rescans,
               certified_rounds=res.stats.certified_rounds,
               block_evals=res.stats.block_evals)

        def stoch_once(g=g, k=k):
            res = greedy_lib.fl_greedy(g, k, method="stochastic",
                                       key=jax.random.PRNGKey(0),
                                       sample=sample)
            jax.block_until_ready(res.cover)
            return res

        stoch_once()
        t = time_fn(lambda: stoch_once().cover, warmup=0, iters=2)
        record(strategy="craig-stochastic", pool=n, k=k,
               ms=round(t * 1e3, 2), on_the_fly=otf, sim_bytes=sim_bytes,
               pool_bytes=n * d * 4, sample=sample)
        if not otf:
            # Forced on-the-fly row at the resident-sim pool size: the
            # direct regression surface for the otf scan (escalation
            # tier + hoisted norms) at a pool CI can still afford.
            def lazy_otf(g=g, k=k):
                res = greedy_lib.fl_greedy(g, k, method="lazy",
                                           block=block, on_the_fly=True)
                jax.block_until_ready(res.cover)
                return res

            res = lazy_otf()
            t = time_fn(lambda: lazy_otf().cover, warmup=0, iters=2)
            record(strategy="craig-lazy-otf", pool=n, k=k,
                   ms=round(t * 1e3, 2), on_the_fly=True, sim_bytes=0,
                   pool_bytes=n * d * 4, rescans=res.stats.rescans,
                   certified_rounds=res.stats.certified_rounds,
                   block_evals=res.stats.block_evals)
    return rows


def run_serve(pool=8192, d=512, k=64, batch=32, quick=False) -> list[dict]:
    """Serve section (DESIGN.md §6): batched multi-target OMP throughput.

    Times ``batch`` concurrent same-pool requests two ways — sequentially
    through per-request ``omp_select`` (what a naive service would do) and
    as one ``omp_select_batched`` solve (what the scheduler's micro-batch
    does) — and records the throughput ratio.  Acceptance for the serve
    subsystem: >= 5x at 32 concurrent requests on the 8192 pool.

    The shape is the serving regime batching actually amortizes: a
    realistic proxy dimension (d = 512, the hidden-grad / projected-LM
    proxy scale) where the per-round pool scan — shared across the batch
    in the batched solver, paid per request sequentially — dominates the
    per-target O(k·d) active-set work.  At tiny proxy dims (d = 64, the
    unit-test scale) both paths are bound by the same per-target NNLS
    traffic and batching is roughly neutral.  Also times the anytime
    path: extending a session ``k/2 -> k`` versus paying a one-shot ``k``
    solve again.
    """
    import numpy as np

    from repro.core.omp import (omp_select, omp_select_batched,
                                omp_session_extend, omp_session_start)

    if quick:
        pool, d, k, batch = 2048, 128, 32, 8
    rows = []
    record = make_recorder("selection_serve", rows)
    g = jax.random.normal(jax.random.PRNGKey(pool), (pool, d))
    # Per-request targets: random non-negative row mixtures (distinct
    # per-tenant targets that actually correlate with the pool, like
    # per-class or validation-gradient targets do).
    mix = jax.random.uniform(jax.random.PRNGKey(1), (batch, pool))
    targets = mix @ g                                        # (B, d)

    def sequential(g=g, targets=targets, k=k):
        outs = [omp_select(g, targets[b], k=k)[1] for b in range(batch)]
        jax.block_until_ready(outs[-1])
        return outs

    def batched(g=g, targets=targets, k=k):
        return omp_select_batched(g, targets, k=k)[1]

    t_seq = time_fn(sequential, warmup=1, iters=3)
    t_bat = time_fn(batched, warmup=1, iters=3)
    speedup = t_seq / max(t_bat, 1e-9)
    record(strategy="serve-sequential", pool=pool, k=k, requests=batch,
           ms=round(t_seq * 1e3, 2),
           req_per_s=round(batch / t_seq, 2))
    record(strategy="serve-batched", pool=pool, k=k, requests=batch,
           ms=round(t_bat * 1e3, 2),
           req_per_s=round(batch / t_bat, 2))
    record(strategy="serve-batched-speedup", pool=pool, k=k,
           requests=batch, speedup=round(speedup, 2), acceptance=5.0)

    # Anytime extension: k/2 -> k resume vs a fresh one-shot k solve.
    target = targets[0]
    sess_half = omp_session_start(g, target, k // 2)
    jax.block_until_ready(sess_half.st.err)

    def extend(sess=sess_half, g=g, k=k):
        out = omp_session_extend(g, sess, k)
        jax.block_until_ready(out.st.err)
        return out

    def oneshot(g=g, target=target, k=k):
        return omp_select(g, target, k=k)[1]

    t_ext = time_fn(extend, warmup=1, iters=3)
    t_one = time_fn(oneshot, warmup=1, iters=3)
    record(strategy="serve-extend", pool=pool, k=k, k_from=k // 2,
           ms=round(t_ext * 1e3, 2))
    record(strategy="serve-extend-oneshot", pool=pool, k=k,
           ms=round(t_one * 1e3, 2))
    record(strategy="serve-extend-saving", pool=pool, k=k, k_from=k // 2,
           ratio=round(t_one / max(t_ext, 1e-9), 2))
    return rows


def run_artifacts(pool=8192, d=64, k=512, quick=False) -> list[dict]:
    """Artifact fast-path section (DESIGN.md §12): amortizing the solve.

    Times the full offline/online split at the parity-gate shape: the
    one-time trajectory build (an anytime solve to ``k_max`` plus
    content-addressed commit), the *cold* serve hit (disk read + full
    integrity verification + memoize), the steady-state hit (dict probe
    + O(k) slice at submit), and the live certified submit+drain it
    replaces.  Acceptance: steady-state hits >= 20x faster than live
    (the gate re-checks this every CI run).
    """
    import os
    import tempfile
    import time as _time

    import numpy as np

    from repro.artifacts import ArtifactStore, build_artifact
    from repro.serve.service import SelectionService

    if quick:
        pool, d, k = 2048, 32, 128
    rows = []
    record = make_recorder("selection_artifacts", rows)
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(pool), (pool, d)),
                   np.float32)
    with tempfile.TemporaryDirectory() as root:
        store = ArtifactStore(root)
        svc = SelectionService(artifact_store=store)
        pid = svc.register_pool(g)
        entry = svc.registry.get(pid)
        tgt = np.asarray(entry.target_sum, np.float32)

        t0 = _time.perf_counter()
        build_artifact(store, g, tgt, k,
                       fingerprint=entry.content_digest)
        t_build = _time.perf_counter() - t0
        store_bytes = sum(
            os.path.getsize(os.path.join(dp, f))
            for dp, _, fs in os.walk(root) for f in fs)
        record(strategy="artifact-build", pool=pool, d=d, k_max=k,
               ms=round(t_build * 1e3, 2),
               store_mb=round(store_bytes / 2**20, 3))

        # Cold hit: disk read + per-blob sha/norm verification + memoize.
        t0 = _time.perf_counter()
        t = svc.submit(pid, k)
        t_cold = _time.perf_counter() - t0
        assert t.degradation == "artifact", t.degradation
        record(strategy="artifact-hit-cold", pool=pool, k=k,
               ms=round(t_cold * 1e3, 3))

        def hit():
            assert svc.submit(pid, k).degradation == "artifact"

        t_hit = time_fn(hit, warmup=1, iters=5)
        record(strategy="artifact-hit", pool=pool, k=k,
               ms=round(t_hit * 1e3, 3),
               req_per_s=round(1.0 / max(t_hit, 1e-9), 1))

        live = SelectionService()
        live_pid = live.register_pool(g)

        def live_solve():
            live.submit(live_pid, k)
            live.drain()

        t_live = time_fn(live_solve, warmup=1, iters=3)
        record(strategy="serve-live-certified", pool=pool, k=k,
               ms=round(t_live * 1e3, 2))
        accept = {} if quick else {"acceptance": 20.0}
        record(strategy="artifact-speedup", pool=pool, k=k,
               speedup=round(t_live / max(t_hit, 1e-9), 1), **accept)
    return rows


def run_faults(pool=8192, d=64, k=256, chunk=1024, buffer_size=256,
               rate=0.2, seed=11, quick=False) -> list[dict]:
    """Fault-recovery overhead + degradation accounting (DESIGN.md §8).

    Times the streaming solve under seeded transient faults (zero-backoff
    retries, so the ratio measures re-read work, not sleeps) against the
    fault-free run and asserts the differential guarantee held
    (``parity``); also measures a kill/checkpoint/resume cycle and one
    serve-tier walk down the degradation ladder.  The acceptance target
    is ``overhead <= 1.5`` at well above a 5% chunk fault rate.
    """
    import shutil
    import tempfile

    import numpy as np

    from repro.core import streaming as stream_lib
    from repro.resilience import (FaultPlan, FaultyChunkIterator,
                                  RetryPolicy, faulty_row_fetch)

    if quick:
        pool, k = 2048, 64
    rows = []
    record = make_recorder("selection_faults", rows)
    pol = RetryPolicy(max_retries=8, backoff_s=0.0, sleep=lambda s: None)
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(pool), (pool, d)),
                   np.float32)
    target = jnp.sum(jnp.asarray(g), axis=0)
    chunks = stream_lib.array_chunks(g, chunk)
    fetch = stream_lib.array_row_fetch(g)
    plan = FaultPlan(seed=seed, transient_rate=rate, row_transient_rate=rate)

    def solve(ci, rf):
        out = stream_lib.omp_select_streaming(
            ci, target, k, buffer_size=buffer_size, row_fetch=rf,
            retry=pol)
        jax.block_until_ready(out.weights)
        return out

    ref = solve(chunks, fetch)                       # warm + reference
    t_clean = time_fn(lambda: solve(chunks, fetch).weights,
                      warmup=0, iters=3)
    fci = FaultyChunkIterator(chunks, plan)
    frf = faulty_row_fetch(fetch, plan)
    out = solve(fci, frf)                            # stats + parity run
    parity = bool(jnp.all(out.indices == ref.indices)) and bool(
        jnp.all(out.mask == ref.mask))
    t_fault = time_fn(
        lambda: solve(FaultyChunkIterator(chunks, plan),
                      faulty_row_fetch(fetch, plan)).weights,
        warmup=0, iters=3)
    record(strategy="stream-faulted", pool=pool, k=k,
           ms=round(t_fault * 1e3, 2), ms_clean=round(t_clean * 1e3, 2),
           overhead=round(t_fault / max(t_clean, 1e-9), 3),
           fault_rate=rate,
           injected=sum(fci.injected.values()) + sum(frf.injected.values()),
           retries=out.stats.retries, quarantined=out.stats.quarantined,
           parity=parity)

    # kill mid-solve -> resume from checkpoint: the recovery the serve
    # tier's "resumed" rung pays for.
    n2, k2 = pool // 4, max(k // 4, 16)
    g2 = g[:n2]
    t2 = jnp.sum(jnp.asarray(g2), axis=0)
    c2 = stream_lib.array_chunks(g2, chunk // 4)
    ref2 = stream_lib.omp_select_streaming(c2, t2, k2, buffer_size=64,
                                           cache_bytes=0, retry=pol)
    td = tempfile.mkdtemp(prefix="bench-faults-")
    try:
        dying = FaultyChunkIterator(
            c2, FaultPlan(seed=seed,
                          die_after_chunks=3 * (n2 // (chunk // 4))))
        try:
            stream_lib.omp_select_streaming(
                dying, t2, k2, buffer_size=64, cache_bytes=0, retry=pol,
                checkpoint_dir=td, checkpoint_every=1)
            killed = False
        except Exception:
            killed = True
        t0 = time.perf_counter()
        res = stream_lib.omp_select_streaming(
            c2, t2, k2, buffer_size=64, cache_bytes=0, retry=pol,
            checkpoint_dir=td, checkpoint_every=1)
        t_resume = time.perf_counter() - t0
        record(strategy="stream-kill-resume", pool=n2, k=k2,
               ms=round(t_resume * 1e3, 2), killed=killed,
               resumes=res.stats.resumes,
               parity=bool(jnp.all(res.indices == ref2.indices)))
    finally:
        shutil.rmtree(td, ignore_errors=True)

    # serve tier: poisoned pool walks the ladder to the stochastic rung.
    from repro.data.loader import ChunkedPool
    from repro.serve import SelectionService

    svc = SelectionService(max_batch=8, retry_policy=pol)
    dead = FaultyChunkIterator(
        stream_lib.chunked_pool_iter(ChunkedPool(g2, chunk_size=chunk // 4)),
        FaultPlan(seed=seed, die_after_chunks=n2 // (chunk // 4) + 1))
    pid = svc.register_chunked_pool(dead)
    svc.scheduler.stream_buffer = 16
    t0 = time.perf_counter()
    ticket = svc.submit(pid, k=k2)
    svc.drain()
    record(strategy="serve-degrade", pool=n2, k=k2,
           ms=round((time.perf_counter() - t0) * 1e3, 2),
           status=ticket.status, degradation=ticket.degradation,
           **{f"served_{lvl}": cnt for lvl, cnt in
              svc.scheduler.stats()["degraded_served"].items()})
    return rows


def run_partitioned(scale_pool=65536, scale_parts=(1, 2, 4, 8), d=64,
                    k=512, ooc_pool=1 << 20, part_rows=65536,
                    quick=False) -> list[dict]:
    """Partition-and-merge sharded selection (core/partition.py,
    DESIGN.md §9) — the two claims this table tracks:

    * **near-linear partition scaling** at a fixed pool: total engine
      rounds drop to ~k/P per partition, so the streaming solve speeds up
      close to P even on one device (the P = 1 row *is* the plain
      streaming engine over the whole pool).
    * **flat out-of-core overhead**: growing the pool 65k -> >= 1M rows at
      fixed per-partition size (``part_rows`` rows, so P = n /
      ``part_rows``) keeps the streaming-overhead ratio (partitioned
      stream vs the same partitioned solve on a resident pool) within
      1.5x of the 65k ratio — versus the unpartitioned engine whose
      ratio climbed 3.75x@8k -> 8.6x@65k (``selection_stream``).  The
      >= 1M-row pool lives in a disk memmap: the solver's certified
      engines never hold more than one partition's working set.
    """
    import os
    import shutil
    import tempfile

    import numpy as np

    from repro.core import partition as part_lib

    if quick:
        scale_pool, scale_parts, k = 16384, (1, 2, 4), 128
        ooc_pool, part_rows = 65536, 16384
    rows = []
    record = make_recorder("selection_partitioned", rows)

    g = np.asarray(jax.random.normal(jax.random.PRNGKey(scale_pool),
                                     (scale_pool, d)), np.float32)

    def timed_pair(pool_arr, p):
        def stream_once():
            res = part_lib.gradmatch_partitioned_stream(
                pool=pool_arr, k=k, partitions=p)
            jax.block_until_ready(res.weights)
            return res

        def inmem_once():
            res = part_lib.gradmatch_partitioned(
                np.asarray(pool_arr), k, partitions=p, kind="contiguous")
            jax.block_until_ready(res.weights)
            return res

        res = stream_once()                      # warm + stats
        t_stream = time_fn(lambda: stream_once().weights, warmup=0, iters=2)
        inmem_once()
        t_inmem = time_fn(lambda: inmem_once().weights, warmup=0, iters=2)
        return res, t_stream, t_inmem

    t_p1 = ratio_65k = None
    for p in scale_parts:
        res, t_stream, t_inmem = timed_pair(g, p)
        if t_p1 is None:
            t_p1 = t_stream
        s = res.stats.stream
        record(strategy="gradmatch-partitioned-stream", pool=scale_pool,
               k=k, partitions=p, ms=round(t_stream * 1e3, 2),
               speedup_vs_p1=round(t_p1 / max(t_stream, 1e-9), 2),
               union=res.stats.union_size, merged=res.stats.merged,
               passes=s.passes, certified_rounds=s.certified_rounds,
               err=round(float(res.err), 3))
        record(strategy="gradmatch-partitioned-inmem", pool=scale_pool,
               k=k, partitions=p, ms=round(t_inmem * 1e3, 2))
        ratio = t_stream / max(t_inmem, 1e-9)
        record(strategy="gradmatch-partitioned-overhead", pool=scale_pool,
               k=k, partitions=p, ratio=round(ratio, 2))
        if p == scale_pool // part_rows:
            ratio_65k = ratio
    if ratio_65k is None:          # per-partition anchor not in the grid
        ratio_65k = ratio

    # Out-of-core: >= 1M rows on disk, P sized to part_rows per partition.
    td = tempfile.mkdtemp(prefix="bench-partitioned-")
    try:
        mm = np.memmap(os.path.join(td, "pool.f32"), np.float32, mode="w+",
                       shape=(ooc_pool, d))
        for i in range(0, ooc_pool, 65536):
            stop = min(i + 65536, ooc_pool)
            mm[i:stop] = np.asarray(
                jax.random.normal(jax.random.PRNGKey(i), (stop - i, d)),
                np.float32)
        mm.flush()
        p_ooc = max(ooc_pool // part_rows, 2)
        res, t_stream, t_inmem = timed_pair(mm, p_ooc)
        s = res.stats.stream
        record(strategy="gradmatch-partitioned-stream", pool=ooc_pool,
               k=k, partitions=p_ooc, ms=round(t_stream * 1e3, 2),
               out_of_core=True, pool_bytes=ooc_pool * d * 4,
               union=res.stats.union_size, merged=res.stats.merged,
               passes=s.passes, certified_rounds=s.certified_rounds,
               err=round(float(res.err), 3))
        record(strategy="gradmatch-partitioned-inmem", pool=ooc_pool,
               k=k, partitions=p_ooc, ms=round(t_inmem * 1e3, 2))
        ratio_ooc = t_stream / max(t_inmem, 1e-9)
        record(strategy="gradmatch-partitioned-overhead", pool=ooc_pool,
               k=k, partitions=p_ooc, ratio=round(ratio_ooc, 2),
               out_of_core=True)
        # The 1.5x acceptance is a full-scale claim: below ~65k-row
        # partitions the per-partition fixed costs (dispatch, target
        # pass startup) dominate the numerator and the quick grid's
        # flatness is informational only.
        accept = {} if quick else {"acceptance": 1.5}
        record(strategy="gradmatch-partitioned-flat", pool=ooc_pool, k=k,
               part_rows=part_rows, ratio_small=round(ratio_65k, 2),
               ratio_ooc=round(ratio_ooc, 2),
               flatness=round(ratio_ooc / max(ratio_65k, 1e-9), 2),
               **accept)
    finally:
        shutil.rmtree(td, ignore_errors=True)
    return rows


def run_continual(d=64, k=64, capacity=1024, batch=128, batches=110,
                  down_k=512, down_pool=2048, quick=False) -> list[dict]:
    """Continual-stream maintenance (DESIGN.md §11): sustained admission
    throughput with flat memory over >= 100 batches, and the decremental
    downdate against a from-scratch re-solve at k=512 (the >= 5x
    acceptance — removing the last committed pick must not cost a
    re-solve)."""
    import numpy as np

    from repro.continual import BufferMaintainer
    from repro.core import omp
    from repro.core.decremental import omp_downdate

    if quick:
        k, capacity, batch, batches = 16, 128, 32, 12
        down_k, down_pool = 128, 512
    rows = []
    record = make_recorder("selection_continual", rows)

    # Sustained stream: random batches forever, memory must stay flat.
    rng = np.random.default_rng(0)
    tgt = rng.standard_normal(d).astype(np.float32)
    m = BufferMaintainer(capacity=capacity, d=d, target=tgt, k=k,
                         compress=True, seed=0)
    m.admit(rng.standard_normal((batch, d)).astype(np.float32))  # warmup
    mem_first = m.memory_bytes()
    t0 = time.perf_counter()
    for _ in range(batches - 1):
        m.admit(rng.standard_normal((batch, d)).astype(np.float32))
    jax.block_until_ready(m._sess.st.weights)
    elapsed = time.perf_counter() - t0
    mem_last = m.memory_bytes()
    record(strategy="gradmatch-continual-stream", d=d, k=k,
           capacity=capacity, batch=batch, batches=batches,
           rows_per_s=round(batch * (batches - 1) / max(elapsed, 1e-9), 1),
           admits=m.stats.admits, evicts=m.stats.evicts,
           downdates=m.stats.downdates, resolves=m.stats.resolves,
           replayed_rounds=m.stats.rounds,
           mem_first=mem_first, mem_last=mem_last,
           mem_ratio=round(mem_last / max(mem_first, 1), 4))

    # Decremental downdate vs from-scratch re-solve at the big budget.
    g = jax.random.normal(jax.random.PRNGKey(1), (down_pool, d))
    target = jnp.sum(g, axis=0)
    sess = omp.omp_session_start(g, target, down_k)
    last = int(np.asarray(sess.indices)[down_k - 1])
    t_down = time_fn(
        lambda: omp_downdate(g, sess, last)[0].st.weights,
        warmup=1, iters=3)
    t_solve = time_fn(
        lambda: omp.omp_session_start(g, target, down_k).st.weights,
        warmup=0, iters=2)
    speedup = t_solve / max(t_down, 1e-9)
    accept = {} if quick else {"acceptance": 5.0}
    record(strategy="gradmatch-continual-downdate", pool=down_pool, d=d,
           k=down_k, ms_downdate=round(t_down * 1e3, 2),
           ms_resolve=round(t_solve * 1e3, 2),
           speedup=round(speedup, 2), **accept)
    return rows


def main(quick=False) -> list[dict]:
    return (run(quick=quick) + run_streaming(quick=quick)
            + run_greedy(quick=quick) + run_serve(quick=quick)
            + run_partitioned(quick=quick) + run_faults(quick=quick)
            + run_continual(quick=quick) + run_artifacts(quick=quick))


if __name__ == "__main__":
    main()
