"""Fault-tolerant pytree checkpointing (no orbax in this container).

Properties a 1000-node run needs, all implemented here:

  - **Atomicity**: write to ``<dir>/tmp.<step>``, fsync files, then a single
    ``os.rename`` to ``step_<n>`` — a crash mid-write never corrupts the
    latest checkpoint, restore simply ignores tmp dirs.
  - **Async**: ``CheckpointManager.save(..., blocking=False)`` snapshots
    device arrays to host (cheap) and hands serialization to a writer
    thread; training continues. ``wait()`` joins before the next save or
    exit.
  - **Keep-K GC**: old steps are pruned after a successful rename (never
    before), so there is always a complete checkpoint on disk.
  - **Reshard-on-restore**: arrays are stored with their pytree paths;
    ``restore_sharded`` device_puts each leaf with a *target* sharding that
    may differ from the one it was saved under — this is the elastic-scaling
    path (launch/elastic.py): N-device checkpoints restore onto M devices.
  - **Full training state**: params, optimizer state, data-pipeline state,
    selection state (X^t, w^t, round) and RNG all live in one pytree, so a
    restart resumes bit-exact mid-epoch.

Format: one ``.npz`` (zip of .npy) per checkpoint + a JSON manifest holding
the treedef (paths) — no pickle, robust across refactors.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_SEP = "/"

# numpy's npz cannot round-trip ml_dtypes (bfloat16, fp8): store them as
# same-width unsigned views and restore from the manifest's dtype record.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_storable(arr: np.ndarray) -> np.ndarray:
    name = arr.dtype.name
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name])
    return arr


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_AS:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}

    def keystr(kp) -> str:
        parts = []
        for k in kp:
            for attr in ("key", "idx", "name"):
                if hasattr(k, attr):
                    parts.append(str(getattr(k, attr)))
                    break
            else:
                parts.append(str(k))
        return _SEP.join(parts)

    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[keystr(kp)] = np.asarray(leaf)
    return flat


def _unflatten(flat: dict[str, np.ndarray]) -> Any:
    """Rebuild nested dicts/lists from path keys.

    Lists are stored as dicts with integer-string keys; we rebuild dicts
    only (every pytree we checkpoint is dict/NamedTuple-as-dict shaped).
    """
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save_checkpoint(directory: str, step: int, tree: Any,
                    keep: Optional[int] = None) -> str:
    """Atomic synchronous save.  Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(directory, f"tmp.{step}.{os.getpid()}")
    final = os.path.join(directory, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arr_path = os.path.join(tmp, "arrays.npz")
    with open(arr_path, "wb") as f:
        np.savez(f, **{k: _to_storable(v) for k, v in flat.items()})
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    man_path = os.path.join(tmp, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    if keep is not None:
        _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    """Prune to the newest ``keep`` *intact* checkpoints.

    A ``step_`` dir without its manifest is a partial write (a kill
    after the rename of a dir that never finished filling, or a botched
    manual copy) — it can never be restored, so it is swept as an orphan
    rather than counted toward keep-K.  Counting it would silently
    shrink the real retention: ``keep=2`` with one orphan would leave
    only one restorable checkpoint.
    """
    steps = sorted(
        d for d in os.listdir(directory) if d.startswith("step_"))
    intact = [d for d in steps
              if os.path.exists(os.path.join(directory, d, "manifest.json"))]
    orphans = [d for d in steps if d not in intact]
    doomed = orphans + (intact[:-keep] if keep > 0 else [])
    for d in doomed:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    # stale tmp dirs from crashed writers
    for d in os.listdir(directory):
        if d.startswith("tmp."):
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def intact_steps(directory: str) -> list[int]:
    """Step numbers with a manifest on disk, ascending.  Intact here
    means "the atomic rename completed" — array contents may still be
    unreadable (bit rot), which only ``load_checkpoint`` can discover."""
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_")
        and os.path.exists(os.path.join(directory, d, "manifest.json")))


def latest_step(directory: str) -> Optional[int]:
    steps = intact_steps(directory)
    return steps[-1] if steps else None


def load_checkpoint(directory: str, step: Optional[int] = None) -> dict:
    """Load (nested-dict) checkpoint; ``step=None`` -> latest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: _from_storable(z[k], manifest["dtypes"][k])
                for k in z.files}
    return _unflatten(flat)


def restore_sharded(tree_np: Any, shardings: Any) -> Any:
    """device_put each leaf with its target sharding (reshard-on-restore).

    ``shardings`` is a matching pytree of ``jax.sharding.Sharding`` (or None
    for single-device).  The checkpoint layout is independent of the saving
    mesh, so an 8-way checkpoint restores onto 4 or 16 devices unchanged.
    """
    def put(x, s):
        return jax.device_put(np.asarray(x), s) if s is not None else (
            jax.numpy.asarray(x))

    return jax.tree_util.tree_map(put, tree_np, shardings)


class CheckpointManager:
    """Async keep-K checkpointer."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()  # one in-flight write at a time

        host_tree = jax.tree_util.tree_map(
            lambda x: np.array(x, copy=True), tree)  # snapshot now

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore(self, step: Optional[int] = None) -> dict:
        self.wait()
        return load_checkpoint(self.directory, step)
