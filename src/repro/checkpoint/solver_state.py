"""Mid-solve checkpoints for the streaming selection engine.

The training checkpointer (``checkpoint.py``) already has everything a
killed process needs — atomic tmp+rename, npz + JSON manifest, bf16
stored as uint16 views, keep-K GC.  This module is the thin contract the
streaming solver (``core/streaming.py``) uses on top of it: a snapshot of
the commit-loop state (Gram/NNLS prefix, buffer, compressed-cache
manifest, pass/round counters) is just a nested dict of arrays, saved
every ``checkpoint_every`` committed rounds and restored by the next
solve over the same pool so a killed multi-round solve resumes
bit-exactly (tests/test_resilience.py kills a solve mid-stream and
asserts the resumed selection equals the fault-free run's).

``load_solver_state`` returns ``None`` when there is nothing to resume —
a fresh solve with ``checkpoint_dir`` set must not fail just because it
is the first one.
"""

from __future__ import annotations

from typing import Any, Optional

from repro.checkpoint.checkpoint import (intact_steps, load_checkpoint,
                                         save_checkpoint)


def save_solver_state(directory: str, step: int, tree: Any,
                      keep: int = 2) -> str:
    """Atomically persist one solver snapshot; keeps the last ``keep``."""
    return save_checkpoint(directory, step, tree, keep=keep)


def load_solver_state(directory: str) -> Optional[dict]:
    """Newest *loadable* solver snapshot under ``directory``, or None.

    Newest-first with fallback: a step dir whose manifest survived but
    whose arrays did not (bit rot, torn npz, emptied dir) must not sink
    the resume — keep-2 retention exists precisely so the previous
    intact step can take over.  Only when no retained step loads does
    this report "nothing to resume" (the caller starts fresh, which is
    always correct, just slower)."""
    for step in reversed(intact_steps(directory)):
        try:
            return load_checkpoint(directory, step)
        except Exception:
            continue
    return None
