from repro.checkpoint.checkpoint import (CheckpointManager, load_checkpoint,
                                         restore_sharded, save_checkpoint)

__all__ = [
    "CheckpointManager",
    "load_checkpoint",
    "restore_sharded",
    "save_checkpoint",
]
