from repro.checkpoint.checkpoint import (CheckpointManager, load_checkpoint,
                                         restore_sharded, save_checkpoint)
from repro.checkpoint.solver_state import (load_solver_state,
                                           save_solver_state)

__all__ = [
    "CheckpointManager",
    "load_checkpoint",
    "load_solver_state",
    "restore_sharded",
    "save_checkpoint",
    "save_solver_state",
]
