"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only transformer (same trunk as wav2vec2-XL) trained with masked-unit
cross-entropy over 504 cluster units.  [arXiv:2106.07447; unverified]

Modality frontend (conv feature extractor) is a STUB per the assignment:
``input_specs()`` feeds precomputed frame embeddings (B, T, 1280).  The
original uses a convolutional relative positional embedding; we substitute
RoPE inside attention (TPU-friendly, documented in DESIGN.md SS5).
Encoder-only => no decode step: ``decode_32k`` / ``long_500k`` are skipped.
"""

from repro.configs.base import ATTN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        source="arXiv:2106.07447",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,          # padded_vocab -> 512 for the sharded head
        layer_pattern=(ATTN,),
        n_superblocks=48,
        encoder_only=True,
        causal=False,
        act="gelu",
        norm="layernorm",
        rope=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_superblocks=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=96, remat=False,
    )
