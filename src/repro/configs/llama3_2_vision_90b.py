"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256.  80 self-attention layers + 20 cross-attention (image) layers:
every 5th layer cross-attends to vision states.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Super-block = (4x self-attn + 1x cross-attn), x20 = 100 layers.  The vision
encoder is a STUB per the assignment: ``input_specs()`` provides precomputed
patch embeddings (B, 1600, 8192); the cross-attn layers hold their own
KV projections over those states.  Pure full attention => ``long_500k``
skipped.
"""

from repro.configs.base import ATTN, XATTN, ModelConfig, VisionStubConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        source="hf:meta-llama/Llama-3.2-90B-Vision",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        layer_pattern=(ATTN,) * 4 + (XATTN,),
        n_superblocks=20,
        act="swiglu",
        norm="rmsnorm",
        rope=True,
        rope_theta=500_000.0,
        vision=VisionStubConfig(n_tokens=1600, d_embed=8192),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=5, n_superblocks=1, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=96, remat=False,
        vision=VisionStubConfig(n_tokens=16, d_embed=64),
    )
