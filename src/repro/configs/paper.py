"""Configs for the paper-faithful reproduction experiments.

The paper trains LeNet on MNIST and ResNet18 on CIFAR*/ImageNet.  No image
datasets ship in this container, so the repro experiments run the SAME
selection machinery on structured synthetic classification data (gaussian
mixtures with class structure + optional class imbalance — see
``data/synthetic.py``) with the small classifiers below.  All paper
hyper-parameters that matter to the technique are kept: lambda=0.5, R=20,
kappa=1/2, budgets {1,3,5,10,20,30}%, SGD momentum 0.9, weight decay 5e-4,
cosine annealing.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class ClassifierConfig:
    """Small classification net for the paper-repro experiments.

    ``kind='mlp'`` is a LeNet-scale 2-hidden-layer net on flat features;
    ``kind='cnn'`` is a LeNet-style conv net on (H, W, C) images.
    """

    name: str = "paper-mlp"
    kind: str = "mlp"                 # 'mlp' | 'cnn'
    in_dim: int = 64                  # flat feature dim (mlp)
    image_shape: Tuple[int, int, int] = (28, 28, 1)   # (cnn)
    hidden: Tuple[int, ...] = (128, 64)
    num_classes: int = 10
    act: str = "relu"


@dataclass(frozen=True)
class PaperHParams:
    """Paper SS5 experimental setting (Appendix C.2/C.3)."""

    lam: float = 0.5            # OMP regularizer (Fig. 4g: best at 0.5)
    eps: float = 1e-10          # OMP tolerance (paper: 1e-10)
    select_every: int = 20      # R = 20
    kappa: float = 0.5          # warm-start fraction (Fig. 4f: best at 1/2)
    lr: float = 0.01
    momentum: float = 0.9
    weight_decay: float = 5e-4
    cosine_anneal: bool = True
    budgets: Tuple[float, ...] = (0.05, 0.10, 0.20, 0.30)


def lenet() -> ClassifierConfig:
    return ClassifierConfig(name="paper-lenet", kind="cnn",
                            image_shape=(28, 28, 1), hidden=(120, 84))


def mlp(in_dim: int = 64, num_classes: int = 10) -> ClassifierConfig:
    return ClassifierConfig(name="paper-mlp", kind="mlp", in_dim=in_dim,
                            num_classes=num_classes)
