"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (kv=32, full MHA) d_ff=13440
vocab=92416.  Qwen1.5 architecture: SwiGLU, RMSNorm, RoPE theta=1e6, QKV
projection bias.  [hf:Qwen/CodeQwen1.5-7B; hf]

Pure full attention => ``long_500k`` skipped.
"""

from repro.configs.base import ATTN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        family="dense",
        source="hf:Qwen/CodeQwen1.5-7B",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=32,
        head_dim=128,
        d_ff=13440,
        vocab_size=92416,
        layer_pattern=(ATTN,),
        n_superblocks=32,
        act="swiglu",
        norm="rmsnorm",
        rope=True,
        rope_theta=1_000_000.0,
        attn_bias=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_superblocks=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=96, remat=False,
    )
