"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.

GQA + RoPE, LayerNorm, plain-GELU MLP (4x), tied embeddings.
[arXiv:2402.19173; hf]   Pure full attention => ``long_500k`` skipped.
(The HF config uses a 4096 sliding window during training; the released
model serves full attention — we model full attention.)
"""

from repro.configs.base import ATTN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        source="arXiv:2402.19173",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        head_dim=128,
        d_ff=12288,
        vocab_size=49152,
        layer_pattern=(ATTN,),
        n_superblocks=30,
        act="gelu",
        norm="layernorm",
        rope=True,
        rope_theta=999999.4420358813,
        attn_bias=True,
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_superblocks=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=96, remat=False,
    )
