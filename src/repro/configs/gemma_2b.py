"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.

GeGLU activations, head_dim=256, multi-query attention, tied embeddings,
embeddings scaled by sqrt(d_model).  [arXiv:2403.08295; hf]

Pure full attention => ``long_500k`` skipped.
"""

from repro.configs.base import ATTN, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        source="arXiv:2403.08295",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        layer_pattern=(ATTN,),
        n_superblocks=18,
        act="geglu",
        norm="rmsnorm",
        rope=True,
        tie_embeddings=True,
        embed_scale=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_superblocks=2, d_model=64, n_heads=4, n_kv_heads=1,
        head_dim=16, d_ff=128, vocab_size=96, remat=False,
    )
