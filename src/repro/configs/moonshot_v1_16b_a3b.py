"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=163840, MoE 64 experts top-6 (+2 always-on shared experts,
DeepSeek/Moonlight style).  [hf:moonshotai/Moonlight-16B-A3B; hf]

Adaptation notes (DESIGN.md SS5): Moonlight's leading dense layer is modelled
as MoE like the rest (keeps the scanned super-block homogeneous; the FLOP
difference is <1%).  Pure full attention => ``long_500k`` skipped.
"""

from repro.configs.base import ATTN, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b",
        family="moe",
        source="hf:moonshotai/Moonlight-16B-A3B",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163840,
        layer_pattern=(ATTN,),
        n_superblocks=48,
        act="swiglu",
        norm="rmsnorm",
        rope=True,
        rope_theta=50_000.0,
        moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared_experts=2),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_superblocks=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=32, vocab_size=96, remat=False,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared_experts=1),
    )
