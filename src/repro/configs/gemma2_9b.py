"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000.

Local(4096-window)/global alternating attention, attention-logit softcap 50,
final-logit softcap 30, pre+post RMSNorm, GeGLU, head_dim=256, tied
embeddings.  [arXiv:2408.00118; hf]

Super-block = (local, global) pair, x21 = 42 layers.  ``long_500k`` IS run:
half the layers are sliding-window (KV residency O(window)), and the global
half decodes against a sequence-sharded 500k KV cache — documented choice in
DESIGN.md SS5.
"""

from repro.configs.base import GLOBAL, LOCAL, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        source="arXiv:2408.00118",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        layer_pattern=(LOCAL, GLOBAL),
        n_superblocks=21,
        act="geglu",
        norm="rmsnorm",
        post_norm=True,
        rope=True,
        sliding_window=4096,
        attn_softcap=50.0,
        logit_softcap=30.0,
        tie_embeddings=True,
        embed_scale=True,
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=4, n_superblocks=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=96, sliding_window=32, remat=False,
    )
