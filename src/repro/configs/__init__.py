"""Architecture registry: ``--arch <id>`` resolution for every driver.

``get_config(name)`` / ``get_smoke_config(name)`` return the full published
config / the CPU-runnable reduced config.  ``applicable_shapes(cfg)`` applies
the assignment's skip rules (encoder-only has no decode; ``long_500k`` only
for sub-quadratic archs) and is the single place cell skips are decided.
"""

from __future__ import annotations

from repro.configs import (
    codeqwen1_5_7b,
    gemma2_9b,
    gemma_2b,
    hubert_xlarge,
    llama3_2_vision_90b,
    moonshot_v1_16b_a3b,
    paper,
    qwen3_moe_30b_a3b,
    starcoder2_3b,
    xlstm_1_3b,
    zamba2_7b,
)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, human

_MODULES = {
    "hubert-xlarge": hubert_xlarge,
    "xlstm-1.3b": xlstm_1_3b,
    "gemma-2b": gemma_2b,
    "gemma2-9b": gemma2_9b,
    "starcoder2-3b": starcoder2_3b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "zamba2-7b": zamba2_7b,
    "llama-3.2-vision-90b": llama3_2_vision_90b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return _MODULES[name].config()


def get_smoke_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return _MODULES[name].smoke_config()


def applicable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    """Assignment skip rules; skipped cells are documented in DESIGN.md SS5."""
    out = []
    for shape in SHAPES.values():
        if cfg.encoder_only and shape.kind == "decode":
            continue  # encoder-only: no decode step
        if shape.name == "long_500k" and not cfg.subquadratic:
            continue  # pure full attention: 500k decode skipped
        out.append(shape)
    return out


def all_cells() -> list[tuple[str, str]]:
    """Every runnable (arch, shape) dry-run cell."""
    cells = []
    for arch in ARCH_IDS:
        for shape in applicable_shapes(get_config(arch)):
            cells.append((arch, shape.name))
    return cells


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "all_cells",
    "applicable_shapes",
    "get_config",
    "get_smoke_config",
    "human",
    "paper",
]
