"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64.  Mamba2 backbone + ONE shared attention+MLP block invoked
periodically (weight sharing across invocations).  [arXiv:2411.15242;
unverified]

Layer layout (DESIGN.md SS5): 3 leading Mamba2 blocks (prologue), then 13
scanned super-blocks of (5x Mamba2 + 1 shared-attn invocation) = 3 + 78 = 81.
The shared block's weights live OUTSIDE the scan and are reused at every
invocation — Zamba's parameter-sharing trick.  (The published model also
concatenates the original embeddings into the shared block input and applies
per-invocation LoRA deltas; both are dropped here, noted in DESIGN.md SS5.)

SSM-dominated => runs ``long_500k`` (shared-attn decodes against a
sequence-sharded KV cache).
"""

from repro.configs.base import MAMBA, SHARED_ATTN, ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        source="arXiv:2411.15242",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        layer_pattern=(MAMBA,) * 5 + (SHARED_ATTN,),
        n_superblocks=13,
        prologue=(MAMBA,) * 3,
        act="geglu",
        norm="rmsnorm",
        rope=True,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=9, n_superblocks=1, prologue=(MAMBA,) * 3, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=96,
        remat=False,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
    )
