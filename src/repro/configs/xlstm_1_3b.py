"""xlstm-1.3b [ssm]: 48 blocks d_model=2048 4H vocab=50304, d_ff=0.

sLSTM + mLSTM blocks at the paper's 7:1 ratio for the 1.3B model: each
scanned super-block is 7 mLSTM blocks followed by 1 sLSTM block, x6 = 48.
[arXiv:2405.04517; unverified]

mLSTM: matrix-memory linear-recurrent block (chunkwise-parallel in training,
O(1)-state recurrent in decode) -- runs ``long_500k``.  d_ff=0 per the
assignment: blocks carry their own up/down projections instead of a separate
FFN.
"""

from repro.configs.base import MLSTM, SLSTM, ModelConfig, XLSTMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b",
        family="ssm",
        source="arXiv:2405.04517",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        head_dim=512,            # inner 4096 / 4 heads (v head dim)
        d_ff=0,
        vocab_size=50304,
        layer_pattern=(MLSTM,) * 7 + (SLSTM,),
        n_superblocks=6,
        act="gelu",
        norm="layernorm",
        rope=False,              # recurrence encodes position
        tie_embeddings=True,
        xlstm=XLSTMConfig(proj_factor=2.0, qk_dim_factor=0.25, conv_dim=4),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=8, n_superblocks=1, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=32, vocab_size=96, remat=False,
        xlstm=XLSTMConfig(proj_factor=2.0, qk_dim_factor=0.5, conv_dim=4,
                          chunk=16),
    )
