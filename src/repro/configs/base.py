"""Config dataclasses for the model zoo and the shape registry.

Every assigned architecture is expressed as a ``ModelConfig`` built from the
published dims (see the per-arch modules in this package).  The config is the
single source of truth consumed by:

  - ``models/lm.py``          (init / apply / train_step / serve_step)
  - ``distributed/sharding.py`` (PartitionSpec rules)
  - ``launch/dryrun.py``      (input_specs + lowering)
  - ``benchmarks/roofline.py`` (MODEL_FLOPS = 6*N*D accounting)

Layer heterogeneity (gemma2 local/global alternation, zamba2 mamba+shared-attn
super-blocks, xlstm 7:1 mLSTM:sLSTM, vision cross-attn every 5th layer) is
encoded as ``layer_pattern``: the sub-layer sequence of ONE scanned
super-block.  ``n_layers == len(prologue) + len(layer_pattern) * n_superblocks``
always holds and is checked at construction.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

# Sub-layer type tags usable in layer_pattern / prologue.
ATTN = "attn"          # self-attention + FFN/MoE
LOCAL = "local"        # sliding-window self-attention + FFN
GLOBAL = "global"      # full self-attention + FFN (alias of attn, kept
                       # distinct so gemma2's pairing reads literally)
XATTN = "xattn"        # cross-attention to vision states + FFN
SHARED_ATTN = "shared_attn"  # zamba2: attention+FFN block with weights shared
                             # across all invocations (lives outside the scan)
MAMBA = "mamba2"       # Mamba2 / SSD block
MLSTM = "mlstm"        # xLSTM matrix-memory block
SLSTM = "slstm"        # xLSTM scalar-memory block

LAYER_TYPES = (ATTN, LOCAL, GLOBAL, XATTN, SHARED_ATTN, MAMBA, MLSTM, SLSTM)

ATTN_LIKE = (ATTN, LOCAL, GLOBAL, SHARED_ATTN)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert FFN hidden dim
    n_shared_experts: int = 0      # always-on experts (DeepSeek/Moonlight style)
    capacity_factor: float = 1.25  # tokens-per-expert cap = cf * T*topk/E
    router_aux_weight: float = 1e-2  # load-balance auxiliary loss weight


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64        # N: SSM state size per head
    d_conv: int = 4          # depthwise conv width
    expand: int = 2          # d_inner = expand * d_model
    head_dim: int = 64       # P: channels per SSD head
    chunk: int = 256         # SSD chunk length for the train-time scan


@dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0   # mLSTM up-projection factor
    qk_dim_factor: float = 0.5  # qk head dim = qk_dim_factor * v head dim
    conv_dim: int = 4          # causal conv width in the mLSTM block
    slstm_ff_factor: float = 1.3333  # sLSTM post-FFN expansion
    chunk: int = 256           # chunkwise-parallel segment length


@dataclass(frozen=True)
class VisionStubConfig:
    """Modality frontend is a STUB per the assignment: ``input_specs()``
    provides precomputed patch/frame embeddings of shape (B, n_tokens, d)."""

    n_tokens: int = 1600       # e.g. 1 image tile of 40x40 patches
    d_embed: int = 8192        # projected vision hidden size fed to cross-attn


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The four assigned LM shape cells.
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ----------------------------------------------------------
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""             # citation tag from the assignment

    # -- trunk dims --------------------------------------------------------
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0                # dense FFN hidden (0 for pure-xLSTM archs)
    vocab_size: int = 0

    # -- structure ---------------------------------------------------------
    layer_pattern: Tuple[str, ...] = (ATTN,)
    n_superblocks: int = 0
    prologue: Tuple[str, ...] = ()
    encoder_only: bool = False   # bidirectional attention, no decode step
    causal: bool = True

    # -- attention knobs ----------------------------------------------------
    rope: bool = True
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # window for LOCAL layers
    attn_softcap: Optional[float] = None   # gemma2: 50.0 on attn logits
    qk_norm: bool = False                  # qwen3: RMSNorm on q,k heads
    attn_bias: bool = False                # qwen1.5: qkv projection bias

    # -- ffn / embedding knobs ----------------------------------------------
    act: str = "silu"            # silu | gelu | geglu | swiglu ('geglu' and
                                 # 'swiglu' are gated; 'gelu'/'silu' plain MLP)
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    post_norm: bool = False      # gemma2: extra norm after attn/ffn outputs
    logit_softcap: Optional[float] = None  # gemma2: 30.0 on final logits
    tie_embeddings: bool = False
    embed_scale: bool = False    # gemma: multiply embeddings by sqrt(d_model)

    # -- optional sub-configs -----------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    vision: Optional[VisionStubConfig] = None

    # -- distribution ---------------------------------------------------------
    pipeline_stages: int = 1     # carried so a pipeline schedule can be
                                 # added without config churn (DESIGN.md §6;
                                 # PP unused at this scale point)

    # -- numerics ------------------------------------------------------------
    param_dtype: str = "bfloat16"
    remat: bool = True           # checkpoint each scanned super-block
    unroll_scan: bool = False    # unroll the super-block scan (dry-run cost
                                 # analysis: XLA counts while bodies ONCE, so
                                 # the roofline pass unrolls to get true FLOPs)

    # -- blockwise (flash-style) attention ------------------------------------
    # Sequences >= flash_threshold never materialize the (Sq, Sk) score
    # matrix: q/kv tiles + online softmax (models/attention.py).  In
    # unroll_scan mode the tile loops are python loops with causal/window
    # tile SKIPPING — the exact FLOP schedule a Pallas flash kernel runs.
    flash_threshold: int = 2048
    flash_block_q: int = 1024
    flash_block_kv: int = 1024

    def __post_init__(self):
        expected = len(self.prologue) + len(self.layer_pattern) * self.n_superblocks
        if self.n_layers and expected != self.n_layers:
            raise ValueError(
                f"{self.name}: layer bookkeeping mismatch: "
                f"{len(self.prologue)} prologue + {len(self.layer_pattern)} x "
                f"{self.n_superblocks} superblocks = {expected} != n_layers="
                f"{self.n_layers}"
            )
        for t in self.layer_pattern + self.prologue:
            if t not in LAYER_TYPES:
                raise ValueError(f"{self.name}: unknown layer type {t!r}")

    # -- derived -------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the vocab-parallel head shards over the
        16-way model axis (hubert's 504 -> 512)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def uses_moe(self) -> bool:
        return self.moe is not None and any(
            t in ATTN_LIKE for t in self.layer_pattern
        )

    @property
    def subquadratic(self) -> bool:
        """True when decode at 500k context is admissible (SSM / linear-attn /
        hybrid / windowed): pure full-attention archs skip ``long_500k``."""
        kinds = set(self.layer_pattern + self.prologue)
        if kinds & {MAMBA, MLSTM, SLSTM}:
            return True
        # gemma2-style local/global alternation: half the layers are windowed;
        # decode cost per token is O(window) for those, O(1)-state for none.
        # We admit it (documented in DESIGN.md SS5) because its KV residency is
        # dominated by the windowed half and it exercises the 500k SP path.
        if LOCAL in kinds and self.sliding_window is not None:
            return True
        return False

    def layer_types_in_order(self) -> Tuple[str, ...]:
        return self.prologue + self.layer_pattern * self.n_superblocks

    # -- parameter accounting (for roofline MODEL_FLOPS = 6*N*D) -------------
    def _attn_params(self) -> int:
        qkv = self.d_model * (self.q_dim + 2 * self.kv_dim)
        out = self.q_dim * self.d_model
        return qkv + out

    def _dense_ffn_params(self, d_ff: int) -> int:
        mats = 3 if self.act in ("geglu", "swiglu") else 2
        return mats * self.d_model * d_ff

    def _moe_ffn_params(self, active_only: bool) -> int:
        assert self.moe is not None
        m = self.moe
        router = self.d_model * m.n_experts
        n_used = (m.top_k if active_only else m.n_experts) + m.n_shared_experts
        return router + n_used * self._dense_ffn_params_expert(m.d_ff)

    def _dense_ffn_params_expert(self, d_ff: int) -> int:
        return 3 * self.d_model * d_ff  # experts are always gated (swiglu)

    def _mamba_params(self) -> int:
        assert self.ssm is not None
        s = self.ssm
        d_in = s.expand * self.d_model
        n_heads = d_in // s.head_dim
        in_proj = self.d_model * (2 * d_in + 2 * s.d_state + n_heads)
        conv = s.d_conv * (d_in + 2 * s.d_state)
        out = d_in * self.d_model
        return in_proj + conv + out + 2 * n_heads  # + A_log, D

    def _mlstm_params(self) -> int:
        # Matches models/xlstm.py: up (d->di) + z-gate (d->di) + q,k
        # (di->qk_dim) + i,f gates (di->n_heads) + down (di->d); conv is
        # depthwise (negligible).
        assert self.xlstm is not None
        x = self.xlstm
        d_in = int(x.proj_factor * self.d_model)
        qk = int(d_in * x.qk_dim_factor)
        up = 2 * self.d_model * d_in
        qkproj = 2 * d_in * qk
        gates = 2 * d_in * self.n_heads
        down = d_in * self.d_model
        return up + qkproj + gates + down

    def _slstm_params(self) -> int:
        # Matches models/xlstm.py: 4 input mats (d->d) + 4 recurrent
        # (block-diagonal per head: d*head_dim_s) + gated FF at ff_factor.
        assert self.xlstm is not None
        x = self.xlstm
        d = self.d_model
        inp = 4 * d * d
        rec = 4 * d * (d // max(self.n_heads, 1))
        ff_h = int(d * x.slstm_ff_factor)
        ff = 3 * d * ff_h
        return inp + rec + ff

    def _layer_params(self, kind: str, active_only: bool) -> int:
        if kind in (ATTN, LOCAL, GLOBAL, SHARED_ATTN):
            ffn = (
                self._moe_ffn_params(active_only)
                if self.uses_moe
                else self._dense_ffn_params(self.d_ff)
            )
            return self._attn_params() + ffn
        if kind == XATTN:
            return self._attn_params() + self._dense_ffn_params(self.d_ff)
        if kind == MAMBA:
            return self._mamba_params()
        if kind == MLSTM:
            return self._mlstm_params()
        if kind == SLSTM:
            return self._slstm_params()
        raise ValueError(kind)

    def param_count(self, active_only: bool = False) -> int:
        """Approximate trunk+embedding parameter count.

        ``active_only=True`` counts only routed-in experts (MoE): the N in the
        6*N_active*D MODEL_FLOPS convention.  Zamba2's shared block is counted
        ONCE here (weights are shared) but its FLOPs recur per invocation —
        ``flops_per_token`` handles that distinction.
        """
        total = 0
        seen_shared = False
        for kind in self.layer_types_in_order():
            if kind == SHARED_ATTN:
                if seen_shared:
                    continue
                seen_shared = True
            total += self._layer_params(kind, active_only)
        embed = self.padded_vocab * self.d_model
        total += embed if self.tie_embeddings else 2 * embed
        return total

    def flops_per_token(self) -> int:
        """6 * N_active * 1 (per token), counting shared-block re-invocations
        and excluding embedding gather (matching the 6ND convention: the
        unembedding matmul IS counted via the head params)."""
        per_layer = 0
        for kind in self.layer_types_in_order():
            per_layer += self._layer_params(kind, active_only=True)
        head = self.padded_vocab * self.d_model
        return 6 * (per_layer + head)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def human(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000:
            return f"{n:.2f}{unit}"
        n /= 1000
    return f"{n:.2f}P"


def sqrt_d(cfg: ModelConfig) -> float:
    return math.sqrt(cfg.d_model)
