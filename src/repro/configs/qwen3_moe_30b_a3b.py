"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, MoE 128 experts top-8, q/k RMSNorm, RoPE theta=1e6.
[hf:Qwen/Qwen3-30B-A3B; hf]

Pure full attention => ``long_500k`` skipped.
"""

from repro.configs.base import ATTN, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        source="hf:Qwen/Qwen3-30B-A3B",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        layer_pattern=(ATTN,),
        n_superblocks=48,
        act="swiglu",
        norm="rmsnorm",
        rope=True,
        rope_theta=1_000_000.0,
        qk_norm=True,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff=768),
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, n_superblocks=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=32, vocab_size=96, remat=False,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32),
    )
