"""Bounded-buffer continual selection (DESIGN.md §11).

A continual-learning tenant streams gradient batches forever; the buffer
holds at most ``capacity`` rows yet must keep its committed ``k``-subset
*exact* — index-identical (weights to tolerance) to a from-scratch OMP
solve over whatever rows currently survive in the buffer.  The pieces:

* **Storage** reuses the ``ChunkCache`` arena layout from
  ``core/streaming.py``: a flat bf16 row arena with f32 exact-norm and
  measured-compression-error sidecars plus gid / ok sidecars.  The solver
  works against a f32 pool view of the stored rows (the upcast *is* the
  pool — what you store is what you solve), so compression never makes
  the maintained solution drift from the from-scratch one.
* **Admission** scores each incoming batch against the recorded residual
  trajectory (``decremental.certify_admission``): a round whose winning
  gain clears every newcomer by the f32 band keeps its pick with no work;
  the earliest uncertifiable round is where the replay starts.
  Fail-closed: a violation at round 0 is a full re-solve on the buffer.
* **Eviction** frees slots for newcomers when the buffer is full:
  non-committed residents go first (removing a candidate that never won
  an argmax changes no argmax — a free eviction), scored by current
  residual correlation with seeded softmax-over-scores tie-breaking;
  only then are committed rows removed, lowest recorded winning gain
  first, via the decremental downdate path (truncate at the earliest
  victim round + replay).
* **Narrow-regime forcing**: the session block is rounded up past the
  proxy dimension so the engine never builds the wide-regime column
  cache over the arena — every argmax scores against the live pool view,
  so a slot overwrite is visible to every subsequent round with no cache
  patching (and no staleness to reason about).

The maintained invariant after every ``admit``: the session state equals
a fresh ``omp_session_start(pool_view, target, k, valid=ok,
block=self.block)`` — indices exact away from the f32 noise floor,
weights to tolerance (the bar every engine in this repo certifies
against, tests/test_continual.py).  Checkpointing via the PR 6
``solver_state`` capture makes a killed stream resume *bit*-exactly: the
snapshot holds the arena, session buffers, trajectory and counters, and
everything downstream is deterministic (per-admission RNG is keyed on
``(seed, batch_counter)``).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.solver_state import load_solver_state, save_solver_state
from repro.core import decremental as dec
from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.omp import OMPAnytimeState, OMPIncState, _block_cap, \
    _empty_inc_state
from repro.core.streaming import SelectStats, _bucket, _compress_chunk
from repro.kernels import ops

__all__ = ["BufferMaintainer", "continual_select"]


def _soft_lowest(scores: np.ndarray, m: int, rng: np.random.Generator,
                 temp: float) -> np.ndarray:
    """Sample ``m`` entries biased toward the *lowest* scores.

    Gumbel-top-m over ``-scores / temp`` == sampling without replacement
    from softmax(-scores / temp): a seeded, reproducible tie-breaker —
    equal-gain victims don't depend on argsort stability, and a
    temperature of 0+ recovers the deterministic lowest-m.
    """
    if m >= scores.shape[0]:
        return np.arange(scores.shape[0])
    keys = -scores / max(temp, 1e-12) + rng.gumbel(size=scores.shape[0])
    return np.sort(np.argpartition(keys, -m)[-m:])


class BufferMaintainer:
    """Fixed-capacity row buffer maintaining an exact OMP coreset."""

    def __init__(self, capacity: int, d: int, target, k: int, *,
                 lam: float = 0.5, eps: float = 1e-10, nnls_iters: int = 50,
                 positive: bool = True, compress: bool = True, seed: int = 0,
                 evict_temp: float = 1.0, band_rel: float = 1e-4,
                 band_abs: float = 1e-6, checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 1):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        self.capacity = int(capacity)
        self.d = int(d)
        self.k = int(k)
        self.lam = float(lam)
        self.eps = float(eps)
        self.nnls_iters = int(nnls_iters)
        self.positive = bool(positive)
        self.compress = bool(compress)
        self.seed = int(seed)
        self.evict_temp = float(evict_temp)
        self.band_rel = float(band_rel)
        self.band_abs = float(band_abs)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = int(checkpoint_every)
        # Force the narrow regime: a block strictly wider than d means the
        # session engine never allocates the (n, P) column cache, so slot
        # overwrites need no cache patching (module docstring).
        self.block = 128 * (-(-(self.d + 1) // 128))
        self.target = jnp.asarray(target, jnp.float32)
        if self.target.shape != (self.d,):
            raise ValueError(
                f"target shape {self.target.shape} != ({self.d},)")
        # Arena (ChunkCache layout): bf16 rows + f32 norm / compression-
        # error sidecars, gid / ok sidecars.  The f32 pool view is what
        # the solver sees (== upcast of storage when compress=True).
        self._rows_bf = jnp.zeros((self.capacity, self.d), jnp.bfloat16)
        self._norms = jnp.zeros((self.capacity,), jnp.float32)
        self._errn = jnp.zeros((self.capacity,), jnp.float32)
        self._gids = np.full((self.capacity,), -1, np.int64)
        self._ok = np.zeros((self.capacity,), bool)
        self._pool = jnp.zeros((self.capacity, self.d), jnp.float32)
        self._sess = OMPAnytimeState(
            k=0, block=self.block,
            st=_empty_inc_state(_block_cap(self.k, self.block),
                                self.capacity, self.d, self.target),
            c0=jnp.zeros((self.capacity,), jnp.float32),
            target=self.target,
            valid=jnp.zeros((self.capacity,), bool),
            lam=self.lam, eps=self.eps, nnls_iters=self.nnls_iters,
            positive=self.positive)
        self._trace = dec._empty_trace(self.d)
        self.stats = SelectStats(pool_size=self.capacity)
        self.batches = 0
        self._next_gid = 0

    # -- admission ----------------------------------------------------------

    def admit(self, rows, gids=None) -> dict:
        """Admit one incoming batch; returns an accounting dict.

        Batches larger than the buffer are folded in ``capacity``-row
        waves (only the last wave's rows can survive a wave that itself
        overfills the buffer — same as admitting them one batch at a
        time).  ``gids`` default to a running global counter.
        """
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != self.d:
            raise ValueError(
                f"batch shape {rows.shape} incompatible with d={self.d}")
        b = rows.shape[0]
        if gids is None:
            gids = np.arange(self._next_gid, self._next_gid + b,
                             dtype=np.int64)
        else:
            gids = np.asarray(gids, np.int64)
            if gids.shape != (b,):
                raise ValueError(f"gids shape {gids.shape} != ({b},)")
        self._next_gid = max(self._next_gid, int(gids.max()) + 1 if b else 0)
        report = {"admitted": 0, "evicted": 0, "downdates": 0,
                  "replayed_from": self._sess.k}
        for lo in range(0, b, self.capacity):
            sub = self._admit_wave(rows[lo:lo + self.capacity],
                                   gids[lo:lo + self.capacity])
            report["admitted"] += sub["admitted"]
            report["evicted"] += sub["evicted"]
            report["downdates"] += sub["downdates"]
            report["replayed_from"] = min(report["replayed_from"],
                                          sub["replayed_from"])
        if b == 0:
            return report
        self.batches += 1
        if self.checkpoint_dir and self.batches % self.checkpoint_every == 0:
            self.save_checkpoint()
        return report

    def _committed_rounds(self) -> dict:
        """slot -> earliest committed round (degenerate re-picks map to
        the slot's first, real round)."""
        ind = np.asarray(self._sess.indices)
        msk = np.asarray(self._sess.mask)
        rounds: dict = {}
        for t in np.nonzero(msk)[0]:
            rounds.setdefault(int(ind[t]), int(t))
        return rounds

    def _admit_wave(self, rows: np.ndarray, gids: np.ndarray) -> dict:
        b = rows.shape[0]
        if b == 0:
            return {"admitted": 0, "evicted": 0, "downdates": 0,
                    "replayed_from": self._sess.k}
        rng = np.random.default_rng((self.seed, self.batches))
        rounds = self._committed_rounds()

        # 1) victims: free slots first, then non-committed residents
        #    (free evictions), then committed rows via the downdate path.
        free = np.nonzero(~self._ok)[0]
        n_free = min(b, free.size)
        need = b - n_free
        victims = np.empty((0,), np.int64)
        n_down = 0
        t_evict = self._sess.k
        if need > 0:
            occupied = np.nonzero(self._ok)[0]
            committed = np.fromiter(rounds.keys(), np.int64,
                                    count=len(rounds))
            is_comm = np.isin(occupied, committed)
            noncomm = occupied[~is_comm]
            take_nc = min(need, noncomm.size)
            picks = []
            if take_nc:
                resid = np.asarray(self._sess.st.residual, np.float32)
                sc = np.asarray(self._pool, np.float32)[noncomm] @ resid
                if not self.positive:
                    sc = np.abs(sc)
                picks.append(noncomm[_soft_lowest(sc, take_nc, rng,
                                                  self.evict_temp)])
            n_down = need - take_nc
            if n_down > 0:
                comm = occupied[is_comm]
                gains = np.array([self._trace.win[rounds[int(s)]]
                                  for s in comm], np.float32)
                sel = comm[_soft_lowest(gains, n_down, rng, self.evict_temp)]
                picks.append(sel)
                t_evict = min(rounds[int(s)] for s in sel)
            victims = np.concatenate(picks) if picks else victims

        # 2) write newcomers into victim + free slots (bf16 + sidecars),
        #    patch the pool view and the per-session c0.
        slots = np.sort(np.concatenate([free[:n_free], victims]))
        rows_j = jnp.asarray(rows)
        cpad = _bucket(b)
        padded = jnp.pad(rows_j, ((0, cpad - b), (0, 0)))
        rows_bf, norms, errn = _compress_chunk(padded, jnp.arange(cpad) < b)
        rows_bf, norms, errn = rows_bf[:b], norms[:b], errn[:b]
        stored = rows_bf.astype(jnp.float32) if self.compress else rows_j
        sl = jnp.asarray(slots)
        self._rows_bf = self._rows_bf.at[sl].set(rows_bf)
        self._norms = self._norms.at[sl].set(norms)
        self._errn = self._errn.at[sl].set(errn)
        self._pool = self._pool.at[sl].set(stored)
        self._gids[slots] = gids
        self._ok[slots] = True
        new_c0 = self._sess.c0.at[sl].set(ops.corr(stored, self.target))

        # 3) earliest round the admission can disturb: committed-victim
        #    rounds (their slots now hold newcomer content) and the
        #    earliest certificate violation; fail-closed to 0 == re-solve.
        t_cert = dec.certify_admission(
            np.asarray(stored, np.float32), self._trace, self._sess.k,
            positive=self.positive, band_rel=self.band_rel,
            band_abs=self.band_abs)
        t_star = min(t_evict, t_cert)

        k_before = self._sess.k
        sess = self._sess._replace(c0=new_c0,
                                   valid=jnp.asarray(self._ok))
        trace = self._trace
        if t_star < sess.k:
            if t_star == 0 and k_before > 0:
                self.stats.resolves += 1
            sess = dec.session_truncate(sess, t_star)
            trace = dec.ReplayTrace(resid=trace.resid[:t_star],
                                    win=trace.win[:t_star])
        sess, trace = dec.session_extend_traced(self._pool, sess, self.k,
                                                trace)
        self._sess, self._trace = sess, trace

        self.stats.admits += b
        self.stats.evicts += int(victims.size)
        self.stats.downdates += n_down
        self.stats.rounds += self.k - t_star
        return {"admitted": b, "evicted": int(victims.size),
                "downdates": n_down, "replayed_from": t_star}

    # -- retraction ---------------------------------------------------------

    def invalidate(self, gids) -> int:
        """Drop buffer rows by gid (upstream retractions, label fix-ups).

        Non-committed rows leave for free; committed rows go through the
        decremental path (truncate at the earliest dropped round, replay
        to budget).  Returns the number of rows dropped.
        """
        drop = np.isin(self._gids, np.asarray(gids)) & self._ok
        slots = np.nonzero(drop)[0]
        if slots.size == 0:
            return 0
        rounds = self._committed_rounds()
        hit = [rounds[int(s)] for s in slots if int(s) in rounds]
        self._ok[slots] = False
        sess = self._sess._replace(valid=jnp.asarray(self._ok))
        if hit:
            t_star = min(hit)
            if t_star == 0 and self._sess.k > 0:
                self.stats.resolves += 1
            self.stats.downdates += len(hit)
            self.stats.rounds += self.k - t_star
            sess = dec.session_truncate(sess, t_star)
            trace = dec.ReplayTrace(resid=self._trace.resid[:t_star],
                                    win=self._trace.win[:t_star])
            sess, trace = dec.session_extend_traced(self._pool, sess,
                                                    self.k, trace)
            self._trace = trace
        self._sess = sess
        self.stats.evicts += int(slots.size)
        return int(slots.size)

    # -- results ------------------------------------------------------------

    def slot_result(self):
        """Raw slot-space solution ``(indices, weights, mask, err)`` — the
        differential-test view (compare against a from-scratch solve over
        ``pool_view()``)."""
        return (self._sess.indices, self._sess.weights, self._sess.mask,
                self._sess.err)

    def result(self) -> SelectionResult:
        """Committed coreset in gid space, weights normalized."""
        idx = self._sess.indices
        mask = self._sess.mask
        gids = jnp.asarray(self._gids.astype(np.int32))
        gid_idx = jnp.where(mask, gids[jnp.where(mask, idx, 0)],
                            -1).astype(jnp.int32)
        return SelectionResult(gid_idx,
                               _normalize(self._sess.weights, mask), mask,
                               self._sess.err, stats=self.stats)

    def pool_view(self):
        """(f32 pool, ok mask) — exactly what a from-scratch solve sees."""
        return self._pool, jnp.asarray(self._ok)

    def memory_bytes(self) -> int:
        """Resident bytes: arena + sidecars + f32 solver view + session
        prefix buffers + trace.  Flat in the number of admitted batches —
        the buffer never grows past ``capacity`` and the session past
        ``block_cap(k)`` (the BENCH table asserts this over >= 100
        batches)."""
        arena = (self._rows_bf.nbytes + self._norms.nbytes +
                 self._errn.nbytes + self._gids.nbytes + self._ok.nbytes +
                 self._pool.nbytes)
        st = self._sess.st
        sess = sum(int(np.asarray(x).nbytes) for x in
                   (st.indices, st.mask, st.weights, st.colcache, st.gram,
                    st.gram_absrow, st.tcorr, st.rows, st.residual,
                    self._sess.c0, self._sess.valid))
        trace = self._trace.resid.nbytes + self._trace.win.nbytes
        return int(arena + sess + trace)

    # -- checkpoint / resume (PR 6 solver_state capture) ---------------------

    def state_dict(self) -> dict:
        st = self._sess.st
        return {
            "config": {
                "capacity": np.int64(self.capacity), "d": np.int64(self.d),
                "k": np.int64(self.k), "block": np.int64(self.block),
                "lam": np.float64(self.lam), "eps": np.float64(self.eps),
                "nnls_iters": np.int64(self.nnls_iters),
                "positive": np.bool_(self.positive),
                "compress": np.bool_(self.compress),
                "seed": np.int64(self.seed),
                "evict_temp": np.float64(self.evict_temp),
                "band_rel": np.float64(self.band_rel),
                "band_abs": np.float64(self.band_abs),
                "checkpoint_every": np.int64(self.checkpoint_every),
            },
            "arena": {
                "rows_bf": np.asarray(self._rows_bf),
                "norms": np.asarray(self._norms),
                "errn": np.asarray(self._errn),
                "gids": self._gids.copy(), "ok": self._ok.copy(),
                "pool": np.asarray(self._pool),
            },
            "session": {
                "k": np.int64(self._sess.k),
                "c0": np.asarray(self._sess.c0),
                "valid": np.asarray(self._sess.valid),
                "target": np.asarray(self.target),
                "st": {f: np.asarray(getattr(st, f))
                       for f in OMPIncState._fields},
            },
            "trace": {"resid": self._trace.resid, "win": self._trace.win},
            "counters": {
                "batches": np.int64(self.batches),
                "next_gid": np.int64(self._next_gid),
                "admits": np.int64(self.stats.admits),
                "evicts": np.int64(self.stats.evicts),
                "downdates": np.int64(self.stats.downdates),
                "resolves": np.int64(self.stats.resolves),
                "rounds": np.int64(self.stats.rounds),
                "checkpoints": np.int64(self.stats.checkpoints),
                "resumes": np.int64(self.stats.resumes),
            },
        }

    def save_checkpoint(self) -> str:
        if not self.checkpoint_dir:
            raise ValueError("no checkpoint_dir configured")
        path = save_solver_state(self.checkpoint_dir, self.batches,
                                 self.state_dict())
        self.stats.checkpoints += 1
        return path

    def _load_tree(self, tree: dict) -> None:
        ar = tree["arena"]
        self._rows_bf = jnp.asarray(ar["rows_bf"])
        self._norms = jnp.asarray(ar["norms"])
        self._errn = jnp.asarray(ar["errn"])
        self._gids = np.asarray(ar["gids"], np.int64)
        self._ok = np.asarray(ar["ok"], bool)
        self._pool = jnp.asarray(ar["pool"])
        se = tree["session"]
        st = OMPIncState(**{f: jnp.asarray(se["st"][f])
                            for f in OMPIncState._fields})
        self._sess = self._sess._replace(
            k=int(se["k"]), st=st, c0=jnp.asarray(se["c0"]),
            valid=jnp.asarray(se["valid"]))
        self._trace = dec.ReplayTrace(
            resid=np.asarray(tree["trace"]["resid"], np.float32).reshape(
                -1, self.d),
            win=np.asarray(tree["trace"]["win"], np.float32).reshape(-1))
        ct = tree["counters"]
        self.batches = int(ct["batches"])
        self._next_gid = int(ct["next_gid"])
        for f in ("admits", "evicts", "downdates", "resolves", "rounds",
                  "checkpoints", "resumes"):
            setattr(self.stats, f, int(ct[f]))
        self.stats.resumes += 1

    @classmethod
    def restore(cls, checkpoint_dir: str) -> "Optional[BufferMaintainer]":
        """Resume a killed stream bit-exactly; ``None`` if nothing saved."""
        tree = load_solver_state(checkpoint_dir)
        if tree is None:
            return None
        cfg = tree["config"]
        m = cls(capacity=int(cfg["capacity"]), d=int(cfg["d"]),
                target=np.asarray(tree["session"]["target"]),
                k=int(cfg["k"]), lam=float(cfg["lam"]), eps=float(cfg["eps"]),
                nnls_iters=int(cfg["nnls_iters"]),
                positive=bool(cfg["positive"]),
                compress=bool(cfg["compress"]), seed=int(cfg["seed"]),
                evict_temp=float(cfg["evict_temp"]),
                band_rel=float(cfg["band_rel"]),
                band_abs=float(cfg["band_abs"]),
                checkpoint_dir=checkpoint_dir,
                checkpoint_every=int(cfg["checkpoint_every"]))
        if m.block != int(cfg["block"]):
            raise ValueError(
                f"checkpoint block {int(cfg['block'])} != derived {m.block}")
        m._load_tree(tree)
        return m


def continual_select(proxies, k: int, *, target=None,
                     capacity: Optional[int] = None,
                     batch: Optional[int] = None, lam: float = 0.5,
                     eps: float = 1e-10, seed: int = 0) -> SelectionResult:
    """In-memory driver for strategy ``"gradmatch-continual"``.

    Streams the proxy matrix through a :class:`BufferMaintainer` in
    admission batches.  With the default ``capacity=None`` the buffer
    covers the whole pool (nothing is ever evicted) and the selection is
    the pooled ``gradmatch`` solution — the free-parity case; a smaller
    ``capacity`` bounds memory and selects over the surviving rows.
    ``compress`` is off on this path so the buffer solves the caller's
    exact f32 rows.
    """
    g = jnp.asarray(proxies, jnp.float32)
    n, d = g.shape
    cap = n if capacity is None else int(capacity)
    bs = min(n, 256) if batch is None else int(batch)
    tgt = jnp.sum(g, axis=0) if target is None else jnp.asarray(
        target, jnp.float32)
    m = BufferMaintainer(capacity=cap, d=d, target=tgt, k=k, lam=lam,
                         eps=eps, compress=False, seed=seed)
    g_np = np.asarray(g)
    for lo in range(0, n, bs):
        hi = min(lo + bs, n)
        m.admit(g_np[lo:hi], gids=np.arange(lo, hi, dtype=np.int64))
    return m.result()
