"""Continual-stream selection: bounded-buffer coreset maintenance
(DESIGN.md §11).

``BufferMaintainer`` admits gradient batches forever under a fixed
memory budget, keeping its committed subset exact against a from-scratch
solve over the surviving rows via decremental OMP
(``repro.core.decremental``); ``continual_select`` is the in-memory
strategy driver behind ``selection.select("gradmatch-continual", ...)``.
"""

from repro.continual.buffer import BufferMaintainer, continual_select

__all__ = ["BufferMaintainer", "continual_select"]
