from repro.train.compression import (CompressionState, compress_with_feedback,
                                     topk_sparsify)
from repro.train.trainer import AdaptiveTrainer, TrainerConfig, TrainReport

__all__ = [
    "AdaptiveTrainer",
    "CompressionState",
    "TrainReport",
    "TrainerConfig",
    "compress_with_feedback",
    "topk_sparsify",
]
