"""Adaptive-selection trainer: the paper's Algorithm 1, end to end.

Runs any strategy from ``core.selection.STRATEGIES`` (+ their -WARM
variants) on a classification dataset with the paper's hyper-parameters
(SGD momentum 0.9, wd 5e-4, cosine annealing, R=20, lambda=0.5, kappa=1/2).

Cost accounting: wall-clock on this container measures the host CPU, not
the paper's V100, so the primary efficiency metric is **work units** — one
unit = one example forward+backward (training costs 3x a forward; selection
proxy passes cost 1x forward; OMP/greedy cost is measured in wall time and
reported separately).  Speedups reported by benchmarks are work-unit ratios
vs FULL, the quantity the paper's wall-clock ratios proxy.

Fault tolerance: ``checkpoint_dir`` makes the trainer snapshot (params,
opt state, loader state, selection state, epoch, RNG) every
``checkpoint_every`` epochs through the async CheckpointManager, and
``.run()`` resumes from the latest snapshot if one exists.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.paper import ClassifierConfig, PaperHParams
from repro.core import proxies as proxy_lib
from repro.core import selection as sel_lib
from repro.core import streaming as stream_lib
from repro.core.gradmatch import SelectionResult
from repro.data.loader import ChunkedPool, SubsetLoader
from repro.data.synthetic import Dataset
from repro.optim import cosine_annealing, sgd
from repro.train import steps as steps_lib


@dataclass
class TrainerConfig:
    strategy: str = "gradmatch-pb"     # see core.selection.STRATEGIES
    budget: float = 0.1                # k / n
    epochs: int = 60
    batch_size: int = 64
    warm_start: bool = False           # -WARM variant
    early_stop_frac: Optional[float] = None  # FULL-EARLYSTOP budget match
    hp: PaperHParams = field(default_factory=PaperHParams)
    is_valid: bool = False             # match validation gradients
    per_class: bool = True
    omp_method: str = "incremental"    # OMP solver for gradmatch strategies
    chunk_size: int = 1024             # gradmatch-stream: proxy chunk rows
    stream_buffer: int = 256           # gradmatch-stream: top-M buffer slots
    # gradmatch-stream: compressed proxy-chunk cache budget (bf16 rows +
    # f32 sidecars, DESIGN.md §7) — certified buffer rounds re-verify
    # against this cache instead of re-extracting proxies per round.
    stream_cache_bytes: int = 256 << 20
    seed: int = 0
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 20
    eval_every: int = 5


@dataclass
class TrainReport:
    strategy: str
    budget: float
    final_acc: float
    best_acc: float
    acc_history: list
    work_units: float            # example-equivalents of compute (see above)
    selection_seconds: float
    wall_seconds: float
    selection_rounds: int
    subset_size: int

    @property
    def energy_proxy(self) -> float:
        """J/FLOP-proportional proxy (same ratios as the paper's pyJoules)."""
        return self.work_units


class AdaptiveTrainer:
    def __init__(self, model_cfg: ClassifierConfig, tcfg: TrainerConfig,
                 train: Dataset, val: Dataset, test: Optional[Dataset] = None):
        self.mcfg = model_cfg
        self.tcfg = tcfg
        self.train_ds = train
        self.val_ds = val
        self.test_ds = test if test is not None else val

        hp = tcfg.hp
        frac = 1.0 if tcfg.strategy == "full" else tcfg.budget
        steps_per_epoch = max(
            int(train.n * frac) // tcfg.batch_size, 1)
        lr = (cosine_annealing(hp.lr, tcfg.epochs * steps_per_epoch)
              if hp.cosine_anneal else hp.lr)
        self.opt = sgd(lr, momentum=hp.momentum,
                       weight_decay=hp.weight_decay)
        self.step_fn = steps_lib.make_classifier_step(model_cfg, self.opt)
        self.eval_fn = steps_lib.make_classifier_eval(model_cfg)
        self.proxy_fn = steps_lib.make_proxy_fn(model_cfg)
        self.ckpt = (CheckpointManager(tcfg.checkpoint_dir)
                     if tcfg.checkpoint_dir else None)

    # -- selection round ------------------------------------------------------
    def _run_selection(self, params, key) -> tuple[SelectionResult, float]:
        t0 = time.perf_counter()
        tc = self.tcfg
        n = self.train_ds.n
        k = max(int(n * tc.budget), 1)
        val_target = None
        if tc.is_valid:
            _, vbias = self.proxy_fn(params, self.val_ds.x, self.val_ds.y)
            val_target = jnp.sum(vbias, axis=0)
        if tc.strategy == "gradmatch-stream":
            # Out-of-core path: proxies are extracted one chunk at a time
            # through the chunked pool — the (n, d) proxy matrix never
            # exists on host or device (core/streaming.py, DESIGN.md §7).
            # The row fetcher re-extracts individual proxy rows on demand
            # (row-wise extractors make that bit-exact), so the engine's
            # repair and cache-refill tiers work without a loader pass —
            # certified rounds never re-run the proxy forward pass.
            pool = ChunkedPool(self.train_ds.x, self.train_ds.y,
                               tc.chunk_size)
            chunks = proxy_lib.proxy_chunk_stream(pool.chunks,
                                                  self.proxy_fn, params)
            fetch = proxy_lib.proxy_row_fetch(
                self.train_ds.x, self.train_ds.y, self.proxy_fn, params)
            sel = stream_lib.gradmatch_streaming(
                chunks, k, target=val_target, lam=tc.hp.lam, eps=tc.hp.eps,
                buffer_size=tc.stream_buffer,
                cache_bytes=tc.stream_cache_bytes, row_fetch=fetch)
            jax.block_until_ready(sel.weights)
            return sel, time.perf_counter() - t0
        pcg, bias = self.proxy_fn(params, self.train_ds.x, self.train_ds.y)
        # PB variants & GLISTER use the bias-gradient proxy (comparable
        # across classes); per-class GRAD-MATCH/CRAIG use the per-gradient
        # proxy within each class (paper §4).
        per_class_ok = not tc.is_valid and tc.per_class
        proxies = pcg if (tc.strategy in ("gradmatch", "craig",
                                          "craig-lazy", "craig-stochastic")
                          and per_class_ok) else bias
        sel = sel_lib.select(
            tc.strategy, key, proxies, k,
            labels=self.train_ds.y, num_classes=self.train_ds.num_classes,
            batch_size=tc.batch_size, lam=tc.hp.lam, eps=tc.hp.eps,
            val_target=val_target,
            per_class=per_class_ok,
            omp_method=tc.omp_method,
            chunk_size=tc.chunk_size, stream_buffer=tc.stream_buffer,
        )
        sel = sel_lib.expand_if_pb(tc.strategy, sel, tc.batch_size, n)
        jax.block_until_ready(sel.weights)
        return sel, time.perf_counter() - t0

    # -- main loop --------------------------------------------------------------
    def run(self) -> TrainReport:
        tc = self.tcfg
        key = jax.random.PRNGKey(tc.seed)
        kinit, kloop = jax.random.split(key)

        from repro.models.classifier import init_classifier
        params = init_classifier(self.mcfg, kinit)
        opt_state = self.opt.init(params)

        loader = SubsetLoader(self.train_ds.x, self.train_ds.y,
                              tc.batch_size, seed=tc.seed)

        # Schedule: warm start / early stop accounting.
        n = self.train_ds.n
        epochs = tc.epochs
        warm_epochs = 0
        if tc.warm_start and tc.strategy not in ("full",):
            warm_epochs, subset_epochs = sel_lib.warm_start_epochs(
                epochs, tc.budget, tc.hp.kappa)
            epochs = warm_epochs + subset_epochs
        if tc.strategy == "full" and tc.early_stop_frac is not None:
            # FULL-EARLYSTOP: spend the same work units as a subset run.
            epochs = max(int(round(tc.epochs * tc.early_stop_frac)), 1)
        sched = sel_lib.SelectionSchedule(tc.hp.select_every, warm_epochs,
                                          total_epochs=epochs)

        start_epoch = 0
        work = 0.0
        sel_seconds = 0.0
        sel_rounds = 0
        acc_hist: list = []
        best = 0.0

        # -- resume -----------------------------------------------------------
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            snap = self.ckpt.restore()
            params = jax.tree_util.tree_map(
                jnp.asarray, snap["params"])
            opt_state = jax.tree_util.tree_map(
                jnp.asarray, snap["opt_state"])
            opt_state = type(self.opt.init(params))(
                opt_state["step"], opt_state.get("slots"))
            loader.restore_state(snap["loader"])
            start_epoch = int(snap["meta"]["epoch"])
            work = float(snap["meta"]["work"])
            sel_rounds = int(snap["meta"]["sel_rounds"])

        t_wall = time.perf_counter()
        for epoch in range(start_epoch, epochs):
            in_warm = epoch < warm_epochs
            if (tc.strategy not in ("full",) and not in_warm
                    and sched.is_selection_epoch(epoch)):
                sel, dt = self._run_selection(
                    params, jax.random.fold_in(kloop, epoch))
                loader.set_selection(np.asarray(sel.indices),
                                     np.asarray(sel.weights),
                                     np.asarray(sel.mask))
                sel_seconds += dt
                sel_rounds += 1
                work += n  # one proxy forward over the pool
                if tc.is_valid:
                    work += self.val_ds.n
            elif in_warm or tc.strategy == "full":
                loader.set_selection(np.arange(n),
                                     np.full((n,), 1.0 / n, np.float32),
                                     np.ones((n,), bool))

            for batch in loader.epoch_batches():
                params, opt_state, _ = self.step_fn(params, opt_state, batch)
                work += 3.0 * batch["x"].shape[0]   # fwd + bwd ~ 3x fwd

            if (epoch + 1) % tc.eval_every == 0 or epoch == epochs - 1:
                m = self.eval_fn(params, {"x": self.test_ds.x,
                                          "y": self.test_ds.y})
                acc = float(m["acc"])
                acc_hist.append((epoch + 1, acc))
                best = max(best, acc)

            if (self.ckpt is not None
                    and (epoch + 1) % tc.checkpoint_every == 0):
                self.ckpt.save(epoch + 1, {
                    "params": params,
                    "opt_state": {"step": opt_state.step,
                                  "slots": opt_state.slots},
                    "loader": loader.checkpoint_state(),
                    "meta": {"epoch": epoch + 1, "work": work,
                             "sel_rounds": sel_rounds},
                })

        if self.ckpt is not None:
            self.ckpt.wait()
        jax.block_until_ready(params)
        wall = time.perf_counter() - t_wall
        final = acc_hist[-1][1] if acc_hist else 0.0
        return TrainReport(
            strategy=tc.strategy + ("-warm" if tc.warm_start else ""),
            budget=tc.budget, final_acc=final, best_acc=best,
            acc_history=acc_hist, work_units=work,
            selection_seconds=sel_seconds, wall_seconds=wall,
            selection_rounds=sel_rounds, subset_size=loader.subset_size)
