"""Step builders: jitted train / eval steps for classifiers and LMs.

The weighted-subset objective is a first-class input: every step takes
``batch['weights']`` (the OMP output slice, summing to 1).  LM steps support
microbatch gradient accumulation (sequential ``lax.scan`` over microbatches
— the standard memory/throughput lever) and optional EF-TopK gradient
compression before the optimizer (models the sparse all-reduce transport).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.paper import ClassifierConfig
from repro.models import classifier as clf_lib
from repro.models import lm as lm_lib
from repro.optim import Optimizer, apply_updates
from repro.train import compression as comp_lib


# ---------------------------------------------------------------------------
# Classifier steps (paper-faithful experiments)
# ---------------------------------------------------------------------------

def make_classifier_step(cfg: ClassifierConfig, opt: Optimizer) -> Callable:
    @jax.jit
    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            clf_lib.classifier_loss, argnums=1, has_aux=True)(
                cfg, params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return step


def make_classifier_eval(cfg: ClassifierConfig) -> Callable:
    @jax.jit
    def evaluate(params, batch):
        logits, _ = clf_lib.apply_classifier(cfg, params, batch["x"])
        pred = jnp.argmax(logits, axis=-1)
        acc = jnp.mean((pred == batch["y"]).astype(jnp.float32))
        lg = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lg, axis=-1)
        own = jnp.take_along_axis(lg, batch["y"][:, None], -1)[:, 0]
        return {"acc": acc, "ce": jnp.mean(lse - own)}

    return evaluate


def make_proxy_fn(cfg: ClassifierConfig) -> Callable:
    """Per-example last-layer gradient proxies (paper §4) for a classifier.

    Returns the per-class per-gradient proxy (n, d_h + 1) and the bias-grad
    proxy (n, C); a single forward pass, no trunk backprop.
    """
    from repro.core import proxies as proxy_lib

    @jax.jit
    def proxy(params, x, y):
        logits, hidden = clf_lib.apply_classifier(cfg, params, x)
        pcg = proxy_lib.per_class_grad_proxy(hidden, logits, y)
        bias = proxy_lib.bias_grad_proxy(logits, y)
        return pcg, bias

    return proxy


# ---------------------------------------------------------------------------
# LM steps
# ---------------------------------------------------------------------------

def lm_train_step_fn(
    cfg: ModelConfig,
    opt: Optimizer,
    microbatches: int = 1,
) -> Callable:
    """Raw (un-jitted) (params, opt_state, batch) -> (params, opt_state,
    metrics) — what the dry-run lowers with explicit shardings.

    ``microbatches > 1`` splits the batch on the leading axis and
    accumulates gradients sequentially (scan) — activation memory drops by
    the same factor.  Weighted loss: microbatch weight slices are NOT
    re-normalized (they sum to 1 globally), so the accumulated gradient is
    exactly the full weighted-batch gradient.
    """

    def loss_fn(params, batch):
        return lm_lib.lm_loss(cfg, params, batch)

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, metrics

        def split(x):
            return x.reshape(microbatches, x.shape[0] // microbatches,
                             *x.shape[1:])

        mb = jax.tree_util.tree_map(split, batch)

        def body(acc, one):
            (_, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, one)
            acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), acc, g)
            return acc, metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, metrics = jax.lax.scan(
            body, zeros, mb,
            unroll=microbatches if cfg.unroll_scan else 1)
        metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        return grads, metrics

    def step(params, opt_state, batch):
        grads, metrics = grads_of(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return step


def make_lm_train_step(
    cfg: ModelConfig,
    opt: Optimizer,
    microbatches: int = 1,
    compress_frac: Optional[float] = None,
) -> Callable:
    """Jitted LM train step; see ``lm_train_step_fn``."""
    raw = lm_train_step_fn(cfg, opt, microbatches)
    if compress_frac is None:
        return jax.jit(raw)

    def loss_fn(params, batch):
        return lm_lib.lm_loss(cfg, params, batch)

    @jax.jit
    def step_c(params, opt_state, comp_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, comp_state = comp_lib.compress_with_feedback(
            grads, comp_state, compress_frac)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, comp_state, metrics

    return step_c


def make_lm_proxy_step(cfg: ModelConfig) -> Callable:
    """Per-sequence selection proxies for LM candidate pools (jit)."""

    @jax.jit
    def proxy(params, batch):
        return lm_lib.selection_proxy(cfg, params, batch)

    return proxy
