"""Top-k gradient compression with error feedback (opt-in, off by default).

At 1000-node scale the gradient all-reduce can dominate step time for
small-per-chip batch shapes; top-k sparsification with local error feedback
(Stich et al. 2018; Lin et al. 2018 "Deep Gradient Compression") cuts the
payload by 10-100x while provably preserving SGD convergence (the residual
is re-injected next step, so nothing is lost, only delayed).

Caveat (tested): apply EF-TopK BEFORE a momentum optimizer only with care —
naive momentum amplifies the delayed error-feedback bursts (DGC's fix is
momentum correction: accumulate momentum*velocity inside the compressor).
The trainer applies compression to the raw gradient and lets the optimizer
see the sparse stream; for momentum runs prefer lower density or the
momentum-corrected variant.

Wire format: per leaf, (values (k,), flat indices (k,)) — what a custom
collective would ship.  ``compress_with_feedback`` also returns the dense
"what the other side reconstructs" tensor so the trainer can run entirely
dense when the transport is XLA's all-reduce (this container), keeping the
semantics identical to a real sparse transport.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any   # pytree like grads: error feedback accumulator (f32)


def init_state(grads: Any) -> CompressionState:
    return CompressionState(jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def topk_sparsify(x: jax.Array, frac: float) -> tuple[jax.Array, jax.Array,
                                                      jax.Array]:
    """Keep the top-``frac`` fraction of entries by |value|.

    Returns (dense_masked, values, indices); k >= 1 always.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    k = max(int(flat.shape[0] * frac), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    keep_vals = flat[idx]
    dense = jnp.zeros_like(flat).at[idx].set(keep_vals)
    return dense.reshape(x.shape), keep_vals, idx


def compress_with_feedback(
    grads: Any, state: CompressionState, frac: float = 0.01
) -> tuple[Any, CompressionState]:
    """EF-TopK: compress (grad + residual); residual keeps what was dropped.

    Returns (dense compressed grads, new state).  Applying the returned
    grads through any optimizer reproduces the sparse-transport training
    trajectory exactly.
    """

    def one(g, r):
        acc = g.astype(jnp.float32) + r
        dense, _, _ = topk_sparsify(acc, frac)
        return dense, acc - dense

    pairs = jax.tree_util.tree_map(one, grads, state.residual)
    is_t = lambda x: isinstance(x, tuple)  # noqa: E731
    comp = jax.tree_util.tree_map(lambda t: t[0], pairs, is_leaf=is_t)
    resid = jax.tree_util.tree_map(lambda t: t[1], pairs, is_leaf=is_t)
    return comp, CompressionState(resid)


def compression_ratio(frac: float) -> float:
    """Payload ratio of (values+int32 indices) vs dense f32."""
    return 2.0 * frac
