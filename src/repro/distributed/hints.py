"""Sharding-constraint hints: named annotation points inside model code.

Model code stays mesh-agnostic: it calls ``hints.constrain(x, "moe_dispatch")``
at layout-critical points.  Outside any mesh context this is the identity; a
driver (launch/dryrun.py, train/steps.py) installs a rule table mapping hint
names to PartitionSpecs and the constraint becomes a
``lax.with_sharding_constraint`` — the lever the §Perf hillclimb iterates on
without touching model code.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> Optional[tuple[Mesh, dict]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(mesh: Mesh, table: dict[str, P]):
    """Install hint-name -> PartitionSpec rules for the enclosed trace."""
    prev = _rules()
    _state.rules = (mesh, table)
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x: jax.Array, name: str) -> jax.Array:
    ctx = _rules()
    if ctx is None:
        return x
    mesh, table = ctx
    spec = table.get(name)
    if spec is None:
        return x
    # Drop axes that don't divide the corresponding dim (divisibility
    # fallback — same policy as distributed/sharding.py).
    from repro.distributed.sharding import fit_spec
    spec = fit_spec(x.shape, spec, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
