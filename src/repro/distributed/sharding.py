"""PartitionSpec rules: params, activations, caches, optimizer state.

Policy (DESIGN.md §6):
  - batch dims shard over ('pod', 'data')   [DP; 'pod' is absent single-pod]
  - Megatron TP over 'model': column-parallel up/gate/QKV, row-parallel
    down/O, vocab-parallel embedding + head, experts over 'model' (EP)
  - optional FSDP: parameters additionally sharded over 'data' on the
    non-model dim (ZeRO-3 via GSPMD; all-gathers materialize per layer)
  - KV caches: batch over 'data', head or head_dim over 'model', falling
    back to sequence over 'data' for global_batch=1 (long_500k SP path)

Every rule passes through ``fit_spec``: a mesh axis is dropped from a dim
that it does not divide (gemma-2b's single KV head, hubert's 504-unit head
before padding, 8-head models on a 16-way model axis...).  This is the single
mechanism that makes all 10 archs lower on the same mesh.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

# Mesh axis names (multi-pod meshes add 'pod' in front).
POD, DATA, MODEL = "pod", "data", "model"


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in (POD, DATA) if a in mesh.axis_names)


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, (tuple, list)):
        return int(np.prod([axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.axis_names else 1


def fit_spec(shape: tuple, spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide their dim (per-entry).

    Composite entries like ('pod','data') are truncated left-to-right until
    the product divides.
    """
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        names = tuple(n for n in names if n in mesh.axis_names)
        while names and dim % axis_size(mesh, names) != 0:
            names = names[:-1]
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(names)
    return P(*out)


def named(mesh: Mesh, shape: tuple, spec: P) -> NamedSharding:
    return NamedSharding(mesh, fit_spec(shape, spec, mesh))


# ---------------------------------------------------------------------------
# Parameter rules, keyed by pytree path substrings.
# ---------------------------------------------------------------------------

def _param_rule(path: str, ndim: int, fsdp: bool) -> P:
    """PartitionSpec for one parameter, identified by its flattened path."""
    f = DATA if fsdp else None
    # --- MoE experts: E over model (EP); FSDP on the expert-internal dims.
    if "w_gate" in path and ndim == 3:
        return P(MODEL, f, None)
    if "w_up" in path and ndim == 3:
        return P(MODEL, f, None)
    if "w_down" in path and ndim == 3:
        return P(MODEL, None, f)
    if "router" in path:
        return P(None, MODEL)
    # --- embeddings / head: vocab-parallel.
    if "embed" in path or "lm_head" in path or "unit_head" in path:
        return P(MODEL, f) if "embed" in path else P(f, MODEL)
    # --- attention.
    if any(k in path for k in ("wq", "wk", "wv")):
        return P(f, MODEL)
    if "wo" in path:
        return P(MODEL, f)
    if any(k in path for k in ("bq", "bk", "bv")):
        return P(MODEL)
    # --- dense FFN (also xlstm up/z projections, mamba in_proj).
    if any(k in path for k in ("w_up", "w_gate", "in_proj", "up_proj",
                               "z_proj", "wi_")):
        return P(f, MODEL)
    if any(k in path for k in ("w_down", "out_proj", "down_proj", "wo_")):
        return P(MODEL, f)
    # --- everything else (norms, convs, gates, scalars): replicate.
    return P()


def param_specs(cfg: ModelConfig, params: Any, fsdp: bool = False) -> Any:
    """Pytree of PartitionSpecs matching ``params``.

    Stacked super-block params (leading n_superblocks axis from the scan)
    get their rule shifted right by one dim.
    """

    def one(path_parts, leaf):
        path = "/".join(str(p) for p in path_parts)
        ndim = leaf.ndim
        stacked = path.startswith("blocks/") or path.startswith(
            ("params/blocks",))
        rule_ndim = ndim - 1 if stacked else ndim
        rule = _param_rule(path, rule_ndim, fsdp)
        if stacked:
            rule = P(None, *tuple(rule))
        return rule

    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: one([_key_str(k) for k in kp], leaf), params
    )


def _key_str(k) -> str:
    # DictKey('embed') -> embed ; SequenceKey(0) -> 0
    for attr in ("key", "idx", "name"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def param_shardings(cfg: ModelConfig, params: Any, mesh: Mesh,
                    fsdp: bool = False) -> Any:
    specs = param_specs(cfg, params, fsdp=fsdp)
    return jax.tree_util.tree_map(
        lambda leaf, spec: named(mesh, leaf.shape, spec), params, specs
    )


# ---------------------------------------------------------------------------
# Activation / batch / cache rules.
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, ndim: int, *, seq_shard: bool = False) -> P:
    """Tokens/labels/weights: leading batch dim over DP axes.  For
    global_batch=1 long-context cells, shard the sequence dim instead."""
    dp = dp_axes(mesh)
    if seq_shard and ndim >= 2:
        return P(None, dp if len(dp) > 1 else (dp[0] if dp else None))
    return P(dp if len(dp) > 1 else (dp[0] if dp else None))


def cache_spec(mesh: Mesh, shape: tuple, *, batch_first: bool = True,
               seq_shard: bool = False) -> P:
    """KV cache (B, S, H_kv, hd) or SSM state (B, H, P, N)."""
    dp = dp_axes(mesh)
    dpe = dp if len(dp) > 1 else (dp[0] if dp else None)
    if len(shape) == 4:
        if seq_shard:
            return P(None, dpe, MODEL, None)
        return P(dpe, None, MODEL, None)
    if len(shape) == 3:
        return P(dpe, None, MODEL)
    if len(shape) == 2:
        return P(dpe, None)
    return P(dpe)


def logical_rules(mesh: Mesh) -> dict:
    """Hint-name -> PartitionSpec table consumed by distributed/hints.py.

    These are the §Perf levers: the dry-run baseline uses exactly this table;
    hillclimb iterations override entries.
    """
    dp = dp_axes(mesh)
    dpe = dp if len(dp) > 1 else (dp[0] if dp else None)
    return {
        "moe_dispatch": P(dpe, MODEL, None, None),   # (G, E, C, d)
        "moe_combine": P(dpe, MODEL, None, None),
        "ffn_inner": P(dpe, None, MODEL),            # (B, S, d_ff)
        "attn_out": P(dpe, None, MODEL),             # (B, S, q_dim)
        "residual": P(dpe, None, None),              # (B, S, d_model)
        "logits": P(dpe, None, MODEL),               # (B, S, vocab)
    }
