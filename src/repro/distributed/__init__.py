from repro.distributed import hints, sharding  # noqa: F401
