"""From-scratch optimizers + schedules (no optax in this container).

Interface (optax-like but minimal)::

    opt = sgd(lr=schedule, momentum=0.9, weight_decay=5e-4, nesterov=False)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

``lr`` may be a float or a ``step -> lr`` callable; the step counter lives in
the optimizer state so the whole thing checkpoints as a pytree.  Optimizer
state is kept in f32 regardless of the (possibly bf16) parameter dtype —
the usual mixed-precision master-state arrangement.
"""

from repro.optim.optimizers import (OptState, Optimizer, adamw, apply_updates,
                                    global_norm, sgd)
from repro.optim.schedule import (constant, cosine_annealing,
                                  cosine_with_warmup, exponential_decay)

__all__ = [
    "OptState",
    "Optimizer",
    "adamw",
    "apply_updates",
    "constant",
    "cosine_annealing",
    "cosine_with_warmup",
    "exponential_decay",
    "global_norm",
    "sgd",
]
