"""Learning-rate schedules as pure ``step -> lr`` functions of a traced step.

The paper (App. C.2) uses SGD momentum 0.9, weight decay 5e-4, initial lr
0.01 and *cosine annealing per epoch* — ``cosine_annealing`` is that
schedule, parameterized in steps.  All functions accept a jax scalar and are
jit-safe.
"""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def f(step):
        return jnp.float32(lr)
    return f


def cosine_annealing(lr: float, total_steps: int, final_scale: float = 0.0):
    """SGDR-style cosine from ``lr`` down to ``final_scale * lr``."""
    def f(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / max(
            total_steps, 1)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.float32(lr) * (final_scale + (1.0 - final_scale) * cos)
    return f


def cosine_with_warmup(lr: float, warmup_steps: int, total_steps: int,
                       final_scale: float = 0.1):
    """Linear warmup then cosine decay — the LM-pretraining default."""
    cos = cosine_annealing(lr, max(total_steps - warmup_steps, 1), final_scale)

    def f(step):
        s = step.astype(jnp.float32)
        warm = jnp.float32(lr) * s / max(warmup_steps, 1)
        return jnp.where(s < warmup_steps, warm, cos(s - warmup_steps))
    return f


def exponential_decay(lr: float, decay_steps: int, rate: float = 0.5):
    def f(step):
        return jnp.float32(lr) * rate ** (step.astype(jnp.float32)
                                          / decay_steps)
    return f
