"""SGD(+momentum/Nesterov/weight-decay) and AdamW, pytree-native.

Written against plain jax so the optimizer state shards with the parameters
(each state leaf inherits the parameter PartitionSpec — see
distributed/sharding.py) and checkpoints as a pytree.  Master state is f32;
updates are returned in the *parameter* dtype so bf16 training works without
caller-side casting.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jax.Array], jax.Array]]


class OptState(NamedTuple):
    step: jax.Array          # () int32
    slots: Any               # optimizer-specific pytree(s)


class Optimizer(NamedTuple):
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]


def _lr_at(lr: Schedule, step: jax.Array) -> jax.Array:
    if callable(lr):
        return lr(step)
    return jnp.float32(lr)


def _f32_like(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def _clipped(grads: Any, clip_norm: float | None) -> Any:
    if clip_norm is None:
        return grads
    g = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(g, 1e-12))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale), grads)


def sgd(lr: Schedule, momentum: float = 0.0, weight_decay: float = 0.0,
        nesterov: bool = False, clip_norm: float | None = None) -> Optimizer:
    """Paper default: momentum 0.9, weight decay 5e-4, cosine-annealed lr."""

    def init(params):
        slots = _f32_like(params) if momentum else None
        return OptState(jnp.zeros((), jnp.int32), slots)

    def update(grads, state: OptState, params):
        grads = _clipped(grads, clip_norm)
        lr_t = _lr_at(lr, state.step)

        def one(g, p, m):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if momentum:
                m = momentum * m + g
                d = g + momentum * m if nesterov else m
            else:
                d = g
            upd = (-lr_t * d).astype(p.dtype)
            return upd, m

        if momentum:
            pairs = jax.tree_util.tree_map(one, grads, params, state.slots)
            updates = jax.tree_util.tree_map(
                lambda pr: pr[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
            slots = jax.tree_util.tree_map(
                lambda pr: pr[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        else:
            updates = jax.tree_util.tree_map(
                lambda g, p: one(g, p, None)[0], grads, params)
            slots = None
        return updates, OptState(state.step + 1, slots)

    return Optimizer(init, update)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float | None = 1.0
          ) -> Optimizer:
    """AdamW with f32 (m, v) master slots — the LM-pretraining default."""

    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        {"m": _f32_like(params), "v": _f32_like(params)})

    def update(grads, state: OptState, params):
        grads = _clipped(grads, clip_norm)
        step = state.step + 1
        lr_t = _lr_at(lr, state.step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def one(g, p, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / c1
            vh = v / c2
            d = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            return (-lr_t * d).astype(p.dtype), m, v

        triples = jax.tree_util.tree_map(one, grads, params,
                                         state.slots["m"], state.slots["v"])
        is_t = lambda x: isinstance(x, tuple)  # noqa: E731
        updates = jax.tree_util.tree_map(lambda t: t[0], triples, is_leaf=is_t)
        m = jax.tree_util.tree_map(lambda t: t[1], triples, is_leaf=is_t)
        v = jax.tree_util.tree_map(lambda t: t[2], triples, is_leaf=is_t)
        return updates, OptState(step, {"m": m, "v": v})

    return Optimizer(init, update)


def apply_updates(params: Any, updates: Any) -> Any:
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype),
                                  params, updates)
