"""Backend-aware dispatch for the Pallas kernels.

Public entry points used by core/ and benchmarks.  On TPU the Pallas kernels
run compiled; on CPU (this container) they run through the Pallas interpreter
when explicitly requested (tests) and otherwise fall back to the pure-jnp
reference implementations, which XLA:CPU handles well.  The dispatch is a
plain Python decision made at trace time — no runtime branching ends up in
the compiled program.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import corr as corr_kernel
from repro.kernels import fl_gain as fl_gain_kernel
from repro.kernels import lastlayer_grad as llg_kernel
from repro.kernels import ref
from repro.kernels import sqdist as sqdist_kernel

# Resolution order: explicit override > TPU pallas > jnp reference.
_FORCE: str | None = None  # "pallas" | "interpret" | "ref" | None


def set_backend(mode: str | None) -> None:
    """Force kernel dispatch: 'pallas', 'interpret', 'ref', or None (auto)."""
    global _FORCE
    assert mode in (None, "pallas", "interpret", "ref")
    _FORCE = mode


def _mode() -> str:
    if _FORCE is not None:
        return _FORCE
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def active_mode() -> str:
    """The dispatch mode currently in effect ('pallas' | 'interpret' |
    'ref') — for callers whose *surrounding* computation depends on it
    (e.g. streaming's commit loop materializes per-row bounds for its
    lookahead envelope on the ref path but uses the fused ``bound_max``
    kernel on TPU, where that vector must never hit HBM)."""
    return _mode()


def corr(grads: jax.Array, residual: jax.Array) -> jax.Array:
    """OMP scores  G @ r  -> (n,) f32."""
    mode = _mode()
    if mode == "ref":
        return ref.corr_ref(grads, residual)
    return corr_kernel.corr(grads, residual, interpret=(mode == "interpret"))


def corr_argmax(colcache: jax.Array, w: jax.Array, base: jax.Array,
                mask: jax.Array, *, absolute: bool = False
                ) -> tuple[jax.Array, jax.Array]:
    """Fused OMP scoring: masked argmax of  base - colcache @ w.

    Returns (index (), score ()).  One streaming pass on TPU (the score
    vector never hits HBM); the jnp reference materializes-then-argmaxes,
    which XLA fuses well enough on CPU.
    """
    mode = _mode()
    if mode == "ref":
        return ref.corr_argmax_ref(colcache, w, base, mask,
                                   absolute=absolute)
    return corr_kernel.corr_argmax(colcache, w, base, mask,
                                   absolute=absolute,
                                   interpret=(mode == "interpret"))


def bound_max(rows: jax.Array, norms: jax.Array, errn: jax.Array,
              residual: jax.Array, acc: jax.Array, thresh: jax.Array,
              mask: jax.Array, *, absolute: bool = False
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused interval-bound scan over the streaming compressed chunk
    cache (DESIGN.md §7): (max upper bound, its index, #rows with
    ``u >= thresh``) for ``u = s̃ + (e + acc·‖g‖)·‖r‖`` over bf16 rows
    with f32 norm/error sidecars.  One streaming pass on TPU (``u``
    never hits HBM); the jnp reference fuses well enough on CPU."""
    mode = _mode()
    if mode == "ref":
        return ref.bound_max_ref(rows, norms, errn, residual, acc,
                                 thresh, mask, absolute=absolute)
    return corr_kernel.bound_max(rows, norms, errn, residual, acc,
                                 thresh, mask, absolute=absolute,
                                 interpret=(mode == "interpret"))


def corr_batched(grads: jax.Array, vecs: jax.Array) -> jax.Array:
    """Batched OMP scores  (B, d) against one pool -> **(n, B)** f32.

    The batched-serving scoring step, pool-major (column b is
    ``corr(grads, vecs[b])`` — the orientation the shared-operand matmul
    produces without a transpose; see the reference).  On Pallas backends
    it maps the single-problem ``corr`` kernel over the batch (the
    kernel's grid carries SMEM state, so a vmap-injected leading grid axis
    would misindex ``program_id`` — mapping sequential launches is the
    safe lowering) and transposes the stacked result.
    """
    mode = _mode()
    if mode == "ref":
        return ref.corr_batched_ref(grads, vecs)
    interpret = mode == "interpret"
    out = jax.lax.map(
        lambda v: corr_kernel.corr(grads, v, interpret=interpret), vecs)
    return out.T


def corr_argmax_batched(mat: jax.Array, w: jax.Array, base_t: jax.Array,
                        mask_t: jax.Array, *, absolute: bool = False
                        ) -> tuple[jax.Array, jax.Array]:
    """Batched fused OMP scoring: per-problem masked argmax of
    ``base - mat @ w`` with ``mat`` either per-problem ``(B, n, p)`` or a
    shared pool ``(n, p)``; ``base_t``/``mask_t`` are pool-major
    ``(n, B)``.  Returns (indices (B,), values (B,)).

    Same Pallas caveat as ``corr_batched``: the fused kernel keeps its
    running (max, index) in SMEM across a sequential grid, so the batch is
    mapped over kernel launches (per-problem ``(n,)`` slices of the
    pool-major operands) rather than vmapped through the kernel.
    """
    mode = _mode()
    if mode == "ref":
        return ref.corr_argmax_batched_ref(mat, w, base_t, mask_t,
                                           absolute=absolute)
    interpret = mode == "interpret"
    base = base_t.T
    mask = mask_t.T
    if mat.ndim == 2:
        return jax.lax.map(
            lambda args: corr_kernel.corr_argmax(
                mat, *args, absolute=absolute, interpret=interpret),
            (w, base, mask))
    return jax.lax.map(
        lambda args: corr_kernel.corr_argmax(
            *args, absolute=absolute, interpret=interpret),
        (mat, w, base, mask))


def fl_gain_argmax(sim: jax.Array, cover: jax.Array, mask: jax.Array
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Facility-location gain scan + masked argmax (resident similarity).

    Returns (gains (n,), index (), value ()).  One streaming pass over the
    similarity on TPU (the per-round ``(n, n)`` maximum temporary of the
    naive greedy never exists); the jnp reference fuses the relu into the
    column reduction on CPU.
    """
    mode = _mode()
    if mode == "ref":
        return ref.fl_gain_argmax_ref(sim, cover, mask)
    return fl_gain_kernel.fl_gain_argmax(sim, cover, mask,
                                         interpret=(mode == "interpret"))


def fl_gain_argmax_otf(grads: jax.Array, cover: jax.Array,
                       row_ok: jax.Array, mask: jax.Array,
                       l_max: jax.Array, sqnorms: jax.Array | None = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Gain scan with tile-on-the-fly similarity from ``grads`` (n, d).

    Same contract as ``fl_gain_argmax`` but the (n, n) similarity is never
    materialized in any memory space — the kernel (and the blocked jnp
    reference) reconstruct ``s_ij = (l_max - ||g_i - g_j||) * row_ok_i``
    tile by tile.  ``l_max`` must upper-bound all pairwise distances.
    ``sqnorms`` optionally hands in precomputed squared row norms (the
    lazy engine hoists them once per selection; without this the dispatch
    re-reduced them on every rescan).
    """
    mode = _mode()
    if mode == "ref":
        return ref.fl_gain_argmax_otf_ref(grads, cover, row_ok, mask,
                                          l_max, sqnorms=sqnorms)
    return fl_gain_kernel.fl_gain_argmax_otf(
        grads, cover, row_ok, mask, l_max, sqnorms=sqnorms,
        interpret=(mode == "interpret"))


def sqdist(a: jax.Array, b: jax.Array) -> jax.Array:
    """Pairwise squared distances -> (n, m) f32."""
    mode = _mode()
    if mode == "ref":
        return ref.sqdist_ref(a, b)
    return sqdist_kernel.sqdist(a, b, interpret=(mode == "interpret"))


def lastlayer_grad(hidden: jax.Array, logits: jax.Array, labels: jax.Array
                   ) -> tuple[jax.Array, jax.Array]:
    """(resid, per-gradient hidden grad) for classification heads."""
    mode = _mode()
    if mode == "ref":
        return ref.lastlayer_grad_ref(hidden, logits, labels)
    return llg_kernel.lastlayer_grad(
        hidden, logits, labels, interpret=(mode == "interpret"))


def hidden_grad(logits: jax.Array, labels: jax.Array, unembed: jax.Array
                ) -> jax.Array:
    """dL/dh = (softmax(Z) - onehot(Y)) @ W^T for LM heads, fused on TPU."""
    mode = _mode()
    if mode == "ref":
        resid, _ = ref.lastlayer_grad_ref(
            jnp.zeros((logits.shape[0], 1), jnp.float32), logits, labels)
        return resid @ unembed.T.astype(resid.dtype)
    return llg_kernel.hidden_grad_fused(
        logits, labels, unembed, interpret=(mode == "interpret"))
