"""Pallas TPU kernels for the GRAD-MATCH selection hot spots.

Layout (per kernel): <name>.py holds the pl.pallas_call + BlockSpec tiling,
ref.py the pure-jnp oracles, ops.py the backend-aware jit'd dispatch.
"""

from repro.kernels.ops import (corr, fl_gain_argmax, fl_gain_argmax_otf,
                               hidden_grad, lastlayer_grad, set_backend,
                               sqdist)

__all__ = ["corr", "sqdist", "fl_gain_argmax", "fl_gain_argmax_otf",
           "lastlayer_grad", "hidden_grad", "set_backend"]
