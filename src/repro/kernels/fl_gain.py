"""Pallas TPU kernels for the facility-location greedy (CRAIG, DESIGN.md §5).

Every greedy round scores all ``n`` candidates by their marginal coverage
gain  ``gain_j = Σ_i relu(s_ij − cover_i)``  and takes the argmax.  The seed
formulation materialized the ``(n, n)`` ``maximum(cover, sim)`` temporary per
round; these kernels stream column tiles instead and carry a running
(max, index) pair across the sequential grid, so the full gain scan reads
the similarity exactly once and the only outputs are the ``(n,)`` gain
vector (consumed by the lazy engine's bound refresh) plus two scalars.

``fl_gain_argmax``      — resident ``(n, n)`` similarity, tiled reduction.
``fl_gain_argmax_otf``  — tile-on-the-fly similarity: ``s_ij`` blocks are
computed from the ``(n, d)`` gradient matrix inside the kernel loop
(``s_ij = L_max − ‖g_i − g_j‖``, the sqdist expansion), so CRAIG runs at
pool sizes where the dense similarity alone is 4–16 GB and the ``(n, n)``
matrix never exists in any memory space.

TPU tiling: ``(128, 128)`` similarity tiles, contraction chunked 512-wide
(matching ``sqdist``); per-column partial gains accumulate in a
``(1, TILE_J)`` VMEM scratch across row tiles, and the masked argmax folds
into SMEM scalars at each column tile's last row step (ties → lowest
index, matching ``jnp.argmax``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_I = 128   # coverage-row tile (sublane-aligned)
TILE_J = 128   # candidate-column tile (lane-aligned)
TILE_D = 512   # proxy-dim chunk for the on-the-fly inner product


def _fold_argmax(gains, mask, j, idx_ref, val_ref, *, n_sentinel):
    """Fold one column tile's masked (max, lowest-index) into the running
    SMEM pair.  gains/mask are (1, TILE_J); ties resolve to the lowest
    global column index; an all-masked tile is well-defined at -inf."""
    neg_inf = jnp.float32(-jnp.inf)
    gm = jnp.where(mask > 0, gains, neg_inf)
    tile_max = jnp.max(gm)
    col_ids = jax.lax.broadcasted_iota(jnp.int32, gm.shape, 1)
    tile_idx = jnp.min(
        jnp.where(gm == tile_max, col_ids, jnp.int32(n_sentinel))
    ) + j * TILE_J

    @pl.when(j == 0)
    def _first():
        val_ref[0, 0] = tile_max
        idx_ref[0, 0] = tile_idx

    @pl.when((j > 0) & (tile_max > val_ref[0, 0]))
    def _better():
        val_ref[0, 0] = tile_max
        idx_ref[0, 0] = tile_idx


def _fl_gain_kernel(s_ref, cover_ref, mask_ref, gains_ref, idx_ref, val_ref,
                    acc_ref, *, n_sentinel):
    j = pl.program_id(0)
    i = pl.program_id(1)
    last_i = pl.num_programs(1) - 1

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s = s_ref[...].astype(jnp.float32)            # (TILE_I, TILE_J)
    c = cover_ref[...].astype(jnp.float32)        # (TILE_I, 1)
    acc_ref[...] += jnp.sum(jnp.maximum(s - c, 0.0), axis=0, keepdims=True)

    @pl.when(i == last_i)
    def _reduce():
        g = acc_ref[...]                          # (1, TILE_J)
        gains_ref[...] = g
        _fold_argmax(g, mask_ref[...], j, idx_ref, val_ref,
                     n_sentinel=n_sentinel)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fl_gain_argmax(sim: jax.Array, cover: jax.Array, mask: jax.Array, *,
                   interpret: bool = False
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Facility-location gain scan over a resident similarity.

    sim (n, n), cover (n,), mask (n,) bool ->
    (gains (n,) f32, argmax index i32 (), max gain f32 ()).

    Gains are raw (unmasked); the argmax honors ``mask`` with lowest-index
    tie-breaking and an all-False mask yields (0, -inf), matching the jnp
    reference.  Zero row/column padding is exact: padded rows contribute
    ``relu(0 − 0) = 0`` and padded columns are masked out.
    """
    n = sim.shape[0]
    i_pad = (-n) % TILE_I
    j_pad = (-n) % TILE_J
    s = jnp.pad(sim, ((0, i_pad), (0, j_pad)))
    c = jnp.pad(cover, (0, i_pad)).astype(jnp.float32).reshape(-1, 1)
    m = jnp.pad(mask.astype(jnp.float32), (0, j_pad)).reshape(1, -1)
    ni, nj = s.shape

    kernel = functools.partial(_fl_gain_kernel, n_sentinel=nj)
    gains, idx, val = pl.pallas_call(
        kernel,
        grid=(nj // TILE_J, ni // TILE_I),
        in_specs=[
            pl.BlockSpec((TILE_I, TILE_J), lambda j, i: (i, j)),
            pl.BlockSpec((TILE_I, 1), lambda j, i: (i, 0)),
            pl.BlockSpec((1, TILE_J), lambda j, i: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_J), lambda j, i: (0, j)),
            pl.BlockSpec((1, 1), lambda j, i: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda j, i: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, nj), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, TILE_J), jnp.float32)],
        interpret=interpret,
    )(s, c, m)
    return gains[0, :n], idx[0, 0], val[0, 0]


def _fl_gain_otf_kernel(gr_ref, gc_ref, rn_ref, cn_ref, cover_ref, rok_ref,
                        mask_ref, lmax_ref, gains_ref, idx_ref, val_ref,
                        dot_ref, acc_ref, *, n_sentinel):
    j = pl.program_id(0)
    i = pl.program_id(1)
    kd = pl.program_id(2)
    last_i = pl.num_programs(1) - 1
    last_kd = pl.num_programs(2) - 1

    @pl.when(kd == 0)
    def _init_dot():
        dot_ref[...] = jnp.zeros_like(dot_ref)

    @pl.when((i == 0) & (kd == 0))
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = gr_ref[...].astype(jnp.float32)           # (TILE_I, TILE_D) rows
    b = gc_ref[...].astype(jnp.float32)           # (TILE_J, TILE_D) cands
    dot_ref[...] += a @ b.T                       # (TILE_I, TILE_J) — MXU

    @pl.when(kd == last_kd)
    def _accumulate():
        rn = rn_ref[...].astype(jnp.float32)      # (TILE_I, 1) |g_i|^2
        cn = cn_ref[...].astype(jnp.float32)      # (1, TILE_J) |g_j|^2
        d2 = rn + cn - 2.0 * dot_ref[...]
        dist = jnp.sqrt(jnp.maximum(d2, 0.0))
        s = (lmax_ref[0, 0] - dist) * rok_ref[...]   # invalid/pad rows -> 0
        c = cover_ref[...].astype(jnp.float32)
        acc_ref[...] += jnp.sum(jnp.maximum(s - c, 0.0), axis=0,
                                keepdims=True)

        @pl.when(i == last_i)
        def _reduce():
            g = acc_ref[...]
            gains_ref[...] = g
            _fold_argmax(g, mask_ref[...], j, idx_ref, val_ref,
                         n_sentinel=n_sentinel)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fl_gain_argmax_otf(grads: jax.Array, cover: jax.Array,
                       row_ok: jax.Array, mask: jax.Array,
                       l_max: jax.Array,
                       sqnorms: jax.Array | None = None, *,
                       interpret: bool = False
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Gain scan with the similarity computed tile-by-tile from ``grads``.

    grads (n, d), cover (n,), row_ok (n,) bool (rows allowed to demand
    coverage — invalid rows contribute 0, exactly like the zeroed rows of
    the resident path), mask (n,) bool (candidate columns), l_max () f32
    (the similarity offset; must upper-bound all pairwise distances) ->
    (gains (n,) f32, argmax index i32 (), max gain f32 ()).

    The (n, n) similarity never exists: each (TILE_I, TILE_J) block is
    reconstructed from two gradient tiles and folded into the per-column
    gain accumulator immediately.  ``sqnorms`` (squared row norms of the
    unpadded grads) skips the per-call norm reduction when the caller
    already holds them; zero-padded rows have zero norm either way.
    """
    n, d = grads.shape
    n_pad = (-n) % TILE_I          # TILE_I == TILE_J: one row/col pad
    d_pad = (-d) % TILE_D
    g = jnp.pad(grads.astype(jnp.float32), ((0, n_pad), (0, d_pad)))
    sqn = (jnp.sum(g * g, axis=1) if sqnorms is None
           else jnp.pad(jnp.asarray(sqnorms, jnp.float32), (0, n_pad)))
    rn = sqn.reshape(-1, 1)
    cn = sqn.reshape(1, -1)
    c = jnp.pad(cover, (0, n_pad)).astype(jnp.float32).reshape(-1, 1)
    rok = jnp.pad(row_ok.astype(jnp.float32), (0, n_pad)).reshape(-1, 1)
    m = jnp.pad(mask.astype(jnp.float32), (0, n_pad)).reshape(1, -1)
    lm = jnp.asarray(l_max, jnp.float32).reshape(1, 1)
    np_, dp = g.shape

    kernel = functools.partial(_fl_gain_otf_kernel, n_sentinel=np_)
    gains, idx, val = pl.pallas_call(
        kernel,
        grid=(np_ // TILE_J, np_ // TILE_I, dp // TILE_D),
        in_specs=[
            pl.BlockSpec((TILE_I, TILE_D), lambda j, i, kd: (i, kd)),
            pl.BlockSpec((TILE_J, TILE_D), lambda j, i, kd: (j, kd)),
            pl.BlockSpec((TILE_I, 1), lambda j, i, kd: (i, 0)),
            pl.BlockSpec((1, TILE_J), lambda j, i, kd: (0, j)),
            pl.BlockSpec((TILE_I, 1), lambda j, i, kd: (i, 0)),
            pl.BlockSpec((TILE_I, 1), lambda j, i, kd: (i, 0)),
            pl.BlockSpec((1, TILE_J), lambda j, i, kd: (0, j)),
            pl.BlockSpec((1, 1), lambda j, i, kd: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_J), lambda j, i, kd: (0, j)),
            pl.BlockSpec((1, 1), lambda j, i, kd: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda j, i, kd: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((TILE_I, TILE_J), jnp.float32),
            pltpu.VMEM((1, TILE_J), jnp.float32),
        ],
        interpret=interpret,
    )(g, g, rn, cn, c, rok, m, lm)
    return gains[0, :n], idx[0, 0], val[0, 0]
