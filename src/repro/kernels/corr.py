"""Pallas TPU kernel: OMP residual correlation  scores = G @ r.

This is the inner loop of OMP (Algorithm 2): every selection round scores all
``n`` candidates against the current residual.  ``G`` is ``(n, d)`` gradient
proxies (n up to ~1e5 candidate micro-batches, d = proxy dim ≲ 8192), ``r`` is
``(d,)``.

TPU tiling: rows are processed in MXU-aligned tiles of 128 and the proxy
dimension in VMEM-sized chunks of 512; each grid step multiplies a
``(128, 512)`` tile of G against the matching slice of ``r`` and accumulates
into the per-row output tile, so the working set stays well inside VMEM
(128*512*4B = 256 KiB per G tile) regardless of n and d.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 128   # rows per grid step (MXU sublane-aligned)
TILE_D = 512   # proxy-dim chunk per grid step (lane-aligned, 128 | TILE_D)


def _corr_kernel(g_ref, r_ref, out_ref):
    j = pl.program_id(1)
    g = g_ref[...].astype(jnp.float32)          # (TILE_N, TILE_D)
    r = r_ref[...].astype(jnp.float32)          # (TILE_D, 1)
    partial = g @ r                             # (TILE_N, 1)  -- MXU matvec

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret",))
def corr(grads: jax.Array, residual: jax.Array, *, interpret: bool = False
         ) -> jax.Array:
    """scores = grads @ residual, f32.  grads (n, d), residual (d,) -> (n,).

    Pads n up to TILE_N and d up to TILE_D (zero padding is exact for a dot
    product) and strips the padding afterwards.
    """
    n, d = grads.shape
    n_pad = (-n) % TILE_N
    d_pad = (-d) % TILE_D
    g = jnp.pad(grads, ((0, n_pad), (0, d_pad)))
    r = jnp.pad(residual, (0, d_pad)).reshape(-1, 1)
    np_, dp = g.shape

    out = pl.pallas_call(
        _corr_kernel,
        grid=(np_ // TILE_N, dp // TILE_D),
        in_specs=[
            pl.BlockSpec((TILE_N, TILE_D), lambda i, j: (i, j)),
            pl.BlockSpec((TILE_D, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(g, r)
    return out[:n, 0]
