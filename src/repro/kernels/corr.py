"""Pallas TPU kernels for the OMP scoring step.

``corr``: residual correlation  scores = G @ r — the inner loop of OMP
(Algorithm 2): every selection round scores all ``n`` candidates against the
current residual.  ``G`` is ``(n, d)`` gradient proxies (n up to ~1e5
candidate micro-batches, d = proxy dim ≲ 8192), ``r`` is ``(d,)``.

``corr_argmax``: the incremental solver's fused scores-and-argmax.  Scores
are ``c0 - C @ w`` over the cached correlation columns ``C`` (DESIGN.md §2);
the kernel streams row tiles of ``C``, applies the availability mask, and
carries a running (max, argmin-index) pair across the grid — the ``(n,)``
score vector is never materialized in HBM and the candidate pool is read
exactly once per round.

TPU tiling: rows are processed in MXU-aligned tiles of 128 and the
contraction dimension in VMEM-sized chunks of 512; each grid step multiplies
a ``(128, 512)`` tile against the matching slice of the vector operand and
accumulates into a per-row register tile, so the working set stays well
inside VMEM (128*512*4B = 256 KiB per tile) regardless of n and d.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_N = 128   # rows per grid step (MXU sublane-aligned)
TILE_D = 512   # proxy-dim chunk per grid step (lane-aligned, 128 | TILE_D)


def _corr_kernel(g_ref, r_ref, out_ref):
    j = pl.program_id(1)
    g = g_ref[...].astype(jnp.float32)          # (TILE_N, TILE_D)
    r = r_ref[...].astype(jnp.float32)          # (TILE_D, 1)
    partial = g @ r                             # (TILE_N, 1)  -- MXU matvec

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret",))
def corr(grads: jax.Array, residual: jax.Array, *, interpret: bool = False
         ) -> jax.Array:
    """scores = grads @ residual, f32.  grads (n, d), residual (d,) -> (n,).

    Pads n up to TILE_N and d up to TILE_D (zero padding is exact for a dot
    product) and strips the padding afterwards.
    """
    n, d = grads.shape
    n_pad = (-n) % TILE_N
    d_pad = (-d) % TILE_D
    g = jnp.pad(grads, ((0, n_pad), (0, d_pad)))
    r = jnp.pad(residual, (0, d_pad)).reshape(-1, 1)
    np_, dp = g.shape

    out = pl.pallas_call(
        _corr_kernel,
        grid=(np_ // TILE_N, dp // TILE_D),
        in_specs=[
            pl.BlockSpec((TILE_N, TILE_D), lambda i, j: (i, j)),
            pl.BlockSpec((TILE_D, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(g, r)
    return out[:n, 0]


def _bound_max_kernel(g_ref, nrm_ref, err_ref, r_ref, sc_ref, mask_ref,
                      val_ref, idx_ref, cnt_ref, acc_ref, *,
                      absolute: bool, n_valid: int):
    """Fused interval-bound scan (streaming OMP certification, §7).

    Row tiles of the bf16 cache are matvec'd against the residual across
    d chunks; at the last chunk the per-row upper bound ``u = s̃ +
    (e + acc·‖g‖)·‖r‖`` is formed from the f32 sidecars and folded into
    running (max, lowest-index, offender-count) SMEM scalars — ``u``
    never hits HBM.  ``sc_ref`` is (1, 3) SMEM: [‖r‖, acc, thresh].
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    last_j = pl.num_programs(1) - 1

    @pl.when(j == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)          # (TILE_N, TILE_D)
    r = r_ref[...].astype(jnp.float32)          # (TILE_D, 1)
    acc_ref[...] += g @ r

    @pl.when(j == last_j)
    def _reduce():
        neg_inf = jnp.float32(-jnp.inf)
        rnorm = sc_ref[0, 0]
        acc = sc_ref[0, 1]
        thresh = sc_ref[0, 2]
        s = acc_ref[...]                        # (TILE_N, 1)
        if absolute:
            s = jnp.abs(s)
        u = s + (err_ref[...] + acc * nrm_ref[...]) * rnorm
        u = jnp.where(mask_ref[...] > 0, u, neg_inf)
        tile_max = jnp.max(u)
        row_ids = jax.lax.broadcasted_iota(jnp.int32, u.shape, 0)
        tile_idx = jnp.min(
            jnp.where(u == tile_max, row_ids, jnp.int32(n_valid))
        ) + i * TILE_N
        tile_cnt = jnp.sum(((mask_ref[...] > 0)
                            & (u >= thresh)).astype(jnp.int32))

        @pl.when(i == 0)
        def _first():
            val_ref[0, 0] = tile_max
            idx_ref[0, 0] = tile_idx
            cnt_ref[0, 0] = tile_cnt

        @pl.when(i > 0)
        def _fold():
            cnt_ref[0, 0] += tile_cnt

            @pl.when(tile_max > val_ref[0, 0])
            def _better():
                val_ref[0, 0] = tile_max
                idx_ref[0, 0] = tile_idx


@functools.partial(jax.jit, static_argnames=("absolute", "interpret"))
def bound_max(rows: jax.Array, norms: jax.Array, errn: jax.Array,
              residual: jax.Array, acc: jax.Array, thresh: jax.Array,
              mask: jax.Array, *, absolute: bool = False,
              interpret: bool = False
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused compressed-cache bound scan: see ``ref.bound_max_ref`` for
    the contract.  Pads n to TILE_N (padded rows masked out, zero
    sidecars) and d to TILE_D (zero padding is exact for the dot)."""
    n, d = rows.shape
    n_pad = (-n) % TILE_N
    d_pad = (-d) % TILE_D
    g = jnp.pad(rows, ((0, n_pad), (0, d_pad)))
    r = jnp.pad(residual.astype(jnp.float32), (0, d_pad)).reshape(-1, 1)
    nrm = jnp.pad(norms.astype(jnp.float32), (0, n_pad)).reshape(-1, 1)
    err = jnp.pad(errn.astype(jnp.float32), (0, n_pad)).reshape(-1, 1)
    m = jnp.pad(mask.astype(jnp.float32), (0, n_pad)).reshape(-1, 1)
    rnorm = jnp.sqrt(jnp.sum(r * r))
    sc = jnp.stack([rnorm, jnp.asarray(acc, jnp.float32),
                    jnp.asarray(thresh, jnp.float32)]).reshape(1, 3)
    np_, dp = g.shape

    kernel = functools.partial(_bound_max_kernel, absolute=absolute,
                               n_valid=np_)
    val, idx, cnt = pl.pallas_call(
        kernel,
        grid=(np_ // TILE_N, dp // TILE_D),
        in_specs=[
            pl.BlockSpec((TILE_N, TILE_D), lambda i, j: (i, j)),
            pl.BlockSpec((TILE_N, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_D, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 3), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((TILE_N, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((TILE_N, 1), jnp.float32)],
        interpret=interpret,
    )(g, nrm, err, r, sc, m)
    return val[0, 0], idx[0, 0], cnt[0, 0]


def _corr_argmax_kernel(c_ref, w_ref, base_ref, mask_ref, idx_ref, val_ref,
                        acc_ref, *, absolute: bool, n_valid: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    last_j = pl.num_programs(1) - 1

    @pl.when(j == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = c_ref[...].astype(jnp.float32)          # (TILE_N, TILE_D)
    w = w_ref[...].astype(jnp.float32)          # (TILE_D, 1)
    acc_ref[...] += c @ w                       # (TILE_N, 1)  -- MXU matvec

    @pl.when(j == last_j)
    def _reduce():
        neg_inf = jnp.float32(-jnp.inf)
        s = base_ref[...] - acc_ref[...]        # (TILE_N, 1) scores
        if absolute:
            s = jnp.abs(s)
        s = jnp.where(mask_ref[...] > 0, s, neg_inf)
        tile_max = jnp.max(s)
        # Lowest row index attaining the tile max (first-occurrence tie
        # break, matching jnp.argmax); -inf == -inf keeps the all-masked
        # tile well-defined at local index 0.
        row_ids = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        tile_idx = jnp.min(
            jnp.where(s == tile_max, row_ids, jnp.int32(n_valid))
        ) + i * TILE_N

        @pl.when(i == 0)
        def _first():
            val_ref[0, 0] = tile_max
            idx_ref[0, 0] = tile_idx

        @pl.when((i > 0) & (tile_max > val_ref[0, 0]))
        def _better():
            val_ref[0, 0] = tile_max
            idx_ref[0, 0] = tile_idx


@functools.partial(jax.jit, static_argnames=("absolute", "interpret"))
def corr_argmax(colcache: jax.Array, w: jax.Array, base: jax.Array,
                mask: jax.Array, *, absolute: bool = False,
                interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Fused masked argmax of  scores = base - colcache @ w.

    colcache (n, k), w (k,), base (n,), mask (n,) bool ->
    (argmax index i32 (), max score f32 ()).

    One streaming pass: row tiles accumulate the matvec across k chunks,
    then fold their masked tile-max into a running (value, index) carried in
    SMEM across the sequential TPU grid.  Ties resolve to the lowest index
    and an all-False mask yields (0, -inf), both matching the jnp reference.
    Pads n up to TILE_N (padded rows are masked out) and k up to TILE_D
    (zero padding is exact for the dot product).
    """
    n, k = colcache.shape
    n_pad = (-n) % TILE_N
    k_pad = (-k) % TILE_D
    c = jnp.pad(colcache, ((0, n_pad), (0, k_pad)))
    wv = jnp.pad(w, (0, k_pad)).astype(jnp.float32).reshape(-1, 1)
    b = jnp.pad(base, (0, n_pad)).astype(jnp.float32).reshape(-1, 1)
    m = jnp.pad(mask.astype(jnp.float32), (0, n_pad)).reshape(-1, 1)
    np_, kp = c.shape

    kernel = functools.partial(_corr_argmax_kernel, absolute=absolute,
                               n_valid=np_)
    idx, val = pl.pallas_call(
        kernel,
        grid=(np_ // TILE_N, kp // TILE_D),
        in_specs=[
            pl.BlockSpec((TILE_N, TILE_D), lambda i, j: (i, j)),
            pl.BlockSpec((TILE_D, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((TILE_N, 1), jnp.float32)],
        interpret=interpret,
    )(c, wv, b, m)
    return idx[0, 0], val[0, 0]
