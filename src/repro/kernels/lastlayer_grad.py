"""Pallas TPU kernels for fused last-layer gradient proxies (paper §4).

GRAD-MATCH's scalable variants never backprop through the trunk: for a CE
head the per-sample last-layer gradient is closed form in (hidden, logits,
labels).  Two fused kernels cover the two regimes:

``lastlayer_grad``  — classification heads (small C):
    resid = softmax(Z) - onehot(Y)            (n, C)
    hgrad = resid[i, y_i] * hidden            (n, d_h)   (per-gradient approx)
  fused in one pass over row tiles; the ``(n, C)`` probabilities never round-
  trip through HBM in f32.

``hidden_grad_fused`` — LM heads (V up to 256k):
    out = (softmax(Z) - onehot(Y)) @ W_unembed^T          (n, d_h)
  the exact head-input gradient ``dL/dh``.  The naive path materializes the
  ``(n, V)`` residual (at V=256k and n=64k candidate tokens that is 32 GiB);
  here a flash-style two-phase schedule streams Z and W in (128, 512) tiles:
  phase 0 computes the running softmax max/denominator per row, phase 1
  accumulates ``p @ W^T`` chunk-by-chunk and subtracts the one-hot row via a
  small ``onehot @ W`` MXU matmul (gather-free).  HBM traffic is exactly one
  read of Z and W per row tile and one write of the (n, d_h) output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE_N = 128    # rows per grid step
TILE_V = 512    # vocab chunk
TILE_H = 512    # hidden chunk

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Kernel A: classification heads (single-block C and d_h).
# ---------------------------------------------------------------------------

def _lastlayer_kernel(hid_ref, z_ref, y_ref, resid_ref, hgrad_ref):
    z = z_ref[...].astype(jnp.float32)                       # (N, C)
    labels = y_ref[...]                                      # (N, 1) int32
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    cols = lax.broadcasted_iota(jnp.int32, z.shape, 1)
    onehot = (cols == labels).astype(jnp.float32)
    resid = p - onehot
    resid_ref[...] = resid
    own = jnp.sum(resid * onehot, axis=-1, keepdims=True)    # (N, 1)
    hgrad_ref[...] = own * hid_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lastlayer_grad(
    hidden: jax.Array,   # (n, d_h)
    logits: jax.Array,   # (n, C)  -- small C (classification head)
    labels: jax.Array,   # (n,) int
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    n, c = logits.shape
    dh = hidden.shape[1]
    n_pad = (-n) % TILE_N
    hid = jnp.pad(hidden, ((0, n_pad), (0, 0)))
    z = jnp.pad(logits, ((0, n_pad), (0, 0)),
                constant_values=0.0)
    y = jnp.pad(labels.astype(jnp.int32), (0, n_pad)).reshape(-1, 1)
    np_ = z.shape[0]

    resid, hgrad = pl.pallas_call(
        _lastlayer_kernel,
        grid=(np_ // TILE_N,),
        in_specs=[
            pl.BlockSpec((TILE_N, dh), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N, c), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TILE_N, c), lambda i: (i, 0)),
            pl.BlockSpec((TILE_N, dh), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((np_, c), jnp.float32),
            jax.ShapeDtypeStruct((np_, dh), jnp.float32),
        ],
        interpret=interpret,
    )(hid, z, y)
    return resid[:n], hgrad[:n]


# ---------------------------------------------------------------------------
# Kernel B: LM heads -- fused (softmax(Z) - onehot) @ W^T, flash-style.
# ---------------------------------------------------------------------------

def _hidden_grad_kernel(z_ref, y_ref, wt_ref, out_ref, m_ref, l_ref,
                        *, n_vchunks):
    phase = pl.program_id(1)
    h = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when((phase == 0) & (h == 0))
    def _stats():
        # Online softmax statistics over vocab chunks (flash rescaling).
        @pl.when(j == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref[...], _NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref[...])

        z = z_ref[...].astype(jnp.float32)                   # (N, V_CHUNK)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(z, axis=-1, keepdims=True))
        scale = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * scale + jnp.sum(
            jnp.exp(z - m_new), axis=-1, keepdims=True)
        m_ref[...] = m_new

    @pl.when(phase == 1)
    def _accumulate():
        z = z_ref[...].astype(jnp.float32)                   # (N, V_CHUNK)
        labels = y_ref[...]                                  # (N, 1)
        wt = wt_ref[...].astype(jnp.float32)                 # (V_CHUNK, H)
        p = jnp.exp(z - m_ref[...]) / l_ref[...]
        cols = lax.broadcasted_iota(jnp.int32, z.shape, 1) + j * z.shape[1]
        onehot = (cols == labels).astype(jnp.float32)
        partial = (p - onehot) @ wt                          # (N, H) on MXU

        @pl.when(j == 0)
        def _init():
            out_ref[...] = partial

        @pl.when(j > 0)
        def _acc():
            out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret",))
def hidden_grad_fused(
    logits: jax.Array,    # (n, V)
    labels: jax.Array,    # (n,) int
    unembed: jax.Array,   # (d_h, V) head weight  (out = resid @ unembed.T)
    *,
    interpret: bool = False,
) -> jax.Array:
    n, v = logits.shape
    dh = unembed.shape[0]
    n_pad = (-n) % TILE_N
    v_pad = (-v) % TILE_V
    h_pad = (-dh) % TILE_H
    # Padding vocab with -inf-ish logits keeps softmax exact; padded W rows
    # are zero so they contribute nothing to the matmul.
    z = jnp.pad(logits, ((0, n_pad), (0, v_pad)), constant_values=_NEG_INF)
    y = jnp.pad(labels.astype(jnp.int32), (0, n_pad)).reshape(-1, 1)
    wt = jnp.pad(unembed.T, ((0, v_pad), (0, h_pad)))
    np_, vp = z.shape
    hp = wt.shape[1]
    n_vchunks = vp // TILE_V

    out = pl.pallas_call(
        functools.partial(_hidden_grad_kernel, n_vchunks=n_vchunks),
        grid=(np_ // TILE_N, 2, hp // TILE_H, n_vchunks),
        in_specs=[
            pl.BlockSpec((TILE_N, TILE_V), lambda i, p, h, j: (i, j)),
            pl.BlockSpec((TILE_N, 1), lambda i, p, h, j: (i, 0)),
            pl.BlockSpec((TILE_V, TILE_H), lambda i, p, h, j: (j, h)),
        ],
        out_specs=pl.BlockSpec((TILE_N, TILE_H), lambda i, p, h, j: (i, h)),
        out_shape=jax.ShapeDtypeStruct((np_, hp), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((TILE_N, 1), jnp.float32),   # running max
            pltpu.VMEM((TILE_N, 1), jnp.float32),   # running denominator
        ],
        interpret=interpret,
    )(z, y, wt)
    return out[:n, :dh]
