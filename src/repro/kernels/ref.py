"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (tests sweep
shapes/dtypes with ``interpret=True`` and assert_allclose against these), and
they are also the dispatch fallback on backends without Pallas support
(see ops.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def corr_ref(grads: jax.Array, residual: jax.Array) -> jax.Array:
    """OMP residual-correlation scores:  (n, d) @ (d,) -> (n,) in f32."""
    return grads.astype(jnp.float32) @ residual.astype(jnp.float32)


def corr_argmax_ref(colcache: jax.Array, w: jax.Array, base: jax.Array,
                    mask: jax.Array, absolute: bool = False
                    ) -> tuple[jax.Array, jax.Array]:
    """Masked argmax of  scores = base - colcache @ w  (incremental OMP).

    colcache (n, k), w (k,), base (n,), mask (n,) bool ->
    (argmax index i32 (), max score f32 ()).  Ties resolve to the lowest
    index (jnp.argmax semantics); an all-False mask yields (0, -inf).
    """
    scores = base.astype(jnp.float32) - (
        colcache.astype(jnp.float32) @ w.astype(jnp.float32))
    if absolute:
        scores = jnp.abs(scores)
    scores = jnp.where(mask, scores, -jnp.inf)
    idx = jnp.argmax(scores).astype(jnp.int32)
    return idx, scores[idx]


def sqdist_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Pairwise squared euclidean distances  (n, d), (m, d) -> (n, m), f32.

    Computed the numerically-stable expanded way (same contraction order the
    kernel uses) so the oracle and the kernel agree to float tolerance.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    an = jnp.sum(a * a, axis=-1)
    bn = jnp.sum(b * b, axis=-1)
    d2 = an[:, None] + bn[None, :] - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def lastlayer_grad_ref(
    hidden: jax.Array,   # (n, d_h)
    logits: jax.Array,   # (n, v)
    labels: jax.Array,   # (n,) int32
) -> tuple[jax.Array, jax.Array]:
    """Fused last-layer CE gradient pieces.

    Returns
      resid : (n, v)  = softmax(logits) - onehot(labels)   (dL/db per sample)
      hgrad : (n, d_h) = resid @ nothing -- the *hidden-side* reduction the
              per-batch proxy needs is resid^T @ hidden aggregated per batch;
              here we return the per-sample row-scaled hidden
              own_resid * hidden (the paper's per-gradient approximation),
              own_resid = resid[i, labels[i]].
    """
    z = logits.astype(jnp.float32)
    z = z - jax.lax.stop_gradient(jnp.max(z, axis=-1, keepdims=True))
    p = jnp.exp(z) / jnp.sum(jnp.exp(z), axis=-1, keepdims=True)
    y = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    resid = p - y
    own = jnp.take_along_axis(resid, labels[:, None].astype(jnp.int32), axis=-1)
    hgrad = own * hidden.astype(jnp.float32)
    return resid, hgrad
