"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (tests sweep
shapes/dtypes with ``interpret=True`` and assert_allclose against these), and
they are also the dispatch fallback on backends without Pallas support
(see ops.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def corr_ref(grads: jax.Array, residual: jax.Array) -> jax.Array:
    """OMP residual-correlation scores:  (n, d) @ (d,) -> (n,) in f32."""
    return grads.astype(jnp.float32) @ residual.astype(jnp.float32)


def corr_argmax_ref(colcache: jax.Array, w: jax.Array, base: jax.Array,
                    mask: jax.Array, absolute: bool = False
                    ) -> tuple[jax.Array, jax.Array]:
    """Masked argmax of  scores = base - colcache @ w  (incremental OMP).

    colcache (n, k), w (k,), base (n,), mask (n,) bool ->
    (argmax index i32 (), max score f32 ()).  Ties resolve to the lowest
    index (jnp.argmax semantics); an all-False mask yields (0, -inf).
    """
    scores = base.astype(jnp.float32) - (
        colcache.astype(jnp.float32) @ w.astype(jnp.float32))
    if absolute:
        scores = jnp.abs(scores)
    scores = jnp.where(mask, scores, -jnp.inf)
    idx = jnp.argmax(scores).astype(jnp.int32)
    return idx, scores[idx]


def corr_batched_ref(grads: jax.Array, vecs: jax.Array) -> jax.Array:
    """Batched OMP scores:  (n, d) @ (B, d)^T -> **(n, B)** in f32.

    One shared-operand matmul instead of B matvecs — the batched serving
    path's scoring step (column b is ``corr_ref(grads, vecs[b])``).  The
    transposed orientation is deliberate: contracting along the pool's
    contiguous rows (``g @ v^T``) runs ~2x faster on XLA:CPU than
    ``v @ g^T`` and feeds an axis-0 argmax with no output transpose.
    """
    return grads.astype(jnp.float32) @ vecs.astype(jnp.float32).T


def corr_argmax_batched_ref(mat: jax.Array, w: jax.Array, base_t: jax.Array,
                            mask_t: jax.Array, absolute: bool = False
                            ) -> tuple[jax.Array, jax.Array]:
    """Batched twin of ``corr_argmax_ref``:  B fused score-and-argmax.

    ``mat`` is either a per-problem column cache ``(B, n, p)`` or a shared
    pool matrix ``(n, p)`` (the narrow-regime call, where every problem
    scores the same pool against its own residual ``w``).  w (B, p);
    ``base_t``/``mask_t`` are **pool-major** ``(n, B)`` (same orientation
    as ``corr_batched_ref`` output — the hot matmul then never transposes)
    -> (indices (B,) i32, values (B,) f32).  Per-problem semantics match
    the single-problem reference: lowest-index tie-break (axis-0 argmax),
    all-masked column yields (0, -inf).
    """
    w = w.astype(jnp.float32)
    base_t = base_t.astype(jnp.float32)
    if mat.ndim == 2:
        scores = base_t - mat.astype(jnp.float32) @ w.T        # (n, B)
    else:
        scores = base_t - jnp.einsum("bnp,bp->nb",
                                     mat.astype(jnp.float32), w)
    if absolute:
        scores = jnp.abs(scores)
    scores = jnp.where(mask_t, scores, -jnp.inf)
    idx = jnp.argmax(scores, axis=0).astype(jnp.int32)
    vals = scores[idx, jnp.arange(scores.shape[1])]
    return idx, vals


def bound_max_ref(rows: jax.Array, norms: jax.Array, errn: jax.Array,
                  residual: jax.Array, acc: jax.Array, thresh: jax.Array,
                  mask: jax.Array, absolute: bool = False
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused interval-bound scan over a compressed row cache (streaming
    OMP certification rung 2, DESIGN.md §7).

    rows (n, d) bf16 (or f32), norms/errn (n,) f32 sidecars (exact row
    norm, ``‖g − bf16(g)‖``), residual (d,), acc () accumulation-margin
    scalar, thresh () comparison threshold (the buffer max), mask (n,)
    bool -> (max upper bound f32 (), its argmax index i32 (), count of
    masked rows with ``u >= thresh`` i32 ()).

    ``u_i = s̃_i + (e_i + acc·‖g_i‖)·‖r‖`` upper-bounds the exact f32
    score of the uncompressed row; the count is the certification
    offender count.  Ties resolve to the lowest index; an all-False mask
    yields (-inf, 0, 0).
    """
    r = residual.astype(jnp.float32)
    s = rows.astype(jnp.float32) @ r
    if absolute:
        s = jnp.abs(s)
    rnorm = jnp.sqrt(jnp.sum(r * r))
    u = s + (errn + acc * norms) * rnorm
    u_m = jnp.where(mask, u, -jnp.inf)
    idx = jnp.argmax(u_m).astype(jnp.int32)
    return (u_m[idx], idx,
            jnp.sum(mask & (u_m >= thresh)).astype(jnp.int32))


def fl_gain_argmax_ref(sim: jax.Array, cover: jax.Array, mask: jax.Array
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Facility-location gain scan (CRAIG greedy, resident similarity).

    sim (n, n), cover (n,), mask (n,) bool ->
    (gains (n,) f32 with gain_j = sum_i relu(s_ij - cover_i), masked argmax
    index i32 (), max gain f32 ()).  Gains are raw (unmasked); ties resolve
    to the lowest index (jnp.argmax semantics) and an all-False mask yields
    (0, -inf).  XLA fuses the relu into the column reduction, so no
    (n, n) temporary materializes on the reference path either.
    """
    gains = jnp.sum(
        jnp.maximum(sim.astype(jnp.float32)
                    - cover.astype(jnp.float32)[:, None], 0.0),
        axis=0,
    )
    masked = jnp.where(mask, gains, -jnp.inf)
    idx = jnp.argmax(masked).astype(jnp.int32)
    return gains, idx, masked[idx]


def fl_gains_cols_ref(cand: jax.Array, cand_sqn: jax.Array,
                      grads: jax.Array, sqnorms: jax.Array,
                      cover: jax.Array, row_ok: jax.Array,
                      l_max: jax.Array, block: int = 256) -> jax.Array:
    """FL gains for an explicit candidate slice, blocked over coverage
    rows: cand (m, d) against the pool grads (n, d) -> (m,) gains with
    ``gain_j = Σ_i relu((l_max - ||g_i - c_j||)·row_ok_i − cover_i)``,
    peak memory O(block·m).  The single copy of the strip computation:
    the full scan below runs it with cand = grads, the lazy engine's
    block refresh and the pmap-sharded scan run it on slices — keeping
    every on-the-fly gain bit-for-bit reduction-order-identical, which
    the lazy certification margin assumes.
    """
    n, d = grads.shape
    g = grads.astype(jnp.float32)
    lm = jnp.asarray(l_max, jnp.float32)
    nb = -(-n // block)
    pad = nb * block - n
    gp = jnp.pad(g, ((0, pad), (0, 0)))
    sqnp = jnp.pad(sqnorms, (0, pad))
    cp = jnp.pad(cover.astype(jnp.float32), (0, pad))
    okp = jnp.pad(row_ok.astype(jnp.float32), (0, pad))
    cand = cand.astype(jnp.float32)

    def body(b, gains):
        lo = b * block
        rows = jax.lax.dynamic_slice(gp, (lo, 0), (block, d))
        rn = jax.lax.dynamic_slice(sqnp, (lo,), (block,))
        cv = jax.lax.dynamic_slice(cp, (lo,), (block,))
        ok = jax.lax.dynamic_slice(okp, (lo,), (block,))
        d2 = rn[:, None] + cand_sqn[None, :] - 2.0 * (rows @ cand.T)
        s = (lm - jnp.sqrt(jnp.maximum(d2, 0.0))) * ok[:, None]
        return gains + jnp.sum(jnp.maximum(s - cv[:, None], 0.0), axis=0)

    return jax.lax.fori_loop(0, nb, body,
                             jnp.zeros((cand.shape[0],), jnp.float32))


def fl_gain_argmax_otf_ref(grads: jax.Array, cover: jax.Array,
                           row_ok: jax.Array, mask: jax.Array,
                           l_max: jax.Array, block: int = 1024,
                           sqnorms: jax.Array | None = None
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """On-the-fly twin of ``fl_gain_argmax_ref``: same outputs, but the
    similarity ``s_ij = (l_max - ||g_i - g_j||) * row_ok_i`` is produced in
    (block, n) row strips from grads (n, d) — the (n, n) matrix never
    materializes, which is the whole point of this code path (it doubles
    as the off-TPU dispatch target at pool sizes where a resident
    similarity would be GBs).  ``sqnorms`` (the squared row norms) lets
    callers that already hold them (the lazy engine hoists them once per
    selection) skip the per-call recomputation.  The 1024-row strip
    default is the measured CPU sweet spot for the full scan (~1.9x over
    256-row strips at pool 32768 — fewer passes over the candidate
    operand); the strip size only changes reduction order, which the
    lazy certification margin absorbs.
    """
    g = grads.astype(jnp.float32)
    sqn = (jnp.sum(g * g, axis=1) if sqnorms is None
           else jnp.asarray(sqnorms, jnp.float32))
    gains = fl_gains_cols_ref(g, sqn, g, sqn, cover, row_ok, l_max,
                              block=block)
    masked = jnp.where(mask, gains, -jnp.inf)
    idx = jnp.argmax(masked).astype(jnp.int32)
    return gains, idx, masked[idx]


def sqdist_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Pairwise squared euclidean distances  (n, d), (m, d) -> (n, m), f32.

    Computed the numerically-stable expanded way (same contraction order the
    kernel uses) so the oracle and the kernel agree to float tolerance.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    an = jnp.sum(a * a, axis=-1)
    bn = jnp.sum(b * b, axis=-1)
    d2 = an[:, None] + bn[None, :] - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def lastlayer_grad_ref(
    hidden: jax.Array,   # (n, d_h)
    logits: jax.Array,   # (n, v)
    labels: jax.Array,   # (n,) int32
) -> tuple[jax.Array, jax.Array]:
    """Fused last-layer CE gradient pieces.

    Returns
      resid : (n, v)  = softmax(logits) - onehot(labels)   (dL/db per sample)
      hgrad : (n, d_h) = resid @ nothing -- the *hidden-side* reduction the
              per-batch proxy needs is resid^T @ hidden aggregated per batch;
              here we return the per-sample row-scaled hidden
              own_resid * hidden (the paper's per-gradient approximation),
              own_resid = resid[i, labels[i]].
    """
    z = logits.astype(jnp.float32)
    z = z - jax.lax.stop_gradient(jnp.max(z, axis=-1, keepdims=True))
    p = jnp.exp(z) / jnp.sum(jnp.exp(z), axis=-1, keepdims=True)
    y = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    resid = p - y
    own = jnp.take_along_axis(resid, labels[:, None].astype(jnp.int32), axis=-1)
    hgrad = own * hidden.astype(jnp.float32)
    return resid, hgrad
