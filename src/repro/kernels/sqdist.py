"""Pallas TPU kernel: tiled pairwise squared distances for CRAIG.

CRAIG's facility-location greedy needs the full pairwise similarity
``s_ij = L_max - ||g_i - g_j||`` over the candidate ground set.  Materializing
the ``(n, n)`` matrix from an ``(n, d)`` gradient matrix is the memory hot
spot (the reason CRAIG "could not run on ImageNet" in the paper).

This kernel emits ``(128, 128)`` output tiles and accumulates the inner
product over d in 512-wide chunks, so HBM traffic is one pass over G per
output block-row and VMEM holds only three small tiles at a time.  The squared
norms enter on the *last* d-chunk so the accumulator is a single f32 tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128
TILE_N = 128
TILE_D = 512


def _sqdist_kernel(a_ref, b_ref, an_ref, bn_ref, out_ref, *, n_dchunks):
    k = pl.program_id(2)
    a = a_ref[...].astype(jnp.float32)           # (TILE_M, TILE_D)
    b = b_ref[...].astype(jnp.float32)           # (TILE_N, TILE_D)
    partial = a @ b.T                            # (TILE_M, TILE_N)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(k > 0)
    def _acc():
        out_ref[...] += partial

    # Final chunk: fold in the norms, flip sign:  d2 = an + bn - 2 ab.
    @pl.when(k == n_dchunks - 1)
    def _finish():
        an = an_ref[...].astype(jnp.float32)     # (TILE_M, 1)
        bn = bn_ref[...].astype(jnp.float32)     # (TILE_N, 1)
        d2 = an + bn.T - 2.0 * out_ref[...]
        out_ref[...] = jnp.maximum(d2, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sqdist(a: jax.Array, b: jax.Array, *, interpret: bool = False
           ) -> jax.Array:
    """Pairwise squared euclidean distance (n, d) x (m, d) -> (n, m) f32."""
    n, d = a.shape
    m, _ = b.shape
    n_pad = (-n) % TILE_M
    m_pad = (-m) % TILE_N
    d_pad = (-d) % TILE_D
    ap = jnp.pad(a, ((0, n_pad), (0, d_pad)))
    bp = jnp.pad(b, ((0, m_pad), (0, d_pad)))
    an = jnp.sum(ap.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    bn = jnp.sum(bp.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    np_, dp = ap.shape
    mp = bp.shape[0]
    n_dchunks = dp // TILE_D

    out = pl.pallas_call(
        functools.partial(_sqdist_kernel, n_dchunks=n_dchunks),
        grid=(np_ // TILE_M, mp // TILE_N, n_dchunks),
        in_specs=[
            pl.BlockSpec((TILE_M, TILE_D), lambda i, j, k: (i, k)),
            pl.BlockSpec((TILE_N, TILE_D), lambda i, j, k: (j, k)),
            pl.BlockSpec((TILE_M, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((TILE_N, 1), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp), jnp.float32),
        interpret=interpret,
    )(ap, bp, an, bn)
    return out[:n, :m]
