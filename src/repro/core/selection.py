"""Strategy dispatch + adaptive-selection schedule (paper Algorithm 1).

``select()`` maps a strategy name to its selector over a proxy matrix —
the one place the trainer, benchmarks and examples resolve
GRAD-MATCH / CRAIG / GLISTER / RANDOM, their PB variants, and the CRAIG
greedy tiers (``craig`` = dense oracle, ``craig-lazy`` = certified lazy
greedy with identical selections, ``craig-stochastic`` = seeded
stochastic greedy — see ``core/greedy.py`` / DESIGN.md §5).

``warm_start_epochs()`` implements the paper's warm-start budget split
(§4): run ``T_f = kappa * T * (k/n)`` epochs of full-data training, then
``T_s = kappa * T`` epochs of subset training — at kappa = 1/2 the total
compute equals the non-warm schedule's (the paper's "50% warm-start / 50%
data selection").

``SelectionSchedule`` answers "is epoch t a selection epoch?" (every R
epochs, and always at the first subset epoch).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.continual import buffer as continual_lib
from repro.core import craig as craig_lib
from repro.core import glister as glister_lib
from repro.core import gradmatch as gm_lib
from repro.core import partition as part_lib
from repro.core import proxies as proxy_lib
from repro.core import random_sel
from repro.core import streaming as stream_lib
from repro.core.gradmatch import SelectionResult

STRATEGIES = ("gradmatch", "gradmatch-stream", "gradmatch-partitioned",
              "gradmatch-pb", "gradmatch-continual", "craig", "craig-lazy",
              "craig-lazy-otf", "craig-stochastic", "craig-pb", "glister",
              "random", "full")

# CRAIG tiers: the dense oracle and the fast greedy modes of the shared
# engine (core/greedy.py).  "craig-lazy" selects index-identically to
# "craig"; "craig-lazy-otf" is the same certified lazy greedy with the
# similarity tiled from the gradients on the fly — index-identical again
# (FL gains are shift-invariant in l_max) at O(1) similarity memory;
# "craig-stochastic" is the seeded approximate tier.
_CRAIG_METHODS = {"craig": "dense", "craig-lazy": "lazy",
                  "craig-lazy-otf": "lazy",
                  "craig-stochastic": "stochastic"}
_CRAIG_ON_THE_FLY = frozenset({"craig-lazy-otf"})


def select(
    strategy: str,
    key: jax.Array,
    proxies: jax.Array,            # (n, d) per-example gradient proxies
    k: int,
    labels: Optional[jax.Array] = None,
    num_classes: int = 0,
    batch_size: int = 32,
    lam: float = 0.5,
    eps: float = 1e-10,
    val_target: Optional[jax.Array] = None,   # (d,) validation-gradient sum
    per_class: bool = True,
    omp_method: str = "incremental",   # OMP solver for gradmatch strategies
    chunk_size: int = 2048,            # gradmatch-stream: pool chunk rows
    stream_buffer: int = 256,          # gradmatch-stream: top-M buffer slots
    stream_cache_bytes: int = stream_lib.DEFAULT_CACHE_BYTES,
    partitions: Optional[int] = None,  # gradmatch-partitioned: P (None = auto)
    buffer_cap: Optional[int] = None,      # gradmatch-continual: buffer rows
    continual_batch: Optional[int] = None,  # gradmatch-continual: admit size
) -> SelectionResult:
    """Resolve one selection round.  ``val_target`` switches isValid=True.

    PB variants interpret ``k`` as an example budget and convert it to
    ``k // batch_size`` mini-batches; their result indexes *batches* — use
    ``gm_lib.expand_batch_selection`` to map back to examples.

    ``omp_method`` picks the OMP solver for the gradmatch strategies:
    ``"incremental"`` (cached-correlation production path) or ``"dense"``
    (the reference re-solve-from-scratch formulation, kept for parity tests
    and benchmark baselines).

    ``"gradmatch-stream"`` runs the certified-exact streaming block-OMP
    (``core/streaming.py``, DESIGN.md §7) over the proxies chunked by
    ``chunk_size`` — the same subset as ``"gradmatch"`` with pooled
    (non-per-class) OMP, at ``O(chunk + stream_buffer·d +
    stream_cache_bytes)`` peak pool memory (the compressed chunk cache
    is what lets the engine commit many rounds per loader pass;
    ``stream_cache_bytes`` must be positive here — running cacheless is
    only available on ``streaming.omp_select_streaming`` directly).  The
    returned result
    carries the engine's ``SelectStats``.  Callers with a truly
    out-of-core pool should use ``streaming.gradmatch_streaming``
    directly with a chunk factory (the trainer does).
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; known: {STRATEGIES}")
    # Strategy-specific knobs are rejected, not silently ignored, when the
    # strategy cannot honor them — a caller passing them is expressing an
    # expectation this dispatch would otherwise quietly drop.
    if partitions is not None:
        if strategy != "gradmatch-partitioned":
            raise ValueError(
                f"partitions={partitions} only applies to "
                f"'gradmatch-partitioned', not {strategy!r} — it would be "
                "silently ignored (drop it, or switch strategy)")
        if partitions < 1:
            raise ValueError(
                f"partitions must be >= 1, got {partitions}; omit it (or "
                "pass None) for automatic partition sizing")
    for name, val in (("buffer_cap", buffer_cap),
                      ("continual_batch", continual_batch)):
        if val is None:
            continue
        if strategy != "gradmatch-continual":
            raise ValueError(
                f"{name}={val} only applies to 'gradmatch-continual', not "
                f"{strategy!r} — it would be silently ignored")
        if val < 1:
            raise ValueError(f"{name} must be >= 1, got {val}")
    n = proxies.shape[0]
    if strategy == "full":
        w = jnp.full((n,), 1.0 / n, jnp.float32)
        return SelectionResult(jnp.arange(n, dtype=jnp.int32), w,
                               jnp.ones((n,), bool), jnp.float32(0.0))
    if strategy == "random":
        return random_sel.random_select(key, n, k)
    if strategy == "gradmatch":
        if per_class and labels is not None and num_classes > 1 and (
                val_target is None):
            return gm_lib.gradmatch_per_class(
                proxies, labels, num_classes, k, lam=lam, eps=eps,
                method=omp_method)
        return gm_lib.gradmatch(proxies, k, target=val_target, lam=lam,
                                eps=eps, method=omp_method)
    if strategy == "gradmatch-stream":
        if stream_cache_bytes <= 0:
            # The engine itself accepts cache_bytes=0 (certified, but
            # every commit re-pays a loader pass); through this in-memory
            # convenience path that trade is never what the caller wants —
            # it is always a typo or a unit slip (bytes, not MB/rows).
            raise ValueError(
                f"stream_cache_bytes must be > 0, got "
                f"{stream_cache_bytes}: the compressed chunk cache is "
                "what lets gradmatch-stream commit rounds without "
                "re-reading the pool.  Pass bytes (e.g. 1 << 24); to "
                "deliberately run cacheless use "
                "streaming.omp_select_streaming(cache_bytes=0) directly.")
        return stream_lib.gradmatch_streaming_array(
            proxies, k, target=val_target, lam=lam, eps=eps,
            chunk_size=chunk_size, buffer_size=stream_buffer,
            cache_bytes=stream_cache_bytes)
    if strategy == "gradmatch-partitioned":
        # Partition-and-merge sharded selection (core/partition.py,
        # DESIGN.md §9): per-class partitions when the per-class mode
        # applies (mirroring "gradmatch"), hashed partitions otherwise;
        # out-of-core pools go through
        # ``partition.gradmatch_partitioned_stream`` directly.
        use_labels = (per_class and labels is not None and num_classes > 1
                      and val_target is None)
        return part_lib.gradmatch_partitioned(
            proxies, k, partitions=0 if partitions is None else partitions,
            labels=labels if use_labels else None,
            num_classes=num_classes if use_labels else 0,
            target=val_target, lam=lam, eps=eps, method=omp_method)
    if strategy == "gradmatch-continual":
        # Bounded-buffer maintained selection (repro.continual, DESIGN.md
        # §11): the pool is streamed through a fixed-capacity buffer in
        # admission batches; always pooled (like gradmatch-stream).  With
        # the default buffer_cap=None the buffer covers the pool and the
        # result is the pooled gradmatch solution; a smaller cap bounds
        # memory and selects over the rows surviving eviction.
        return continual_lib.continual_select(
            proxies, k, target=val_target, capacity=buffer_cap,
            batch=continual_batch, lam=lam, eps=eps)
    if strategy == "gradmatch-pb":
        return gm_lib.gradmatch_pb(
            proxies, batch_size, max(k // batch_size, 1), lam=lam, eps=eps,
            target=val_target, method=omp_method)
    if strategy in _CRAIG_METHODS:
        return craig_lib.craig(proxies, k, method=_CRAIG_METHODS[strategy],
                               key=key,
                               on_the_fly=(True if strategy in
                                           _CRAIG_ON_THE_FLY else None))
    if strategy == "craig-pb":
        return craig_lib.craig_pb(proxies, batch_size,
                                  max(k // batch_size, 1))
    if strategy == "glister":
        tgt = val_target if val_target is not None else jnp.sum(proxies, 0)
        return glister_lib.glister(proxies, tgt, k)
    raise ValueError(f"unknown strategy {strategy!r}; known: {STRATEGIES}")


def expand_if_pb(strategy: str, sel: SelectionResult, batch_size: int,
                 n_examples: int) -> SelectionResult:
    if strategy.endswith("-pb"):
        return gm_lib.expand_batch_selection(sel, batch_size, n_examples)
    return sel


def warm_start_epochs(total_epochs: int, budget_frac: float,
                      kappa: float = 0.5) -> tuple[int, int]:
    """(T_f full-data epochs, T_s subset epochs) per the paper's split.

    The split only makes sense for a genuine subset run: ``budget_frac``
    is ``k/n`` and must sit in (0, 1) — at >= 1 the "warm start" would be
    longer than full training (use strategy="full" instead), and the old
    code silently produced that schedule.  ``kappa`` in (0, 1] scales the
    total compute; 0 would yield zero subset epochs.
    """
    if total_epochs <= 0:
        raise ValueError(f"total_epochs must be positive, got {total_epochs}")
    if not 0.0 < budget_frac < 1.0:
        raise ValueError(
            f"budget_frac must be in (0, 1), got {budget_frac}; a fraction "
            ">= 1 makes the warm start longer than full-data training — "
            "use strategy='full' for a full-data run")
    if not 0.0 < kappa <= 1.0:
        raise ValueError(f"kappa must be in (0, 1], got {kappa}")
    t_s = max(int(round(kappa * total_epochs)), 1)
    t_f = int(round(t_s * budget_frac))
    return t_f, t_s


@dataclass(frozen=True)
class SelectionSchedule:
    select_every: int = 20         # R
    warm_epochs: int = 0           # T_f
    # Optional: the run length this schedule is meant for.  When given,
    # a warm start covering the whole run (so *no* selection epoch ever
    # fires and the trainer silently trains full-data at subset LR) is
    # rejected here instead of surfacing as a mystery accuracy gap.
    total_epochs: Optional[int] = None

    def __post_init__(self):
        if self.select_every <= 0:
            raise ValueError(
                f"select_every (R) must be positive, got "
                f"{self.select_every}; R <= 0 never re-selects")
        if self.warm_epochs < 0:
            raise ValueError(
                f"warm_epochs must be >= 0, got {self.warm_epochs}")
        if (self.total_epochs is not None
                and self.warm_epochs >= self.total_epochs):
            raise ValueError(
                f"warm_epochs={self.warm_epochs} >= total_epochs="
                f"{self.total_epochs}: the warm start swallows the whole "
                "run and no selection epoch ever fires")

    def is_selection_epoch(self, epoch: int) -> bool:
        """Selection at the first post-warm epoch, then every R."""
        if epoch < self.warm_epochs:
            return False
        return (epoch - self.warm_epochs) % self.select_every == 0
