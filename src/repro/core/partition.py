"""Partition-and-merge sharded selection (DESIGN.md §9).

Million-row pools make a single global OMP the bottleneck twice over: the
per-round argmax scans all ``n`` rows, and the streaming engine's
certification traffic grows with the pool (the overhead ratio climbed
3.75x @ 8k → 8.59x @ 65k in ``BENCH_selection.json``).  CRAIG's
decomposition argument (arXiv:1906.01827) — and the paper's own per-class
mode — justify the classic fix: split the pool into ``P`` partitions,
solve each small problem with the existing certified engines, then run a
**certified merge round** over the union of partition picks.

The three layers here:

* ``make_plan`` / ``split_budget`` — partition the pool (per-class when
  labels exist, hashed otherwise; contiguous ranges for out-of-core
  streams) and split the budget exactly (remainder to the largest
  partitions, quotas capped at partition size, surplus rebalanced).
* per-partition solves — device-parallel via plain ``pmap``
  (``distributed.pmap_partition_omp``, the ``_pmap_scorer`` pattern; no
  shard_map on this jax) for resident pools, or chunk-wise via the PR-5/6
  streaming engine (``subrange_chunks`` views of one shared loader) for
  out-of-core partitions.  Each partition matches its own gradient-sum
  target; the targets sum to the global eq.-2 target, so the union of
  picks covers it.
* the **certified merge** — one incremental-Gram OMP re-solve
  (``omp_select``, index-exact vs the dense oracle) over the union of
  partition picks against the *global* target.  The merge reweights every
  pick globally, drops redundant cross-partition picks, and its ``err``
  is the true global objective of the returned solution.

Per-partition weights never survive to the result — only indices do —
which is what makes quota truncation exact: OMP round ``t`` depends only
on rounds ``< t`` (the greedy prefix property), so the first ``quota_p``
picks of a ``k_cap``-round solve equal a fresh ``quota_p``-round solve's.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import omp as omp_lib
from repro.core import streaming as stream_lib
from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.omp import split_budget

__all__ = [
    "PartitionPlan", "PartitionStats", "make_plan", "split_budget",
    "gradmatch_partitioned", "gradmatch_partitioned_stream",
]

# Knuth's multiplicative hash over the row id: deterministic, stateless,
# spreads contiguous id ranges uniformly over partitions.
_HASH_MULT = np.uint64(2654435761)
_HASH_MOD = np.uint64(1 << 32)


class PartitionPlan(NamedTuple):
    """How the pool splits: ``kind`` in {"class", "hash", "contiguous"}.

    ``assign`` maps each row to its partition (class/hash kinds);
    ``bounds`` is the ``(P+1,)`` row-offset fence (contiguous kind, the
    streaming path — no (n,) array needs materializing there).  ``sizes``
    counts *candidate* rows per partition (invalid rows excluded).
    """
    kind: str
    num_parts: int
    sizes: np.ndarray                       # (P,) candidate rows per part
    assign: Optional[np.ndarray] = None     # (n,) partition id per row
    bounds: Optional[np.ndarray] = None     # (P+1,) contiguous offsets


@dataclasses.dataclass
class PartitionStats:
    """Partition/merge accounting attached to ``SelectionResult.stats``."""
    num_parts: int
    kind: str
    quotas: tuple
    union_size: int          # partition picks entering the merge
    merged: int              # picks surviving the merge re-solve
    stream: Optional[stream_lib.SelectStats] = None  # out-of-core solves


def make_plan(n: int, partitions: int = 0, labels=None, num_classes: int = 0,
              kind: str = "auto", valid=None) -> PartitionPlan:
    """Build a partition plan over ``n`` rows.

    ``kind="auto"`` picks per-class when labels exist (the paper's
    decomposition — partition targets are then exactly the per-class
    targets), hashed otherwise.  ``partitions`` only applies to the
    non-class kinds (class partitioning is one partition per class);
    ``0`` means auto: ``max(local_device_count, 2)`` so the pmap path has
    work per device even on small hosts.
    """
    n = int(n)
    if kind == "auto":
        kind = "class" if (labels is not None and num_classes > 1) else "hash"
    valid_np = (np.ones(n, bool) if valid is None
                else np.asarray(valid, bool))
    if kind == "class":
        if labels is None or num_classes <= 0:
            raise ValueError("kind='class' needs labels and num_classes")
        assign = np.asarray(labels, np.int64)
        p = int(num_classes)
        ok = valid_np & (assign >= 0) & (assign < p)
        sizes = np.bincount(assign[ok], minlength=p)
        return PartitionPlan("class", p, sizes, assign=assign)
    p = int(partitions) if partitions > 0 else max(
        jax.local_device_count(), 2)
    p = max(1, min(p, n)) if n else 1
    if kind == "hash":
        ids = np.arange(n, dtype=np.uint64)
        assign = (((ids * _HASH_MULT) % _HASH_MOD) % np.uint64(p)).astype(
            np.int64)
        sizes = np.bincount(assign[valid_np], minlength=p)
        return PartitionPlan("hash", p, sizes, assign=assign)
    if kind == "contiguous":
        bounds = (np.arange(p + 1, dtype=np.int64) * n) // p
        sizes = np.array([int(valid_np[bounds[i]:bounds[i + 1]].sum())
                          for i in range(p)], np.int64)
        return PartitionPlan("contiguous", p, sizes, bounds=bounds)
    raise ValueError(f"unknown partition kind {kind!r}; "
                     "known: class, hash, contiguous, auto")


def _empty_result(k: int, err) -> SelectionResult:
    z = jnp.zeros((k,))
    return SelectionResult(jnp.full((k,), -1, jnp.int32),
                           z.astype(jnp.float32), z.astype(bool),
                           jnp.float32(err))


def _certified_merge(union_rows, union_gids, target, k: int, lam: float,
                     eps: float, nnls_iters: int):
    """The merge round: incremental-Gram OMP over the union of partition
    picks against the global target.  Returns padded ``(k,)`` arrays with
    *global* ids plus the true global ``err`` of the merged solution.

    The merge budget is ``min(k, |union|)`` — never more rounds than
    candidates, so every committed slot is a distinct union row (beyond
    exhaustion the solver would duplicate its argmax-of-nothing pick).
    """
    u = int(union_rows.shape[0])
    k_merge = min(int(k), u)
    m_idx, m_w, m_mask, m_err = omp_lib.omp_select(
        jnp.asarray(union_rows, jnp.float32),
        jnp.asarray(target, jnp.float32), k=k_merge, lam=lam, eps=eps,
        nnls_iters=nnls_iters, method="incremental")
    m_idx = np.asarray(m_idx)
    m_mask_np = np.asarray(m_mask)
    out_idx = np.full((k,), -1, np.int32)
    out_w = np.zeros((k,), np.float32)
    out_mask = np.zeros((k,), bool)
    out_idx[:k_merge][m_mask_np] = union_gids[m_idx[m_mask_np]]
    out_w[:k_merge] = np.where(m_mask_np, np.asarray(m_w), 0.0)
    out_mask[:k_merge] = m_mask_np
    return (jnp.asarray(out_idx), jnp.asarray(out_w), jnp.asarray(out_mask),
            m_err, int(m_mask_np.sum()))


def gradmatch_partitioned(
    proxies,                     # (n, d) candidate gradient proxies
    k: int,
    partitions: int = 0,
    labels=None,
    num_classes: int = 0,
    target=None,                 # (d,) global target; None = eq.-2 sum
    lam: float = 0.5,
    eps: float = 1e-10,
    valid=None,
    kind: str = "auto",
    method: str = "incremental",
    use_pmap: Optional[bool] = None,   # None = auto (>1 local device)
    nnls_iters: int = 50,
) -> SelectionResult:
    """Partition-and-merge GRAD-MATCH over a resident pool.

    Splits per ``make_plan``, solves every partition against its own
    target (per-class sums for the class kind — bit-identical to
    ``gradmatch_per_class``'s targets — else the partition's row sum, or
    a size-proportional slice of an explicit ``target``), truncates each
    partition to its exact ``split_budget`` quota, and re-solves the
    union in one certified merge round.  Device-parallel across
    partitions via ``distributed.pmap_partition_omp`` when more than one
    local device is present (``use_pmap=True`` forces the pmap path even
    on one device — same groups, sequential dispatch).
    """
    pool_np = np.asarray(proxies, np.float32)
    n, d = pool_np.shape
    valid_np = (np.ones(n, bool) if valid is None
                else np.asarray(valid, bool))
    plan = make_plan(n, partitions, labels=labels, num_classes=num_classes,
                     kind=kind, valid=valid_np)
    quotas = split_budget(k, plan.sizes)
    k_cap = int(quotas.max()) if quotas.size else 0
    stats = PartitionStats(plan.num_parts, plan.kind, tuple(quotas.tolist()),
                           0, 0)
    if k_cap == 0:
        err = float(np.sum(np.square(
            np.zeros(d) if target is None else np.asarray(target))))
        return SelectionResult(*_empty_result(k, err)[:4], stats)

    # Gather rows per partition, padded to the widest partition.
    p_count = plan.num_parts
    if plan.assign is not None:
        gid_lists = [np.flatnonzero(valid_np & (plan.assign == p))
                     for p in range(p_count)]
    else:
        gid_lists = [
            plan.bounds[p] + np.flatnonzero(
                valid_np[plan.bounds[p]:plan.bounds[p + 1]])
            for p in range(p_count)]
    n_max = max(1, max(len(g) for g in gid_lists))
    parts = np.zeros((p_count, n_max, d), np.float32)
    pvalid = np.zeros((p_count, n_max), bool)
    pgids = np.full((p_count, n_max), -1, np.int64)
    for p, gi in enumerate(gid_lists):
        parts[p, :len(gi)] = pool_np[gi]
        pvalid[p, :len(gi)] = True
        pgids[p, :len(gi)] = gi

    n_valid = int(valid_np.sum())
    if target is not None:
        g_target = jnp.asarray(target, jnp.float32)
        fracs = plan.sizes / max(n_valid, 1)
        targets_p = jnp.asarray(fracs, jnp.float32)[:, None] * g_target
    elif plan.kind == "class":
        # The exact per-class targets gradmatch_per_class matches against
        # (same one-hot contraction, so the class path is index-exact
        # against it — summing gathered rows instead would drift an ulp).
        g_j = jnp.asarray(pool_np * valid_np[:, None])
        onehot = jax.nn.one_hot(jnp.asarray(plan.assign), p_count,
                                dtype=g_j.dtype)
        targets_p = onehot.T @ g_j
        g_target = jnp.sum(targets_p, axis=0)
    else:
        targets_p = jnp.sum(jnp.asarray(parts)
                            * jnp.asarray(pvalid)[:, :, None], axis=1)
        g_target = jnp.sum(targets_p, axis=0)

    if use_pmap is None:
        use_pmap = jax.local_device_count() > 1
    if use_pmap:
        from repro.core import distributed as dist_lib
        idx, _, mask, _ = dist_lib.pmap_partition_omp(
            parts, targets_p, pvalid, k_cap, lam=lam, eps=eps,
            nnls_iters=nnls_iters, method=method)
    else:
        def one_part(g, t, v):
            p_idx, _, p_mask, _ = omp_lib.omp_select(
                g, t, k=k_cap, lam=lam, eps=eps, nnls_iters=nnls_iters,
                valid=v, method=method)
            return p_idx, p_mask

        idx, mask = jax.vmap(one_part)(jnp.asarray(parts), targets_p,
                                       jnp.asarray(pvalid))

    # Quota truncation (index-exact, see module docstring) + global ids.
    idx_np = np.asarray(idx)
    mask_np = np.asarray(mask) & (np.arange(k_cap)[None, :]
                                  < quotas[:, None])
    union_gids = np.concatenate(
        [pgids[p][idx_np[p][mask_np[p]]] for p in range(p_count)]
        or [np.zeros((0,), np.int64)])
    stats.union_size = int(union_gids.shape[0])
    if stats.union_size == 0:
        err = float(jnp.sum(g_target ** 2))
        return SelectionResult(*_empty_result(k, err)[:4], stats)

    out_idx, out_w, out_mask, err, merged = _certified_merge(
        pool_np[union_gids], union_gids, g_target, k, lam, eps, nnls_iters)
    stats.merged = merged
    return SelectionResult(out_idx, _normalize(out_w, out_mask), out_mask,
                           err, stats)


def _accumulate_stats(agg: stream_lib.SelectStats,
                      s: stream_lib.SelectStats) -> None:
    for f in dataclasses.fields(stream_lib.SelectStats):
        if f.name == "pool_size":
            continue
        setattr(agg, f.name, getattr(agg, f.name) + getattr(s, f.name))


def _gather_rows_by_scan(pool_iter: Callable, gids: np.ndarray,
                         d: int) -> np.ndarray:
    """One loader pass gathering exact rows by global id (factory-only
    pools without a ``row_fetch`` capability)."""
    rows = np.zeros((len(gids), d), np.float32)
    slot = {int(g): i for i, g in enumerate(gids)}
    order = np.sort(np.asarray(gids, np.int64))
    j, off = 0, 0
    for chunk, _ in pool_iter():
        c = chunk.shape[0]
        while j < len(order) and order[j] < off + c:
            g = int(order[j])
            rows[slot[g]] = np.asarray(chunk[g - off], np.float32)
            j += 1
        off += c
        if j >= len(order):
            break
    return rows


def gradmatch_partitioned_stream(
    pool=None,                   # (n, d) array/memmap; or None + pool_iter
    k: int = 0,
    partitions: int = 0,
    pool_iter: Optional[Callable] = None,  # (chunk, valid) factory
    n: Optional[int] = None,     # pool rows (counted in one pass if None)
    row_fetch: Optional[Callable] = None,
    target=None,
    lam: float = 0.5,
    eps: float = 1e-10,
    chunk_size: int = 4096,
    buffer_size: int = 256,
    cache_bytes: int = stream_lib.DEFAULT_CACHE_BYTES,  # per partition
    retry=None,
    nnls_iters: int = 50,
) -> SelectionResult:
    """Out-of-core partition-and-merge: contiguous row ranges, each solved
    by the PR-5/6 certified streaming engine over a ``subrange_chunks``
    view of one shared loader, then the certified merge.

    Why the overhead ratio goes *flat* in pool size: every certification/
    buffer cost the streaming engine pays scales with its pool — here
    each engine sees ``n/P`` rows and solves ``~k/P`` rounds, so growing
    ``n`` at fixed ``n/P`` keeps per-partition work at the small-pool
    regime where streaming is cheap.  ``cache_bytes`` is a *per-partition*
    budget; partitions run sequentially on one host, so peak cache
    residency is one partition's (each cache is dropped before the next
    partition solves).

    ``partitions=0`` sizes partitions to ~128k rows (capped at 16).  The
    per-partition quota assumes valid-dense pools (quotas come from raw
    range sizes; the engine still never *selects* an invalid row).
    """
    if pool is not None:
        n, d = int(pool.shape[0]), int(pool.shape[1])
        pool_iter = stream_lib.array_chunks(pool, chunk_size)
        if row_fetch is None:
            row_fetch = stream_lib.array_row_fetch(pool)
    else:
        if pool_iter is None:
            raise ValueError("need pool= or pool_iter=")
        first = next(iter(pool_iter()), None)
        if first is None:
            raise ValueError("empty pool iterator")
        d = int(first[0].shape[1])
        if n is None:
            n = sum(int(c.shape[0]) for c, _ in pool_iter())
    p_count = int(partitions) if partitions > 0 else min(
        16, max(2, -(-n // 131072)))
    p_count = max(1, min(p_count, n))
    bounds = (np.arange(p_count + 1, dtype=np.int64) * n) // p_count
    sizes = np.diff(bounds)
    quotas = split_budget(k, sizes)
    agg = stream_lib.SelectStats(pool_size=n)
    picks = []
    part_targets = []
    g_target = (None if target is None
                else jnp.asarray(target, jnp.float32))
    for p in range(p_count):
        if quotas[p] == 0:
            continue
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        sub = stream_lib.subrange_chunks(pool_iter, lo, hi)
        cache = stream_lib.ChunkCache(int(cache_bytes), d)
        sub_fetch = (None if row_fetch is None
                     else stream_lib.offset_row_fetch(row_fetch, lo))
        # One summing pass per partition: the partition target *and* the
        # cache warm-up (so the solve's certified rounds hit memory).
        t_p, _ = stream_lib.streaming_target(sub, cache=cache, retry=retry)
        if g_target is not None:
            t_p = g_target * ((hi - lo) / n)
        part_targets.append(t_p)
        out = stream_lib.omp_select_streaming(
            sub, t_p, int(quotas[p]), lam=lam, eps=eps,
            nnls_iters=nnls_iters, buffer_size=buffer_size, cache=cache,
            row_fetch=sub_fetch, retry=retry)
        _accumulate_stats(agg, out.stats)
        local = np.asarray(out.indices)[np.asarray(out.mask)]
        picks.append(lo + local.astype(np.int64))
    stats = PartitionStats(p_count, "contiguous", tuple(quotas.tolist()),
                           0, 0, stream=agg)
    if g_target is None:
        g_target = jnp.sum(jnp.stack(part_targets), axis=0) \
            if part_targets else jnp.zeros((d,), jnp.float32)
    union_gids = np.concatenate(picks or [np.zeros((0,), np.int64)])
    stats.union_size = int(union_gids.shape[0])
    if stats.union_size == 0:
        err = float(jnp.sum(g_target ** 2))
        return SelectionResult(*_empty_result(k, err)[:4], stats)
    if row_fetch is not None:
        union_rows = np.asarray(row_fetch(union_gids), np.float32)
    else:
        union_rows = _gather_rows_by_scan(pool_iter, union_gids, d)
        agg.passes += 1
    out_idx, out_w, out_mask, err, merged = _certified_merge(
        union_rows, union_gids, g_target, k, lam, eps, nnls_iters)
    stats.merged = merged
    return SelectionResult(out_idx, _normalize(out_w, out_mask), out_mask,
                           err, stats)
