"""CRAIG baseline (Mirzasoleiman et al. 2020a) — facility location greedy.

CRAIG minimizes the *upper bound* (paper eq. 4/5)::

    E_hat(X) = sum_i min_{j in X} || g_i - g_j ||

equivalently maximizes the facility-location function
``F_hat(X) = sum_i max_{j in X} (L_max - ||g_i - g_j||)`` with the classic
1-1/e greedy.  Weights are cluster sizes: w_j = #{ i : j = argmax sim(i, j) }.

The greedy itself lives in ``core/greedy.py`` (DESIGN.md §5) and runs in
three tiers selected by ``method``:

- ``"dense"``   — the naive full-rescan loop, kept as the parity oracle.
- ``"lazy"``    — certified lazy greedy: index-identical selections at a
  per-round cost of one top-``block`` bound refresh instead of an O(n²)
  scan, with the fused ``fl_gain_argmax`` kernel handling the occasional
  full rescan.
- ``"stochastic"`` — seeded stochastic greedy (per-round candidate
  subsampling), the approximate tier for pools where even lazy rounds are
  too expensive.

Beyond ``greedy._OTF_AUTO_BYTES`` (or with ``on_the_fly=True``) the lazy/
stochastic tiers tile the similarity on the fly from ``grads`` — the
``(n, n)`` matrix never materializes, which is what makes CRAIG feasible at
pool 32768/65536 where the resident similarity alone is 4–16 GB.

``l_max`` is the similarity offset ``s_ij = L_max - ||g_i - g_j||``; it
defaults to the max observed distance on the resident path and to the
``2·max‖g‖`` diameter bound on the fly.  Pass it explicitly whenever two
scans must agree on gain values (the parity tests do).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import greedy as greedy_lib
from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.greedy import pairwise_sim  # noqa: F401  (re-export)


def craig(
    grads: jax.Array,               # (n, d)
    k: int,
    sim: jax.Array | None = None,   # optional precomputed (n, n) similarity
    valid: jax.Array | None = None,
    dist_fn=None,
    method: str = "dense",          # "dense" | "lazy" | "stochastic"
    l_max: jax.Array | float | None = None,
    block: int = 64,                # lazy: top-B bound-refresh width
    sample: int = 64,               # stochastic: per-round sample size
    key: jax.Array | None = None,   # stochastic sampling seed
    on_the_fly: bool | None = None,
) -> SelectionResult:
    n = grads.shape[0]
    g = grads.astype(jnp.float32)
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    if sim is None and dist_fn is not None:
        sim = greedy_lib.build_sim(g, l_max=l_max, dist_fn=dist_fn)
    # Resolve the scan once, here: the weights/objective below must use
    # the exact (sim, L_max, otf) the selection ran under.
    sim, lm, otf = greedy_lib.resolve_fl_scan(g, sim, method, l_max=l_max,
                                              on_the_fly=on_the_fly)

    res = greedy_lib.fl_greedy(
        g, k, sim=sim, valid=valid, l_max=lm, method=method, block=block,
        sample=sample, key=key, on_the_fly=otf)

    # Weights: size of each medoid's cluster (paper: w_j = #assigned to j).
    # Medoid similarities are read as rows (k, n) — the similarity is
    # symmetric, and a row gather is contiguous where a column gather
    # strides the whole matrix.
    sel = jnp.where(res.mask, res.indices, 0)
    if otf:
        sqn = jnp.sum(g * g, axis=1)
        sim_sel = greedy_lib.fl_rows(
            g, sqn, valid.astype(jnp.float32), lm, sel)      # (k, n)
    else:
        sim_sel = sim[sel]                                   # (k, n)
    neg_inf = jnp.float32(-jnp.inf)
    sim_sel = jnp.where(res.mask[:, None], sim_sel, neg_inf)
    assign = jnp.argmax(sim_sel, axis=0)                     # (n,) slot ids
    w = jnp.sum(
        jax.nn.one_hot(assign, int(k), dtype=jnp.float32)
        * valid[:, None].astype(jnp.float32),
        axis=0,
    )
    w = jnp.where(res.mask, w, 0.0)
    # Remaining coverage deficit sum_i (L_max - cover_i), valid rows only —
    # rows zeroed out of the similarity demand no coverage.
    err = jnp.sum(jnp.where(valid, lm - res.cover, 0.0))
    return SelectionResult(res.indices, _normalize(w, res.mask), res.mask,
                           jnp.float32(err))


def craig_pb(example_proxies: jax.Array, batch_size: int, k_batches: int,
             dist_fn=None, method: str = "dense",
             key: jax.Array | None = None) -> SelectionResult:
    """CRAIGPB: facility location over mini-batch mean gradients."""
    from repro.core import proxies as proxy_lib

    pb = proxy_lib.per_batch(example_proxies, batch_size)
    return craig(pb, k=k_batches, dist_fn=dist_fn, method=method, key=key)
