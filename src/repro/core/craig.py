"""CRAIG baseline (Mirzasoleiman et al. 2020a) — facility location greedy.

CRAIG minimizes the *upper bound* (paper eq. 4/5)::

    E_hat(X) = sum_i min_{j in X} || g_i - g_j ||

equivalently maximizes the facility-location function
``F_hat(X) = sum_i max_{j in X} (L_max - ||g_i - g_j||)`` with the classic
1-1/e greedy.  Weights are cluster sizes: w_j = #{ i : j = argmax sim(i, j) }.

TPU adaptation: the greedy is a fixed-k ``lax.fori_loop`` over a tiled
similarity matrix.  The (n, n) pairwise distances come from the Pallas
``sqdist`` kernel via kernels/ops.py when n is large; this module accepts a
precomputed similarity or builds one densely for small n.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.gradmatch import SelectionResult, _normalize


def pairwise_sim(grads: jax.Array, dist_fn=None) -> jax.Array:
    """Similarity  s_ij = L_max - ||g_i - g_j||  (n, n), L_max = max dist."""
    if dist_fn is not None:
        d2 = dist_fn(grads, grads)
    else:
        sq = jnp.sum(grads**2, axis=-1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * (grads @ grads.T)
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    return jnp.max(dist) - dist


def craig(
    grads: jax.Array,               # (n, d)
    k: int,
    sim: jax.Array | None = None,   # optional precomputed (n, n) similarity
    valid: jax.Array | None = None,
    dist_fn=None,
) -> SelectionResult:
    n = grads.shape[0]
    if sim is None:
        sim = pairwise_sim(grads.astype(jnp.float32), dist_fn=dist_fn)
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    # Invalid candidates can neither be selected nor demand coverage.
    vrow = valid[:, None].astype(sim.dtype)
    sim = sim * vrow  # rows of invalid i contribute 0 to coverage

    neg_inf = jnp.float32(-jnp.inf)

    def body(t, carry):
        indices, mask, cover = carry           # cover: (n,) current max sim
        # marginal gain of adding j:  sum_i max(cover_i, s_ij) - sum_i cover_i
        gains = jnp.sum(jnp.maximum(cover[:, None], sim), axis=0) - jnp.sum(
            cover
        )
        # Unused slots point at the out-of-bounds sentinel n so mode="drop"
        # discards them (an in-bounds sentinel races duplicate writes when
        # candidate n-1 is genuinely selected — see omp.py).
        taken = jnp.zeros((n,), dtype=bool).at[
            jnp.where(mask, indices, n)
        ].set(mask, mode="drop")
        gains = jnp.where(valid & ~taken, gains, neg_inf)
        e = jnp.argmax(gains).astype(jnp.int32)
        indices = indices.at[t].set(e)
        mask = mask.at[t].set(True)
        cover = jnp.maximum(cover, sim[:, e])
        return indices, mask, cover

    indices0 = jnp.full((k,), -1, dtype=jnp.int32)
    mask0 = jnp.zeros((k,), dtype=bool)
    cover0 = jnp.zeros((n,), dtype=jnp.float32)
    indices, mask, cover = lax.fori_loop(0, k, body, (indices0, mask0, cover0))

    # Weights: size of each medoid's cluster (paper: w_j = #assigned to j).
    sel = jnp.where(mask, indices, 0)
    sim_sel = sim[:, sel]                                    # (n, k)
    sim_sel = jnp.where(mask[None, :], sim_sel, neg_inf)
    assign = jnp.argmax(sim_sel, axis=1)                     # (n,) slot ids
    w = jnp.sum(
        jax.nn.one_hot(assign, k, dtype=jnp.float32)
        * valid[:, None].astype(jnp.float32),
        axis=0,
    )
    w = jnp.where(mask, w, 0.0)
    return SelectionResult(indices, _normalize(w, mask), mask,
                           jnp.float32(jnp.sum(jnp.max(sim) - cover)))


def craig_pb(example_proxies: jax.Array, batch_size: int, k_batches: int,
             dist_fn=None) -> SelectionResult:
    """CRAIGPB: facility location over mini-batch mean gradients."""
    from repro.core import proxies as proxy_lib

    pb = proxy_lib.per_batch(example_proxies, batch_size)
    return craig(pb, k=k_batches, dist_fn=dist_fn)
