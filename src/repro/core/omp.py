"""Orthogonal Matching Pursuit (Algorithm 2 of the paper), TPU-native.

The paper minimizes, over subsets ``X`` (|X| <= k) and non-negative weights
``w``::

    Err_lambda(w, X) = || sum_{i in X} w_i g_i  -  g_tgt ||^2 + lambda ||w||^2

where ``g_i`` are candidate gradients (rows of ``G``, shape (n, d)) and
``g_tgt`` is the full training-set or validation-set gradient.  OMP greedily
adds the candidate with the largest |residual correlation| and re-solves the
(regularized, non-negative) least squares on the active set.

Hardware adaptation (see DESIGN.md S3): the reference implementation in CORDS
uses dynamic Python lists + scipy NNLS on CPU.  Here the whole solver is a
fixed-iteration ``lax.fori_loop`` with a *padded* active set of static size k,
so it jits, vmaps (per-class decomposition = leading batch axis) and runs
sharded on a pod without host round-trips.

Weights are solved by projected-gradient non-negative ridge regression on the
active set -- a small (k x k) problem solved in VMEM-resident registers.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class OMPState(NamedTuple):
    """Carry for the OMP loop (all static shapes)."""

    indices: jax.Array   # (k,) int32, selected candidate ids, -1 = unused slot
    mask: jax.Array      # (k,) bool, slot valid
    weights: jax.Array   # (k,) f32, non-negative weights for active slots
    residual: jax.Array  # (d,) f32, g_tgt - G_S^T w
    err: jax.Array       # () f32, current ||residual||^2 + lam*||w||^2


def _nnls_active(
    gram: jax.Array,      # (k, k) = G_S G_S^T  (masked rows/cols zeroed)
    corr: jax.Array,      # (k,)   = G_S g_tgt
    mask: jax.Array,      # (k,) bool
    lam: float,
    n_iters: int,
) -> jax.Array:
    """Non-negative ridge LS on the (masked) active set via projected gradient.

    Solves  min_{w>=0} 0.5 w^T (A + lam I) w - c^T w  restricted to mask.
    Lipschitz step 1/L with L = trace upper bound; fixed iterations keep the
    whole thing jittable.  k is small (<= few hundred) so this is negligible
    next to the correlation scan over n candidates.
    """
    k = gram.shape[0]
    a = gram + lam * jnp.eye(k, dtype=gram.dtype)
    # Zero out inactive rows/cols so they stay at w=0.
    m = mask.astype(gram.dtype)
    a = a * m[:, None] * m[None, :]
    c = corr * m
    # Lipschitz bound: row-sum (Gershgorin) of |A|, floored for stability.
    lip = jnp.maximum(jnp.max(jnp.sum(jnp.abs(a), axis=1)), 1e-6)
    step = 1.0 / lip

    def body(_, w):
        grad = a @ w - c
        w = jnp.maximum(w - step * grad, 0.0)
        return w * m

    w0 = jnp.zeros((k,), dtype=gram.dtype)
    return lax.fori_loop(0, n_iters, body, w0)


@functools.partial(
    jax.jit, static_argnames=("k", "nnls_iters", "positive", "corr_fn")
)
def omp_select(
    grads: jax.Array,          # (n, d) candidate gradients (rows)
    target: jax.Array,         # (d,)   target gradient (full train or val)
    k: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nnls_iters: int = 50,
    positive: bool = True,
    valid: jax.Array | None = None,   # (n,) bool — candidate availability
    corr_fn=None,              # optional kernel: (G, r) -> (n,) scores
):
    """Run OMP for exactly ``k`` rounds (slots beyond the eps-stop get masked).

    Returns (indices (k,), weights (k,), mask (k,), err ()).  Indices of
    unused slots are -1 and their weights 0, so downstream consumers can use
    the padded arrays directly (static shapes for jit).
    """
    n, d = grads.shape
    grads = grads.astype(jnp.float32)
    target = target.astype(jnp.float32)
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)

    neg_inf = jnp.float32(-jnp.inf)

    def correlate(residual):
        if corr_fn is not None:
            return corr_fn(grads, residual)
        return grads @ residual

    def body(t, state: OMPState):
        # 1) residual correlations;  already-selected / invalid candidates out.
        scores = correlate(state.residual)
        if positive:
            scores_sel = scores          # match direction of the target
        else:
            scores_sel = jnp.abs(scores)
        taken = jnp.zeros((n,), dtype=bool).at[
            jnp.where(state.mask, state.indices, n - 1)
        ].set(state.mask, mode="drop")
        scores_sel = jnp.where(valid & ~taken, scores_sel, neg_inf)
        e = jnp.argmax(scores_sel).astype(jnp.int32)

        # stop criterion E_lambda <= eps  -> do not grow the active set.
        grow = state.err > eps
        new_indices = state.indices.at[t].set(jnp.where(grow, e, -1))
        new_mask = state.mask.at[t].set(grow)

        # 2) re-solve non-negative ridge LS on the active set.
        sel = jnp.where(new_mask, new_indices, 0)
        g_s = grads[sel] * new_mask[:, None].astype(grads.dtype)  # (k, d)
        gram = g_s @ g_s.T
        corr = g_s @ target
        w = _nnls_active(gram, corr, new_mask, lam, nnls_iters)

        # 3) residual + error refresh.
        approx = w @ g_s
        residual = target - approx
        err = jnp.sum(residual**2) + lam * jnp.sum(w**2)
        return OMPState(new_indices, new_mask, w, residual, err)

    init = OMPState(
        indices=jnp.full((k,), -1, dtype=jnp.int32),
        mask=jnp.zeros((k,), dtype=bool),
        weights=jnp.zeros((k,), dtype=jnp.float32),
        residual=target,
        err=jnp.sum(target**2) + jnp.float32(0.0),
    )
    out = lax.fori_loop(0, k, body, init)
    return out.indices, out.weights, out.mask, out.err


def omp_select_per_class(
    grads: jax.Array,        # (n, d)
    labels: jax.Array,       # (n,) int class ids
    targets: jax.Array,      # (num_classes, d) per-class target gradients
    num_classes: int,
    k_per_class: int,
    lam: float = 0.5,
    eps: float = 1e-10,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Paper's per-class decomposition, batched over classes with vmap.

    Each class-c problem only sees candidates with label c (others masked
    invalid).  Returns flattened (num_classes*k, ...) padded arrays.
    """

    def one_class(c, target):
        valid = labels == c
        idx, w, mask, _ = omp_select(
            grads, target, k=k_per_class, lam=lam, eps=eps, valid=valid
        )
        return idx, w, mask

    idx, w, mask = jax.vmap(one_class)(jnp.arange(num_classes), targets)
    return idx.reshape(-1), w.reshape(-1), mask.reshape(-1)


def matching_error(
    grads: jax.Array, target: jax.Array, indices: jax.Array,
    weights: jax.Array, mask: jax.Array, lam: float = 0.0,
) -> jax.Array:
    """Err_lambda for a given (X, w) — used by tests & benchmarks."""
    sel = jnp.where(mask, indices, 0)
    g_s = grads[sel] * mask[:, None].astype(grads.dtype)
    resid = target - weights @ g_s
    return jnp.sqrt(jnp.sum(resid**2)) + lam * jnp.sum(weights**2)
