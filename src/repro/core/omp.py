"""Orthogonal Matching Pursuit (Algorithm 2 of the paper), TPU-native.

The paper minimizes, over subsets ``X`` (|X| <= k) and non-negative weights
``w``::

    Err_lambda(w, X) = || sum_{i in X} w_i g_i  -  g_tgt ||^2 + lambda ||w||^2

where ``g_i`` are candidate gradients (rows of ``G``, shape (n, d)) and
``g_tgt`` is the full training-set or validation-set gradient.  OMP greedily
adds the candidate with the largest |residual correlation| and re-solves the
(regularized, non-negative) least squares on the active set.

Two solvers live here (see DESIGN.md §2):

``omp_select`` (default ``method="incremental"``)
    The production path.  Cross-round state is cached so nothing is ever
    recomputed from scratch:

    * ``c0 = G @ g_tgt`` is computed once; a column cache ``C[:, t] = G @
      g_{e_t}`` is extended by one column per round, so the per-round scores
      are ``c0 - C @ w`` — the candidate matrix is touched once per round
      for the single new column instead of a full residual matvec plus a
      ``(k, d)`` active-set gather.
    * the active-set Gram ``A = G_S G_S^T`` and target correlation ``c_S =
      G_S g_tgt`` grow by one row/col per round (the new Gram row is a free
      read out of the column cache: ``A[t, j] = C[e_t, j]``), and the NNLS
      consumes these cached buffers — the ``(k, d)`` active matrix is never
      re-materialized.
    * the residual norm is tracked through the identity ``||r||^2 =
      ||g_tgt||^2 - 2 w^T c_S + w^T A w``; it is evaluated in the factored
      form ``||g_tgt - w^T R||^2`` over the cached active rows ``R`` (the
      same value, but immune to the f32 cancellation that the expanded form
      suffers when the residual is ~eps, which would defeat the early stop).
    * rounds are processed in blocks with statically-growing prefix buffers,
      so round ``t`` pays O(t)-sized matvecs rather than O(k)-sized ones.

    Per-round cost: O(n·t) scores + O(n·d) new column + O(t·min(t, d)) per
    NNLS iteration, versus the dense solver's O(t^2·d) Gram rebuild.

``omp_select_dense`` (= ``method="dense"``)
    The straightforward re-solve-from-scratch formulation (what CORDS does
    with dynamic Python lists + scipy NNLS on CPU, here as a fixed-iteration
    ``lax.fori_loop`` over a *padded* active set).  Kept as the reference
    implementation: parity tests assert the incremental path reproduces its
    selections to f32 tolerance, and benchmarks report the speedup.

Both jit, vmap (per-class decomposition = leading batch axis) and run
sharded on a pod without host round-trips.  Weights are solved by
projected-gradient non-negative ridge regression on the active set — a
small problem solved in VMEM-resident registers.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels import ops


class OMPState(NamedTuple):
    """Carry for the dense OMP loop (all static shapes)."""

    indices: jax.Array   # (k,) int32, selected candidate ids, -1 = unused slot
    mask: jax.Array      # (k,) bool, slot valid
    weights: jax.Array   # (k,) f32, non-negative weights for active slots
    residual: jax.Array  # (d,) f32, g_tgt - G_S^T w
    err: jax.Array       # () f32, current ||residual||^2 + lam*||w||^2


class OMPIncState(NamedTuple):
    """Carry for the incremental OMP loop.

    ``indices``/``mask`` are full ``(k,)``; everything else is a prefix
    buffer of the current block width P (grown between blocks, see
    ``omp_select``), so early rounds pay O(t)-sized work.
    """

    indices: jax.Array   # (k,) int32
    mask: jax.Array      # (k,) bool
    weights: jax.Array   # (P,) f32
    colcache: jax.Array  # (n, P) f32, C[:, t] = G @ g_{e_t} (wide regime)
    gram: jax.Array      # (P, P) f32, active-set Gram (inactive rows/cols 0)
    gram_absrow: jax.Array  # (P,) f32, sum_j |A_ij| over active j (cached
                            # Gershgorin row sums for the NNLS step size)
    tcorr: jax.Array     # (P,) f32, c_S[t] = g_{e_t} . g_tgt
    rows: jax.Array      # (P, d) f32, cached active rows (zero when unused)
    residual: jax.Array  # (d,) f32, g_tgt - w^T rows
    err: jax.Array       # () f32


def _nnls_active(
    gram: jax.Array,      # (k, k) = G_S G_S^T  (masked rows/cols zeroed)
    corr: jax.Array,      # (k,)   = G_S g_tgt
    mask: jax.Array,      # (k,) bool
    lam: float,
    n_iters: int,
) -> jax.Array:
    """Non-negative ridge LS on the (masked) active set via projected gradient.

    Solves  min_{w>=0} 0.5 w^T (A + lam I) w - c^T w  restricted to mask.
    Lipschitz step 1/L with L = trace upper bound; fixed iterations keep the
    whole thing jittable.  k is small (<= few hundred) so this is negligible
    next to the correlation scan over n candidates.
    """
    k = gram.shape[0]
    a = gram + lam * jnp.eye(k, dtype=gram.dtype)
    # Zero out inactive rows/cols so they stay at w=0.
    m = mask.astype(gram.dtype)
    a = a * m[:, None] * m[None, :]
    c = corr * m
    # Lipschitz bound: row-sum (Gershgorin) of |A|, floored for stability.
    lip = jnp.maximum(jnp.max(jnp.sum(jnp.abs(a), axis=1)), 1e-6)
    step = 1.0 / lip

    def body(_, w):
        grad = a @ w - c
        w = jnp.maximum(w - step * grad, 0.0)
        return w * m

    w0 = jnp.zeros((k,), dtype=gram.dtype)
    return lax.fori_loop(0, n_iters, body, w0)


def _nnls_active_cached(
    gram: jax.Array,         # (k, k) cached Gram, inactive rows/cols zero
    gram_absrow: jax.Array,  # (k,) cached sum_j |A_ij| over active j
    rows: jax.Array,         # (k, d) cached active rows, inactive rows zero
    corr: jax.Array,         # (k,) cached c_S, inactive entries zero
    mask: jax.Array,         # (k,) bool
    lam: float,
    n_iters: int,
) -> jax.Array:
    """Same math as ``_nnls_active``, consuming the incremental caches.

    The masked system matrix is never materialized: the step size comes from
    the cached Gershgorin row sums (O(1) per round instead of O(k^2) per
    call), and the matvec ``A @ w`` uses whichever factor is cheaper —
    ``R (R^T w)`` at O(k·d) when d < k, or the cached ``(k, k)`` Gram at
    O(k^2) when the proxy dimension dominates.
    """
    m = mask.astype(rows.dtype)
    c = corr * m
    lip = jnp.maximum(jnp.max(m * (gram_absrow + lam)), 1e-6)
    step = 1.0 / lip
    k, d = rows.shape
    use_factor = d < k  # static shapes -> trace-time choice

    def body(_, w):
        if use_factor:
            aw = rows @ (w @ rows) + lam * w
        else:
            aw = gram @ w + lam * w
        w = jnp.maximum(w - step * (aw - c), 0.0)
        return w * m

    w0 = jnp.zeros((k,), dtype=rows.dtype)
    return lax.fori_loop(0, n_iters, body, w0)


def _omp_select_dense(grads, target, k, lam, eps, nnls_iters, positive,
                      valid, corr_fn):
    """Reference solver: re-gather + re-solve the active set every round."""
    n, d = grads.shape
    neg_inf = jnp.float32(-jnp.inf)

    def correlate(residual):
        if corr_fn is not None:
            return corr_fn(grads, residual)
        return grads @ residual

    def body(t, state: OMPState):
        # 1) residual correlations;  already-selected / invalid candidates out.
        scores = correlate(state.residual)
        if positive:
            scores_sel = scores          # match direction of the target
        else:
            scores_sel = jnp.abs(scores)
        # Unused slots point at the out-of-bounds sentinel n so mode="drop"
        # discards them (an in-bounds sentinel would race duplicate writes).
        taken = jnp.zeros((n,), dtype=bool).at[
            jnp.where(state.mask, state.indices, n)
        ].set(state.mask, mode="drop")
        scores_sel = jnp.where(valid & ~taken, scores_sel, neg_inf)
        e = jnp.argmax(scores_sel).astype(jnp.int32)

        # stop criterion E_lambda <= eps  -> do not grow the active set.
        grow = state.err > eps
        new_indices = state.indices.at[t].set(jnp.where(grow, e, -1))
        new_mask = state.mask.at[t].set(grow)

        # 2) re-solve non-negative ridge LS on the active set.
        sel = jnp.where(new_mask, new_indices, 0)
        g_s = grads[sel] * new_mask[:, None].astype(grads.dtype)  # (k, d)
        gram = g_s @ g_s.T
        corr = g_s @ target
        w = _nnls_active(gram, corr, new_mask, lam, nnls_iters)

        # 3) residual + error refresh.
        approx = w @ g_s
        residual = target - approx
        err = jnp.sum(residual**2) + lam * jnp.sum(w**2)
        return OMPState(new_indices, new_mask, w, residual, err)

    init = OMPState(
        indices=jnp.full((k,), -1, dtype=jnp.int32),
        mask=jnp.zeros((k,), dtype=bool),
        weights=jnp.zeros((k,), dtype=jnp.float32),
        residual=target,
        err=jnp.sum(target**2) + jnp.float32(0.0),
    )
    out = lax.fori_loop(0, k, body, init)
    return out.indices, out.weights, out.mask, out.err


def _grow_prefix(st: OMPIncState, width: int, keep_cols: bool) -> OMPIncState:
    """Zero-pad the prefix buffers out to ``width`` slots (static).

    ``keep_cols=False`` (narrow-proxy regime, see below) stops growing the
    column cache — it is dead state from that block on.
    """
    pad = width - st.weights.shape[0]
    return OMPIncState(
        indices=st.indices,
        mask=st.mask,
        weights=jnp.pad(st.weights, (0, pad)),
        colcache=(jnp.pad(st.colcache, ((0, 0), (0, pad))) if keep_cols
                  else st.colcache),
        gram=jnp.pad(st.gram, ((0, pad), (0, pad))),
        gram_absrow=jnp.pad(st.gram_absrow, (0, pad)),
        tcorr=jnp.pad(st.tcorr, (0, pad)),
        rows=jnp.pad(st.rows, ((0, pad), (0, 0))),
        residual=st.residual,
        err=st.err,
    )


def _inc_body_factory(grads, target, c0, valid, lam, eps, nnls_iters,
                      absolute):
    """Round-body factory shared by every incremental-Gram consumer.

    ``_omp_select_incremental`` (one-shot), the anytime session engine
    (``omp_session_start`` / ``omp_session_extend``) and their tests all
    run the body this returns — one copy of the cached-correlation round
    update, so a session resume is bit-identical to the one-shot rounds it
    skips.
    """
    n = grads.shape[0]
    zeros_n = jnp.zeros((n,), dtype=jnp.float32)

    def make_body(use_cols: bool):
        def body(t, st: OMPIncState):
            p = st.weights.shape[0]     # static prefix width, t < p <= k
            # 1) fused scores-and-argmax (one streaming pass, no (n,)
            #    score vector materialized on TPU).
            # Out-of-bounds sentinel for unused slots, dropped by the
            # scatter — see the dense body for why n-1 would be wrong.
            taken = jnp.zeros((n,), dtype=bool).at[
                jnp.where(st.mask, st.indices, n)
            ].set(st.mask, mode="drop")
            avail = valid & ~taken
            if use_cols:
                e, _ = ops.corr_argmax(st.colcache, st.weights, c0, avail,
                                       absolute=absolute)
            else:
                e, _ = ops.corr_argmax(grads, -st.residual, zeros_n, avail,
                                       absolute=absolute)

            # stop criterion E_lambda <= eps -> do not grow the active set.
            grow = st.err > eps
            growf = grow.astype(jnp.float32)
            indices = st.indices.at[t].set(jnp.where(grow, e, -1))
            mask = st.mask.at[t].set(grow)
            mask_p = mask[:p]

            # 2) extend the caches by one slot (updates are gated on `grow`
            #    so a stopped solver leaves every buffer unchanged).
            g_e = grads[e] * growf
            rows = st.rows.at[t].set(g_e)
            if use_cols:
                # Single touch of G this round; the new Gram row is a free
                # read out of the cache: A[t, j] = g_{e_t}.g_{e_j} = C[e, j].
                colcache = st.colcache.at[:, t].set(ops.corr(grads, g_e))
                row_vals = jnp.where(mask_p, colcache[e], 0.0) * growf
            else:
                colcache = st.colcache
                row_vals = jnp.where(mask_p, rows @ g_e, 0.0)
            gram = st.gram.at[t, :].set(row_vals).at[:, t].set(row_vals)
            # Gershgorin row sums pick up the new row/col in O(p).
            absrow = jnp.where(mask_p, st.gram_absrow + jnp.abs(row_vals),
                               0.0)
            absrow = absrow.at[t].set(jnp.sum(jnp.abs(row_vals)))
            tcorr = st.tcorr.at[t].set(c0[e] * growf)

            # 3) NNLS on the cached active-set buffers.
            w = _nnls_active_cached(gram, absrow, rows, tcorr, mask_p, lam,
                                    nnls_iters)
            # ||r||^2 = ||g_tgt||^2 - 2 w^T c_S + w^T A w, evaluated in the
            # factored form over cached rows (immune to the cancellation
            # the expanded form suffers near the eps-stop).
            resid = target - w @ rows
            err = jnp.sum(resid**2) + lam * jnp.sum(w**2)
            return OMPIncState(indices, mask, w, colcache, gram, absrow,
                               tcorr, rows, resid, err)
        return body

    return make_body


def _empty_inc_state(k: int, n: int, d: int,
                     target: jax.Array) -> OMPIncState:
    return OMPIncState(
        indices=jnp.full((k,), -1, dtype=jnp.int32),
        mask=jnp.zeros((k,), dtype=bool),
        weights=jnp.zeros((0,), dtype=jnp.float32),
        colcache=jnp.zeros((n, 0), dtype=jnp.float32),
        gram=jnp.zeros((0, 0), dtype=jnp.float32),
        gram_absrow=jnp.zeros((0,), dtype=jnp.float32),
        tcorr=jnp.zeros((0,), dtype=jnp.float32),
        rows=jnp.zeros((0, d), dtype=jnp.float32),
        residual=target,
        err=jnp.sum(target**2) + jnp.float32(0.0),
    )


def _omp_select_incremental(grads, target, k, lam, eps, nnls_iters, positive,
                            valid, block):
    """Incremental-Gram OMP: cached correlations, no per-round rebuilds.

    Two statically-chosen regimes per block of rounds, both O(t)-incremental
    (the ``(k, d)`` active matrix is never re-gathered and the Gram never
    rebuilt), differing only in which cached factor scores candidates:

    * wide-proxy (P <= d): scores = c0 - C @ w over the ``(n, P)`` column
      cache; the new Gram row is a free read ``C[e, :]``.  O(n·P) < O(n·d)
      per round.
    * narrow-proxy (d < P): scores = G @ r with the residual maintained
      from the cached active rows (r = g_tgt - w^T R, O(P·d)); the new
      Gram row is ``R @ g_e``.  O(n·d) < O(n·P) per round.

    Both feed the same fused ``corr_argmax`` kernel (scores never hit HBM
    on TPU): the wide call is (C, w, c0), the narrow call is (G, -r, 0).
    """
    n, d = grads.shape
    c0 = ops.corr(grads, target)        # (n,), computed exactly once
    make_body = _inc_body_factory(grads, target, c0, valid, lam, eps,
                                  nnls_iters, absolute=not positive)
    st = _empty_inc_state(k, n, d, target)
    for lo in range(0, k, block):
        hi = min(lo + block, k)
        use_cols = hi <= d
        st = _grow_prefix(st, hi, keep_cols=use_cols)
        st = lax.fori_loop(lo, hi, make_body(use_cols), st)
    return st.indices, st.weights, st.mask, st.err


@functools.partial(
    jax.jit,
    static_argnames=("k", "nnls_iters", "positive", "corr_fn", "method",
                     "block"),
)
def omp_select(
    grads: jax.Array,          # (n, d) candidate gradients (rows)
    target: jax.Array,         # (d,)   target gradient (full train or val)
    k: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nnls_iters: int = 50,
    positive: bool = True,
    valid: jax.Array | None = None,   # (n,) bool — candidate availability
    corr_fn=None,              # optional kernel: (G, r) -> (n,) scores
    method: str = "incremental",      # "incremental" | "dense"
    block: int = 128,          # rounds per statically-sized prefix block
):
    """Run OMP for exactly ``k`` rounds (slots beyond the eps-stop get masked).

    Returns (indices (k,), weights (k,), mask (k,), err ()).  Indices of
    unused slots are -1 and their weights 0, so downstream consumers can use
    the padded arrays directly (static shapes for jit).

    ``method="incremental"`` (default) runs the cached-correlation solver;
    ``method="dense"`` runs the reference re-solve-from-scratch formulation.
    A custom ``corr_fn`` scores against an explicit residual vector, which
    only the dense formulation materializes, so it implies ``method="dense"``.
    """
    if method not in ("incremental", "dense"):
        raise ValueError(f"unknown OMP method {method!r}")
    n, d = grads.shape
    grads = grads.astype(jnp.float32)
    target = target.astype(jnp.float32)
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    if method == "dense" or corr_fn is not None:
        return _omp_select_dense(grads, target, k, lam, eps, nnls_iters,
                                 positive, valid, corr_fn)
    return _omp_select_incremental(grads, target, k, lam, eps, nnls_iters,
                                   positive, valid, block)


def omp_select_dense(grads, target, k, lam=0.5, eps=1e-10, nnls_iters=50,
                     positive=True, valid=None, corr_fn=None):
    """Reference dense solver — parity oracle for ``omp_select``."""
    return omp_select(grads, target, k, lam=lam, eps=eps,
                      nnls_iters=nnls_iters, positive=positive, valid=valid,
                      corr_fn=corr_fn, method="dense")


# ---------------------------------------------------------------------------
# anytime sessions: checkpointed solves with budget extension k -> k'
# ---------------------------------------------------------------------------

class OMPAnytimeState(NamedTuple):
    """Host-side checkpoint of an in-flight incremental OMP solve.

    The serve layer (``repro.serve``) stores one of these per client
    session so a budget extension ``k -> k'`` is a *resume*: the cached
    prefix buffers pick up at round ``k`` and only the new rounds run.

    Unlike ``omp_select`` — whose prefix widths depend on the final ``k``
    through ``hi = min(lo + block, k)`` — the session engine always grows
    prefixes to **full block multiples**, so the width schedule (and the
    wide/narrow regime choice) at every round is independent of the budget
    the caller happened to ask for first.  That makes the resumed rounds
    bit-identical to the rounds a single ``extend`` straight to ``k'``
    would run: ``extend(k) ; extend(k')`` and ``extend(k')`` produce the
    same arrays, and both match a one-shot ``omp_select(k')`` selection
    index-exactly away from the f32 noise floor (weights to tolerance —
    the NNLS sees block-padded buffers whose extra rows are exact zeros).

    ``k`` is the rounds solved so far; ``st`` carries the (k,)-capacity
    index/mask buffers (capacity = ``k`` rounded up to ``block``) plus the
    prefix-grown caches; ``c0``/``target``/``valid`` are per-session
    constants so an extension never rescans the pool for them.
    """

    k: int               # rounds solved so far (static)
    block: int           # prefix growth quantum (static)
    st: OMPIncState      # buffers at block-multiple capacity
    c0: jax.Array        # (n,) G @ g_tgt, computed once at session start
    target: jax.Array    # (d,)
    valid: jax.Array     # (n,) bool
    lam: float
    eps: float
    nnls_iters: int
    positive: bool

    @property
    def indices(self) -> jax.Array:
        return self.st.indices[: self.k]

    @property
    def weights(self) -> jax.Array:
        return self.st.weights[: self.k]

    @property
    def mask(self) -> jax.Array:
        return self.st.mask[: self.k]

    @property
    def err(self) -> jax.Array:
        return self.st.err


def _block_cap(k: int, block: int) -> int:
    return max(block * (-(-k // block)), block)


@functools.partial(
    jax.jit,
    static_argnames=("use_cols", "lam", "eps", "nnls_iters", "absolute"),
)
def _run_session_block(grads, target, c0, valid, st: OMPIncState, t0, t1,
                       use_cols: bool, lam: float, eps: float,
                       nnls_iters: int, absolute: bool) -> OMPIncState:
    # t0/t1 are dynamic so arbitrary k -> k' extensions inside one block
    # width reuse a single compiled program (one per prefix width).
    body = _inc_body_factory(grads, target, c0, valid, lam, eps, nnls_iters,
                             absolute)(use_cols)
    return lax.fori_loop(t0, t1, body, st)


def _pad_slots(st: OMPIncState, cap: int) -> OMPIncState:
    """Grow the full-(k,) index/mask buffers to ``cap`` slots."""
    pad = cap - st.indices.shape[0]
    if pad <= 0:
        return st
    return st._replace(
        indices=jnp.pad(st.indices, (0, pad), constant_values=-1),
        mask=jnp.pad(st.mask, (0, pad)),
    )


def omp_session_start(
    grads: jax.Array,          # (n, d) candidate pool (shared, not stored)
    target: jax.Array,         # (d,)
    k: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nnls_iters: int = 50,
    positive: bool = True,
    valid: jax.Array | None = None,
    block: int = 128,
) -> OMPAnytimeState:
    """Open an anytime OMP session and solve the first ``k`` rounds.

    The pool itself is not captured in the state — callers (the serve
    registry) own it and pass the *same* array back to
    ``omp_session_extend``; the session holds everything derived from it.
    """
    n, d = grads.shape
    grads = grads.astype(jnp.float32)
    target = target.astype(jnp.float32)
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    c0 = ops.corr(grads, target)
    st = _empty_inc_state(_block_cap(k, block), n, d, target)
    sess = OMPAnytimeState(k=0, block=int(block), st=st, c0=c0,
                           target=target, valid=valid, lam=float(lam),
                           eps=float(eps), nnls_iters=int(nnls_iters),
                           positive=bool(positive))
    return omp_session_extend(grads, sess, k)


def omp_session_extend(grads: jax.Array, sess: OMPAnytimeState,
                       k_new: int) -> OMPAnytimeState:
    """Extend a session's budget to ``k_new`` rounds (a resume, not a
    recompute: only rounds ``[sess.k, k_new)`` execute).

    ``grads`` must be the pool the session was started on.  ``k_new`` may
    not shrink the budget — the prefix property means a client wanting
    fewer rounds already has them (``sess.indices[:k_small]`` *is* the
    ``k_small`` solution), so a smaller ask is a caller bug worth raising.
    """
    if k_new < sess.k:
        raise ValueError(
            f"cannot shrink an anytime session: have k={sess.k}, asked "
            f"k'={k_new} (slice indices[:k'] instead — prefix property)")
    if k_new == sess.k:
        return sess
    grads = grads.astype(jnp.float32)
    d = grads.shape[1]
    block = sess.block
    st = _pad_slots(sess.st, _block_cap(k_new, block))
    for lo in range((sess.k // block) * block, k_new, block):
        width = lo + block           # full-block width: independent of k
        use_cols = width <= d
        if st.weights.shape[0] < width:
            st = _grow_prefix(st, width, keep_cols=use_cols)
        t0, t1 = max(lo, sess.k), min(lo + block, k_new)
        st = _run_session_block(
            grads, sess.target, sess.c0, sess.valid, st, t0, t1, use_cols,
            sess.lam, sess.eps, sess.nnls_iters, absolute=not sess.positive)
    return sess._replace(k=int(k_new), st=st)


def session_result(sess: OMPAnytimeState
                   ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """(indices (k,), weights (k,), mask (k,), err ()) — the same contract
    as ``omp_select`` at the session's current budget."""
    return sess.indices, sess.weights, sess.mask, sess.err


def session_prefix_result(sess: OMPAnytimeState, k: int
                          ) -> tuple[jax.Array, jax.Array, jax.Array,
                                     jax.Array]:
    """First-``k`` slice of a session: the serve tier's degraded answer.

    The *indices and mask* are certified — the anytime prefix property
    means ``sess.indices[:k]`` is exactly what a one-shot ``k`` solve
    picks.  The *weights* are not: the NNLS weights at budget ``sess.k``
    restricted to the prefix differ from a fresh ``k``-round solve's, so
    they are returned as-is (the caller renormalizes) and the answer must
    be labelled degraded (``anytime-prefix``), never passed off as a full
    solve.  ``k`` may not exceed the session's solved budget.
    """
    k = int(k)
    if k > sess.k:
        raise ValueError(
            f"session has only {sess.k} solved rounds, asked prefix {k} "
            "(extend the session instead)")
    return (sess.indices[:k], sess.weights[:k], sess.mask[:k], sess.err)


class OMPTrajectory(NamedTuple):
    """Host-side record of a full anytime solve to ``k_max`` — the payload
    the artifact store persists (``repro.artifacts``, DESIGN.md §12).

    ``weights_traj`` is lower-triangular: row ``t-1`` holds the NNLS
    weights *after round t* (entries ``>= t`` are zero), so slicing
    ``(indices[:k], weights_traj[k-1, :k], mask[:k], err_trace[k-1])``
    reproduces the session engine's answer at budget ``k`` bit-exactly.
    """

    indices: np.ndarray       # (k_max,) int32
    mask: np.ndarray          # (k_max,) bool
    weights_traj: np.ndarray  # (k_max, k_max) f32, row t-1 = after round t
    err_trace: np.ndarray     # (k_max,) f32, Err_lambda after round t


def omp_session_trajectory(
    grads: jax.Array,
    target: jax.Array,
    k_max: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nnls_iters: int = 50,
    positive: bool = True,
    valid: jax.Array | None = None,
    block: int = 128,
) -> tuple[OMPAnytimeState, OMPTrajectory]:
    """Solve to ``k_max`` one round at a time, recording every prefix.

    Because the session engine's prefix-width schedule is independent of
    the budget asked for (full block multiples — see ``OMPAnytimeState``),
    extending round-by-round is bit-identical to extending straight to
    ``k_max``: row ``t-1`` of the trajectory equals what a fresh
    ``omp_session_start(grads, target, t)`` reports, and the recorded
    indices/mask match a one-shot ``omp_select(t)`` prefix exactly.  This
    is the offline builder's path (one solve, every budget served), not a
    hot path — the per-round host round-trip is the cost of recording.

    Inputs are handed to the session engine *unconverted*: bit-exactness
    between the recorded trajectory and a later live solve holds when
    the live call sees the same arrays (host/device placement included)
    — the differential gate and the serve fast path both arrange that.
    """
    k_max = int(k_max)
    if k_max < 1:
        raise ValueError(f"k_max must be >= 1, got {k_max}")
    sess = omp_session_start(grads, target, 0, lam=lam, eps=eps,
                             nnls_iters=nnls_iters, positive=positive,
                             valid=valid, block=block)
    weights_traj = np.zeros((k_max, k_max), np.float32)
    err_trace = np.zeros((k_max,), np.float32)
    for t in range(1, k_max + 1):
        sess = omp_session_extend(grads, sess, t)
        weights_traj[t - 1, :t] = np.asarray(sess.weights, np.float32)
        err_trace[t - 1] = np.float32(sess.err)
    traj = OMPTrajectory(
        indices=np.asarray(sess.indices, np.int32),
        mask=np.asarray(sess.mask, bool),
        weights_traj=weights_traj,
        err_trace=err_trace,
    )
    return sess, traj


# ---------------------------------------------------------------------------
# batched multi-target OMP: one pool scan serves B concurrent targets
# ---------------------------------------------------------------------------

class OMPBatchState(NamedTuple):
    """Leading-batch-axis twin of ``OMPIncState`` (see that docstring)."""

    indices: jax.Array   # (B, k) int32
    mask: jax.Array      # (B, k) bool
    weights: jax.Array   # (B, P) f32
    colcache: jax.Array  # (B, n, P) f32
    gram: jax.Array      # (B, P, P) f32
    gram_absrow: jax.Array  # (B, P) f32
    tcorr: jax.Array     # (B, P) f32
    rows: jax.Array      # (B, P, d) f32
    residual: jax.Array  # (B, d) f32
    err: jax.Array       # (B,) f32


def _grow_prefix_batched(st: OMPBatchState, width: int,
                         keep_cols: bool) -> OMPBatchState:
    pad = width - st.weights.shape[1]
    z2 = ((0, 0), (0, pad))
    return OMPBatchState(
        indices=st.indices,
        mask=st.mask,
        weights=jnp.pad(st.weights, z2),
        colcache=(jnp.pad(st.colcache, ((0, 0), (0, 0), (0, pad)))
                  if keep_cols else st.colcache),
        gram=jnp.pad(st.gram, ((0, 0), (0, pad), (0, pad))),
        gram_absrow=jnp.pad(st.gram_absrow, z2),
        tcorr=jnp.pad(st.tcorr, z2),
        rows=jnp.pad(st.rows, ((0, 0), (0, pad), (0, 0))),
        residual=st.residual,
        err=st.err,
    )


def _omp_select_batched_incremental(grads, targets, k, lam, eps, nnls_iters,
                                    positive, valids, block):
    """Incremental-Gram OMP over ``B`` targets sharing one pool.

    The per-round structure is identical to ``_omp_select_incremental``
    (same block-quantized prefix widths, same wide/narrow regime choice,
    same NNLS on cached buffers), but every pool-touching step is batched:
    the narrow-regime scoring is one ``(n, d) @ (d, B)`` matmul instead of
    ``B`` matvecs and the new column build is one ``(n, d) @ (d, B)``
    matmul — the candidate matrix is read once per round *for the whole
    batch*, which is where the serve scheduler's throughput comes from.
    Selections match per-target ``omp_select`` index-exactly away from the
    f32 noise floor (the math is identical; only reduction shapes differ).
    """
    n, d = grads.shape
    bsz = targets.shape[0]
    # Pool-sized arrays live pool-major (n, B) — the orientation the
    # shared-operand scan matmul produces natively (see kernels/ref.py).
    c0_t = ops.corr_batched(grads, targets)        # (n, B), exactly once
    zeros_nb = jnp.zeros((n, bsz), dtype=jnp.float32)
    valids_t = valids.T                            # (n, B), hoisted
    bcol = jnp.arange(bsz, dtype=jnp.int32)
    bcols_k = jnp.broadcast_to(bcol[:, None], (bsz, k))
    absolute = not positive
    take_b = jax.vmap(lambda mat, i: mat[i])       # (B, n, p)[b, e_b]
    nnls_b = jax.vmap(_nnls_active_cached,
                      in_axes=(0, 0, 0, 0, 0, None, None))

    def scatter_taken_t(mask, indices):
        # One 2-D scatter into the (n, B) taken mask; out-of-bounds row
        # sentinel n drops unused slots (same trick as the single solver).
        return jnp.zeros((n, bsz), dtype=bool).at[
            jnp.where(mask, indices, n), bcols_k].set(mask, mode="drop")

    def make_body(use_cols: bool):
        def body(t, st: OMPBatchState):
            p = st.weights.shape[1]
            avail_t = valids_t & ~scatter_taken_t(st.mask, st.indices)
            if use_cols:
                e, _ = ops.corr_argmax_batched(st.colcache, st.weights,
                                               c0_t, avail_t,
                                               absolute=absolute)
            else:
                e, _ = ops.corr_argmax_batched(grads, -st.residual,
                                               zeros_nb, avail_t,
                                               absolute=absolute)

            grow = st.err > eps                            # (B,)
            growf = grow.astype(jnp.float32)
            indices = st.indices.at[:, t].set(jnp.where(grow, e, -1))
            mask = st.mask.at[:, t].set(grow)
            mask_p = mask[:, :p]

            g_e = grads[e] * growf[:, None]                # (B, d)
            rows = st.rows.at[:, t].set(g_e)
            if use_cols:
                newcol = ops.corr_batched(grads, g_e)      # (n, B)
                colcache = st.colcache.at[:, :, t].set(newcol.T)
                row_vals = jnp.where(mask_p, take_b(colcache, e),
                                     0.0) * growf[:, None]
            else:
                colcache = st.colcache
                row_vals = jnp.where(
                    mask_p, jnp.einsum("bpd,bd->bp", rows, g_e), 0.0)
            gram = st.gram.at[:, t, :].set(row_vals).at[:, :, t].set(row_vals)
            absrow = jnp.where(mask_p,
                               st.gram_absrow + jnp.abs(row_vals), 0.0)
            absrow = absrow.at[:, t].set(jnp.sum(jnp.abs(row_vals), axis=1))
            tcorr = st.tcorr.at[:, t].set(c0_t[e, bcol] * growf)

            w = nnls_b(gram, absrow, rows, tcorr, mask_p, lam, nnls_iters)
            resid = targets - jnp.einsum("bp,bpd->bd", w, rows)
            err = jnp.sum(resid**2, axis=1) + lam * jnp.sum(w**2, axis=1)
            return OMPBatchState(indices, mask, w, colcache, gram, absrow,
                                 tcorr, rows, resid, err)
        return body

    st = OMPBatchState(
        indices=jnp.full((bsz, k), -1, dtype=jnp.int32),
        mask=jnp.zeros((bsz, k), dtype=bool),
        weights=jnp.zeros((bsz, 0), dtype=jnp.float32),
        colcache=jnp.zeros((bsz, n, 0), dtype=jnp.float32),
        gram=jnp.zeros((bsz, 0, 0), dtype=jnp.float32),
        gram_absrow=jnp.zeros((bsz, 0), dtype=jnp.float32),
        tcorr=jnp.zeros((bsz, 0), dtype=jnp.float32),
        rows=jnp.zeros((bsz, 0, d), dtype=jnp.float32),
        residual=targets,
        err=jnp.sum(targets**2, axis=1),
    )
    for lo in range(0, k, block):
        hi = min(lo + block, k)      # same prefix schedule as omp_select
        # Regime choice re-derived for the batch: the column cache is
        # *per-target* (``B·n·P`` touched per wide round) while the
        # narrow-regime pool scan is *shared* (``n·d`` once for the whole
        # batch) — so wide only pays off when ``B·P <= d``, not ``P <= d``.
        # Same math either way (scores are c0 - C@w == G@r); only the
        # reduction shapes differ, below the index-parity noise floor.
        use_cols = hi * bsz <= d
        st = _grow_prefix_batched(st, hi, keep_cols=use_cols)
        st = lax.fori_loop(lo, hi, make_body(use_cols), st)
    return st.indices, st.weights, st.mask, st.err


@functools.partial(
    jax.jit,
    static_argnames=("k", "nnls_iters", "positive", "method", "block"),
)
def omp_select_batched(
    grads: jax.Array,          # (n, d) shared candidate pool
    targets: jax.Array,        # (B, d) one target per concurrent request
    k: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nnls_iters: int = 50,
    positive: bool = True,
    valid: jax.Array | None = None,   # (B, n) or (n,) availability
    method: str = "incremental",
    block: int = 128,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Solve ``B`` OMP problems over one shared pool in a single program.

    Returns ``(indices (B, k), weights (B, k), mask (B, k), err (B,))`` —
    row ``b`` is what ``omp_select(grads, targets[b], ...)`` returns.  The
    serve scheduler micro-batches same-pool ``SelectRequest``s through
    this: B sequential solves become one batched solve whose pool-touching
    matvecs are shared-operand matmuls (see DESIGN.md §6).
    """
    if method not in ("incremental", "dense"):
        raise ValueError(f"unknown OMP method {method!r}")
    n, d = grads.shape
    grads = grads.astype(jnp.float32)
    targets = targets.astype(jnp.float32)
    bsz = targets.shape[0]
    if valid is None:
        valid = jnp.ones((bsz, n), dtype=bool)
    elif valid.ndim == 1:
        valid = jnp.broadcast_to(valid, (bsz, n))
    if method == "dense":
        return jax.vmap(
            lambda t, v: _omp_select_dense(grads, t, k, lam, eps,
                                           nnls_iters, positive, v, None)
        )(targets, valid)
    return _omp_select_batched_incremental(grads, targets, k, lam, eps,
                                           nnls_iters, positive, valid,
                                           block)


def split_budget(k: int, sizes: Sequence[int]) -> np.ndarray:
    """Split a global budget ``k`` across partitions of the given sizes.

    Paper Algorithm 1's per-class accounting, done exactly: an even split
    with the ``k % P`` remainder going to the largest partitions first,
    every quota capped at its partition size, and capped-off surplus
    rebalanced over the partitions that still have capacity — iterated
    until the budget is placed.  Guarantees ``sum(quota) == min(k,
    sum(sizes))`` and ``quota[p] <= sizes[p]`` for every partition.

    Host-side (numpy) on purpose: quotas are static solver shapes.
    """
    sizes = np.asarray(sizes, np.int64)
    if sizes.ndim != 1 or sizes.shape[0] == 0:
        raise ValueError(f"sizes must be a non-empty 1-D sequence, got "
                         f"shape {sizes.shape}")
    if (sizes < 0).any():
        raise ValueError(f"negative partition size in {sizes}")
    quota = np.zeros(sizes.shape[0], np.int64)
    remaining = min(int(k), int(sizes.sum()))
    # Largest-first order, ties broken by partition id for determinism.
    order = np.argsort(-sizes, kind="stable")
    while remaining > 0:
        cap = sizes - quota
        act = order[cap[order] > 0]
        base, rem = divmod(remaining, len(act))
        add = np.full(len(act), base, np.int64)
        add[:rem] += 1                      # remainder to largest first
        add = np.minimum(add, cap[act])
        quota[act] += add
        remaining -= int(add.sum())
    return quota


def omp_select_per_class(
    grads: jax.Array,        # (n, d)
    labels: jax.Array,       # (n,) int class ids
    targets: jax.Array,      # (num_classes, d) per-class target gradients
    num_classes: int,
    k_per_class: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    method: str = "incremental",
    quotas: Optional[Sequence[int]] = None,   # (C,) per-class budgets
    nnls_iters: int = 50,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Paper's per-class decomposition, batched over classes with vmap.

    Each class-c problem only sees candidates with label c (others masked
    invalid).  Returns flattened (num_classes*k, ...) padded arrays.

    ``quotas`` gives each class its own round budget (``split_budget``'s
    output; ``k_per_class`` is ignored then, the vmap runs ``max(quotas)``
    rounds for every class so shapes stay static).  Class ``c`` keeps the
    first ``quotas[c]`` rounds — index-exact by the greedy prefix
    property: round ``t`` of OMP only depends on rounds ``< t``, so the
    truncated prefix equals a fresh ``quotas[c]``-round solve — and its
    weights are re-solved by one NNLS on the truncated active set (the
    full-budget weights are *not* the prefix weights).
    """

    if quotas is None:
        def one_class(c, target):
            valid = labels == c
            idx, w, mask, _ = omp_select(
                grads, target, k=k_per_class, lam=lam, eps=eps, valid=valid,
                method=method,
            )
            return idx, w, mask

        idx, w, mask = jax.vmap(one_class)(jnp.arange(num_classes), targets)
        return idx.reshape(-1), w.reshape(-1), mask.reshape(-1)

    quotas = np.asarray(quotas, np.int64)
    if quotas.shape != (num_classes,):
        raise ValueError(
            f"quotas must be ({num_classes},), got {quotas.shape}")
    k_cap = int(quotas.max()) if quotas.size else 0
    if k_cap == 0:                      # empty budget: all-off result
        z = jnp.zeros((0,))
        return (z.astype(jnp.int32), z.astype(jnp.float32),
                z.astype(bool))
    quotas_j = jnp.asarray(quotas, jnp.int32)
    slot = jnp.arange(k_cap, dtype=jnp.int32)

    def one_class(c, target, quota):
        valid = labels == c
        idx, _, mask, _ = omp_select(
            grads, target, k=k_cap, lam=lam, eps=eps, valid=valid,
            method=method,
        )
        mask = mask & (slot < quota)
        idx = jnp.where(mask, idx, -1)
        # Exact reweight of the truncated prefix: one NNLS over the
        # quota-sized active set against the class target.
        sel = jnp.where(mask, idx, 0)
        g_s = grads[sel] * mask[:, None].astype(grads.dtype)
        gram = g_s @ g_s.T
        corr = g_s @ target.astype(grads.dtype)
        w = _nnls_active(gram, corr, mask, lam, nnls_iters)
        return idx, jnp.where(mask, w, 0.0), mask

    idx, w, mask = jax.vmap(one_class)(jnp.arange(num_classes), targets,
                                       quotas_j)
    return idx.reshape(-1), w.reshape(-1), mask.reshape(-1)


def matching_error(
    grads: jax.Array, target: jax.Array, indices: jax.Array,
    weights: jax.Array, mask: jax.Array, lam: float = 0.0,
) -> jax.Array:
    """Err_lambda for a given (X, w) — used by tests & benchmarks.

    Returns the paper's squared objective  ||G_S^T w - g_tgt||^2 +
    lam ||w||^2, matching the ``err`` tracked inside ``omp_select``.
    """
    sel = jnp.where(mask, indices, 0)
    g_s = grads[sel] * mask[:, None].astype(grads.dtype)
    resid = target - weights @ g_s
    return jnp.sum(resid**2) + lam * jnp.sum(weights**2)
