"""Last-layer gradient proxies (paper S4, 'last-layer' + 'per-gradient').

For a cross-entropy head ``z = H W + b`` the per-sample gradients are closed
form (no backprop through the trunk needed):

    dL_i/db   = p_i - y_i                      (num_classes,)
    dL_i/dW   = h_i (p_i - y_i)^T              (d_h, num_classes)
    dL_i/dh_i = W (p_i - y_i)                  (d_h,)   -- 'hidden grad'

The paper's GRAD-MATCH uses the last linear layer's gradients; its
*per-gradient* approximation keeps only the slice for the sample's own class.
For LM heads (vocab up to 256k) even the bias gradient is large, so we provide
a fixed-seed random projection (Johnson-Lindenstrauss: preserves the inner
products OMP relies on) and the hidden-gradient proxy (dimension d_model).

All functions work on examples; per-batch (PB) proxies are means over the
batch axis, computed by the fused Pallas kernel in kernels/lastlayer_grad.py
when n is large (see kernels/ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def softmax_residual(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """(p - onehot(y)) per sample/token.  logits (..., C), labels (...,)."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    y = jax.nn.one_hot(labels, logits.shape[-1], dtype=p.dtype)
    return p - y


def bias_grad_proxy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-sample last-layer *bias* gradient: (n, C)."""
    return softmax_residual(logits, labels)


def last_layer_grad_proxy(
    hidden: jax.Array,    # (n, d_h)
    logits: jax.Array,    # (n, C)
    labels: jax.Array,    # (n,)
    concat_bias: bool = True,
) -> jax.Array:
    """Full last-layer gradient, flattened: (n, d_h*C [+ C]).

    This is the exact per-sample gradient of the CE loss w.r.t. (W, b) of the
    final linear layer -- what non-per-class GRAD-MATCH matches.
    Only use for small C (paper: CIFAR/MNIST heads).
    """
    resid = softmax_residual(logits, labels)                 # (n, C)
    outer = hidden[:, :, None] * resid[:, None, :]           # (n, d_h, C)
    flat = outer.reshape(outer.shape[0], -1)
    if concat_bias:
        flat = jnp.concatenate([flat, resid], axis=-1)
    return flat


def per_class_grad_proxy(
    hidden: jax.Array, logits: jax.Array, labels: jax.Array
) -> jax.Array:
    """Paper's per-class *per-gradient* approximation: (n, d_h + 1).

    For sample i of class c keep only row c of dW plus the class bias term:
    g_i = [ (p_ic - 1) * h_i ,  p_ic - 1 ].  Used with per-class OMP where all
    candidates share the class, so rows are comparable.
    """
    resid = softmax_residual(logits, labels)                 # (n, C)
    own = jnp.take_along_axis(resid, labels[:, None], axis=-1)  # (n, 1)
    return jnp.concatenate([own * hidden, own], axis=-1)


def hidden_grad_proxy(
    hidden: jax.Array,     # (..., d_h) final pre-head hidden states
    logits: jax.Array,     # (..., V)
    labels: jax.Array,     # (...,)
    unembed: jax.Array,    # (d_h, V) head weight
) -> jax.Array:
    """dL/dh = (p - y) @ W^T : the LM-friendly proxy, dimension d_model.

    Exact head-input gradient; one extra (.., V) x (V, d_h) matmul.  For LM
    candidates = micro-batches, call with (B, T, ...) and mean over T.
    """
    resid = softmax_residual(logits, labels)
    del hidden  # only needed by callers that concat features; kept for API
    return resid @ unembed.T.astype(resid.dtype)


@functools.partial(jax.jit, static_argnames=("out_dim",))
def random_project(x: jax.Array, out_dim: int, seed: int = 0) -> jax.Array:
    """Fixed-seed JL projection (n, D) -> (n, out_dim), D large (e.g. vocab)."""
    key = jax.random.PRNGKey(seed)
    proj = jax.random.normal(key, (x.shape[-1], out_dim), dtype=jnp.float32)
    return (x.astype(jnp.float32) @ proj) / jnp.sqrt(jnp.float32(out_dim))


def proxy_chunk_stream(pool_iter, proxy_fn, params, pick: str = "bias"):
    """Adapt a raw-data chunk iterator into a proxy chunk factory.

    ``pool_iter`` is a re-iterable factory yielding ``(x, y, offset)`` (see
    ``data.loader.ChunkedPool``); ``proxy_fn(params, x, y)`` returns
    ``(per_class_proxy, bias_proxy)`` (``train.steps.make_proxy_fn``).  The
    returned factory yields ``(proxy_chunk, None)`` pairs in the protocol
    ``core.streaming.omp_select_streaming`` consumes — proxies for one
    chunk at a time, so the full ``(n, d)`` proxy matrix never exists.
    """
    which = {"per_class": 0, "bias": 1}[pick]

    def chunks():
        for x, y, _ in pool_iter():
            yield proxy_fn(params, x, y)[which], None

    return chunks


def proxy_row_fetch(x, y, proxy_fn, params, pick: str = "bias"):
    """Exact-proxy-row fetch for the streaming engine's repair/refill
    tiers: re-extracts the proxies of a handful of rows by global id.

    Valid because the proxy extractors are row-wise (softmax/products
    within each row only), so ``proxy_fn`` on a gathered subset yields
    bit-identical rows to the chunked extraction the scan path used —
    the certified repairs stay exact without a full re-extraction pass.
    """
    import numpy as np

    which = {"per_class": 0, "bias": 1}[pick]

    def fetch(ids):
        ids = np.asarray(ids)
        return np.asarray(proxy_fn(params, x[ids], y[ids])[which],
                          np.float32)

    return fetch


def per_batch(proxies: jax.Array, batch_size: int) -> jax.Array:
    """Group per-example proxies into per-mini-batch (PB) proxies.

    (n, d) -> (n // B, d); each row is the *mean* gradient of one mini-batch,
    i.e. exactly the gradient used by a weighted mini-batch SGD step.  n must
    be divisible by B (the loader pads the candidate pool).
    """
    n, d = proxies.shape
    nb = n // batch_size
    return proxies[: nb * batch_size].reshape(nb, batch_size, d).mean(axis=1)
