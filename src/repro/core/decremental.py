"""Decremental OMP: remove committed rows from an anytime solution.

Every engine so far only *grows* the active set.  The continual buffer
(``repro.continual``) also needs to shrink it — evicting a committed row
when the buffer is full — without paying a from-scratch re-solve.  The
math rests on the **greedy prefix property**: round ``t`` of the
incremental solver is a pure function of the pool, the target, and the
state left by rounds ``< t``.  A candidate that never won an argmax never
influenced any round, so

* removing a *non-committed* candidate from the pool changes nothing;
* removing the pick of round ``i`` leaves rounds ``< i`` bit-identical —
  the tail ``[i, k)`` is the only part that must be recomputed.

``omp_downdate`` therefore truncates the session's prefix buffers at the
removed pick's round (deleting its Gram row/column, cached row and target
correlation), re-runs the factor-form NNLS on the surviving active set,
recomputes the residual, and replays the tail with real argmaxes.  When
the removed pick is the *last* round — the common case for the continual
buffer, whose eviction policy targets the lowest-gain (latest-ladder)
picks — there is no tail and the whole removal is one truncation +
NNLS + residual refresh: O(k·d + k²), versus O(k·n·d) for a re-solve.

``session_extend_traced`` is the replay engine the buffer maintainer
uses: identical state transitions to ``omp_session_extend`` (it steps the
same compiled ``_run_session_block`` program one round at a time), while
recording the residual trajectory and each round's winning gain — the
**admission certificate** ``certify_admission`` checks newcomers against.
A newcomer whose correlation with some round's entering residual is not
clearly below that round's recorded winning gain *might* have won it;
fail-closed, the maintainer replays from the earliest such round (and a
violation at round 0 is exactly a full re-solve on the buffer).

Exactness bar (same as the anytime sessions, DESIGN.md §6): indices are
exact away from the f32 noise floor, weights to tolerance.  The one
deliberate deviation from bit-replay is ``gram_absrow``: truncation
recomputes the Gershgorin row sums from the surviving Gram instead of
replaying their incremental accumulation, which can move the NNLS step
size by an ulp.  Ties (duplicate rows) still resolve identically —
identical rows produce identical scores and ``corr_argmax`` breaks ties
by slot order.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.omp import (OMPAnytimeState, OMPIncState, _block_cap,
                            _empty_inc_state, _grow_prefix, _nnls_active_cached,
                            _pad_slots, _run_session_block, omp_session_extend)

__all__ = [
    "DowndateInfo",
    "ReplayTrace",
    "certify_admission",
    "omp_downdate",
    "session_extend_traced",
    "session_truncate",
]


@functools.partial(jax.jit, static_argnames=("lam", "nnls_iters"))
def _truncate_buffers(st: OMPIncState, target, t, lam: float,
                      nnls_iters: int) -> OMPIncState:
    """Slice the prefix buffers to the first ``t`` rounds and re-tighten.

    The sliced width is the *fresh* session's block-quantized width after
    ``t`` rounds (the caller guarantees the slices land on it), stale
    slots are zeroed — they were written by the discarded rounds — and
    weights / residual / err are re-derived by the same factor-form NNLS
    call round ``t - 1`` made over the same buffers (w0 = 0, fixed
    iterations: a deterministic function of the caches).
    """
    wt = st.weights.shape[0]            # == block * ceil(t / block)
    keep = jnp.arange(wt) < t
    indices = jnp.where(keep, st.indices[:wt], -1).astype(jnp.int32)
    mask = st.mask[:wt] & keep
    rows = jnp.where(keep[:, None], st.rows, 0.0)
    tcorr = jnp.where(keep, st.tcorr, 0.0)
    gram = jnp.where(keep[:, None] & keep[None, :], st.gram, 0.0)
    wc = st.colcache.shape[1]
    colcache = jnp.where(jnp.arange(wc)[None, :] < t, st.colcache, 0.0)
    absrow = jnp.where(keep, jnp.sum(jnp.abs(gram), axis=1), 0.0)
    w = _nnls_active_cached(gram, absrow, rows, tcorr, mask, lam, nnls_iters)
    resid = target - w @ rows
    err = jnp.sum(resid**2) + lam * jnp.sum(w**2)
    return OMPIncState(indices, mask, w, colcache, gram, absrow, tcorr,
                       rows, resid, err)


def session_truncate(sess: OMPAnytimeState, t: int,
                     valid: Optional[jax.Array] = None) -> OMPAnytimeState:
    """Truncate an anytime session to its first ``t`` rounds — exactly.

    By the greedy prefix property the result is the state a fresh
    ``t``-round session over the same pool holds (weights at the noise
    floor — see the module docstring on ``gram_absrow``), so a subsequent
    ``omp_session_extend`` continues as if rounds ``>= t`` never ran.

    ``valid`` optionally replaces the candidate mask the replayed rounds
    will see (the downdate path clears the removed candidate's slot).
    """
    t = int(t)
    if not 0 <= t <= sess.k:
        raise ValueError(
            f"cannot truncate to t={t}: session holds k={sess.k} rounds")
    v = sess.valid if valid is None else jnp.asarray(valid, bool)
    if t == sess.k and valid is None:
        return sess
    block = sess.block
    d = sess.st.rows.shape[1]
    n = v.shape[0]
    if t == 0:
        st0 = _empty_inc_state(_block_cap(1, block), n, d, sess.target)
        return sess._replace(k=0, st=st0, valid=v)
    cap_t = _block_cap(t, block)        # == fresh width after t rounds
    st = sess.st._replace(
        indices=sess.st.indices[:cap_t],
        mask=sess.st.mask[:cap_t],
        weights=sess.st.weights[:cap_t],
        colcache=sess.st.colcache[:, :min(cap_t, sess.st.colcache.shape[1])],
        gram=sess.st.gram[:cap_t, :cap_t],
        gram_absrow=sess.st.gram_absrow[:cap_t],
        tcorr=sess.st.tcorr[:cap_t],
        rows=sess.st.rows[:cap_t],
    )
    st = _truncate_buffers(st, sess.target, t, sess.lam, sess.nnls_iters)
    return sess._replace(k=t, st=st, valid=v)


class DowndateInfo(NamedTuple):
    """Accounting for one ``omp_downdate`` call."""

    round: int      # earliest round the removed candidate was committed at
    replayed: int   # tail rounds re-run with real argmaxes
    resolved: bool  # True when the removal degenerated to a full re-solve


def omp_downdate(grads: jax.Array, sess: OMPAnytimeState, idx: int,
                 k_new: Optional[int] = None):
    """Remove committed candidate ``idx`` from an anytime OMP solution.

    Deletes the candidate's Gram row/column, cached row and target
    correlation by truncating the prefix buffers at its round ``i``,
    re-runs the factor-form NNLS on the surviving active set, recomputes
    the residual, and replays rounds ``[i, k_new)`` with real argmaxes
    over the surviving pool (``valid[idx]`` is cleared: the row leaves
    both the solution and the candidate set).  ``k_new`` defaults to
    ``sess.k - 1`` — the budget shrinks with the removal.

    Differential guarantee: ``omp_downdate`` (optionally followed by
    ``omp_session_extend``) matches a from-scratch ``omp_select`` /
    ``omp_session_start`` on the surviving rows at the session engine's
    usual parity — indices exact away from the f32 noise floor, weights
    to tolerance.  Cost: O(k·d + k²) when the removed pick is the last
    round (truncate + one NNLS + one residual, zero replay); an earlier
    pick replays its ``k_new - i`` tail rounds; ``i == 0`` degenerates to
    a full re-solve (``resolved=True`` — the fail-closed floor).

    Returns ``(new_session, DowndateInfo)``.
    """
    idx = int(idx)
    ind = np.asarray(sess.indices)
    msk = np.asarray(sess.mask)
    hits = np.nonzero((ind == idx) & msk)[0]
    if hits.size == 0:
        committed = np.unique(ind[msk])
        raise ValueError(
            f"candidate {idx} is not committed in this session "
            f"(committed: {committed[:16].tolist()}"
            f"{'...' if committed.size > 16 else ''})")
    i = int(hits[0])
    if k_new is None:
        k_new = sess.k - 1
    if k_new < i:
        raise ValueError(
            f"k_new={k_new} would truncate below the removed round {i}")
    new_valid = sess.valid.at[idx].set(False)
    out = session_truncate(sess, i, valid=new_valid)
    if k_new > i:
        out = omp_session_extend(grads, out, k_new)
    return out, DowndateInfo(round=i, replayed=int(k_new) - i,
                             resolved=(i == 0))


class ReplayTrace(NamedTuple):
    """Per-round certificate data for the continual buffer maintainer.

    ``resid[t]`` is the residual *entering* round ``t``; ``win[t]`` is the
    winner's residual-correlation gain at that round — the quantity the
    engine's argmax maximized, so it is exactly what a newcomer must beat
    to change the round.  Sentinels: ``+inf`` for eps-stopped rounds (no
    newcomer can un-stop the criterion), ``-inf`` for degenerate rounds
    (pool exhausted: the engine re-commits an already-taken slot; any
    newcomer wins such a round and must force a replay).
    """

    resid: np.ndarray   # (k, d) f32
    win: np.ndarray     # (k,) f32, +/-inf sentinels as above


def _empty_trace(d: int) -> ReplayTrace:
    return ReplayTrace(resid=np.zeros((0, d), np.float32),
                       win=np.zeros((0,), np.float32))


def session_extend_traced(grads: jax.Array, sess: OMPAnytimeState,
                          k_new: int, trace: Optional[ReplayTrace] = None):
    """``omp_session_extend`` that also records a ``ReplayTrace``.

    Steps the same compiled ``_run_session_block`` program one round at a
    time (the fori_loop body composes, so the resulting state is
    bit-identical to the block extension), capturing each round's entering
    residual; winning gains are batch-computed afterwards in the same
    arithmetic ``certify_admission`` uses.  ``trace`` must cover the
    ``sess.k`` rounds already solved (pass ``None`` only for a fresh
    session); the returned trace covers ``[0, k_new)``.

    Returns ``(new_session, new_trace)``.
    """
    d = grads.shape[1]
    if trace is None:
        if sess.k != 0:
            raise ValueError(
                f"session holds {sess.k} rounds but no trace was given")
        trace = _empty_trace(d)
    if trace.win.shape[0] != sess.k:
        raise ValueError(
            f"trace covers {trace.win.shape[0]} rounds, session holds "
            f"{sess.k}")
    if k_new < sess.k:
        raise ValueError(
            f"cannot shrink an anytime session: have k={sess.k}, asked "
            f"k'={k_new} (use session_truncate)")
    if k_new == sess.k:
        return sess, trace
    grads = grads.astype(jnp.float32)
    block = sess.block
    absolute = not sess.positive
    st = _pad_slots(sess.st, _block_cap(k_new, block))
    resids = []
    for t in range(sess.k, k_new):
        width = block * (t // block + 1)     # full-block session schedule
        use_cols = width <= d
        if st.weights.shape[0] < width:
            st = _grow_prefix(st, width, keep_cols=use_cols)
        resids.append(st.residual)
        st = _run_session_block(
            grads, sess.target, sess.c0, sess.valid, st, t, t + 1, use_cols,
            sess.lam, sess.eps, sess.nnls_iters, absolute=absolute)
    new_sess = sess._replace(k=int(k_new), st=st)

    ind = np.asarray(st.indices[:k_new])
    msk = np.asarray(st.mask[:k_new])
    valid_np = np.asarray(sess.valid)
    r_new = np.asarray(jnp.stack(resids), np.float32)        # (T, d)
    picks = ind[sess.k:k_new]
    rows_t = np.asarray(grads[jnp.asarray(np.where(picks >= 0, picks, 0))],
                        np.float32)
    gains = np.einsum("td,td->t", rows_t, r_new)
    if absolute:
        gains = np.abs(gains)
    win_new = np.empty((k_new - sess.k,), np.float32)
    seen = set(ind[:sess.k][msk[:sess.k]].tolist())
    for j, t in enumerate(range(sess.k, k_new)):
        if not msk[t]:
            win_new[j] = np.inf          # eps-stopped: unbeatable
        elif int(picks[j]) in seen or not valid_np[picks[j]]:
            win_new[j] = -np.inf         # degenerate re-pick: always replay
        else:
            win_new[j] = gains[j]
            seen.add(int(picks[j]))
    return new_sess, ReplayTrace(
        resid=np.concatenate([trace.resid, r_new], axis=0),
        win=np.concatenate([trace.win, win_new]))


def certify_admission(new_rows: np.ndarray, trace: ReplayTrace, k: int,
                      positive: bool = True, band_rel: float = 1e-4,
                      band_abs: float = 1e-6) -> int:
    """Earliest committed round a newcomer could win — fail-closed.

    Scores every newcomer row against the recorded residual trajectory; a
    round whose winning gain does not clear the best newcomer score by
    the f32 tolerance band cannot be certified to survive the admission
    and must be replayed.  Returns ``k`` when every round is certified
    (the committed solution is already the from-scratch solution over the
    new pool); ``0`` means nothing is certain — a full re-solve.
    """
    if k == 0:
        return 0
    if new_rows.shape[0] == 0:
        return k
    s = np.asarray(new_rows, np.float32) @ trace.resid[:k].T     # (B, k)
    if not positive:
        s = np.abs(s)
    best = s.max(axis=0)
    win = trace.win[:k]
    band = band_rel * np.abs(win) + band_abs
    with np.errstate(invalid="ignore"):
        ok = np.where(np.isposinf(win), True,
                      np.where(np.isneginf(win), False, best < win - band))
    bad = ~ok.astype(bool)
    return int(np.argmax(bad)) if bad.any() else k
