"""GRAD-MATCH: gradient-matching data subset selection (paper Alg. 1 + 2).

Entry points:
  - ``gradmatch``          : OMP over per-example proxies (optionally per-class)
  - ``gradmatch_pb``       : OMP over per-mini-batch proxies (the PB variant)
  - ``SelectionResult``    : padded static-shape result consumed by the trainer

The target gradient is the *sum* of candidate gradients when matching the
training loss (isValid=False) or the sum of validation-proxy gradients when
matching the validation loss (isValid=True) -- exactly eq. (2) of the paper.
Returned weights are normalized to sum to 1 (the normalization Thm 1 assumes);
the trainer multiplies back by the subset size so loss magnitudes match an
unweighted mean and the usual LR schedules transfer.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import omp as omp_lib
from repro.core import proxies as proxy_lib


class SelectionResult(NamedTuple):
    indices: jax.Array  # (k,) int32 candidate ids, -1 on unused slots
    weights: jax.Array  # (k,) f32, >= 0, sums to 1 over valid slots
    mask: jax.Array     # (k,) bool
    err: jax.Array      # () f32  final E_lambda value (diagnostic)
    # Solver accounting (streaming entry points attach their SelectStats;
    # None elsewhere, so array-only consumers are unaffected).
    stats: Optional[Any] = None

    @property
    def size(self):
        return jnp.sum(self.mask)


def _normalize(w: jax.Array, mask: jax.Array) -> jax.Array:
    w = jnp.where(mask, w, 0.0)
    s = jnp.sum(w)
    # Degenerate all-zero solutions fall back to uniform over the mask.
    uniform = mask.astype(w.dtype) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.where(s > 1e-12, w / jnp.maximum(s, 1e-12), uniform)


def gradmatch(
    grads: jax.Array,            # (n, d) candidate gradient proxies
    k: int,
    target: jax.Array | None = None,   # (d,) defaults to sum of grads
    lam: float = 0.5,
    eps: float = 1e-10,
    valid: jax.Array | None = None,
    corr_fn=None,
    method: str = "incremental",       # OMP solver: "incremental" | "dense"
) -> SelectionResult:
    """Plain GRAD-MATCH on an explicit candidate gradient matrix."""
    if target is None:
        if valid is None:
            target = jnp.sum(grads, axis=0)
        else:
            target = jnp.sum(grads * valid[:, None].astype(grads.dtype), axis=0)
    idx, w, mask, err = omp_lib.omp_select(
        grads, target, k=k, lam=lam, eps=eps, valid=valid, corr_fn=corr_fn,
        method=method,
    )
    return SelectionResult(idx, _normalize(w, mask), mask, err)


def gradmatch_per_class(
    grads: jax.Array,       # (n, d) per-class per-gradient proxies
    labels: jax.Array,      # (n,)
    num_classes: int,
    k: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    method: str = "incremental",
) -> SelectionResult:
    """Paper default: one OMP per class, budget split exactly.

    The budget split is Algorithm 1's accounting done right
    (``omp.split_budget``): the ``k % C`` remainder goes to the largest
    classes first, each quota is capped at its class size, and capped-off
    surplus is rebalanced — so the selection holds exactly ``min(k,
    n_valid)`` rows (rows whose label falls outside ``[0, num_classes)``
    are not candidates).  ``err`` is the true global objective
    ``||Σ_c g_tgt_c − Σ w·g||² + λ||w||²`` of the unnormalized per-class
    solution against the summed target — not a placeholder.
    """
    labels_np = np.asarray(labels)
    in_range = (labels_np >= 0) & (labels_np < num_classes)
    sizes = np.bincount(labels_np[in_range], minlength=num_classes)
    quotas = omp_lib.split_budget(k, sizes)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=grads.dtype)  # (n, C)
    targets = onehot.T @ grads                                       # (C, d)
    idx, w, mask = omp_lib.omp_select_per_class(
        grads, labels, targets, num_classes, 0, lam=lam, eps=eps,
        method=method, quotas=quotas,
    )
    err = omp_lib.matching_error(grads, jnp.sum(targets, axis=0), idx, w,
                                 mask, lam=lam)
    # Per-class weights each sum to ~their class share; renormalize globally.
    return SelectionResult(idx, _normalize(w, mask), mask, err)


def gradmatch_pb(
    example_proxies: jax.Array,  # (n, d)
    batch_size: int,
    k_batches: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    target: jax.Array | None = None,
    corr_fn=None,
    method: str = "incremental",
) -> SelectionResult:
    """GRAD-MATCHPB: ground set = mini-batches (paper S3, 'PB' variant)."""
    pb = proxy_lib.per_batch(example_proxies, batch_size)
    if target is None:
        # Sum of *batch* gradients approximates the full gradient / B.
        target = jnp.sum(pb, axis=0)
    return gradmatch(pb, k=k_batches, target=target, lam=lam, eps=eps,
                     corr_fn=corr_fn, method=method)


def expand_batch_selection(
    sel: SelectionResult, batch_size: int, n_examples: int
) -> SelectionResult:
    """Expand a per-batch selection to per-example indices/weights.

    Batch j covers examples [j*B, (j+1)*B); each inherits w_j / B so the
    total still sums to 1.
    """
    k = sel.indices.shape[0]
    base = jnp.where(sel.mask, sel.indices, 0) * batch_size          # (k,)
    offs = jnp.arange(batch_size, dtype=jnp.int32)                   # (B,)
    ex_idx = (base[:, None] + offs[None, :]).reshape(-1)             # (k*B,)
    ex_idx = jnp.where(jnp.repeat(sel.mask, batch_size), ex_idx, -1)
    ex_idx = jnp.where(ex_idx < n_examples, ex_idx, -1)
    ex_mask = ex_idx >= 0
    ex_w = jnp.repeat(sel.weights / batch_size, batch_size)
    ex_w = jnp.where(ex_mask, ex_w, 0.0)
    s = jnp.maximum(jnp.sum(ex_w), 1e-12)
    return SelectionResult(ex_idx.astype(jnp.int32), ex_w / s, ex_mask,
                           sel.err, sel.stats)
