"""RANDOM baseline: uniform subset, uniform weights (paper's skyline-for-time)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gradmatch import SelectionResult


def random_select(key: jax.Array, n: int, k: int,
                  valid: jax.Array | None = None) -> SelectionResult:
    if valid is None:
        perm = jax.random.permutation(key, n)[:k]
    else:
        # Gumbel top-k over valid candidates — jit-safe weighted sampling
        # without replacement.
        g = jax.random.gumbel(key, (n,))
        g = jnp.where(valid, g, -jnp.inf)
        perm = jax.lax.top_k(g, k)[1]
    mask = jnp.ones((k,), dtype=bool)
    w = jnp.full((k,), 1.0 / k, dtype=jnp.float32)
    return SelectionResult(perm.astype(jnp.int32), w, mask, jnp.float32(0.0))
