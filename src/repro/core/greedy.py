"""Shared fixed-shape greedy-maximization engine (DESIGN.md §5).

The submodular baselines (CRAIG's facility location, GLISTER's Taylor
greedy) all reduce to "argmax a per-candidate score k times under a taken
mask".  The seed implementations paid ``O(n²)`` per round for CRAIG — every
round recomputed all n marginal gains over a resident ``(n, n)``
similarity.  This module provides the engine they are refactored onto:

- **Certified lazy greedy** (``method="lazy"``): cached stale gains are
  upper bounds by submodularity (coverage only grows, so marginal gains
  only shrink).  Each round re-evaluates a fixed-size top-``B`` block of
  candidates ordered by stale bound and accepts the block argmax whenever
  its exact gain strictly beats the best stale bound outside the block —
  the same certify-or-rescan structure as the streaming OMP buffer
  (DESIGN.md §4), so selections stay **index-identical** to the naive
  greedy (ties re-broken to the lowest global id, matching
  ``jnp.argmax``).  When certification fails after ``max_tries`` block
  refreshes, one full gain scan (the fused ``ops.fl_gain_argmax`` kernel)
  restores exactness and refreshes every bound.
- **Stochastic greedy** (``method="stochastic"``): the approximate tier —
  per round a seeded uniform sample of the available candidates is scored
  exactly and its argmax accepted (Mirzasoleiman et al.'s stochastic
  greedy; (1 − 1/e − ε) in expectation at sample ≈ (n/k)·ln(1/ε)).
- **Dense greedy** (``method="dense"``): the naive full-rescan
  formulation, kept as the parity oracle for the differential tests.
- **Tile-on-the-fly similarity** (``on_the_fly=True``, auto beyond
  ``_OTF_AUTO_BYTES``): every similarity access is reconstructed from the
  ``(n, d)`` gradients (``s_ij = L_max − ‖g_i − g_j‖``), so the ``(n, n)``
  matrix never materializes and CRAIG runs at pool sizes where it alone
  would be 4–16 GB.

The whole solver is one jitted program per (shape, method): a
``fori_loop`` over rounds with a bounded ``while_loop`` of block refreshes
and a ``lax.cond`` rescan fallback inside — no host round-trips.

``modular_greedy`` is the non-submodular sibling: a fixed-k greedy over a
score vector ``grads @ v`` with a caller-supplied state-advance hook,
argmax'd by the fused ``ops.corr_argmax`` kernel (GLISTER's loop).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops, ref

_NEG_INF = jnp.float32(-jnp.inf)

# Materialize the similarity below this footprint; stream it from grads
# above (n = 11585 at f32 — the 8192 bench pool stays resident, 32768+ is
# tiled on the fly).
_OTF_AUTO_BYTES = 512 << 20


def pairwise_sim(grads: jax.Array, dist_fn=None,
                 l_max: jax.Array | float | None = None) -> jax.Array:
    """Similarity  s_ij = L_max - ||g_i - g_j||  (n, n).

    ``l_max`` defaults to the max observed distance (the seed behavior);
    pass it explicitly when mixing resident and tiled/on-the-fly scans so
    both use a consistent offset (any upper bound on the pairwise
    distances is valid — ``default_l_max`` gives the cheap O(n·d) one).
    """
    if dist_fn is not None:
        d2 = dist_fn(grads, grads)
    else:
        sq = jnp.sum(grads**2, axis=-1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * (grads @ grads.T)
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    lm = jnp.max(dist) if l_max is None else jnp.asarray(l_max, jnp.float32)
    return lm - dist


def default_l_max(grads: jax.Array) -> jax.Array:
    """O(n·d) distance upper bound: the diameter bound 2·max‖g‖."""
    g = grads.astype(jnp.float32)
    return 2.0 * jnp.sqrt(jnp.max(jnp.sum(g * g, axis=1)))


def auto_on_the_fly(n: int) -> bool:
    """The engine's resident-vs-tiled default: tile the similarity on the
    fly once the (n, n) f32 matrix would exceed ``_OTF_AUTO_BYTES``.  The
    single source of truth — benchmarks read it too."""
    return n * n * 4 > _OTF_AUTO_BYTES


@functools.lru_cache(maxsize=None)
def _sim_builder(dist_fn, with_lmax: bool):
    if with_lmax:
        return jax.jit(lambda g, lm: pairwise_sim(g, dist_fn=dist_fn,
                                                  l_max=lm))
    return jax.jit(lambda g: pairwise_sim(g, dist_fn=dist_fn))


def build_sim(grads: jax.Array,
              l_max: jax.Array | float | None = None,
              dist_fn=None) -> jax.Array:
    """Jit-compiled ``pairwise_sim`` — the eager build dispatches several
    (n, n) intermediates one op at a time, which at pool 8192 costs more
    than the entire lazy greedy.  ``dist_fn`` must be a stable (module-
    level) callable: the jitted builder is cached per function."""
    g = grads.astype(jnp.float32)
    if l_max is None:
        return _sim_builder(dist_fn, False)(g)
    return _sim_builder(dist_fn, True)(g, jnp.asarray(l_max, jnp.float32))


@dataclass(frozen=True)
class GreedyStats:
    """Accounting for benchmarks and the certification tests."""
    rounds: int = 0             # accepted selections
    certified_rounds: int = 0   # rounds resolved inside the top-B block
    rescans: int = 0            # full gain scans (incl. the round-0 init)
    block_evals: int = 0        # top-B refresh iterations


class GreedyResult(NamedTuple):
    indices: jax.Array   # (k,) int32 candidate ids, -1 on unused slots
    mask: jax.Array      # (k,) bool
    gains: jax.Array     # (k,) f32 accepted marginal gain per round
    cover: jax.Array     # (n,) f32 final coverage  max_{j in S} s_ij
    stats: Optional[GreedyStats]


# ---------------------------------------------------------------------------
# shared fixed-shape pieces
# ---------------------------------------------------------------------------

def taken_mask(indices: jax.Array, mask: jax.Array, n: int) -> jax.Array:
    """(n,) bool of already-selected candidates.  Unused slots point at the
    out-of-bounds sentinel n so mode="drop" discards them (an in-bounds
    sentinel races duplicate writes when candidate n-1 is genuinely
    selected — see omp.py)."""
    return jnp.zeros((n,), bool).at[
        jnp.where(mask, indices, n)].set(mask, mode="drop")


def _lowest_id_argmax(vals: jax.Array, ids: jax.Array, sentinel: int
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """(max value, winning global id, local position), ties -> lowest id.

    The candidate vector is ordered by stale bound (not by id), so the
    plain positional argmax would not reproduce ``jnp.argmax``'s global
    lowest-index tie-breaking; re-break by id explicitly.
    """
    m = jnp.max(vals)
    pos = jnp.argmin(jnp.where(vals == m, ids, jnp.int32(sentinel)))
    return m, ids[pos], pos


def fl_rows(grads: jax.Array, sqnorms: jax.Array, row_okf: jax.Array,
            l_max: jax.Array, ids: jax.Array) -> jax.Array:
    """Similarity columns for candidate ``ids``, transposed to (B, n) —
    ``(l_max - ||g_i - g_j||) * row_ok_i`` laid out row-contiguous (the
    distance is symmetric, so candidate j's column is its row; the
    coverage-row validity lands on the fast axis).  Row B of the result is
    exactly the ``cover`` update vector for candidate ids[B]."""
    cand = grads[ids]                                      # (B, d)
    d2 = (sqnorms[ids][:, None] + sqnorms[None, :]
          - 2.0 * (cand @ grads.T))
    return (l_max - jnp.sqrt(jnp.maximum(d2, 0.0))) * row_okf[None, :]


def fl_gains_cols(cand: jax.Array, cand_sqn: jax.Array, grads: jax.Array,
                  sqnorms: jax.Array, cover: jax.Array, row_okf: jax.Array,
                  l_max: jax.Array, block: int = 256) -> jax.Array:
    """FL gains for an explicit candidate slice, blocked over coverage
    rows — the building block the pmap-sharded scan maps over column
    shards (core/distributed.py).  One shared implementation with the
    full-scan oracle (``ref.fl_gains_cols_ref``) so block-path and
    scan-path gains stay reduction-order-identical.
    """
    return ref.fl_gains_cols_ref(cand, cand_sqn, grads, sqnorms, cover,
                                 row_okf, l_max, block=block)


# ---------------------------------------------------------------------------
# facility-location greedy solvers (one jitted program each)
# ---------------------------------------------------------------------------

def _fl_gains_ids(sim, grads, sqnorms, row_okf, l_max, cover, ids,
                  otf: bool):
    """Exact gains + similarity rows (B, n) for a candidate block.

    The resident path gathers *rows* of the (symmetric, doubly-masked)
    similarity — contiguous reads, where a column gather would stride the
    whole matrix — and reduces along the fast axis; row ``b`` doubles as
    the cover-update vector for ``ids[b]``.
    """
    if otf:
        rows = fl_rows(grads, sqnorms, row_okf, l_max, ids)
    else:
        rows = sim[ids]
    return jnp.sum(jnp.maximum(rows - cover[None, :], 0.0), axis=1), rows


def _fl_gains_all(sim, grads, row_okf, l_max, cover, avail, otf: bool,
                  sqnorms=None):
    """Full exact gain scan via the fused kernel dispatch.  ``sqnorms``
    hands the engine's hoisted row norms down to the on-the-fly scan so
    the dispatch does not recompute them per rescan."""
    if otf:
        return ops.fl_gain_argmax_otf(grads, cover, row_okf > 0, avail,
                                      l_max, sqnorms=sqnorms)
    return ops.fl_gain_argmax(sim, cover, avail)


def _fl_col_of(sim, grads, sqnorms, row_okf, l_max, e, otf: bool):
    """Cover-update vector of candidate ``e`` (its similarity column ==
    its row under the symmetric doubly-masked layout)."""
    if otf:
        return fl_rows(grads, sqnorms, row_okf, l_max, e[None])[0]
    return sim[e]


@functools.partial(jax.jit, static_argnames=("k", "block", "max_tries",
                                             "otf"))
def _fl_lazy(sim, grads, valid, l_max, *, k: int, block: int,
             max_tries: int, otf: bool):
    # Escalation tier: when the top-B block cannot certify, one refresh
    # of a much wider stale-bound block usually can — at O(wide·n)
    # versus the O(n²) full rescan it replaces, which dominates
    # on-the-fly runs (pool 32768: a rescan reconstructs the whole
    # similarity from grads).  Only the truly adversarial rounds (ties,
    # mass bound decay) still pay the rescan.  The otf escalation runs
    # through the *blocked* column scan (peak O(row_block·wide), and
    # reduction-order-identical to the full rescan's gains, which share
    # the implementation); the resident escalation gathers similarity
    # rows and is kept narrower so the (wide, n) gather stays small.
    wide = min((64 if otf else 8) * block, valid.shape[0])
    n = valid.shape[0]
    row_okf = valid.astype(jnp.float32)
    if otf:
        grads = grads.astype(jnp.float32)
        sqnorms = jnp.sum(grads * grads, axis=1)
    else:
        # Invalid rows can neither be selected nor demand coverage; zero
        # both their rows AND columns so the matrix stays symmetric (the
        # block refresh gathers rows where the scan reduces columns —
        # gains of valid candidates are identical either way, and invalid
        # columns are masked out of every argmax).
        sim = (sim.astype(jnp.float32) * row_okf[:, None]
               * row_okf[None, :])
        sqnorms = None
    # Certification margin: with a resident similarity the block and scan
    # formulas reduce identically; the on-the-fly paths accumulate in a
    # different order, so inflate the outside bound past f32 reassociation
    # noise (failing closed into a rescan is exact, certifying on noise is
    # not).
    rel = jnp.float32(1e-5 if otf else 1e-6)

    def gains_ids(cover, ids):
        return _fl_gains_ids(sim, grads, sqnorms, row_okf, l_max, cover,
                             ids, otf)

    def col_of(e):
        return _fl_col_of(sim, grads, sqnorms, row_okf, l_max, e, otf)

    def body(t, carry):
        (indices, mask, cover, bounds, picked, evals, rescans,
         certified) = carry
        avail = valid & ~taken_mask(indices, mask, n)

        def round_fn(carry):
            (indices, mask, cover, bounds, picked, evals, rescans,
             certified) = carry

            def try_cond(st):
                _, tries, cert, _, _, _ = st
                return (~cert) & (tries < max_tries)

            def try_body(st):
                bounds, tries, _, _, _, _ = st
                _, bids = lax.top_k(jnp.where(avail, bounds, _NEG_INF),
                                    block)
                exact, rows = gains_ids(cover, bids)
                # Exact gains are valid bounds for *any* candidate (taken
                # ones drop to ~0, but they are masked off anyway).
                bounds = bounds.at[bids].set(exact)
                ex_m = jnp.where(avail[bids], exact, _NEG_INF)
                bmax, e, pos = _lowest_id_argmax(ex_m, bids, n)
                outside = jnp.max(jnp.where(avail, bounds,
                                            _NEG_INF).at[bids].set(
                                                _NEG_INF))
                thresh = jnp.where(jnp.isfinite(outside),
                                   outside + rel * jnp.abs(outside),
                                   outside)
                return (bounds, tries + 1, bmax > thresh, e, ex_m[pos],
                        rows[pos])

            st0 = (bounds, jnp.int32(0), jnp.bool_(False), jnp.int32(0),
                   _NEG_INF, jnp.zeros((n,), jnp.float32))
            bounds, tries, cert, e_b, g_b, col_b = lax.while_loop(
                try_cond, try_body, st0)

            def keep(_):
                return bounds, e_b, g_b, col_b, jnp.int32(0)

            def rescan_from(bounds):
                gains, idx, val = _fl_gains_all(sim, grads, row_okf,
                                                l_max, cover, avail, otf,
                                                sqnorms=sqnorms)
                return gains, idx, val, col_of(idx), jnp.int32(1)

            if wide > block:
                def fallback(_):
                    _, wids = lax.top_k(jnp.where(avail, bounds,
                                                  _NEG_INF), wide)
                    if otf:
                        exact = fl_gains_cols(
                            grads[wids], sqnorms[wids], grads, sqnorms,
                            cover, row_okf, l_max, block=1024)
                    else:
                        exact, rows_w = gains_ids(cover, wids)
                    b2 = bounds.at[wids].set(exact)
                    ex_m = jnp.where(avail[wids], exact, _NEG_INF)
                    bmax, e2, pos2 = _lowest_id_argmax(ex_m, wids, n)
                    outside = jnp.max(jnp.where(avail, b2,
                                                _NEG_INF).at[wids].set(
                                                    _NEG_INF))
                    thresh = jnp.where(jnp.isfinite(outside),
                                       outside + rel * jnp.abs(outside),
                                       outside)

                    def keep2(_):
                        col = (col_of(e2) if otf else rows_w[pos2])
                        return b2, e2, ex_m[pos2], col, jnp.int32(0)

                    return lax.cond(bmax > thresh, keep2,
                                    lambda _: rescan_from(b2),
                                    operand=None)
            else:
                def fallback(_):
                    return rescan_from(bounds)

            bounds, e, gain, col, scanned = lax.cond(cert, keep, fallback,
                                                     operand=None)
            indices = indices.at[t].set(e)
            mask = mask.at[t].set(True)
            cover = jnp.maximum(cover, col)
            picked = picked.at[t].set(gain)
            return (indices, mask, cover, bounds, picked, evals + tries,
                    rescans + scanned,
                    certified + jnp.int32(scanned == 0))

        # Exhausted pool (k > #valid): skip the whole round — no block
        # refreshes, no rescan, stats untouched (they are the published
        # certification accounting).
        return lax.cond(jnp.any(avail), round_fn, lambda c: c, carry)

    # Round 0 is a full scan by construction: it initializes every bound
    # exactly (stale +inf bounds would force max_tries wasted refreshes).
    cover0 = jnp.zeros((n,), jnp.float32)
    gains0, e0, val0 = _fl_gains_all(sim, grads, row_okf, l_max, cover0,
                                     valid, otf, sqnorms=sqnorms)
    grow0 = jnp.any(valid)
    indices = jnp.full((k,), -1, jnp.int32).at[0].set(
        jnp.where(grow0, e0, -1))
    mask = jnp.zeros((k,), bool).at[0].set(grow0)
    cover = jnp.where(grow0, jnp.maximum(cover0, col_of(e0)), cover0)
    picked = jnp.zeros((k,), jnp.float32).at[0].set(
        jnp.where(grow0, val0, 0.0))
    carry = (indices, mask, cover, gains0, picked, jnp.int32(0),
             jnp.int32(1), jnp.int32(0))
    (indices, mask, cover, _, picked, evals, rescans,
     certified) = lax.fori_loop(1, k, body, carry)
    return indices, mask, picked, cover, evals, rescans, certified


@functools.partial(jax.jit, static_argnames=("k", "sample", "otf"))
def _fl_stochastic(sim, grads, valid, l_max, key, *, k: int, sample: int,
                   otf: bool):
    n = valid.shape[0]
    row_okf = valid.astype(jnp.float32)
    if otf:
        grads = grads.astype(jnp.float32)
        sqnorms = jnp.sum(grads * grads, axis=1)
    else:
        sim = (sim.astype(jnp.float32) * row_okf[:, None]
               * row_okf[None, :])
        sqnorms = None

    def body(t, carry):
        indices, mask, cover, picked = carry
        avail = valid & ~taken_mask(indices, mask, n)

        def round_fn(carry):
            indices, mask, cover, picked = carry
            # Uniform sample without replacement over the available pool:
            # the top-s of i.i.d. uniforms masked to avail (fixed shape,
            # seeded).
            u = jax.random.uniform(jax.random.fold_in(key, t), (n,))
            _, sids = lax.top_k(jnp.where(avail, u, _NEG_INF), sample)
            exact, rows = _fl_gains_ids(sim, grads, sqnorms, row_okf,
                                        l_max, cover, sids, otf)
            ex_m = jnp.where(avail[sids], exact, _NEG_INF)
            _, e, pos = _lowest_id_argmax(ex_m, sids, n)
            indices = indices.at[t].set(e)
            mask = mask.at[t].set(True)
            cover = jnp.maximum(cover, rows[pos])
            picked = picked.at[t].set(ex_m[pos])
            return indices, mask, cover, picked

        # Exhausted pool: skip the sample eval entirely.
        return lax.cond(jnp.any(avail), round_fn, lambda c: c, carry)

    carry = (jnp.full((k,), -1, jnp.int32), jnp.zeros((k,), bool),
             jnp.zeros((n,), jnp.float32), jnp.zeros((k,), jnp.float32))
    indices, mask, cover, picked = lax.fori_loop(0, k, body, carry)
    return indices, mask, picked, cover


@functools.partial(jax.jit, static_argnames=("k",))
def _fl_dense(sim, valid, *, k: int):
    """Naive full-rescan greedy — the parity oracle (every round scores
    all n candidates exactly; nothing cached, nothing certified)."""
    n = valid.shape[0]
    sim = sim.astype(jnp.float32) * valid[:, None].astype(jnp.float32)

    def body(t, carry):
        indices, mask, cover, picked = carry
        avail = valid & ~taken_mask(indices, mask, n)
        gains = jnp.sum(jnp.maximum(sim - cover[:, None], 0.0), axis=0)
        gains = jnp.where(avail, gains, _NEG_INF)
        e = jnp.argmax(gains).astype(jnp.int32)
        grow = jnp.any(avail)
        indices = indices.at[t].set(jnp.where(grow, e, -1))
        mask = mask.at[t].set(grow)
        cover = jnp.where(grow, jnp.maximum(cover, sim[:, e]), cover)
        picked = picked.at[t].set(jnp.where(grow, gains[e], 0.0))
        return indices, mask, cover, picked

    carry = (jnp.full((k,), -1, jnp.int32), jnp.zeros((k,), bool),
             jnp.zeros((n,), jnp.float32), jnp.zeros((k,), jnp.float32))
    indices, mask, cover, picked = lax.fori_loop(0, k, body, carry)
    return indices, mask, picked, cover


def resolve_fl_scan(grads, sim, method: str,
                    l_max=None, on_the_fly: bool | None = None):
    """One place that decides how the similarity is scanned: returns
    ``(sim, l_max, on_the_fly)`` with the resident matrix built (jitted)
    when needed and the offset defaulted consistently.  ``fl_greedy`` and
    ``craig`` both consume this, so the post-selection weights/objective
    can never use a different offset than the selection did."""
    if grads is None and sim is None:
        raise ValueError("need grads or a resident sim")
    n = (sim if grads is None else grads).shape[0]
    if sim is not None or method == "dense":
        if on_the_fly:
            raise ValueError(
                "on_the_fly=True contradicts a resident similarity: the "
                "dense oracle scans a materialized sim, and a passed-in "
                "sim is already materialized — drop one or the other")
        on_the_fly = False            # the oracle scores a resident sim
    elif on_the_fly is None:
        on_the_fly = auto_on_the_fly(n)
    if on_the_fly:
        if grads is None:
            raise ValueError("on-the-fly similarity needs grads")
        lm = default_l_max(grads) if l_max is None else l_max
        sim = None
    else:
        if sim is None:
            sim = build_sim(grads, l_max=l_max)
        lm = jnp.max(sim) if l_max is None else l_max
    return sim, jnp.asarray(lm, jnp.float32), on_the_fly


def fl_greedy(
    grads: jax.Array | None = None,   # (n, d) — required when on_the_fly
    k: int = 1,
    *,
    sim: jax.Array | None = None,     # (n, n) resident similarity
    valid: jax.Array | None = None,
    l_max: jax.Array | float | None = None,
    method: str = "lazy",             # "lazy" | "stochastic" | "dense"
    block: int = 64,                  # B — lazy top-B refresh width
    max_tries: int = 6,               # block refreshes before a rescan
    sample: int = 64,                 # s — stochastic per-round sample
    key: jax.Array | None = None,     # stochastic sampling seed
    on_the_fly: bool | None = None,   # None: auto by similarity footprint
) -> GreedyResult:
    """Facility-location maximization over ``grads`` (or a resident
    ``sim``).  ``method="lazy"`` is index-identical to ``"dense"``;
    ``"stochastic"`` is the seeded approximate tier.

    A resident ``sim`` must be **symmetric** (any metric similarity is):
    the lazy/stochastic block refresh reads candidate *rows* where the
    full scan reduces columns — contiguous gathers instead of striding
    the whole matrix.

    ``l_max`` is the similarity offset; it defaults to the observed max
    distance (resident) or the ``default_l_max`` diameter bound
    (on-the-fly).  Pass it explicitly when comparing the two scans.
    """
    if grads is None and sim is None:
        raise ValueError("need grads or a resident sim")
    n = (sim if grads is None else grads).shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    sim, lm, on_the_fly = resolve_fl_scan(grads, sim, method, l_max=l_max,
                                          on_the_fly=on_the_fly)
    k = int(k)

    if method == "dense":
        indices, mask, picked, cover = _fl_dense(sim, valid, k=k)
        stats = GreedyStats(rounds=int(jnp.sum(mask)),
                            rescans=int(jnp.sum(mask)))
    elif method == "stochastic":
        if key is None:
            key = jax.random.PRNGKey(0)
        indices, mask, picked, cover = _fl_stochastic(
            sim, grads, valid, lm, key, k=k, sample=min(int(sample), n),
            otf=on_the_fly)
        stats = GreedyStats(rounds=int(jnp.sum(mask)))
    elif method == "lazy":
        indices, mask, picked, cover, evals, rescans, certified = _fl_lazy(
            sim, grads, valid, lm, k=k, block=min(int(block), n),
            max_tries=int(max_tries), otf=on_the_fly)
        stats = GreedyStats(rounds=int(jnp.sum(mask)),
                            certified_rounds=int(certified),
                            rescans=int(rescans), block_evals=int(evals))
    else:
        raise ValueError(f"unknown greedy method {method!r}")
    return GreedyResult(indices, mask, picked, cover, stats)


# ---------------------------------------------------------------------------
# modular greedy (GLISTER): argmax of grads @ v with a state-advance hook
# ---------------------------------------------------------------------------

def modular_greedy(
    grads: jax.Array,                 # (n, d)
    k: int,
    advance: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
    v0: jax.Array,                    # (d,) initial score state
    valid: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fixed-k greedy over the score hook ``scores_t = grads @ v_t``.

    ``advance(v, e, t)`` produces the next score state after accepting
    candidate ``e`` in round ``t``.  The per-round masked argmax runs
    through the fused ``ops.corr_argmax`` kernel (scores never hit HBM on
    TPU); rows exhaust gracefully when k >= #valid (mask False, index -1).
    Returns (indices (k,), mask (k,), picked scores (k,)).
    """
    n = grads.shape[0]
    g = grads.astype(jnp.float32)
    if valid is None:
        valid = jnp.ones((n,), bool)
    zeros = jnp.zeros((n,), jnp.float32)

    def body(t, carry):
        indices, mask, v, picked = carry
        avail = valid & ~taken_mask(indices, mask, n)
        # scores = g @ v  ==  zeros - g @ (-v): the corr_argmax contract.
        e, val = ops.corr_argmax(g, -v, zeros, avail)
        grow = jnp.any(avail)
        indices = indices.at[t].set(jnp.where(grow, e, -1))
        mask = mask.at[t].set(grow)
        v = jnp.where(grow, advance(v, e, t), v)
        picked = picked.at[t].set(jnp.where(grow, val, 0.0))
        return indices, mask, v, picked

    carry = (jnp.full((k,), -1, jnp.int32), jnp.zeros((k,), bool),
             v0.astype(jnp.float32), jnp.zeros((k,), jnp.float32))
    indices, mask, _, picked = lax.fori_loop(0, int(k), body, carry)
    return indices, mask, picked
