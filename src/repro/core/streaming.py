"""Streaming block-OMP selection over out-of-core candidate pools.

``omp_select`` (core/omp.py) holds the whole ``(n, d)`` proxy pool in
memory and touches it every round.  This module selects from pools that do
NOT fit: the pool is consumed through a re-iterable *chunk factory* (a
callable returning a fresh iterator of ``(chunk, valid)`` pairs in a fixed
order — e.g. ``array_chunks`` over an ``np.memmap``, or a per-chunk proxy
extractor, see ``data/loader.ChunkedPool`` + ``core/proxies``), so peak
pool-dependent memory is ``O(chunk + M·d)`` for a top-``M`` candidate
buffer — independent of the pool size ``n``.  (The active-set state is
``O(k·d + k²)``, exactly as in-memory OMP.)

The solver is *certified-exact*: it selects the identical subset the
in-memory incremental solver would (the differential tests in
``tests/test_omp_parity.py`` assert index-exact parity, with the dense
solver as the common oracle).  Per **pass** over the pool:

  1. every chunk is scored against the carried residual (``ops.corr``) and
     reduced to its top-``m`` candidates (values, global ids, rows);
  2. chunk buffers are merged into a global top-``M`` buffer ordered by
     ``(score desc, id asc)`` — ties resolve to the lowest global index,
     matching ``jnp.argmax`` semantics of the in-memory solver;
  3. incremental-Gram OMP rounds run over the buffer (scored by the fused
     ``ops.corr_argmax`` kernel) for as long as a screening bound proves
     the buffer argmax is the *global* argmax:  every row outside the
     buffer had pass-score ≤ T (the buffer's admission threshold), so its
     score against the drifted residual ``r`` is at most
     ``T + gmax·‖r − r0‖`` (Cauchy-Schwarz, ``gmax`` = max row norm).  The
     first round of a pass has ``r == r0`` and is always exact.  When the
     bound fails, the pass ends and the pool is rescanned against the new
     residual.

Worst case (adversarial residual drift) is one selection per pass —
``O(n·d)`` scoring flops per round, the same as the in-memory solver's
narrow regime, paid through chunked streaming reads instead of a resident
pool.  Structured pools (M ≥ #competitive candidates, duplicate-heavy
pools, ``k ≥ n`` tails) certify many rounds per pass.

The NNLS re-solve consumes the same cached Gram / Gershgorin / target-
correlation buffers as ``omp.OMPIncState``, sliced to the identical
``block``-quantized prefix widths, so weights match the in-memory solver
to f32 tolerance.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Iterator, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.omp import _nnls_active_cached
from repro.kernels import ops

_NEG_INF = jnp.float32(-jnp.inf)
_BIG_ID = jnp.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# chunk protocol
# ---------------------------------------------------------------------------

def array_chunks(pool, chunk_size: int, valid=None) -> Callable[[], Iterator]:
    """Chunk factory over an ``(n, d)`` array (in-memory or ``np.memmap``).

    Each call returns a fresh iterator of ``(chunk, valid_chunk)`` in the
    same deterministic order — streaming selection makes several passes.
    Rows are only touched one chunk at a time, so a memory-mapped pool is
    never materialized.
    """
    n = pool.shape[0]
    cs = int(chunk_size)

    def chunks():
        for lo in range(0, n, cs):
            hi = min(lo + cs, n)
            yield pool[lo:hi], (None if valid is None else valid[lo:hi])

    return chunks


def chunked_pool_iter(pool, valid=None) -> Callable[[], Iterator]:
    """Adapt a ``data.loader.ChunkedPool`` to the ``(chunk, valid)``
    protocol ``omp_select_streaming`` consumes.

    ``pool.chunks()`` yields ``(x, y, offset)``; the labels are dropped
    (proxy pools registered with the serve layer are already gradient
    proxies — raw-data pools go through ``proxies.proxy_chunk_stream``
    instead).  ``valid`` is an optional full-length (n,) mask sliced per
    chunk by the offsets the pool reports.
    """

    def chunks():
        for x, _, lo in pool.chunks():
            c = x.shape[0]
            yield x, (None if valid is None else valid[lo:lo + c])

    return chunks


def streaming_target(pool_iter: Callable[[], Iterator]):
    """One pass: ``(sum of valid rows, total row count)`` — eq. (2) target."""
    total = None
    n = 0
    for chunk, v in pool_iter():
        c = jnp.asarray(chunk, jnp.float32)
        if v is not None:
            c = c * jnp.asarray(v)[:, None].astype(jnp.float32)
        s = jnp.sum(c, axis=0)
        total = s if total is None else total + s
        n += chunk.shape[0]
    if total is None:
        raise ValueError("empty pool iterator")
    return total, n


def _bucket(c: int) -> int:
    """Pad chunk length to the next power of two (bounds jit variants)."""
    p = 8
    while p < c:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# jitted pieces (module-level so the jit cache persists across calls)
# ---------------------------------------------------------------------------

def _score_chunk_impl(chunk, pool_ok, gids, offset, residual, sel_idx,
                      sel_mask, m: int, absolute: bool,
                      need_norms: bool = True):
    """Top-``m`` of one chunk against the carried residual.

    Returns (vals (m,), ids (m,), rows (m, d), ok (m,), cmax (), cthresh ())
    where ``cthresh`` upper-bounds the pass-score of every row this chunk
    *dropped* (−inf when nothing real could have been dropped) and ``cmax``
    is the max row norm — both feed the certification bound.  ``gmax`` is
    frozen after the first pass, so later passes skip the norm reduction
    (``need_norms=False`` returns 0 — the pool is static across passes).
    """
    c = chunk.shape[0]
    scores = ops.corr(chunk, residual)                       # (c,)
    s = jnp.abs(scores) if absolute else scores
    # Chunk rows cover the contiguous id range [offset, offset+c), so the
    # taken mask is an O(k) scatter, not an O(c*k) compare.  Slots owned by
    # other chunks (or unused) point at the out-of-bounds sentinel c and
    # are dropped — an in-bounds sentinel would race duplicate writes.
    local = sel_idx - offset
    inb = sel_mask & (local >= 0) & (local < c)
    taken = jnp.zeros((c,), bool).at[
        jnp.where(inb, local, c)].set(inb, mode="drop")
    avail = pool_ok & ~taken
    s_sel = jnp.where(avail, s, _NEG_INF)
    vals, pos = lax.top_k(s_sel, m)                          # ties: low pos
    if need_norms:
        norms = jnp.sqrt(jnp.sum(chunk * chunk, axis=1))
        cmax = jnp.max(jnp.where(pool_ok, norms, 0.0))
    else:
        cmax = jnp.float32(0.0)
    cthresh = vals[m - 1] if chunk.shape[0] > m else _NEG_INF
    return vals, gids[pos], chunk[pos], pool_ok[pos], cmax, cthresh


_score_chunk = functools.partial(
    jax.jit, static_argnames=("m", "absolute", "need_norms"))(
        _score_chunk_impl)


@functools.partial(jax.jit, static_argnames=("size",))
def _merge_topm(bv, bi, br, bok, cv, ci, cr, cok, size: int):
    """Merge two candidate buffers, keep top-``size`` by (score desc, id asc).

    The explicit lexicographic order (padding ids last) is what makes the
    buffer argmax reproduce ``jnp.argmax`` lowest-index tie-breaking
    globally.
    """
    vals = jnp.concatenate([bv, cv])
    ids = jnp.concatenate([bi, ci])
    rows = jnp.concatenate([br, cr])
    ok = jnp.concatenate([bok, cok])
    id_order = jnp.where(ids >= 0, ids, _BIG_ID)
    order = jnp.lexsort((id_order, -vals))[:size]
    return vals[order], ids[order], rows[order], ok[order]


@functools.partial(jax.jit, static_argnames=("absolute",))
def _buffer_argmax(buf_rows, buf_ids, buf_ok, sel_idx, sel_mask, residual,
                   absolute: bool):
    """Fused score-and-argmax over the buffer (current residual).

    The buffer is ordered by *pass-scan* score, so the kernel's
    lowest-position tie-break is not lowest-global-id under a drifted
    residual; exact ties are re-broken by id to match ``jnp.argmax`` over
    the full pool (the all-masked degenerate resolves to the lowest id
    too, mirroring the in-memory argmax-of-all--inf picking index 0).
    """
    taken = jnp.any(
        (buf_ids[:, None] == sel_idx[None, :]) & sel_mask[None, :], axis=1)
    avail = buf_ok & ~taken
    zeros = jnp.zeros((buf_rows.shape[0],), jnp.float32)
    pos0, maxv = ops.corr_argmax(buf_rows, -residual, zeros, avail,
                                 absolute=absolute)
    s = ops.corr(buf_rows, residual)
    s = jnp.abs(s) if absolute else s
    tie = jnp.where(avail, s, _NEG_INF) == maxv
    cand = jnp.where(tie, jnp.where(buf_ids >= 0, buf_ids, _BIG_ID),
                     _BIG_ID)
    # If a backend's corr/corr_argmax accumulations disagree at the last
    # bit, no tie matches — fall back to the kernel's own argmax.
    pos = jnp.where(jnp.any(tie), jnp.argmin(cand), pos0)
    return pos, buf_ids[pos], maxv


@functools.partial(jax.jit, static_argnames=("p", "nnls_iters"))
def _apply_selection(t, pos, buf_rows, indices, mask, rows, gram, absrow,
                     tcorr, target, e, lam, p: int, nnls_iters: int):
    """Grow the incremental-Gram state by slot ``t`` and re-solve weights.

    Identical update to ``omp._omp_select_incremental``'s body, operating
    on the ``[:p]`` prefix of full ``(k,)``-shaped buffers (``p`` follows
    the same block-quantized growth schedule, so the NNLS sees bit-equal
    inputs and the same d-vs-p factor choice).
    """
    g_e = buf_rows[pos]
    indices = indices.at[t].set(e)
    mask = mask.at[t].set(True)
    rows = rows.at[t].set(g_e)
    mask_p = mask[:p]
    row_vals = jnp.where(mask_p, rows[:p] @ g_e, 0.0)
    gram = gram.at[t, :p].set(row_vals).at[:p, t].set(row_vals)
    ar = jnp.where(mask_p, absrow[:p] + jnp.abs(row_vals), 0.0)
    ar = ar.at[t].set(jnp.sum(jnp.abs(row_vals)))
    absrow = absrow.at[:p].set(ar)
    tcorr = tcorr.at[t].set(jnp.dot(g_e, target))
    w_p = _nnls_active_cached(gram[:p, :p], absrow[:p], rows[:p], tcorr[:p],
                              mask_p, lam, nnls_iters)
    weights = jnp.zeros((indices.shape[0],), jnp.float32).at[:p].set(w_p)
    residual = target - w_p @ rows[:p]
    err = jnp.sum(residual**2) + lam * jnp.sum(w_p**2)
    return indices, mask, weights, rows, gram, absrow, tcorr, residual, err


# ---------------------------------------------------------------------------
# the streaming solver
# ---------------------------------------------------------------------------

@dataclass
class StreamStats:
    """Pass/round accounting for benchmarks and the harness tests."""
    passes: int = 0
    rounds: int = 0
    certified_rounds: int = 0   # rounds certified with a drifted residual
    chunks: int = 0
    pool_size: int = 0


class StreamingOMPResult(NamedTuple):
    indices: jax.Array   # (k,) int32, -1 on unused slots
    weights: jax.Array   # (k,) f32
    mask: jax.Array      # (k,) bool
    err: jax.Array       # () f32
    stats: StreamStats


def omp_select_streaming(
    pool_iter: Callable[[], Iterator],   # factory of (chunk, valid) iters
    target,                              # (d,) target gradient
    k: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nnls_iters: int = 50,
    positive: bool = True,
    buffer_size: int = 256,              # M — carried top-M candidate buffer
    chunk_topm: Optional[int] = None,    # m per chunk (default: M)
    block: int = 128,                    # NNLS prefix growth (parity w/ omp)
    max_passes: Optional[int] = None,
    score_chunk_fn=None,                 # hook: distributed.pmap_chunk_topm
) -> StreamingOMPResult:
    """OMP over a chunked pool; exact parity with ``omp_select``.

    ``pool_iter()`` must yield the same chunks in the same order on every
    call (the solver rescans when certification fails).  ``score_chunk_fn``
    overrides the local chunk scorer with the same signature/returns as
    ``_score_chunk`` — ``core.distributed.pmap_chunk_topm`` scores chunks
    shard-parallel across local devices.
    """
    target = jnp.asarray(target, jnp.float32)
    d = target.shape[0]
    k = int(k)
    m_cfg = int(chunk_topm) if chunk_topm is not None else int(buffer_size)
    big_m = int(buffer_size)
    absolute = not positive
    scorer = score_chunk_fn if score_chunk_fn is not None else _score_chunk

    indices = jnp.full((k,), -1, jnp.int32)
    mask = jnp.zeros((k,), bool)
    weights = jnp.zeros((k,), jnp.float32)
    rows = jnp.zeros((k, d), jnp.float32)
    gram = jnp.zeros((k, k), jnp.float32)
    absrow = jnp.zeros((k,), jnp.float32)
    tcorr = jnp.zeros((k,), jnp.float32)
    residual = target
    err = float(jnp.sum(target**2))
    lam_f = jnp.float32(lam)

    stats = StreamStats()
    gmax = None
    cap = int(max_passes) if max_passes is not None else k + 2
    t = 0
    while t < k and err > eps:
        if stats.passes >= cap:
            raise RuntimeError(
                f"streaming OMP exceeded {cap} passes — is the pool "
                "iterator stable across passes?")
        # ---- scan pass: chunked top-m, merged into the top-M buffer ------
        bv = jnp.full((big_m,), -jnp.inf, jnp.float32)
        bi = jnp.full((big_m,), -1, jnp.int32)
        br = jnp.zeros((big_m, d), jnp.float32)
        bok = jnp.zeros((big_m,), bool)
        # Device-scalar accumulators: no host sync inside the chunk loop.
        thresh_d = jnp.float32(-jnp.inf)
        gmax_d = jnp.float32(0.0)
        offset = 0
        for chunk, cvalid in pool_iter():
            c = int(chunk.shape[0])
            cpad = _bucket(c)
            ch = jnp.asarray(chunk, jnp.float32)
            pos_in = jnp.arange(cpad, dtype=jnp.int32)
            if cpad != c:
                ch = jnp.pad(ch, ((0, cpad - c), (0, 0)))
            ok = pos_in < c
            if cvalid is not None:
                ok = ok & jnp.pad(jnp.asarray(cvalid, bool),
                                  (0, cpad - c))
            gids = jnp.where(pos_in < c, offset + pos_in, -1)
            m_eff = min(m_cfg, cpad, big_m)
            vals, ids, rws, rok, cmax, cthresh = scorer(
                ch, ok, gids, jnp.int32(offset), residual, indices, mask,
                m=m_eff, absolute=absolute, need_norms=gmax is None)
            bv, bi, br, bok = _merge_topm(bv, bi, br, bok, vals, ids, rws,
                                          rok, size=big_m)
            thresh_d = jnp.maximum(thresh_d, cthresh)
            gmax_d = jnp.maximum(gmax_d, cmax)
            offset += c
            stats.chunks += 1
        if offset == 0:
            break
        stats.pool_size = offset
        if gmax is None:
            gmax = float(gmax_d)
        # Rows dropped at the merge are bounded by the buffer's min value
        # (−inf while the buffer is not full, i.e. nothing real dropped).
        thresh = float(jnp.maximum(thresh_d, bv[big_m - 1]))
        r0 = residual
        # ---- certified rounds over the buffer ----------------------------
        first = True
        while t < k and err > eps:
            pos, e, maxv = _buffer_argmax(br, bi, bok, indices, mask,
                                          residual, absolute=absolute)
            if not first:
                drift = float(jnp.linalg.norm(residual - r0))
                # Cauchy-Schwarz screening: any out-of-buffer row scores at
                # most thresh + gmax*drift (small inflation absorbs f32
                # rounding in the bound itself, on the safe side).
                if not float(maxv) > thresh + gmax * drift * (1 + 1e-6):
                    break
                stats.certified_rounds += 1
            p = min(k, block * (t // block + 1))
            (indices, mask, weights, rows, gram, absrow, tcorr, residual,
             err_t) = _apply_selection(
                jnp.int32(t), pos, br, indices, mask, rows, gram, absrow,
                tcorr, target, e, lam_f, p=p, nnls_iters=nnls_iters)
            err = float(err_t)
            t += 1
            stats.rounds += 1
            first = False
        stats.passes += 1

    return StreamingOMPResult(indices, weights, mask, jnp.float32(err),
                              stats)


# ---------------------------------------------------------------------------
# GRAD-MATCH wrappers
# ---------------------------------------------------------------------------

def gradmatch_streaming(
    pool_iter: Callable[[], Iterator],
    k: int,
    target=None,
    lam: float = 0.5,
    eps: float = 1e-10,
    buffer_size: int = 256,
    chunk_topm: Optional[int] = None,
    score_chunk_fn=None,
) -> SelectionResult:
    """GRAD-MATCH over a chunked pool; target defaults to one summing pass."""
    if target is None:
        target, _ = streaming_target(pool_iter)
    out = omp_select_streaming(
        pool_iter, target, k, lam=lam, eps=eps, buffer_size=buffer_size,
        chunk_topm=chunk_topm, score_chunk_fn=score_chunk_fn)
    return SelectionResult(out.indices, _normalize(out.weights, out.mask),
                           out.mask, out.err)


def gradmatch_streaming_array(
    proxies,                 # (n, d) array (in-memory or memmap)
    k: int,
    target=None,
    valid=None,
    lam: float = 0.5,
    eps: float = 1e-10,
    chunk_size: int = 2048,
    buffer_size: int = 256,
    score_chunk_fn=None,
) -> SelectionResult:
    """Streaming GRAD-MATCH over an explicit array, chunked on the fly.

    The target matches ``gradmatch``'s (full-matrix sum) so the two paths
    agree bit-for-bit on the pools the in-memory solver can hold.
    """
    if target is None:
        g = jnp.asarray(proxies, jnp.float32)
        if valid is None:
            target = jnp.sum(g, axis=0)
        else:
            target = jnp.sum(g * jnp.asarray(valid)[:, None].astype(g.dtype),
                             axis=0)
    out = omp_select_streaming(
        array_chunks(proxies, chunk_size, valid=valid), target, k, lam=lam,
        eps=eps, buffer_size=buffer_size, score_chunk_fn=score_chunk_fn)
    return SelectionResult(out.indices, _normalize(out.weights, out.mask),
                           out.mask, out.err)
