"""Streaming block-OMP selection over out-of-core candidate pools.

``omp_select`` (core/omp.py) holds the whole ``(n, d)`` proxy pool in
memory and touches it every round.  This module selects from pools that do
NOT fit: the pool is consumed through a re-iterable *chunk factory* (a
callable returning a fresh iterator of ``(chunk, valid)`` pairs in a fixed
order — e.g. ``array_chunks`` over an ``np.memmap``, or a per-chunk proxy
extractor, see ``data/loader.ChunkedPool`` + ``core/proxies``), so peak
pool-dependent memory is ``O(chunk + M·d + cache_bytes)`` for a top-``M``
candidate buffer plus a *compressed chunk cache* — independent of the pool
size ``n``.  (The active-set state is ``O(k·d + k²)``, exactly as
in-memory OMP.)

The solver is *certified-exact*: it selects the identical subset the
in-memory incremental solver would (the differential tests in
``tests/test_omp_parity.py`` assert index-exact parity, with the dense
solver as the common oracle).  The engine is **multi-round-per-pass**
(DESIGN.md §7): each loader pass refreshes a top-``M`` exact-row buffer
*and* the compressed cache, then commits ``B >= 1`` certified OMP rounds
against the buffer before touching the loader again.  A round is
certified when the buffer's best in-buffer score provably beats every
out-of-buffer candidate, established by a ladder of bounds (cheapest
first, each fail-closed into the next):

  1. **Residual-projection sketch** (per chunk, O(C)): every out-of-
     buffer row of chunk ``c`` had pass-score ``g·r0 <= T_c`` (the
     chunk/merge admission threshold).  Decomposing the drifted residual
     ``r = α·r0 + r_perp`` gives ``g·r <= α·T_c + ‖g‖·‖r_perp‖`` (α >= 0
     case), bounded per chunk by its max valid row norm — strictly
     tighter than the plain Cauchy–Schwarz ``T + gmax·‖r − r0‖`` bound
     because only the *orthogonal* drift pays the norm product.
  2. **Compressed-cache interval bound** (per row, O(n·d) in-memory
     bf16): cached chunks are re-scored from their bf16 rows in f32
     accumulation; ``u_i = s̃_i + (e_i + acc·‖g_i‖)·‖r‖`` upper-bounds
     the exact f32 score, where ``e_i = ‖g_i − bf16(g_i)‖`` is the
     *measured* compression error stored in the f32 sidecar (typically
     ~2^-9.5·‖g_i‖, versus the worst-case 2^-8 bound — which is what
     keeps the interval tight enough to fire).  If no available
     out-of-buffer row's ``u_i`` reaches the buffer max, the round is
     certified.  Ties fail closed, exactly like the lazy greedy tier
     (DESIGN.md §5).
  3. **Exact-row repair** (optional, needs ``row_fetch``): when only a
     few cached rows' intervals overlap the buffer max, their *exact*
     f32 rows are fetched by id and admitted into a bounded repair annex
     of the buffer; the re-run argmax is then exact by construction.
  4. **Rescan**: otherwise the buffer is refreshed — from the cache when
     it covers the whole pool and ``row_fetch`` exists (an interval scan
     picks every possible top-``M`` member, their exact rows are
     fetched: no loader traffic), else by a full loader pass.

Worst case (no cache, adversarial residual drift) is one selection per
pass — ``O(n·d)`` scoring flops per round, the same as the in-memory
solver's narrow regime, paid through chunked streaming reads.  With the
cache resident the loader is touched ~once: rescans hit memory instead
of the loader, which is what makes the streaming tier's overhead vs the
in-memory solver a small constant (the parity gate enforces <= 5x at
pool 8192 with ``passes <= k/8 + 2``).

The NNLS re-solve consumes the same cached Gram / Gershgorin / target-
correlation buffers as ``omp.OMPIncState``, sliced to the identical
``block``-quantized prefix widths, so weights match the in-memory solver
to f32 tolerance.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Iterator, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.checkpoint.solver_state import (load_solver_state,
                                           save_solver_state)
from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.omp import _nnls_active_cached
from repro.kernels import ops
from repro.resilience.faults import CorruptChunkError
from repro.resilience.recovery import RetryPolicy, with_retries

_NEG_INF = jnp.float32(-jnp.inf)
_BIG_ID = jnp.int32(2**31 - 1)

# Soundness margin for scoring a bf16-compressed row in f32 accumulation
# against the exact f32 row.  The compression error is *measured*, not
# bounded: the cache stores ‖g − bf16(g)‖ per row (f32 sidecar), so by
# Cauchy–Schwarz |s̃ − s| <= e_i·‖r‖ plus the accumulation-order term —
# two different f32 summation orders differ by <= d·2^-23 relative to
# ‖g‖·‖r‖; the 1.25 factor absorbs second-order terms.  The measured
# e_i is typically ~2^-9.5·‖g‖ (RMS of half-ulp rounding) versus the
# worst-case 2^-8·‖g‖ a bound-only margin would have to assume, which is
# what keeps false interval overlaps — and therefore repair fetches —
# rare.  See DESIGN.md §7 for the derivation and when the bf16 cache is
# bit-safe outright.
DEFAULT_CACHE_BYTES = 256 << 20


def _acc_margin(d: int) -> float:
    return float(d * 2.0 ** -23 * 1.25)


# ---------------------------------------------------------------------------
# chunk protocol
# ---------------------------------------------------------------------------

def array_chunks(pool, chunk_size: int, valid=None) -> Callable[[], Iterator]:
    """Chunk factory over an ``(n, d)`` array (in-memory or ``np.memmap``).

    Each call returns a fresh iterator of ``(chunk, valid_chunk)`` in the
    same deterministic order — streaming selection makes several passes.
    Rows are only touched one chunk at a time, so a memory-mapped pool is
    never materialized.
    """
    n = pool.shape[0]
    cs = int(chunk_size)

    def chunks():
        for lo in range(0, n, cs):
            hi = min(lo + cs, n)
            yield pool[lo:hi], (None if valid is None else valid[lo:hi])

    return chunks


def array_row_fetch(pool) -> Callable:
    """Exact-row fetch capability for an array-backed pool: the repair
    and cache-refill tiers gather a handful of rows by global id instead
    of paying a loader pass.  Must return the same f32 rows the chunk
    factory yields (here: a plain gather)."""

    def fetch(ids):
        return np.asarray(pool[np.asarray(ids)], np.float32)

    return fetch


def chunked_pool_iter(pool, valid=None) -> Callable[[], Iterator]:
    """Adapt a ``data.loader.ChunkedPool`` to the ``(chunk, valid)``
    protocol ``omp_select_streaming`` consumes.

    ``pool.chunks()`` yields ``(x, y, offset)``; the labels are dropped
    (proxy pools registered with the serve layer are already gradient
    proxies — raw-data pools go through ``proxies.proxy_chunk_stream``
    instead).  ``valid`` is an optional full-length (n,) mask sliced per
    chunk by the offsets the pool reports.
    """

    def chunks():
        for x, _, lo in pool.chunks():
            c = x.shape[0]
            yield x, (None if valid is None else valid[lo:lo + c])

    return chunks


def subrange_chunks(pool_iter: Callable[[], Iterator], lo: int,
                    hi: int) -> Callable[[], Iterator]:
    """Clip a chunk factory to the global row range ``[lo, hi)``.

    The partition solver's per-partition view of a shared loader: chunk
    boundaries need not align with the range — straddling chunks are
    sliced — and a fresh iterator walks the same sub-chunks in the same
    order on every call (the streaming engine's determinism contract),
    because the parent factory's order is deterministic and the clipping
    is pure arithmetic on its offsets.  Row ids inside the view are
    partition-local; add ``lo`` to map a pick back to a global id.
    """
    lo, hi = int(lo), int(hi)

    def chunks():
        off = 0
        for chunk, v in pool_iter():
            c = chunk.shape[0]
            if off + c > lo:
                s = max(lo - off, 0)
                e = min(hi - off, c)
                if s < e:
                    yield chunk[s:e], (None if v is None else v[s:e])
            off += c
            if off >= hi:
                break

    return chunks


def offset_row_fetch(row_fetch: Callable, lo: int) -> Callable:
    """Shift an exact-row fetcher into a ``subrange_chunks`` view: local
    id ``i`` fetches global row ``lo + i``."""
    lo = int(lo)

    def fetch(ids):
        return row_fetch(np.asarray(ids, np.int64) + lo)

    return fetch


def streaming_target(pool_iter: Callable[[], Iterator],
                     cache: "ChunkCache | None" = None,
                     retry: "RetryPolicy | None" = None):
    """One pass: ``(sum of valid rows, total row count)`` — eq. (2) target.

    When a ``cache`` is given the same pass also warms the compressed
    chunk cache (the serve registry's admission pass doubles as the cache
    fill, so the first request's rescans already hit memory).  With a
    ``retry`` policy, transient iterator faults restart the pass (the
    summing accumulators are pass-local and ``cache.offer`` is idempotent
    for resident chunks, so a restart is exact).
    """

    def scan():
        total = None
        n = 0
        idx = 0
        for chunk, v in pool_iter():
            c = jnp.asarray(chunk, jnp.float32)
            if v is not None:
                c = c * jnp.asarray(v)[:, None].astype(jnp.float32)
            s = jnp.sum(c, axis=0)
            total = s if total is None else total + s
            offer_chunk(cache, idx, n, chunk, v)
            n += chunk.shape[0]
            idx += 1
        return total, n, idx

    if retry is None:
        total, n, idx = scan()
    else:
        total, n, idx = with_retries(scan, retry)
    if total is None:
        raise ValueError("empty pool iterator")
    if cache is not None and cache.covers(idx):
        cache.complete = idx
    return total, n


def _bucket(c: int) -> int:
    """Pad chunk length to the next power of two (bounds jit variants)."""
    p = 8
    while p < c:
        p *= 2
    return p


def offer_chunk(cache: "ChunkCache | None", idx: int, offset: int,
                chunk, v) -> None:
    """Offer one ``(chunk, valid)`` pair to the compressed cache: pad the
    chunk to its power-of-two bucket, build the ok-mask and global row
    ids for rows ``[offset, offset + len(chunk))``, and hand it to
    ``cache.offer``.  The warming-pass body, shared by the one-shot
    ``streaming_target`` scan and the registry's incremental
    (deferred-warm) admission so the two can never drift."""
    if cache is None:
        return
    c = chunk.shape[0]
    cpad = _bucket(c)
    ch = jnp.asarray(chunk, jnp.float32)
    if cpad != c:
        ch = jnp.pad(ch, ((0, cpad - c), (0, 0)))
    ok = jnp.arange(cpad) < c
    if v is not None:
        ok = ok & jnp.pad(jnp.asarray(v, bool), (0, cpad - c))
    gids = jnp.where(jnp.arange(cpad) < c,
                     offset + jnp.arange(cpad, dtype=jnp.int32), -1)
    cache.offer(idx, offset, c, ch, ok, gids)


# ---------------------------------------------------------------------------
# compressed chunk cache (bf16 rows + f32 row-norm sidecar, LRU-bounded)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def _compress_chunk(ch, ok):
    """bf16 rows + f32 sidecars: the exact row norm and the *measured*
    compression-error norm ‖g − bf16(g)‖ (both computed against the
    pre-rounding rows — they are what make the interval bound sound AND
    tight; a worst-case 2^-8 relative margin would be ~3-4x looser)."""
    norms = jnp.sqrt(jnp.sum(ch * ch, axis=1))
    rows_bf = ch.astype(jnp.bfloat16)
    diff = ch - rows_bf.astype(jnp.float32)
    errn = jnp.sqrt(jnp.sum(diff * diff, axis=1))
    return rows_bf, jnp.where(ok, norms, 0.0), jnp.where(ok, errn, 0.0)


@jax.jit
def _arena_write(rows_a, norms_a, errn_a, gids_a, ok_a, rows_c, norms_c,
                 errn_c, gids_c, ok_c, lo):
    rows_a = lax.dynamic_update_slice(rows_a, rows_c, (lo, 0))
    norms_a = lax.dynamic_update_slice(norms_a, norms_c, (lo,))
    errn_a = lax.dynamic_update_slice(errn_a, errn_c, (lo,))
    gids_a = lax.dynamic_update_slice(gids_a, gids_c, (lo,))
    ok_a = lax.dynamic_update_slice(ok_a, ok_c, (lo,))
    return rows_a, norms_a, errn_a, gids_a, ok_a


class ChunkCache:
    """Compressed chunk cache: one flat bf16 row arena with f32 norm /
    global-id / validity sidecars, slotted per chunk, LRU-evicted to stay
    under ``cache_bytes``.

    The cache is keyed by chunk position in the (stable) iteration order
    and is safe to share across solves over the same pool (the serve
    registry admits it once and every request reuses it) — per-solve
    state (taken / in-buffer masks) lives in the solver, not here.
    """

    def __init__(self, cache_bytes: int, d: int):
        self.cache_bytes = int(cache_bytes)
        self.d = int(d)
        # bf16 row + f32 norm + f32 error norm + i32 gid + bool ok (+
        # the solver's two per-solve masks, counted so the budget is
        # honest).
        self.bytes_per_row = 2 * d + 4 + 4 + 4 + 3
        self.cap_rows_budget = max(self.cache_bytes // self.bytes_per_row, 0)
        self.slot_rows = 0            # fixed once the first chunk arrives
        self.cap_slots = 0
        self.rows = None              # (cap_rows, d) bf16
        self.norms = None             # (cap_rows,) f32 exact row norms
        self.errn = None              # (cap_rows,) f32 ‖g − bf16(g)‖
        self.gids = None              # (cap_rows,) i32
        self.ok = None                # (cap_rows,) bool
        # chunk_idx -> (slot, offset, length); insertion-recency ordered.
        self.entries: dict[int, tuple[int, int, int]] = {}
        self._lru: list[int] = []
        self.insertions = 0
        self.evictions = 0
        # Set by a full warming pass (streaming_target): the pool's total
        # chunk count.  A solver handed a cache that still covers all
        # `complete` chunks can bootstrap straight from it — zero loader
        # passes (the serve registry's admission pass is the only scan
        # the pool ever sees).
        self.complete = 0

    @property
    def cap_rows(self) -> int:
        return 0 if self.rows is None else self.rows.shape[0]

    def slot_of(self, chunk_idx: int) -> int | None:
        e = self.entries.get(chunk_idx)
        return None if e is None else e[0]

    def _touch(self, chunk_idx: int) -> None:
        self._lru.remove(chunk_idx)
        self._lru.append(chunk_idx)

    def _grow_to(self, slots: int) -> None:
        rows_new = slots * self.slot_rows
        pad = rows_new - self.cap_rows
        if pad <= 0:
            return
        if self.rows is None:
            self.rows = jnp.zeros((rows_new, self.d), jnp.bfloat16)
            self.norms = jnp.zeros((rows_new,), jnp.float32)
            self.errn = jnp.zeros((rows_new,), jnp.float32)
            self.gids = jnp.full((rows_new,), -1, jnp.int32)
            self.ok = jnp.zeros((rows_new,), bool)
        else:
            self.rows = jnp.pad(self.rows, ((0, pad), (0, 0)))
            self.norms = jnp.pad(self.norms, (0, pad))
            self.errn = jnp.pad(self.errn, (0, pad))
            self.gids = jnp.pad(self.gids, (0, pad), constant_values=-1)
            self.ok = jnp.pad(self.ok, (0, pad))

    def offer(self, chunk_idx: int, offset: int, length: int, ch, ok,
              gids) -> bool:
        """Present one (padded f32) chunk; returns True when its rows are
        resident after the call.  A resident chunk is only LRU-touched
        (its content is static across passes); a new chunk is compressed
        and written, evicting least-recently-offered chunks if needed.
        """
        ent = self.entries.get(chunk_idx)
        if ent is not None:
            if ent[1] != offset or ent[2] != length:
                raise RuntimeError(
                    "pool iterator unstable: chunk %d moved from offset %d"
                    " (len %d) to offset %d (len %d)"
                    % (chunk_idx, ent[1], ent[2], offset, length))
            self._touch(chunk_idx)
            return True
        cpad = ch.shape[0]
        if self.slot_rows == 0:
            self.slot_rows = cpad
            self.cap_slots = self.cap_rows_budget // max(self.slot_rows, 1)
        if cpad > self.slot_rows or self.cap_slots == 0:
            return False              # uncacheable under this budget
        if len(self.entries) < self.cap_slots:
            slot = len(self.entries)
            want = min(self.cap_slots,
                       max(2 * max(len(self.entries), 1), slot + 1))
            self._grow_to(want)
        else:
            victim = self._lru.pop(0)
            slot, _, _ = self.entries.pop(victim)
            self.evictions += 1
        if cpad < self.slot_rows:
            ch = jnp.pad(ch, ((0, self.slot_rows - cpad), (0, 0)))
            ok = jnp.pad(ok, (0, self.slot_rows - cpad))
            gids = jnp.pad(gids, (0, self.slot_rows - cpad),
                           constant_values=-1)
        rows_c, norms_c, errn_c = _compress_chunk(ch, ok)
        lo = jnp.int32(slot * self.slot_rows)
        self.rows, self.norms, self.errn, self.gids, self.ok = _arena_write(
            self.rows, self.norms, self.errn, self.gids, self.ok, rows_c,
            norms_c, errn_c, gids, ok, lo)
        self.entries[chunk_idx] = (slot, offset, length)
        self._lru.append(chunk_idx)
        self.insertions += 1
        return True

    def covers(self, num_chunks: int) -> bool:
        return len(self.entries) == num_chunks and num_chunks > 0

    def quarantine(self, pos) -> None:
        """Mask arena rows out of every certification scan (the engine's
        fail-closed corruption response — see DESIGN.md §8).  Positions at
        or past ``cap_rows`` scatter-drop.  The mask persists for the
        cache's lifetime: a shared serve cache keeps refusing rows whose
        backing data went bad, across requests."""
        if self.ok is None:
            return
        p = jnp.asarray(np.asarray(pos, np.int64), jnp.int32)
        self.ok = self.ok.at[p].set(False, mode="drop")

    def state_dict(self) -> dict:
        """Checkpointable snapshot (streaming checkpoint/resume).  The
        entry table is stored in LRU order so a restore reproduces the
        eviction behavior — and therefore the solve — exactly."""
        st = {"cache_bytes": np.int64(self.cache_bytes),
              "d": np.int64(self.d),
              "slot_rows": np.int64(self.slot_rows),
              "cap_slots": np.int64(self.cap_slots),
              "complete": np.int64(self.complete),
              "insertions": np.int64(self.insertions),
              "evictions": np.int64(self.evictions),
              "ent_cidx": np.asarray(self._lru, np.int64),
              "ent_slot": np.asarray(
                  [self.entries[c][0] for c in self._lru], np.int64),
              "ent_off": np.asarray(
                  [self.entries[c][1] for c in self._lru], np.int64),
              "ent_len": np.asarray(
                  [self.entries[c][2] for c in self._lru], np.int64)}
        if self.rows is not None:
            st.update(rows=self.rows, norms=self.norms, errn=self.errn,
                      gids=self.gids, ok=self.ok)
        return st

    def load_state(self, st: dict) -> None:
        if int(st["d"]) != self.d:
            raise ValueError(
                f"cache checkpoint is for d={int(st['d'])}, "
                f"this cache has d={self.d}")
        self.cache_bytes = int(st["cache_bytes"])
        self.cap_rows_budget = max(self.cache_bytes // self.bytes_per_row,
                                   0)
        self.slot_rows = int(st["slot_rows"])
        self.cap_slots = int(st["cap_slots"])
        self.complete = int(st["complete"])
        self.insertions = int(st["insertions"])
        self.evictions = int(st["evictions"])
        self.entries = {}
        self._lru = []
        for c, s, o, ln in zip(np.asarray(st["ent_cidx"]).tolist(),
                               np.asarray(st["ent_slot"]).tolist(),
                               np.asarray(st["ent_off"]).tolist(),
                               np.asarray(st["ent_len"]).tolist()):
            self.entries[int(c)] = (int(s), int(o), int(ln))
            self._lru.append(int(c))
        if "rows" in st:
            self.rows = jnp.asarray(st["rows"])
            self.norms = jnp.asarray(st["norms"])
            self.errn = jnp.asarray(st["errn"])
            self.gids = jnp.asarray(st["gids"])
            self.ok = jnp.asarray(st["ok"])
        else:
            self.rows = self.norms = self.errn = None
            self.gids = self.ok = None

    def stats(self) -> dict:
        return {"resident_chunks": len(self.entries),
                "cap_slots": self.cap_slots,
                "slot_rows": self.slot_rows,
                "insertions": self.insertions,
                "evictions": self.evictions,
                "resident_bytes": self.cap_rows * self.bytes_per_row}


# ---------------------------------------------------------------------------
# jitted pieces (module-level so the jit cache persists across calls)
# ---------------------------------------------------------------------------

def _score_chunk_impl(chunk, pool_ok, gids, offset, residual, sel_idx,
                      sel_mask, m: int, absolute: bool,
                      need_norms: bool = True):
    """Top-``m`` of one chunk against the carried residual.

    Returns (vals (m,), ids (m,), rows (m, d), ok (m,), cmax (), cthresh ())
    where ``cthresh`` upper-bounds the pass-score of every row this chunk
    *dropped* (−inf when nothing real could have been dropped) and ``cmax``
    is the max valid row norm — both feed the certification sketch.  Norms
    are only reduced on a chunk's first pass (``need_norms=False`` returns
    0 — the pool is static across passes, so the per-chunk norm bound is
    frozen then).
    """
    c = chunk.shape[0]
    scores = ops.corr(chunk, residual)                       # (c,)
    s = jnp.abs(scores) if absolute else scores
    # Chunk rows cover the contiguous id range [offset, offset+c), so the
    # taken mask is an O(k) scatter, not an O(c*k) compare.  Slots owned by
    # other chunks (or unused) point at the out-of-bounds sentinel c and
    # are dropped — an in-bounds sentinel would race duplicate writes.
    local = sel_idx - offset
    inb = sel_mask & (local >= 0) & (local < c)
    taken = jnp.zeros((c,), bool).at[
        jnp.where(inb, local, c)].set(inb, mode="drop")
    avail = pool_ok & ~taken
    s_sel = jnp.where(avail, s, _NEG_INF)
    vals, pos = lax.top_k(s_sel, m)                          # ties: low pos
    if need_norms:
        norms = jnp.sqrt(jnp.sum(chunk * chunk, axis=1))
        cmax = jnp.max(jnp.where(pool_ok, norms, 0.0))
    else:
        cmax = jnp.float32(0.0)
    cthresh = vals[m - 1] if chunk.shape[0] > m else _NEG_INF
    return vals, gids[pos], chunk[pos], pool_ok[pos], cmax, cthresh


_score_chunk = functools.partial(
    jax.jit, static_argnames=("m", "absolute", "need_norms"))(
        _score_chunk_impl)


@functools.partial(jax.jit, static_argnames=("size",))
def _merge_topm(bv, bi, br, bok, cv, ci, cr, cok, size: int):
    """Merge two candidate buffers, keep top-``size`` by (score desc, id asc).

    The explicit lexicographic order (padding ids last) is what makes the
    buffer argmax reproduce ``jnp.argmax`` lowest-index tie-breaking
    globally.
    """
    vals = jnp.concatenate([bv, cv])
    ids = jnp.concatenate([bi, ci])
    rows = jnp.concatenate([br, cr])
    ok = jnp.concatenate([bok, cok])
    id_order = jnp.where(ids >= 0, ids, _BIG_ID)
    order = jnp.lexsort((id_order, -vals))[:size]
    return vals[order], ids[order], rows[order], ok[order]


def _buffer_scores_argmax(buf_rows, buf_ids, buf_dead, residual,
                          absolute: bool):
    """Score-and-argmax over the buffer (current residual), one matvec.

    ``buf_dead`` marks slots that can never win — invalid rows, pads and
    already-picked rows (the commit loop folds each pick in directly, so
    no per-round (slots, k) selection compare is paid).  The buffer is
    ordered by *pass-scan* score, so a positional argmax tie-break is
    not lowest-global-id under a drifted residual; ties are broken by id
    explicitly to match ``jnp.argmax`` over the full pool (the
    all-masked degenerate resolves to the lowest id too, mirroring the
    in-memory argmax-of-all--inf picking index 0).  Per-row scores are
    the same f32 dot the in-memory solver's ``ops.corr`` computes, so
    the value parity the certification compares against is exact.
    """
    s = ops.corr(buf_rows, residual)
    s = jnp.abs(s) if absolute else s
    s_m = jnp.where(buf_dead, _NEG_INF, s)
    maxv = jnp.max(s_m)
    cand = jnp.where(s_m == maxv,
                     jnp.where(buf_ids >= 0, buf_ids, _BIG_ID), _BIG_ID)
    pos = jnp.argmin(cand)
    return pos, buf_ids[pos], maxv


def _sketch_bound(residual, r0, chunk_thresh, chunk_norm, chunk_cached,
                  absolute: bool):
    """Max possible drifted-residual score of any out-of-buffer row of an
    *uncached* chunk: the residual-projection bound of the module
    docstring, NaN-safe at T_c = -inf (empty tail) and inflated past f32
    reassociation noise (certifying on noise would break parity; failing
    closed into the next rung is exact)."""
    r0n2 = jnp.sum(r0 * r0)
    r0n = jnp.sqrt(r0n2)
    alpha = jnp.dot(residual, r0) / jnp.maximum(r0n2, 1e-30)
    rperp = residual - alpha * r0
    rpn = jnp.sqrt(jnp.sum(rperp * rperp))
    fin = jnp.isfinite(chunk_thresh)
    t_safe = jnp.where(fin, chunk_thresh, 0.0)
    if absolute:
        proj = jnp.abs(alpha) * t_safe
    else:
        proj = jnp.where(alpha >= 0, alpha * t_safe,
                         -alpha * chunk_norm * r0n)
    bound = jnp.where(fin, proj + chunk_norm * rpn, _NEG_INF)
    # f32-noise inflation (fail closed); -inf stays -inf, not NaN.
    bound = jnp.where(fin, bound + 1e-6 * jnp.abs(bound) + 1e-30, bound)
    return jnp.max(jnp.where(chunk_cached, _NEG_INF, bound))


@functools.partial(jax.jit, static_argnames=("fmax",))
def _admit_fetched(buf_rows, buf_ids, buf_dead, new_rows, new_ids,
                   new_ok, cursor, ar_inbuf, new_pos, *, fmax: int):
    """Write up to ``fmax`` fetched exact rows into the repair annex at
    ``cursor`` and mark their arena slots in-buffer.  Slot positions past
    the annex (or dead entries, id -1) scatter-drop."""
    live = new_ids >= 0
    slots = jnp.where(live, cursor + jnp.cumsum(live) - 1,
                      buf_ids.shape[0])
    buf_rows = buf_rows.at[slots].set(new_rows, mode="drop")
    buf_ids = buf_ids.at[slots].set(new_ids, mode="drop")
    buf_dead = buf_dead.at[slots].set(~new_ok, mode="drop")
    ar_inbuf = ar_inbuf.at[new_pos].set(live, mode="drop")
    return buf_rows, buf_ids, buf_dead, ar_inbuf


@functools.partial(jax.jit, static_argnames=("absolute", "cand_cap", "m"))
def _arena_refresh_scan(ar_rows, ar_norms, ar_errn, ar_gids, ar_ok,
                        ar_taken, ar_inbuf, buf_rows, buf_ids, buf_dead,
                        residual, acc, *,
                        absolute: bool, cand_cap: int, m: int):
    """Cache-served refill, phase 1: interval-scan the arena and return
    every *new* row that could belong to the exact top-``m`` of the pool
    under the current residual.

    ``cutoff`` is the ``m``-th largest *lower* bound over (out-of-buffer
    arena rows, exact current-buffer scores); any out-of-buffer row
    whose *upper* bound clears it is a candidate.  Rows already in the
    buffer/annex are excluded — their exact rows are on hand and merge
    back via their exact scores, so only the genuine newcomers (usually
    a few dozen) pay a fetch.  Rows below the cutoff provably score
    below all ``m`` eventual buffer members, so the merged result
    reproduces the loader pass's top-``m`` bit-exactly.
    """
    rnorm = jnp.sqrt(jnp.sum(residual * residual))
    s = ops.corr(ar_rows.astype(jnp.float32), residual)
    s = jnp.abs(s) if absolute else s
    pad = (ar_errn + acc * ar_norms) * rnorm
    u = s + pad
    l = s - pad
    avail = ar_ok & ~ar_taken & ~ar_inbuf
    sb = ops.corr(buf_rows, residual)
    sb = jnp.abs(sb) if absolute else sb
    avail_b = ~buf_dead & (buf_ids >= 0)
    l_all = jnp.concatenate([jnp.where(avail, l, _NEG_INF),
                             jnp.where(avail_b, sb, _NEG_INF)])
    cutoff = lax.top_k(l_all, m)[0][m - 1]
    cand = avail & (u >= cutoff)
    vals, pos = lax.top_k(jnp.where(cand, u, _NEG_INF), cand_cap)
    pos = pos.astype(jnp.int32)
    live = vals > _NEG_INF
    return (jnp.where(live, ar_gids[pos], -1),
            jnp.where(live, pos, ar_rows.shape[0]),
            jnp.sum(cand), jnp.sum(avail) + jnp.sum(avail_b))


@functools.partial(jax.jit, static_argnames=("absolute", "m"))
def _refresh_merge(f_rows, f_ids, f_ok, buf_rows, buf_ids, buf_dead,
                   residual, ar_inbuf, chunk_off,
                   slot_lo, *, absolute: bool, m: int):
    """Cache-served refill, phase 2: exact-score the fetched candidates
    plus the surviving buffer rows and keep the top-``m`` by (score desc,
    id asc) — the identical ordering a loader pass's merge produces.
    Also rebuilds the arena in-buffer mask from the merged ids via the
    device-side chunk map (no host round-trip per refill)."""
    sf = ops.corr(f_rows, residual)
    sf = jnp.abs(sf) if absolute else sf
    vf = jnp.where(f_ok & (f_ids >= 0), sf, _NEG_INF)
    sb = ops.corr(buf_rows, residual)
    sb = jnp.abs(sb) if absolute else sb
    avail_b = ~buf_dead & (buf_ids >= 0)
    vb = jnp.where(avail_b, sb, _NEG_INF)
    mv, mi, mr, mok = _merge_topm(vb, buf_ids, buf_rows, avail_b, vf,
                                  f_ids, f_rows, f_ok, size=m)
    nc = chunk_off.shape[0]
    cap = ar_inbuf.shape[0]
    j = jnp.clip(jnp.searchsorted(chunk_off, mi, side="right") - 1, 0,
                 nc - 1)
    pos = slot_lo[j] + mi - chunk_off[j]
    pos = jnp.where((mi >= 0) & (slot_lo[j] >= 0), pos, jnp.int32(cap))
    inbuf = jnp.zeros_like(ar_inbuf).at[pos].set(True, mode="drop")
    return mv, mi, mr, mv == _NEG_INF, inbuf


@jax.jit
def _scatter_mask(mask, pos):
    return mask.at[pos].set(True, mode="drop")


@jax.jit
def _verify_norms(ch, ok, ref):
    """Per-row corruption check of a re-read chunk against the cache's
    f32 exact-norm sidecar (recorded at first contact).  The tolerance
    covers f32 reassociation between the two norm computations; real
    corruption (a flipped exponent/sign-magnitude bit, truncation) moves
    the norm orders of magnitude past it.  A norm-preserving corruption
    (pure sign flips) is not detectable this way — DESIGN.md §8 scopes
    the fault model."""
    nn = jnp.where(ok, jnp.sqrt(jnp.sum(ch * ch, axis=1)), 0.0)
    return ok & (jnp.abs(nn - ref) > 1e-4 * (ref + 1e-6))


@functools.partial(
    jax.jit, static_argnames=("p", "nnls_iters", "absolute", "has_arena",
                              "fmax"))
def _commit_rounds(buf_rows, buf_ids, buf_dead, indices, mask, weights,
                   rows, gram, absrow, tcorr, target, residual, err,
                   lam, r0, chunk_thresh, chunk_norm, chunk_cached,
                   ar_rows, ar_norms, ar_errn, ar_gids, ar_ok, ar_inbuf,
                   ar_taken, chunk_off, slot_lo, t0, t_hi, t_first, eps,
                   acc, *, p: int, nnls_iters: int, absolute: bool,
                   has_arena: bool, fmax: int):
    """Commit as many certified OMP rounds against the buffer as the
    bounds allow, entirely on device — the lookahead core of the
    multi-round-per-pass engine.  No per-round host dispatch: the
    incremental-Gram update runs in-place inside the while_loop (same
    flops as the in-memory solver's round body), the sketch rung is
    O(C), and the cache-arena interval rung is an in-memory matvec whose
    bf16->f32 operand conversion is loop-invariant (XLA hoists it, so
    each round pays an f32-speed scan).

    Round ``t_first`` (the one right after a buffer refresh, -1 for
    none) is exact by construction and bypasses certification.  The loop
    stops at ``t_hi`` (the next prefix-block boundary), at the eps-stop,
    or at the first round the bounds cannot certify; the failing round's
    (maxv, sketch, u_max, #offenders) plus the top-``fmax`` offender
    (gid, arena row) pairs land in the result so the host can run the
    repair tier without re-scanning.
    """
    use_ref = ops.active_mode() == "ref"
    if has_arena:
        # Ref/CPU path: hoist the bf16->f32 conversion out of the loop
        # (one resident f32 copy, f32-speed scans every round).  On the
        # fused-kernel path the copy would defeat the kernel's whole
        # point (u and the converted rows never touching HBM), so no
        # persistent conversion is made there.
        arf = ar_rows.astype(jnp.float32) if use_ref else None
        cap = ar_rows.shape[0]
        nc = chunk_off.shape[0]

    def pick_pos(e):
        """Arena slot of global id ``e`` (device-side chunk map);
        sentinel ``cap`` (dropped by the scatter) when uncached."""
        j = jnp.clip(jnp.searchsorted(chunk_off, e, side="right") - 1,
                     0, nc - 1)
        pos = slot_lo[j] + e - chunk_off[j]
        return jnp.where((e >= 0) & (slot_lo[j] >= 0), pos,
                         jnp.int32(cap))

    def cond(c):
        t, go = c[0], c[1]
        return go & (t < t_hi) & (c[10] > eps)

    def body(c):
        (t, go, indices, mask, weights, rows, gram, absrow, tcorr,
         residual, err, ar_taken, bdead, diag, off) = c
        pos, e, maxv = _buffer_scores_argmax(buf_rows, buf_ids, bdead,
                                             residual, absolute)
        sk = _sketch_bound(residual, r0, chunk_thresh, chunk_norm,
                           chunk_cached, absolute)
        sketch_ok = maxv > sk
        if has_arena:
            avail_a = ar_ok & ~ar_taken & ~ar_inbuf
            # The interval scan is only consulted when the sketch rung
            # passed — on fully-cached pools the sketch is -inf and the
            # scan runs every round; on structured pools the sketch
            # often settles it alone.  On TPU the fused ``bound_max``
            # kernel consumes the cache directly (one streaming pass, u
            # never hits HBM); the ref path passes arf pre-converted so
            # the bf16->f32 cast stays loop-invariant.
            def scan(_):
                if not use_ref:
                    u_max, _, n_off = ops.bound_max(
                        ar_rows, ar_norms, ar_errn, residual, acc,
                        maxv, avail_a, absolute=absolute)
                    return u_max, n_off
                rnorm = jnp.sqrt(jnp.sum(residual * residual))
                s = arf @ residual
                s = jnp.abs(s) if absolute else s
                u = s + (ar_errn + acc * ar_norms) * rnorm
                u_m = jnp.where(avail_a, u, _NEG_INF)
                return jnp.max(u_m), jnp.sum(avail_a & (u_m >= maxv))

            u_max, n_off = lax.cond(
                sketch_ok, scan,
                lambda _: (_NEG_INF, jnp.int32(0)), operand=None)
        else:
            u_max, n_off = _NEG_INF, jnp.int32(0)
        cert = (sketch_ok & (maxv > u_max) & jnp.isfinite(maxv)
                ) | (t == t_first)
        diag = (maxv, sk, u_max, n_off)

        def commit(_):
            g_e = buf_rows[pos]
            ind = indices.at[t].set(e)
            msk = mask.at[t].set(True)
            rws = rows.at[t].set(g_e)
            mask_p = msk[:p]
            row_vals = jnp.where(mask_p, rws[:p] @ g_e, 0.0)
            grm = gram.at[t, :p].set(row_vals).at[:p, t].set(row_vals)
            ar = jnp.where(mask_p, absrow[:p] + jnp.abs(row_vals), 0.0)
            ar = ar.at[t].set(jnp.sum(jnp.abs(row_vals)))
            arow = absrow.at[:p].set(ar)
            tc = tcorr.at[t].set(jnp.dot(g_e, target))
            w_p = _nnls_active_cached(grm[:p, :p], arow[:p], rws[:p],
                                      tc[:p], mask_p, lam, nnls_iters)
            w = jnp.zeros_like(weights).at[:p].set(w_p)
            resid = target - w_p @ rws[:p]
            er = jnp.sum(resid**2) + lam * jnp.sum(w_p**2)
            tk = (ar_taken.at[pick_pos(e)].set(True, mode="drop")
                  if has_arena else ar_taken)
            bd = bdead.at[pos].set(True)
            return (t + 1, jnp.bool_(True), ind, msk, w, rws, grm, arow,
                    tc, resid, er, tk, bd, diag, off)

        def stop(_):
            # Runs once, at the exit round: hand the host the repair
            # tier's worklist (the offending rows' ids/slots by upper
            # bound) so it never re-scans the arena.
            if has_arena and fmax > 0:
                rnorm = jnp.sqrt(jnp.sum(residual * residual))
                # Runs once per loop exit: a transient conversion here is
                # fine on the fused-kernel path (no persistent f32 copy).
                rows_f = arf if use_ref else ar_rows.astype(jnp.float32)
                s = rows_f @ residual
                s = jnp.abs(s) if absolute else s
                u = s + (ar_errn + acc * ar_norms) * rnorm
                u_m = jnp.where(avail_a, u, _NEG_INF)
                vals, opos = lax.top_k(u_m, fmax)
                opos = opos.astype(jnp.int32)
                live = vals > _NEG_INF
                off_out = (jnp.where(live, ar_gids[opos], -1),
                           jnp.where(live, opos,
                                     jnp.int32(ar_rows.shape[0])))
            else:
                off_out = off
            return (t, jnp.bool_(False), indices, mask, weights, rows,
                    gram, absrow, tcorr, residual, err, ar_taken, bdead,
                    diag, off_out)

        return lax.cond(cert, commit, stop, operand=None)

    diag0 = (_NEG_INF, _NEG_INF, _NEG_INF, jnp.int32(0))
    off0 = (jnp.full((max(fmax, 1),), -1, jnp.int32),
            jnp.full((max(fmax, 1),), ar_rows.shape[0], jnp.int32))
    init = (t0, jnp.bool_(True), indices, mask, weights, rows, gram,
            absrow, tcorr, residual, err, ar_taken, buf_dead, diag0,
            off0)
    return lax.while_loop(cond, body, init)


# ---------------------------------------------------------------------------
# the streaming solver
# ---------------------------------------------------------------------------

@dataclass
class SelectStats:
    """Pass/round/cache accounting for benchmarks, the harness tests and
    the ``max_passes`` diagnostics."""
    passes: int = 0             # full loader scans
    rounds: int = 0
    certified_rounds: int = 0   # rounds committed without loader traffic
    chunks: int = 0
    pool_size: int = 0
    refills: int = 0            # buffer refreshes served from the cache
    repairs: int = 0            # bounded exact-row repair events
    fetched_rows: int = 0       # exact rows fetched by id (repair+refill)
    cache_hits: int = 0         # certification chunk lookups in the arena
    cache_misses: int = 0       # ... that had to use the sketch bound
    retries: int = 0            # transient faults retried (chunks + rows)
    quarantined: int = 0        # rows masked out after persistent
                                # corruption (never silently selected)
    checkpoints: int = 0        # mid-solve snapshots written
    resumes: int = 0            # solves resumed from a checkpoint
    admits: int = 0             # continual: rows admitted to the buffer
    evicts: int = 0             # continual: buffer rows evicted (any tier)
    downdates: int = 0          # continual: committed rows removed via the
                                # decremental downdate path
    resolves: int = 0           # continual: fail-closed full re-solves

    @property
    def cache_hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 0.0

    def summary(self) -> str:
        s = (f"passes={self.passes} rounds={self.rounds} "
             f"certified_rounds={self.certified_rounds} "
             f"refills={self.refills} repairs={self.repairs} "
             f"fetched_rows={self.fetched_rows} "
             f"cache_hit_rate={self.cache_hit_rate:.2f}")
        if self.retries or self.quarantined:
            s += (f" retries={self.retries} "
                  f"quarantined={self.quarantined}")
        if self.resumes:
            s += f" resumes={self.resumes}"
        if self.admits or self.evicts or self.downdates or self.resolves:
            s += (f" admits={self.admits} evicts={self.evicts} "
                  f"downdates={self.downdates} resolves={self.resolves}")
        return s


# Backwards-compatible alias (PR 2 name).
StreamStats = SelectStats


class StreamingPassBudgetError(RuntimeError):
    """Raised when streaming OMP exceeds its ``max_passes`` budget.

    Carries the accumulated ``SelectStats`` so the failure is diagnosable
    without re-running (is the iterator unstable?  did certification
    never fire?  was the cache thrashing?)."""

    def __init__(self, cap: int, stats: SelectStats):
        self.cap = cap
        self.stats = stats
        super().__init__(
            f"streaming OMP exceeded its pass budget (cap={cap}). "
            f"Solver state at failure: {stats.summary()}. "
            "Is the pool iterator stable across passes?  An adversarial "
            "pool that never certifies needs max_passes >= k + 2.")


class StreamingOMPResult(NamedTuple):
    indices: jax.Array   # (k,) int32, -1 on unused slots
    weights: jax.Array   # (k,) f32
    mask: jax.Array      # (k,) bool
    err: jax.Array       # () f32
    stats: SelectStats


def omp_select_streaming(
    pool_iter: Callable[[], Iterator],   # factory of (chunk, valid) iters
    target,                              # (d,) target gradient
    k: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nnls_iters: int = 50,
    positive: bool = True,
    buffer_size: int = 256,              # M — carried top-M candidate buffer
    chunk_topm: Optional[int] = None,    # m per chunk (default: M)
    block: int = 128,                    # NNLS prefix growth (parity w/ omp)
    max_passes: Optional[int] = None,
    score_chunk_fn=None,                 # hook: distributed.pmap_chunk_topm
    cache: Optional[ChunkCache] = None,  # shared compressed cache (serve)
    cache_bytes: int = DEFAULT_CACHE_BYTES,  # budget when cache is None
    row_fetch: Optional[Callable] = None,    # ids -> exact f32 rows
    repair_slots: int = 512,             # annex width for exact-row repairs
    retry: Optional[RetryPolicy] = None,     # transient-fault recovery
    checkpoint_dir: Optional[str] = None,    # mid-solve snapshots
    checkpoint_every: int = 8,           # committed rounds between saves
    resume: bool = True,                 # pick up a prior checkpoint
) -> StreamingOMPResult:
    """OMP over a chunked pool; exact parity with ``omp_select``.

    ``pool_iter()`` must yield the same chunks in the same order on every
    call (the solver rescans when certification fails).  ``score_chunk_fn``
    overrides the local chunk scorer with the same signature/returns as
    ``_score_chunk`` — ``core.distributed.pmap_chunk_topm`` scores chunks
    shard-parallel across local devices.

    ``cache``/``cache_bytes`` control the compressed chunk cache (pass
    ``cache_bytes=0`` to disable).  ``row_fetch(ids)`` is the optional
    exact-row gather capability (``array_row_fetch`` for array pools);
    without it the repair and cache-refill tiers are skipped and every
    certification failure costs a loader pass, which is still exact.

    Recovery (DESIGN.md §8): transient loader/fetch faults
    (``resilience.TransientFault``) are retried per ``retry`` (default
    ``RetryPolicy()``) at whole-pass / fetch granularity — a restarted
    pass rebuilds its accumulators from scratch, so recovery never
    changes the selection.  Re-read chunks and re-fetched rows are
    verified against the cache's f32 exact-norm sidecars; content that
    still disagrees after the retry budget is *quarantined* — masked out
    of the certificate ladder and never silently selected.  With
    ``checkpoint_dir``, the commit-loop state is snapshotted every
    ``checkpoint_every`` committed rounds via ``repro.checkpoint`` and a
    later call with the same arguments resumes bit-exactly
    (``resume=False`` ignores an existing checkpoint).
    """
    target = jnp.asarray(target, jnp.float32)
    d = target.shape[0]
    k = int(k)
    m_cfg = int(chunk_topm) if chunk_topm is not None else int(buffer_size)
    big_m = int(buffer_size)
    annex = int(repair_slots) if row_fetch is not None else 0
    fmax = min(128, annex) if annex else 0
    absolute = not positive
    scorer = score_chunk_fn if score_chunk_fn is not None else _score_chunk
    if cache is None:
        cache = ChunkCache(int(cache_bytes), d)
    if retry is None:
        retry = RetryPolicy()
    acc = jnp.float32(_acc_margin(d))

    indices = jnp.full((k,), -1, jnp.int32)
    mask = jnp.zeros((k,), bool)
    weights = jnp.zeros((k,), jnp.float32)
    rows = jnp.zeros((k, d), jnp.float32)
    gram = jnp.zeros((k, k), jnp.float32)
    absrow = jnp.zeros((k,), jnp.float32)
    tcorr = jnp.zeros((k,), jnp.float32)
    residual = target
    err = float(jnp.sum(target**2))
    lam_f = jnp.float32(lam)

    stats = SelectStats()
    cap = int(max_passes) if max_passes is not None else k + 2
    t = 0

    # Buffer (M exact rows + annex repair slots), sketch state, per-solve
    # arena masks.  All built by the first loader pass.
    bi = br = bdead = None
    annex_cursor = big_m
    r0 = None
    chunk_thresh = chunk_norm = chunk_cached = None
    chunk_norm_host: list[float] = []
    chunk_meta: list[tuple[int, int]] = []   # (offset, length) per chunk
    ar_taken = ar_inbuf = None
    num_chunks = 0
    quarantined: set[int] = set()   # global ids failed-closed (corruption)
    corrupt_seen: dict[int, int] = {}   # chunk idx -> mismatched reads
    last_ckpt = 0

    def _note_retry(attempt, exc) -> None:
        stats.retries += 1

    def arena_ready() -> bool:
        return cache.cap_rows > 0 and len(cache.entries) > 0

    def _quarantine(gids_np) -> None:
        """Fail-closed response to persistent corruption: drop the rows
        from every candidate source (arena validity, buffer liveness, and
        — via the ``quarantined`` set — future loader passes).  Rows
        already committed to the selection were read clean when picked
        and stay; quarantine governs candidacy, not history."""
        nonlocal bdead
        fresh = [int(g) for g in np.atleast_1d(np.asarray(gids_np))
                 if g >= 0 and int(g) not in quarantined]
        if not fresh:
            return
        quarantined.update(fresh)
        stats.quarantined = len(quarantined)
        if arena_ready() and chunk_meta:
            cache.quarantine(gids_to_pos(np.asarray(fresh, np.int64)))
        if bi is not None:
            hit = jnp.zeros_like(bdead)
            for g in fresh:
                hit = hit | (bi == g)
            bdead = bdead | hit

    def sync_arena_masks() -> None:
        """(Re)size the per-solve arena masks to the arena capacity."""
        nonlocal ar_taken, ar_inbuf
        cap_r = cache.cap_rows
        if ar_taken is None or ar_taken.shape[0] != cap_r:
            old_t, old_i = ar_taken, ar_inbuf
            ar_taken = jnp.zeros((cap_r,), bool)
            ar_inbuf = jnp.zeros((cap_r,), bool)
            if old_t is not None and old_t.shape[0] <= cap_r:
                pad = cap_r - old_t.shape[0]
                ar_taken = jnp.pad(old_t, (0, pad))
                ar_inbuf = jnp.pad(old_i, (0, pad))

    def rebuild_inbuf(ids) -> None:
        """Mark the (host-synced) buffer ids' arena slots in-buffer.
        Positions are sentinel-padded to a fixed width so the scatter jit
        compiles once per buffer size."""
        nonlocal ar_inbuf
        if ar_inbuf is None:
            return
        pos = gids_to_pos(np.asarray(ids, np.int64))
        ar_inbuf = _scatter_mask(jnp.zeros_like(ar_inbuf),
                                 jnp.asarray(pos))

    def loader_pass() -> bool:
        """Full loader scan: refresh buffer + cache + sketch state.
        Returns False on an empty pool.  Transient iterator faults
        restart the whole scan under the retry policy — the merge
        accumulators below are scan-local, ``chunk_meta`` appends are
        guarded, ``chunk_norm_host`` only extends after a completed scan
        and ``cache.offer`` is idempotent for resident chunks, so a
        restart recomputes the identical refresh (``stats.chunks`` may
        over-count across aborted scans; passes count completed scans)."""
        if stats.passes >= cap:
            raise StreamingPassBudgetError(cap, stats)
        return with_retries(_scan_pass, retry, on_retry=_note_retry)

    def _scan_pass() -> bool:
        nonlocal bi, br, bdead, annex_cursor, r0, chunk_thresh
        nonlocal chunk_norm, chunk_cached, num_chunks
        mv = jnp.full((big_m,), -jnp.inf, jnp.float32)
        mi = jnp.full((big_m,), -1, jnp.int32)
        mr = jnp.zeros((big_m, d), jnp.float32)
        mok = jnp.zeros((big_m,), bool)
        # Device-scalar accumulators: no host sync inside the chunk loop.
        threshs = []
        norms_new = []
        offset = 0
        cidx = 0
        first_visit = len(chunk_norm_host) == 0
        for chunk, cvalid in pool_iter():
            c = int(chunk.shape[0])
            cpad = _bucket(c)
            ch = jnp.asarray(chunk, jnp.float32)
            pos_in = jnp.arange(cpad, dtype=jnp.int32)
            if cpad != c:
                ch = jnp.pad(ch, ((0, cpad - c), (0, 0)))
            ok = pos_in < c
            if cvalid is not None:
                ok = ok & jnp.pad(jnp.asarray(cvalid, bool),
                                  (0, cpad - c))
            if quarantined:
                ql = [g - offset for g in quarantined
                      if offset <= g < offset + c]
                if ql:
                    ok = ok & ~jnp.zeros((cpad,), bool).at[
                        jnp.asarray(ql, jnp.int32)].set(True)
            gids = jnp.where(pos_in < c, offset + pos_in, -1)
            if cidx >= len(chunk_meta):
                chunk_meta.append((offset, c))
            slot = cache.slot_of(cidx)
            if slot is not None:
                # Re-read of a resident chunk: verify the content against
                # the exact-norm sidecar recorded at first contact.  A
                # mismatch is first treated as a transient misread (the
                # scan restarts); a chunk that keeps disagreeing past the
                # retry budget has its mismatching rows quarantined and
                # the scan proceeds without them.
                lo = slot * cache.slot_rows
                bad = np.asarray(_verify_norms(
                    ch, ok, cache.norms[lo:lo + cpad]))
                if bad.any():
                    seen = corrupt_seen.get(cidx, 0) + 1
                    corrupt_seen[cidx] = seen
                    if seen <= retry.max_retries:
                        raise CorruptChunkError(
                            f"chunk {cidx} disagrees with its exact-norm "
                            f"sidecar on {int(bad.sum())} row(s) "
                            f"(mismatched read {seen})")
                    _quarantine(offset + np.flatnonzero(bad))
                    ok = ok & jnp.asarray(~bad)
            m_eff = min(m_cfg, cpad, big_m)
            need_n = cidx >= len(chunk_norm_host)
            vals, ids, rws, rok, cmax, cthresh = scorer(
                ch, ok, gids, jnp.int32(offset), residual, indices, mask,
                m=m_eff, absolute=absolute, need_norms=need_n)
            mv, mi, mr, mok = _merge_topm(mv, mi, mr, mok, vals, ids, rws,
                                          rok, size=big_m)
            if need_n:
                norms_new.append(cmax)
            cache.offer(cidx, offset, c, ch, ok, gids)
            threshs.append(cthresh)
            offset += c
            cidx += 1
            stats.chunks += 1
        if offset == 0:
            return False
        stats.pool_size = offset
        if first_visit:
            num_chunks = cidx
        chunk_norm_host.extend(float(x) for x in norms_new)
        # A chunk inserted this pass may have evicted an earlier one —
        # the resident set is only final once the pass completes.
        cached_flags = [cache.slot_of(i) is not None for i in range(cidx)]
        # Rows dropped at the merge are bounded by the buffer's min value
        # (−inf while the buffer is not full, i.e. nothing real dropped).
        merge_min = mv[big_m - 1]
        chunk_thresh = jnp.maximum(jnp.stack(threshs), merge_min)
        chunk_norm = jnp.asarray(chunk_norm_host, jnp.float32)
        chunk_cached = jnp.asarray(cached_flags)
        r0 = residual
        bi = jnp.concatenate([mi, jnp.full((annex,), -1, jnp.int32)])
        br = jnp.concatenate([mr, jnp.zeros((annex, d), jnp.float32)])
        # Slots that can never win the argmax: taken/invalid rows were
        # scored -inf by the chunk scorer, pads carry -inf too; annex
        # slots start dead until a repair admits into them.
        bdead = jnp.concatenate([mv == _NEG_INF,
                                 jnp.ones((annex,), bool)])
        annex_cursor = big_m
        sync_arena_masks()
        rebuild_inbuf(mi)
        stats.passes += 1
        return True

    def cache_refill() -> bool:
        """Refresh the buffer from the arena (no loader traffic).  Only
        sound when the cache covers every chunk; returns False when the
        candidate set is empty/oversized and a loader pass is needed."""
        nonlocal bi, br, bdead, annex_cursor, r0, ar_inbuf
        if not (row_fetch is not None and cache.covers(num_chunks)
                and arena_ready()):
            return False
        # Merge deeper than M: pushing the buffer boundary well below the
        # decaying in-buffer max keeps the endgame rounds (where score
        # spacing shrinks under the interval width) free of offender
        # churn, while two repair batches' worth of annex stays free.
        deep = big_m + max(annex - 2 * fmax, 0)
        cand_cap = min(_bucket(min(4 * big_m, cache.cap_rows)),
                       cache.cap_rows)
        gids, pos, n_cand, n_avail = _arena_refresh_scan(
            cache.rows, cache.norms, cache.errn, cache.gids, cache.ok,
            ar_taken, ar_inbuf, br, bi, bdead, residual,
            acc, absolute=absolute, cand_cap=cand_cap, m=deep)
        n_cand = int(n_cand)
        if n_cand == 0 or n_cand > cand_cap or int(n_avail) == 0:
            return False
        # fb >= n_cand always (n_cand <= cand_cap), but the bucket can
        # round past gids' length when cap_rows is not a power of two.
        fb = min(_bucket(max(n_cand, 1)), cand_cap)
        ids_np = np.asarray(gids[:fb])
        fetched, live = checked_fetch(ids_np, np.asarray(pos[:fb]))
        f_ids = jnp.asarray(np.where(live, ids_np, -1))
        mv, mi, mr, mdead, inbuf_new = _refresh_merge(
            jnp.asarray(fetched), f_ids, f_ids >= 0, br, bi, bdead,
            residual, ar_inbuf, chunk_off_d, slot_lo_d,
            absolute=absolute, m=deep)
        # Outside rows now provably score below the new buffer minimum
        # (they sat under the refill cutoff); the sketch rung is moot
        # while coverage is complete, so only r0 needs refreshing.
        r0 = residual
        pad = big_m + annex - deep
        bi = jnp.concatenate([mi, jnp.full((pad,), -1, jnp.int32)])
        br = jnp.concatenate([mr, jnp.zeros((pad, d), jnp.float32)])
        bdead = jnp.concatenate([mdead, jnp.ones((pad,), bool)])
        annex_cursor = deep
        ar_inbuf = inbuf_new
        stats.refills += 1
        stats.fetched_rows += int(live.sum())
        return True

    chunk_off_d = slot_lo_d = None    # device-side chunk map (pick_pos)

    def checked_fetch(ids_np, pos_np):
        """Exact-row fetch with transient retry + corruption detection.

        Fetched rows whose arena position holds an f32 exact-norm sidecar
        must reproduce it (the sidecar was computed from the row at first
        contact; the fetch contract is byte-identical f32 rows).  Rows
        that disagree are re-fetched under the retry budget; persistent
        disagreement quarantines them — returned ``live`` drops them, so
        a corrupted row is never admitted to the buffer.  Entries with
        id -1 are dead padding and fetch nothing.
        """
        ids_np = np.asarray(ids_np, np.int64)
        pos_np = np.asarray(pos_np, np.int64)
        live = ids_np >= 0
        out = np.zeros((len(ids_np), d), np.float32)
        if not live.any():
            return out, live
        todo = live.copy()
        misreads = 0
        while True:
            sel = np.flatnonzero(todo)
            rows_f = with_retries(
                lambda: np.asarray(row_fetch(ids_np[sel]), np.float32),
                retry, on_retry=_note_retry)
            out[sel] = rows_f
            if not arena_ready():
                break
            have = pos_np[sel] < cache.cap_rows
            if not have.any():
                break
            ref = np.asarray(cache.norms[jnp.asarray(
                np.clip(pos_np[sel], 0, cache.cap_rows - 1), jnp.int32)])
            r64 = rows_f.astype(np.float64)
            nf = np.sqrt(np.einsum("ij,ij->i", r64, r64))
            bad = have & (np.abs(nf - ref) > 1e-4 * (ref + 1e-6))
            if not bad.any():
                break
            misreads += 1
            if misreads > retry.max_retries:
                _quarantine(ids_np[sel[bad]])
                live[sel[bad]] = False
                out[sel[bad]] = 0.0
                break
            _note_retry(misreads, None)
            retry.sleep(retry.delay(misreads - 1))
            todo = np.zeros_like(todo)
            todo[sel[bad]] = True
        return out, live

    def gids_to_pos(ids_np: np.ndarray) -> np.ndarray:
        """Vectorized host map: global ids -> arena rows (sentinel
        ``cap_rows`` for dead ids / uncached chunks)."""
        offs = np.asarray([m[0] for m in chunk_meta], np.int64)
        slo = np.full((len(chunk_meta),), -1, np.int64)
        for cidx, (slot, _, _) in cache.entries.items():
            if cidx < len(slo):
                slo[cidx] = slot * cache.slot_rows
        j = np.clip(np.searchsorted(offs, ids_np, side="right") - 1, 0,
                    len(offs) - 1)
        pos = slo[j] + ids_np - offs[j]
        return np.where((ids_np >= 0) & (slo[j] >= 0), pos,
                        cache.cap_rows).astype(np.int32)

    def rebuild_taken() -> None:
        """Rebuild the arena taken-mask from the committed selection —
        one sentinel-padded scatter.  Needed after loader passes (slot
        assignments may change); between them the device commit loop
        maintains the mask itself."""
        nonlocal ar_taken
        sync_arena_masks()
        sel_np = np.asarray(indices)
        msk_np = np.asarray(mask)
        pos = np.where(msk_np, gids_to_pos(sel_np), cache.cap_rows)
        ar_taken = _scatter_mask(jnp.zeros_like(ar_taken),
                                 jnp.asarray(pos.astype(np.int32)))

    def rebuild_chunk_map() -> None:
        """Device copy of the chunk->arena-slot map the commit loop uses
        to fold its own picks into the taken mask."""
        nonlocal chunk_off_d, slot_lo_d
        off = np.asarray([m[0] for m in chunk_meta] or [0], np.int32)
        slo = np.full((max(num_chunks, 1),), -1, np.int32)
        for cidx, (slot, _, _) in cache.entries.items():
            if cidx < len(slo):
                slo[cidx] = slot * cache.slot_rows
        chunk_off_d = jnp.asarray(off)
        slot_lo_d = jnp.asarray(slo)

    def _capture_tree() -> dict:
        """Snapshot everything the commit loop needs to resume bit-exactly:
        solver prefix state (Gram/NNLS buffers, residual), the candidate
        buffer + annex, sketch state, the compressed-cache manifest and
        arena, per-solve arena masks, host bookkeeping and stats."""
        tree = {
            "cfg": {"k": np.int64(k), "d": np.int64(d),
                    "big_m": np.int64(big_m), "annex": np.int64(annex),
                    "block": np.int64(block),
                    "absolute": np.int64(absolute),
                    "nnls_iters": np.int64(nnls_iters),
                    "lam": np.float64(lam), "eps": np.float64(eps)},
            "solver": {"t": np.int64(t), "err": np.float64(err),
                       "t_first": np.int64(t_first),
                       "need_refresh": np.int64(need_refresh),
                       "annex_cursor": np.int64(annex_cursor),
                       "num_chunks": np.int64(num_chunks),
                       "indices": indices, "mask": mask,
                       "weights": weights, "rows": rows, "gram": gram,
                       "absrow": absrow, "tcorr": tcorr,
                       "residual": residual, "r0": r0,
                       "bi": bi, "br": br, "bdead": bdead,
                       "chunk_thresh": chunk_thresh,
                       "chunk_norm": chunk_norm,
                       "chunk_cached": chunk_cached},
            "host": {"chunk_off": np.asarray(
                         [mm[0] for mm in chunk_meta], np.int64),
                     "chunk_len": np.asarray(
                         [mm[1] for mm in chunk_meta], np.int64),
                     "chunk_norm_host": np.asarray(chunk_norm_host,
                                                   np.float64),
                     "quarantined": np.asarray(sorted(quarantined),
                                               np.int64)},
            "stats": {kk: np.int64(vv) for kk, vv in vars(stats).items()},
            "arena": cache.state_dict(),
        }
        if ar_taken is not None:
            tree["masks"] = {"ar_taken": ar_taken, "ar_inbuf": ar_inbuf}
        return tree

    need_refresh = True
    t_first = -1
    resumed = False
    if checkpoint_dir is not None and resume:
        _tree = load_solver_state(checkpoint_dir)
        if _tree is not None:
            cfg = _tree["cfg"]
            want = {"k": k, "d": d, "big_m": big_m, "annex": annex,
                    "block": int(block), "absolute": int(absolute),
                    "nnls_iters": int(nnls_iters)}
            got = {kk: int(cfg[kk]) for kk in want}
            if (got != want or float(cfg["lam"]) != float(lam)
                    or float(cfg["eps"]) != float(eps)):
                raise ValueError(
                    f"checkpoint under {checkpoint_dir!r} was written by "
                    f"an incompatible solve (saved {got}, this solve "
                    f"{want}) — pass resume=False or a fresh "
                    "checkpoint_dir")
            sol = _tree["solver"]
            t = int(sol["t"])
            err = float(sol["err"])
            t_first = int(sol["t_first"])
            need_refresh = bool(int(sol["need_refresh"]))
            annex_cursor = int(sol["annex_cursor"])
            num_chunks = int(sol["num_chunks"])
            indices = jnp.asarray(sol["indices"])
            mask = jnp.asarray(sol["mask"])
            weights = jnp.asarray(sol["weights"])
            rows = jnp.asarray(sol["rows"])
            gram = jnp.asarray(sol["gram"])
            absrow = jnp.asarray(sol["absrow"])
            tcorr = jnp.asarray(sol["tcorr"])
            residual = jnp.asarray(sol["residual"])
            r0 = jnp.asarray(sol["r0"])
            bi = jnp.asarray(sol["bi"])
            br = jnp.asarray(sol["br"])
            bdead = jnp.asarray(sol["bdead"])
            chunk_thresh = jnp.asarray(sol["chunk_thresh"])
            chunk_norm = jnp.asarray(sol["chunk_norm"])
            chunk_cached = jnp.asarray(sol["chunk_cached"])
            host = _tree["host"]
            chunk_meta.extend(
                zip(np.asarray(host["chunk_off"]).tolist(),
                    np.asarray(host["chunk_len"]).tolist()))
            chunk_norm_host.extend(
                float(x) for x in np.asarray(host["chunk_norm_host"]))
            quarantined.update(
                int(x) for x in np.asarray(host["quarantined"]))
            for kk, vv in _tree["stats"].items():
                setattr(stats, kk, int(vv))
            cache.load_state(_tree["arena"])
            masks_t = _tree.get("masks")
            if masks_t is not None:
                ar_taken = jnp.asarray(masks_t["ar_taken"])
                ar_inbuf = jnp.asarray(masks_t["ar_inbuf"])
            rebuild_chunk_map()
            stats.resumes += 1
            last_ckpt = t
            resumed = True

    if (not resumed and cache.complete > 0 and cache.covers(cache.complete)
            and row_fetch is not None):
        # Bootstrap from a pre-warmed cache (serve admission already paid
        # the summing pass and filled it): the first buffer refresh is a
        # cache refill, so this solve touches the loader zero times.
        num_chunks = cache.complete
        metas = sorted((cidx, off, ln) for cidx, (slot, off, ln)
                       in cache.entries.items())
        chunk_meta.extend((off, ln) for _, off, ln in metas)
        stats.pool_size = sum(ln for _, _, ln in metas)
        chunk_thresh = jnp.zeros((num_chunks,), jnp.float32)  # all cached:
        chunk_norm = jnp.zeros((num_chunks,), jnp.float32)    # sketch moot
        chunk_cached = jnp.ones((num_chunks,), bool)
        r0 = target
        bi = jnp.full((big_m + annex,), -1, jnp.int32)
        br = jnp.zeros((big_m + annex, d), jnp.float32)
        bdead = jnp.ones((big_m + annex,), bool)
        annex_cursor = big_m + annex
        sync_arena_masks()
        rebuild_chunk_map()

    while t < k and err > eps:
        if need_refresh:
            if not cache_refill():
                if not loader_pass():
                    break
                rebuild_taken()
                rebuild_chunk_map()
            need_refresh = False
            t_first = t
        p = min(k, block * (t // block + 1))
        has_arena = arena_ready()
        fm = min(fmax, cache.cap_rows) if has_arena else 0
        dummy = jnp.zeros((1,), jnp.int32)
        (t_new, go, indices, mask, weights, rows, gram, absrow, tcorr,
         residual, err_d, ar_taken_new, bdead, diag,
         offs) = _commit_rounds(
            br, bi, bdead, indices, mask, weights, rows, gram, absrow,
            tcorr, target, residual, jnp.float32(err), lam_f, r0,
            chunk_thresh, chunk_norm, chunk_cached,
            cache.rows if has_arena else jnp.zeros((1, d), jnp.bfloat16),
            cache.norms if has_arena else jnp.zeros((1,)),
            cache.errn if has_arena else jnp.zeros((1,)),
            cache.gids if has_arena else dummy,
            cache.ok if has_arena else jnp.zeros((1,), bool),
            ar_inbuf if has_arena else jnp.zeros((1,), bool),
            ar_taken if has_arena else jnp.zeros((1,), bool),
            chunk_off_d if has_arena else dummy,
            slot_lo_d if has_arena else dummy,
            jnp.int32(t), jnp.int32(p), jnp.int32(t_first), eps, acc,
            p=p, nnls_iters=nnls_iters, absolute=absolute,
            has_arena=has_arena, fmax=fm)
        if has_arena:
            ar_taken = ar_taken_new
        # One host transfer for every per-entry scalar.
        t_new, go, err, d_maxv, d_sk, d_umax, d_noff = [
            x.item() for x in jax.device_get(
                (t_new, go, err_d, *diag))]
        committed = t_new - t
        stats.rounds += committed
        certified = committed - (1 if t_first == t and committed > 0
                                 else 0)
        stats.certified_rounds += certified
        stats.cache_hits += certified * len(cache.entries)
        stats.cache_misses += certified * (num_chunks
                                           - len(cache.entries))
        t = t_new
        t_first = -1
        if (checkpoint_dir is not None and bi is not None and t > last_ckpt
                and t - last_ckpt >= checkpoint_every):
            save_solver_state(checkpoint_dir, t, _capture_tree())
            last_ckpt = t
            stats.checkpoints += 1
        if t >= k or err <= eps:
            break
        if go:
            continue          # block boundary: re-enter at the next p
        # Certification failed at round t; the loop's own scan already
        # localized the blockers.  Repair the few offending cached rows
        # when possible, else refresh the buffer.
        maxv, sk_now, n_off = d_maxv, d_sk, int(d_noff)
        free = big_m + annex - annex_cursor
        if (has_arena and row_fetch is not None
                and 0 < n_off <= min(fm, free)
                and sk_now < maxv and np.isfinite(maxv)):
            gids, a_pos = offs     # extracted by the loop's stop branch
            ids_np = np.asarray(gids).copy()
            pos_np = np.asarray(a_pos).copy()
            # The worklist is the top-fm rows by upper bound: the true
            # offenders (u >= maxv, first by construction — they have
            # the highest bounds) plus a prefetch band that amortizes
            # future boundary crossings.  Clamp it to the free annex
            # room: admitting past it would scatter-drop the buffer
            # writes while still marking the rows in-buffer arena-side —
            # invisible to both scans, a silent exactness hole.  The
            # guard above (n_off <= free) keeps every true offender
            # inside the clamp.
            ids_np[free:] = -1
            pos_np[free:] = cache.cap_rows
            fetched, live = checked_fetch(ids_np, pos_np)
            br, bi, bdead, ar_inbuf = _admit_fetched(
                br, bi, bdead, jnp.asarray(fetched),
                jnp.asarray(np.where(live, ids_np, -1)),
                jnp.asarray(live), jnp.int32(annex_cursor),
                ar_inbuf, jnp.asarray(pos_np), fmax=fm)
            annex_cursor += int(live.sum())
            stats.fetched_rows += int(live.sum())
            stats.repairs += 1
            continue
        need_refresh = True

    return StreamingOMPResult(indices, weights, mask, jnp.float32(err),
                              stats)


# ---------------------------------------------------------------------------
# GRAD-MATCH wrappers
# ---------------------------------------------------------------------------

def gradmatch_streaming(
    pool_iter: Callable[[], Iterator],
    k: int,
    target=None,
    lam: float = 0.5,
    eps: float = 1e-10,
    buffer_size: int = 256,
    chunk_topm: Optional[int] = None,
    score_chunk_fn=None,
    cache: Optional[ChunkCache] = None,
    cache_bytes: int = DEFAULT_CACHE_BYTES,
    row_fetch: Optional[Callable] = None,
    retry: Optional["RetryPolicy"] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 8,
    resume: bool = True,
) -> SelectionResult:
    """GRAD-MATCH over a chunked pool; target defaults to one summing pass
    (which also warms the compressed cache).  The returned
    ``SelectionResult`` carries the solver's ``SelectStats``."""
    if target is None:
        if cache is None:
            first = next(iter(pool_iter()), None)
            if first is None:
                raise ValueError("empty pool iterator")
            cache = ChunkCache(cache_bytes, int(first[0].shape[1]))
        target, _ = streaming_target(pool_iter, cache=cache, retry=retry)
    out = omp_select_streaming(
        pool_iter, target, k, lam=lam, eps=eps, buffer_size=buffer_size,
        chunk_topm=chunk_topm, score_chunk_fn=score_chunk_fn, cache=cache,
        cache_bytes=cache_bytes, row_fetch=row_fetch, retry=retry,
        checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
        resume=resume)
    return SelectionResult(out.indices, _normalize(out.weights, out.mask),
                           out.mask, out.err, out.stats)


def gradmatch_streaming_array(
    proxies,                 # (n, d) array (in-memory or memmap)
    k: int,
    target=None,
    valid=None,
    lam: float = 0.5,
    eps: float = 1e-10,
    chunk_size: int = 2048,
    buffer_size: int = 256,
    score_chunk_fn=None,
    cache_bytes: int = DEFAULT_CACHE_BYTES,
) -> SelectionResult:
    """Streaming GRAD-MATCH over an explicit array, chunked on the fly.

    The target matches ``gradmatch``'s (full-matrix sum) so the two paths
    agree bit-for-bit on the pools the in-memory solver can hold; the
    array doubles as the exact-row fetch capability for the repair and
    cache-refill tiers.
    """
    if target is None:
        g = jnp.asarray(proxies, jnp.float32)
        if valid is None:
            target = jnp.sum(g, axis=0)
        else:
            target = jnp.sum(g * jnp.asarray(valid)[:, None].astype(g.dtype),
                             axis=0)
    out = omp_select_streaming(
        array_chunks(proxies, chunk_size, valid=valid), target, k, lam=lam,
        eps=eps, buffer_size=buffer_size, score_chunk_fn=score_chunk_fn,
        cache_bytes=cache_bytes, row_fetch=array_row_fetch(proxies))
    return SelectionResult(out.indices, _normalize(out.weights, out.mask),
                           out.mask, out.err, out.stats)
