"""Pod-scale GRAD-MATCH: sharded proxies + cross-host OMP (DESIGN.md §3).

At selection time the candidate proxy matrix ``G`` is ``(n, d)`` with rows
sharded over the data-parallel axis (each worker scored its own candidate
micro-batches — no gathering of ``G``).  OMP needs, per round:

  1. ``scores = G @ r``            — embarrassingly row-parallel (local)
  2. the global argmax             — one f32 ``pmax`` + index ``pmin``
  3. the winning row ``g_e``       — one masked ``psum`` of a (d,) vector

so per-round communication is ``O(d)`` (two scalars + one proxy vector),
``O(k * d)`` per selection round overall — negligible against a single
training step, which is the paper's requirement that selection cost stays
invisible at scale.  The small ``(k, k)`` NNLS is computed redundantly on
every shard (replicated), avoiding another collective; its Gram and
target-correlation buffers grow one row/col per round from the cached
active rows (same incremental scheme as ``omp.omp_select``) instead of
being rebuilt at ``O(k^2 d)`` each round.

The whole solver is ONE ``shard_map`` with a ``fori_loop`` inside: no host
round-trips, no per-round dispatch, works identically on the 512-way
dry-run mesh and the single-CPU test mesh.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.omp import _nnls_active_cached


def sharded_omp_select(
    mesh: Mesh,
    grads: jax.Array,            # (n, d) — will be row-sharded over `axis`
    target: jax.Array,           # (d,)   — replicated
    k: int,
    axis: str = "data",
    lam: float = 0.5,
    eps: float = 1e-10,
    nnls_iters: int = 50,
) -> SelectionResult:
    """Distributed OMP: same math as ``omp.omp_select``, sharded over rows.

    ``n`` must be divisible by the axis size (the caller pads the candidate
    pool; padded rows are zero so they can never win the argmax against the
    eps-stop).  Returns replicated (indices, weights, mask, err) with
    *global* candidate indices.
    """
    n, d = grads.shape
    n_shards = mesh.shape[axis]
    assert n % n_shards == 0, (n, n_shards)
    n_local = n // n_shards

    def solver(g_local: jax.Array, tgt: jax.Array):
        g_local = g_local.astype(jnp.float32)
        tgt = tgt.astype(jnp.float32)
        shard_id = lax.axis_index(axis)
        base = shard_id * n_local
        neg_inf = jnp.float32(-jnp.inf)

        def body(t, carry):
            (indices, mask, weights, rows, gram, absrow, tcorr, residual,
             err) = carry
            # 1) local scores against the shared residual.
            scores = g_local @ residual                      # (n_local,)
            # Slots owned by other shards (or unused) point at the
            # out-of-bounds sentinel n_local, dropped by the scatter —
            # an in-bounds sentinel would spuriously mark local candidate
            # 0 taken on multi-shard meshes.
            own = (indices >= base) & (indices < base + n_local) & mask
            local_slots = jnp.where(own, indices - base, n_local)
            taken = jnp.zeros((n_local,), bool).at[local_slots].set(
                own, mode="drop")
            scores = jnp.where(taken, neg_inf, scores)
            # 2) global argmax: pmax on value, pmin on index at max ties.
            best_local = jnp.argmax(scores).astype(jnp.int32)
            best_val = scores[best_local]
            gmax = lax.pmax(best_val, axis)
            cand = jnp.where(best_val == gmax, base + best_local,
                             jnp.int32(n))
            e = lax.pmin(cand, axis)                          # global id
            # 3) fetch the winning row with one masked psum.
            mine = (e >= base) & (e < base + n_local)
            row_local = g_local[jnp.where(mine, e - base, 0)]
            g_e = lax.psum(
                jnp.where(mine, row_local, jnp.zeros_like(row_local)), axis)

            grow = err > eps
            growf = grow.astype(jnp.float32)
            indices = indices.at[t].set(jnp.where(grow, e, -1))
            mask = mask.at[t].set(grow)
            g_e = g_e * growf
            rows = rows.at[t].set(g_e)
            # 4) grow the replicated Gram/target-correlation caches by one
            #    row/col (O(k d), vs the O(k^2 d) rebuild they replace) and
            #    re-solve the small NNLS on the cached buffers.
            row_vals = jnp.where(mask, rows @ g_e, 0.0)
            gram = gram.at[t, :].set(row_vals).at[:, t].set(row_vals)
            absrow = jnp.where(mask, absrow + jnp.abs(row_vals), 0.0)
            absrow = absrow.at[t].set(jnp.sum(jnp.abs(row_vals)))
            tcorr = tcorr.at[t].set(jnp.dot(g_e, tgt))
            weights = _nnls_active_cached(gram, absrow, rows, tcorr, mask,
                                          lam, nnls_iters)
            approx = weights @ rows
            residual = tgt - approx
            err = jnp.sum(residual ** 2) + lam * jnp.sum(weights ** 2)
            return (indices, mask, weights, rows, gram, absrow, tcorr,
                    residual, err)

        init = (
            jnp.full((k,), -1, jnp.int32),
            jnp.zeros((k,), bool),
            jnp.zeros((k,), jnp.float32),
            jnp.zeros((k, d), jnp.float32),
            jnp.zeros((k, k), jnp.float32),
            jnp.zeros((k,), jnp.float32),
            jnp.zeros((k,), jnp.float32),
            tgt,
            jnp.sum(tgt ** 2),
        )
        out = lax.fori_loop(0, k, body, init)
        indices, mask, weights, err = out[0], out[1], out[2], out[8]
        return indices, mask, weights, err

    mapped = jax.shard_map(
        solver, mesh=mesh,
        in_specs=(P(axis, None), P()),
        out_specs=(P(), P(), P(), P()),
    )
    indices, mask, weights, err = jax.jit(mapped)(grads, target)
    return SelectionResult(indices, _normalize(weights, mask), mask, err)


def sharded_gradmatch_pb(
    mesh: Mesh,
    example_proxies: jax.Array,   # (n, d) row-sharded candidate proxies
    batch_size: int,
    k_batches: int,
    axis: str = "data",
    lam: float = 0.5,
    eps: float = 1e-10,
    target: Optional[jax.Array] = None,
) -> SelectionResult:
    """GRAD-MATCHPB at pod scale.

    Per-batch mean proxies are computed shard-locally (each shard owns
    whole micro-batches); the full-pool target gradient is one ``psum``.
    """
    n, d = example_proxies.shape
    n_shards = mesh.shape[axis]
    assert n % (n_shards * batch_size) == 0, (n, n_shards, batch_size)

    def to_batches(g_local):
        nb = g_local.shape[0] // batch_size
        pb = g_local.reshape(nb, batch_size, -1).mean(axis=1)
        tgt = lax.psum(jnp.sum(pb, axis=0), axis)
        return pb, tgt

    pb, tgt = jax.jit(jax.shard_map(
        to_batches, mesh=mesh,
        in_specs=(P(axis, None),),
        out_specs=(P(axis, None), P()),
    ))(example_proxies.astype(jnp.float32))
    if target is not None:
        tgt = target
    return sharded_omp_select(mesh, pb, tgt, k_batches, axis=axis, lam=lam,
                              eps=eps)


# ---------------------------------------------------------------------------
# shard-parallel chunk scoring for streaming selection (core/streaming.py)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _pmap_scorer(m_loc: int, absolute: bool, need_norms: bool):
    """pmap'd per-device top-m chunk scorer (plain pmap — no shard_map, so
    it runs on older jax without AxisType; the shim note in DESIGN.md §3
    does not apply here)."""
    from repro.core.streaming import _score_chunk_impl

    def local(chunk, ok, gids, offset, residual, sel_idx, sel_mask):
        return _score_chunk_impl(chunk, ok, gids, offset, residual,
                                 sel_idx, sel_mask, m_loc, absolute,
                                 need_norms)

    return jax.pmap(local, in_axes=(0, 0, 0, 0, None, None, None))


def pmap_chunk_topm(chunk, pool_ok, gids, offset, residual, sel_idx,
                    sel_mask, *, m: int, absolute: bool,
                    need_norms: bool = True):
    """Shard-parallel drop-in for ``streaming._score_chunk``.

    Rows of the chunk are split across local devices; each computes its
    local top-m, the host merges to the global chunk top-m.  Thresholds
    are combined conservatively (max of local thresholds and the merged
    boundary), so the certification bound stays safe.
    """
    from repro.core import streaming as stream_lib

    ndev = jax.local_device_count()
    c, d = chunk.shape
    per = -(-c // ndev)
    pad = per * ndev - c
    if pad:
        chunk = jnp.pad(jnp.asarray(chunk, jnp.float32), ((0, pad), (0, 0)))
        pool_ok = jnp.pad(pool_ok, (0, pad))
        gids = jnp.pad(gids, (0, pad), constant_values=-1)
    m_loc = min(m, per)
    # Shard s owns the contiguous id range [offset + s*per, offset+(s+1)*per)
    offsets = offset + jnp.arange(ndev, dtype=jnp.int32) * per
    vals, ids, rows, ok, cmax, cthresh = _pmap_scorer(
        m_loc, absolute, need_norms)(
        chunk.reshape(ndev, per, d), pool_ok.reshape(ndev, per),
        gids.reshape(ndev, per), offsets, residual, sel_idx, sel_mask)
    # host-side merge of the ndev local buffers down to the chunk top-m
    mv = jnp.full((m,), -jnp.inf, jnp.float32)
    mi = jnp.full((m,), -1, jnp.int32)
    mr = jnp.zeros((m, d), jnp.float32)
    mok = jnp.zeros((m,), bool)
    for s in range(ndev):
        mv, mi, mr, mok = stream_lib._merge_topm(
            mv, mi, mr, mok, vals[s], ids[s], rows[s], ok[s], size=m)
    thresh = jnp.max(cthresh)
    if ndev * m_loc > m:           # merge itself dropped candidates
        thresh = jnp.maximum(thresh, mv[m - 1])
    return mv, mi, mr, mok, jnp.max(cmax), thresh


# ---------------------------------------------------------------------------
# shard-parallel facility-location gain scan (core/greedy.py, DESIGN.md §5)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _pmap_fl_scorer(per: int, row_block: int):
    """pmap'd per-device FL gain scorer over a candidate-column shard
    (plain pmap — no shard_map, so it runs on older jax; same pattern as
    ``_pmap_scorer`` above)."""
    from repro.core import greedy as greedy_lib

    def local(cand, cand_sqn, avail_l, offset, grads, sqnorms, cover,
              row_okf, l_max):
        gains = greedy_lib.fl_gains_cols(cand, cand_sqn, grads, sqnorms,
                                         cover, row_okf, l_max,
                                         block=row_block)
        gm = jnp.where(avail_l, gains, -jnp.inf)
        v = jnp.max(gm)
        # Lowest local position attaining the max (ties -> lowest global
        # id, since each shard owns a contiguous id range).
        pos = jnp.argmin(jnp.where(gm == v, jnp.arange(per), per))
        return v, offset + pos.astype(jnp.int32)

    return jax.pmap(local, in_axes=(0, 0, 0, 0, None, None, None, None,
                                    None))


class FLPoolShards(NamedTuple):
    """Round-invariant operands of the sharded gain scan, prepared once:
    the candidate shards, their norms, the replicated pool and the shard
    id offsets.  Only (cover, avail) change between greedy rounds, so
    only they are re-fed per round."""
    cand: jax.Array       # (ndev, per, d) candidate column shards
    cand_sqn: jax.Array   # (ndev, per)
    offsets: jax.Array    # (ndev,) global id base per shard
    grads: jax.Array      # (n, d) replicated coverage-row pool, f32
    sqnorms: jax.Array    # (n,)
    per: int
    n: int


def shard_fl_pool(grads) -> FLPoolShards:
    ndev = jax.local_device_count()
    n, d = grads.shape
    g = jnp.asarray(grads, jnp.float32)
    sqnorms = jnp.sum(g * g, axis=1)
    per = -(-n // ndev)
    pad = per * ndev - n
    cand = jnp.pad(g, ((0, pad), (0, 0))).reshape(ndev, per, d)
    cand_sqn = jnp.pad(sqnorms, (0, pad)).reshape(ndev, per)
    offsets = jnp.arange(ndev, dtype=jnp.int32) * per
    return FLPoolShards(cand, cand_sqn, offsets, g, sqnorms, per, n)


def pmap_fl_gains(shards: FLPoolShards, cover, avail, row_okf, l_max, *,
                  row_block: int = 256):
    """One facility-location gain scan, candidate columns sharded across
    local devices.  Returns the replicated (argmax id, max gain) with
    global lowest-id tie-breaking — the per-round collective of the
    sharded CRAIG greedy.  The similarity is reconstructed from the pool
    in (row_block, per-shard) strips, so no device ever holds an (n, n)
    block."""
    ndev = shards.cand.shape[0]
    avail_p = jnp.pad(avail, (0, ndev * shards.per - shards.n))
    vals, ids = _pmap_fl_scorer(shards.per, row_block)(
        shards.cand, shards.cand_sqn, avail_p.reshape(ndev, shards.per),
        shards.offsets, shards.grads, shards.sqnorms, cover, row_okf,
        jnp.asarray(l_max, jnp.float32))
    gmax = jnp.max(vals)
    e = jnp.min(jnp.where(vals == gmax, ids, jnp.int32(shards.n)))
    return e, gmax


def fl_greedy_pmap(grads, k: int, valid=None, l_max=None,
                   row_block: int = 256):
    """CRAIG's greedy with every per-round gain scan pmap-sharded over
    local devices (each shard scores its candidate columns, the host
    merges one (value, id) pair per device — O(devices) per-round
    traffic, mirroring ``sharded_omp_select``'s pmax/pmin election).

    Scan semantics match the dense oracle (every round is a full exact
    scan), so selections are index-identical to ``greedy.fl_greedy
    (method="dense")`` up to similarity-reconstruction rounding; the
    similarity itself is tiled on the fly, never materialized.
    """
    from repro.core import greedy as greedy_lib
    from repro.core.greedy import GreedyResult, GreedyStats

    n = grads.shape[0]
    if valid is None:
        valid = jnp.ones((n,), bool)
    row_okf = valid.astype(jnp.float32)
    lm = greedy_lib.default_l_max(grads) if l_max is None else l_max
    lm = jnp.asarray(lm, jnp.float32)
    shards = shard_fl_pool(grads)     # round-invariant: shipped once

    indices = jnp.full((k,), -1, jnp.int32)
    mask = jnp.zeros((k,), bool)
    picked = jnp.zeros((k,), jnp.float32)
    cover = jnp.zeros((n,), jnp.float32)
    avail = valid
    for t in range(int(k)):
        if not bool(jnp.any(avail)):
            break
        e, gain = pmap_fl_gains(shards, cover, avail, row_okf, lm,
                                row_block=row_block)
        indices = indices.at[t].set(e)
        mask = mask.at[t].set(True)
        picked = picked.at[t].set(gain)
        col = greedy_lib.fl_rows(shards.grads, shards.sqnorms, row_okf,
                                 lm, e[None])[0]
        cover = jnp.maximum(cover, col)
        avail = avail & ~(jnp.arange(n) == e)
    stats = GreedyStats(rounds=int(jnp.sum(mask)),
                        rescans=int(jnp.sum(mask)))
    return GreedyResult(indices, mask, picked, cover, stats)


# ---------------------------------------------------------------------------
# device-parallel partition solves (core/partition.py, DESIGN.md §9)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _pmap_partition_solver(k: int, lam: float, eps: float, nnls_iters: int,
                           method: str, block: int):
    """pmap'd per-device partition OMP (plain pmap — no shard_map, so it
    runs on older jax without AxisType; same pattern as ``_pmap_scorer``
    above).  One device solves one whole partition; partitions are
    independent problems, so no collective is ever needed."""
    from repro.core.omp import omp_select

    def local(grads, target, valid):
        return omp_select(grads, target, k=k, lam=lam, eps=eps,
                          nnls_iters=nnls_iters, valid=valid,
                          method=method, block=block)

    return jax.pmap(local, in_axes=(0, 0, 0))


def pmap_partition_omp(parts, targets, valids, k: int, lam: float = 0.5,
                       eps: float = 1e-10, nnls_iters: int = 50,
                       method: str = "incremental", block: int = 128):
    """Solve ``P`` independent partition OMPs device-parallel.

    ``parts`` is ``(P, n_max, d)`` padded partition pools, ``targets``
    ``(P, d)``, ``valids`` ``(P, n_max)`` (padding rows False).  Partitions
    are dispatched in groups of ``local_device_count``; a ragged tail
    group is padded by repeating its first partition and the extra solves
    dropped.  Returns ``(idx, w, mask, err)`` stacked over partitions with
    *partition-local* row indices — the caller owns the local→global map.
    """
    parts = jnp.asarray(parts, jnp.float32)
    targets = jnp.asarray(targets, jnp.float32)
    valids = jnp.asarray(valids, bool)
    ndev = jax.local_device_count()
    p_total = parts.shape[0]
    fn = _pmap_partition_solver(int(k), float(lam), float(eps),
                                int(nnls_iters), str(method), int(block))
    outs = []
    for s in range(0, p_total, ndev):
        g = parts[s:s + ndev]
        t = targets[s:s + ndev]
        v = valids[s:s + ndev]
        got = g.shape[0]
        if got < ndev:
            reps = ndev - got
            g = jnp.concatenate([g, jnp.repeat(g[:1], reps, axis=0)])
            t = jnp.concatenate([t, jnp.repeat(t[:1], reps, axis=0)])
            v = jnp.concatenate([v, jnp.repeat(v[:1], reps, axis=0)])
        idx, w, mask, err = fn(g, t, v)
        outs.append((idx[:got], w[:got], mask[:got], err[:got]))
    return tuple(jnp.concatenate([o[i] for o in outs], axis=0)
                 for i in range(4))


def replicate(mesh: Mesh, x: jax.Array) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P()))


def shard_rows(mesh: Mesh, x: jax.Array, axis: str = "data") -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P(axis)))
