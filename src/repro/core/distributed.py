"""Pod-scale GRAD-MATCH: sharded proxies + cross-host OMP (DESIGN.md §3).

At selection time the candidate proxy matrix ``G`` is ``(n, d)`` with rows
sharded over the data-parallel axis (each worker scored its own candidate
micro-batches — no gathering of ``G``).  OMP needs, per round:

  1. ``scores = G @ r``            — embarrassingly row-parallel (local)
  2. the global argmax             — one f32 ``pmax`` + index ``pmin``
  3. the winning row ``g_e``       — one masked ``psum`` of a (d,) vector

so per-round communication is ``O(d)`` (two scalars + one proxy vector),
``O(k * d)`` per selection round overall — negligible against a single
training step, which is the paper's requirement that selection cost stays
invisible at scale.  The small ``(k, k)`` NNLS is computed redundantly on
every shard (replicated), avoiding another collective.

The whole solver is ONE ``shard_map`` with a ``fori_loop`` inside: no host
round-trips, no per-round dispatch, works identically on the 512-way
dry-run mesh and the single-CPU test mesh.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.omp import _nnls_active


def sharded_omp_select(
    mesh: Mesh,
    grads: jax.Array,            # (n, d) — will be row-sharded over `axis`
    target: jax.Array,           # (d,)   — replicated
    k: int,
    axis: str = "data",
    lam: float = 0.5,
    eps: float = 1e-10,
    nnls_iters: int = 50,
) -> SelectionResult:
    """Distributed OMP: same math as ``omp.omp_select``, sharded over rows.

    ``n`` must be divisible by the axis size (the caller pads the candidate
    pool; padded rows are zero so they can never win the argmax against the
    eps-stop).  Returns replicated (indices, weights, mask, err) with
    *global* candidate indices.
    """
    n, d = grads.shape
    n_shards = mesh.shape[axis]
    assert n % n_shards == 0, (n, n_shards)
    n_local = n // n_shards

    def solver(g_local: jax.Array, tgt: jax.Array):
        g_local = g_local.astype(jnp.float32)
        tgt = tgt.astype(jnp.float32)
        shard_id = lax.axis_index(axis)
        base = shard_id * n_local
        neg_inf = jnp.float32(-jnp.inf)

        def body(t, carry):
            indices, mask, weights, rows, residual, err = carry
            # 1) local scores against the shared residual.
            scores = g_local @ residual                      # (n_local,)
            taken = jnp.zeros((n_local,), bool)
            local_slots = jnp.where(
                (indices >= base) & (indices < base + n_local) & mask,
                indices - base, 0)
            taken = taken.at[local_slots].set(mask, mode="drop")
            scores = jnp.where(taken, neg_inf, scores)
            # 2) global argmax: pmax on value, pmin on index at max ties.
            best_local = jnp.argmax(scores).astype(jnp.int32)
            best_val = scores[best_local]
            gmax = lax.pmax(best_val, axis)
            cand = jnp.where(best_val == gmax, base + best_local,
                             jnp.int32(n))
            e = lax.pmin(cand, axis)                          # global id
            # 3) fetch the winning row with one masked psum.
            mine = (e >= base) & (e < base + n_local)
            row_local = g_local[jnp.where(mine, e - base, 0)]
            g_e = lax.psum(
                jnp.where(mine, row_local, jnp.zeros_like(row_local)), axis)

            grow = err > eps
            indices = indices.at[t].set(jnp.where(grow, e, -1))
            mask = mask.at[t].set(grow)
            rows = rows.at[t].set(
                jnp.where(grow, g_e, jnp.zeros_like(g_e)))
            # 4) replicated small NNLS on the active rows.
            gram = rows @ rows.T
            corr = rows @ tgt
            weights = _nnls_active(gram, corr, mask, lam, nnls_iters)
            approx = weights @ rows
            residual = tgt - approx
            err = jnp.sum(residual ** 2) + lam * jnp.sum(weights ** 2)
            return indices, mask, weights, rows, residual, err

        init = (
            jnp.full((k,), -1, jnp.int32),
            jnp.zeros((k,), bool),
            jnp.zeros((k,), jnp.float32),
            jnp.zeros((k, d), jnp.float32),
            tgt,
            jnp.sum(tgt ** 2),
        )
        indices, mask, weights, rows, residual, err = lax.fori_loop(
            0, k, body, init)
        return indices, mask, weights, err

    mapped = jax.shard_map(
        solver, mesh=mesh,
        in_specs=(P(axis, None), P()),
        out_specs=(P(), P(), P(), P()),
    )
    indices, mask, weights, err = jax.jit(mapped)(grads, target)
    return SelectionResult(indices, _normalize(weights, mask), mask, err)


def sharded_gradmatch_pb(
    mesh: Mesh,
    example_proxies: jax.Array,   # (n, d) row-sharded candidate proxies
    batch_size: int,
    k_batches: int,
    axis: str = "data",
    lam: float = 0.5,
    eps: float = 1e-10,
    target: Optional[jax.Array] = None,
) -> SelectionResult:
    """GRAD-MATCHPB at pod scale.

    Per-batch mean proxies are computed shard-locally (each shard owns
    whole micro-batches); the full-pool target gradient is one ``psum``.
    """
    n, d = example_proxies.shape
    n_shards = mesh.shape[axis]
    assert n % (n_shards * batch_size) == 0, (n, n_shards, batch_size)

    def to_batches(g_local):
        nb = g_local.shape[0] // batch_size
        pb = g_local.reshape(nb, batch_size, -1).mean(axis=1)
        tgt = lax.psum(jnp.sum(pb, axis=0), axis)
        return pb, tgt

    pb, tgt = jax.jit(jax.shard_map(
        to_batches, mesh=mesh,
        in_specs=(P(axis, None),),
        out_specs=(P(axis, None), P()),
    ))(example_proxies.astype(jnp.float32))
    if target is not None:
        tgt = target
    return sharded_omp_select(mesh, pb, tgt, k_batches, axis=axis, lam=lam,
                              eps=eps)


def replicate(mesh: Mesh, x: jax.Array) -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P()))


def shard_rows(mesh: Mesh, x: jax.Array, axis: str = "data") -> jax.Array:
    return jax.device_put(x, NamedSharding(mesh, P(axis)))
