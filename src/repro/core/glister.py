"""GLISTER baseline (Killamsetty et al. 2021), Taylor-approximated greedy.

As characterized in the paper (S3.2): GLISTER's Taylor approximation amounts
to greedily maximizing the dot product between the summed subset training
gradients and the validation (or training) gradient, *without* learned
weights.  We implement the online variant: after each pick the validation
gradient estimate is advanced one Taylor step,

    v  <-  v - eta * g_e      (theta' = theta - eta * g_e  =>
                               grad L_V(theta') ~ v - eta H g_e ~ v - eta g_e
                               under the GLISTER identity-Hessian approx.)

which reduces to repeated argmax of g_j . v with a shrinking v — this is what
makes it different from (and per the paper, slightly weaker than) GRAD-MATCH.

The loop runs on the shared greedy engine (``greedy.modular_greedy``,
DESIGN.md §5): the per-round masked argmax goes through the fused
``ops.corr_argmax`` kernel (the score vector never hits HBM on TPU), and
the per-round constants — the row norms ``||g_e||`` — are hoisted out of
the ``fori_loop`` body into one precomputed ``(n,)`` array.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import greedy as greedy_lib
from repro.core.gradmatch import SelectionResult


def glister(
    grads: jax.Array,          # (n, d) candidate training-gradient proxies
    val_grad: jax.Array,       # (d,)  validation (or full-train) gradient
    k: int,
    eta: float = 1.0,
    valid: jax.Array | None = None,
) -> SelectionResult:
    grads = grads.astype(jnp.float32)
    # Hoisted per-round constants: row norms (the loop used to recompute
    # ||g_e|| every round) and the 1/k Taylor step scale.
    norms = jnp.sqrt(jnp.sum(grads * grads, axis=1))
    scale = jnp.float32(1.0 / k)
    eta = jnp.float32(eta)

    def advance(v, e, t):
        return v - eta * grads[e] / jnp.maximum(
            norms[e], 1e-8
        ) * scale * jnp.linalg.norm(v)

    indices, mask, _ = greedy_lib.modular_greedy(
        grads, k, advance, val_grad.astype(jnp.float32), valid=valid)
    # GLISTER is unweighted: uniform 1/k (paper: "does not consider a
    # weighted sum ... therefore slightly sub-optimal").
    w = mask.astype(jnp.float32) / jnp.maximum(jnp.sum(mask), 1)
    return SelectionResult(indices, w, mask, jnp.float32(0.0))
