"""GLISTER baseline (Killamsetty et al. 2021), Taylor-approximated greedy.

As characterized in the paper (S3.2): GLISTER's Taylor approximation amounts
to greedily maximizing the dot product between the summed subset training
gradients and the validation (or training) gradient, *without* learned
weights.  We implement the online variant: after each pick the validation
gradient estimate is advanced one Taylor step,

    v  <-  v - eta * g_e      (theta' = theta - eta * g_e  =>
                               grad L_V(theta') ~ v - eta H g_e ~ v - eta g_e
                               under the GLISTER identity-Hessian approx.)

which reduces to repeated argmax of g_j . v with a shrinking v — this is what
makes it different from (and per the paper, slightly weaker than) GRAD-MATCH.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.gradmatch import SelectionResult


def glister(
    grads: jax.Array,          # (n, d) candidate training-gradient proxies
    val_grad: jax.Array,       # (d,)  validation (or full-train) gradient
    k: int,
    eta: float = 1.0,
    valid: jax.Array | None = None,
) -> SelectionResult:
    n = grads.shape[0]
    grads = grads.astype(jnp.float32)
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)
    neg_inf = jnp.float32(-jnp.inf)

    def body(t, carry):
        indices, mask, v = carry
        scores = grads @ v
        # Unused slots point at the out-of-bounds sentinel n so mode="drop"
        # discards them (an in-bounds sentinel races duplicate writes when
        # candidate n-1 is genuinely selected — see omp.py).
        taken = jnp.zeros((n,), dtype=bool).at[
            jnp.where(mask, indices, n)
        ].set(mask, mode="drop")
        scores = jnp.where(valid & ~taken, scores, neg_inf)
        e = jnp.argmax(scores).astype(jnp.int32)
        indices = indices.at[t].set(e)
        mask = mask.at[t].set(True)
        v = v - eta * grads[e] / jnp.maximum(
            jnp.linalg.norm(grads[e]), 1e-8
        ) * jnp.float32(1.0 / k) * jnp.linalg.norm(v)
        return indices, mask, v

    indices0 = jnp.full((k,), -1, dtype=jnp.int32)
    mask0 = jnp.zeros((k,), dtype=bool)
    indices, mask, _ = lax.fori_loop(
        0, k, body, (indices0, mask0, val_grad.astype(jnp.float32))
    )
    # GLISTER is unweighted: uniform 1/k (paper: "does not consider a
    # weighted sum ... therefore slightly sub-optimal").
    w = mask.astype(jnp.float32) / jnp.maximum(jnp.sum(mask), 1)
    return SelectionResult(indices, w, mask, jnp.float32(0.0))
