"""Seeded, deterministic fault injection for chunk streams and row fetches.

The recovery machinery in ``core/streaming.py`` carries a differential
guarantee — under transient faults the selection is bit-identical to the
fault-free run — and a guarantee needs an adversary.  This module is that
adversary: wrappers that make a chunk factory or a ``row_fetch`` callable
misbehave on a schedule that is a pure function of ``(seed, site)``, so

* two runs with the same plan see the *same* faults in the same places
  (run-to-run determinism of the recovered selection is testable), and
* the schedule does not depend on wall clock, process state, or global
  RNG state (injection composes with jit, caching, and retries).

Fault classes (DESIGN.md §8):

``TransientFault``     goes away on re-read; the retry policy's domain.
  ``ChunkReadError``   a chunk read raised (I/O error analogue).
  ``RowFetchError``    an exact-row fetch raised.
  ``CorruptChunkError``a re-read chunk's content disagrees with the
                       cache's exact-norm sidecars (bit-flip analogue);
                       raised by the *engine*, not here — injection just
                       perturbs the data.
``StreamDied``         permanent: the stream is dead for good once its
                       yield budget is spent (process/socket death
                       analogue).  Not retryable; the serve ladder's
                       domain.

Corruption is injected silently (perturbed arrays, no exception) — the
point is to prove the engine *detects* it from the f32 exact-norm
sidecars rather than trusting the read.  First reads of a chunk are never
corrupted: the sidecar written on first contact is the ground truth the
detector compares against, so corrupting it would redefine truth, not
attack it.
"""

from __future__ import annotations

import time
from collections import Counter
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


class FaultError(RuntimeError):
    """Base class for injected/recovered stream faults."""


class TransientFault(FaultError):
    """A fault expected to clear on re-read; retry policies catch these."""


class ChunkReadError(TransientFault):
    """Transient chunk-read failure (I/O error analogue)."""


class RowFetchError(TransientFault):
    """Transient exact-row fetch failure."""


class CorruptChunkError(TransientFault):
    """A chunk's content disagrees with its exact-norm sidecars.

    Transient because a re-read usually clears it (bad DMA, bad wire);
    persistent disagreement is quarantined row-by-row by the engine.
    """


class StreamDied(FaultError):
    """Permanent mid-pass stream death — retries cannot help."""


class SimulatedCrash(FaultError):
    """Raised by ``crash_after`` hooks to model a kill mid-commit.

    The artifact store's ``put`` forwards named stages to the hook; the
    stage it raises at decides what half-written state is left on disk
    (see ``repro.artifacts.store.CRASH_STAGES``).  Never retried — the
    point is what the *next* process finds.
    """


_KIND = {"io": 1, "corrupt": 2, "slow": 3, "row_io": 4, "row_corrupt": 5,
         "disk": 6}


def _draw(seed: int, kind: str, *coords: int) -> float:
    """Uniform in [0, 1), a pure function of (seed, kind, coords)."""
    rng = np.random.default_rng((int(seed), _KIND[kind]) + tuple(
        int(c) for c in coords))
    return float(rng.random())


@dataclass(frozen=True)
class FaultPlan:
    """What to inject, at what rate, keyed on ``seed``.

    Rates are per *encounter*: the e-th time chunk ``c`` (or a row-fetch
    call) is served draws independently from the (seed, c, e) stream, so
    retries see fresh draws but identical runs see identical schedules.
    """

    seed: int = 0
    transient_rate: float = 0.0   # P(chunk read raises ChunkReadError)
    corrupt_rate: float = 0.0     # P(chunk content perturbed); never on
                                  # first encounter (sidecar = ground truth)
    slow_rate: float = 0.0        # P(chunk delayed by slow_s)
    slow_s: float = 0.001
    die_after_chunks: Optional[int] = None  # StreamDied once this many
                                            # chunks were yielded, forever
    die_once: bool = False        # death fires once, then the stream is
                                  # healthy (crashed-and-restarted loader)
    row_transient_rate: float = 0.0  # P(row_fetch call raises)
    row_corrupt_rate: float = 0.0    # P(a fetched row is perturbed), per
                                     # row per call (transient)
    corrupt_ids: tuple = ()          # row ids row_fetch *always* returns
                                     # corrupted (persistent corruption)


def _perturb(rows: np.ndarray) -> np.ndarray:
    """Corrupt row content so the f32 norm moves decisively.

    A sign flip would preserve the norm and dodge the sidecar detector,
    so scale-and-shift instead — the analogue of an exponent-bit flip.
    """
    bad = np.asarray(rows, np.float32).copy()
    bad *= 1.5
    bad += 0.125
    return bad


class FaultyChunkIterator:
    """Wrap a ``(chunk, valid)`` factory with a seeded fault schedule.

    Instances are callables with the same protocol as the factory they
    wrap (each call opens a fresh pass), so they drop into
    ``omp_select_streaming`` / ``streaming_target`` / the serve registry
    unchanged.  Injection bookkeeping (``injected`` counter, encounter
    counts) is observational state only — the schedule itself depends
    only on the plan and per-chunk encounter numbers.
    """

    def __init__(self, inner: Callable, plan: FaultPlan,
                 sleeper: Callable[[float], None] = time.sleep):
        self.inner = inner
        self.plan = plan
        self._sleep = sleeper
        self.passes = 0
        self.yielded = 0            # total chunks served across all passes
        self.encounters: Counter = Counter()   # chunk idx -> times served
        self.injected: Counter = Counter()     # fault kind -> count

    def __call__(self):
        self.passes += 1
        plan = self.plan

        def gen():
            for cidx, item in enumerate(self.inner()):
                if (plan.die_after_chunks is not None
                        and self.yielded >= plan.die_after_chunks
                        and not (plan.die_once
                                 and self.injected["died"] > 0)):
                    self.injected["died"] += 1
                    raise StreamDied(
                        f"stream died after {self.yielded} chunks "
                        f"(die_after_chunks={plan.die_after_chunks})")
                enc = self.encounters[cidx]
                self.encounters[cidx] += 1
                if _draw(plan.seed, "io", cidx, enc) < plan.transient_rate:
                    self.injected["transient"] += 1
                    raise ChunkReadError(
                        f"injected transient read fault at chunk {cidx} "
                        f"(encounter {enc}, seed {plan.seed})")
                if plan.slow_rate and _draw(
                        plan.seed, "slow", cidx, enc) < plan.slow_rate:
                    self.injected["slow"] += 1
                    self._sleep(plan.slow_s)
                chunk, valid = item
                if enc > 0 and _draw(
                        plan.seed, "corrupt", cidx, enc) < plan.corrupt_rate:
                    self.injected["corrupt"] += 1
                    chunk = _perturb(np.asarray(chunk))
                self.yielded += 1
                yield chunk, valid

        return gen()


def faulty_row_fetch(inner: Callable, plan: FaultPlan,
                     injected: Optional[Counter] = None) -> Callable:
    """Wrap a ``row_fetch(ids) -> rows`` callable with seeded faults.

    Transient raises and transient per-row corruption draw per call
    (encounter = call number); rows in ``plan.corrupt_ids`` come back
    corrupted on *every* call — the persistent-corruption case the engine
    must quarantine rather than retry forever.
    """
    counts = injected if injected is not None else Counter()
    calls = [0]

    def fetch(ids):
        call = calls[0]
        calls[0] += 1
        if _draw(plan.seed, "row_io", call) < plan.row_transient_rate:
            counts["row_transient"] += 1
            raise RowFetchError(
                f"injected transient row-fetch fault (call {call}, "
                f"seed {plan.seed})")
        rows = np.asarray(inner(ids), np.float32)
        ids_np = np.asarray(ids, np.int64)
        bad = np.zeros(len(ids_np), bool)
        if plan.row_corrupt_rate:
            bad |= np.array([
                _draw(plan.seed, "row_corrupt", call, j)
                < plan.row_corrupt_rate
                for j in range(len(ids_np))])
        if plan.corrupt_ids:
            bad |= np.isin(ids_np, np.asarray(plan.corrupt_ids, np.int64))
        if bad.any():
            counts["row_corrupt"] += int(bad.sum())
            rows = rows.copy()
            rows[bad] = _perturb(rows[bad])
        return rows

    fetch.injected = counts
    return fetch


# ---------------------------------------------------------------------------
# disk faults: the artifact store's adversary (DESIGN.md §12)
# ---------------------------------------------------------------------------

# Every way the fault suite knows how to corrupt a committed artifact.
# The differential guarantee is quantified over this set: for each kind,
# the store must either serve a verified artifact or report a miss —
# never a corrupt result.
DISK_FAULT_KINDS = (
    "torn-write",          # a blob truncated at a seeded byte offset
    "bit-flip",            # one seeded bit flipped inside a blob
    "truncated-manifest",  # the manifest cut off at a seeded offset
    "kill-between-rename", # blobs committed, manifest never renamed in
    "stale-version",       # valid manifest from an old schema version
)


def crash_after(stage: str) -> Callable[[str], None]:
    """Hook for ``ArtifactStore.put(..., crash=...)``: raise
    ``SimulatedCrash`` when the commit reaches ``stage`` (one of
    ``repro.artifacts.store.CRASH_STAGES``), leaving the store exactly as
    a kill at that point would."""

    def hook(at: str) -> None:
        if at == stage:
            raise SimulatedCrash(f"simulated kill at commit stage {at!r}")

    return hook


def inject_disk_fault(store, ident: str, kind: str, seed: int = 0) -> dict:
    """Corrupt the *committed* artifact ``ident`` in ``store`` in place.

    Pure function of ``(seed, kind, ident)``: which blob, which byte, and
    which bit are seeded draws, so two runs of a fault test mutate the
    same bytes.  Returns a description of what was done (for assertion
    messages).  ``store`` is an ``ArtifactStore``; imported lazily so
    this module keeps zero dependency on the artifacts package.
    """
    import json

    if kind not in DISK_FAULT_KINDS:
        raise ValueError(f"unknown disk fault kind {kind!r}; "
                         f"known: {DISK_FAULT_KINDS}")
    man_path = store.manifest_path(ident)
    with open(man_path) as f:
        manifest = json.load(f)
    # Stable coordinate stream per (seed, kind, ident).
    rng = np.random.default_rng(
        (int(seed), _KIND["disk"], DISK_FAULT_KINDS.index(kind),
         int(ident[:8], 16)))

    if kind in ("torn-write", "bit-flip"):
        blobs = sorted(manifest["blobs"].items())
        name, spec = blobs[int(rng.integers(len(blobs)))]
        path = store.object_path(spec["sha256"])
        size = spec["nbytes"]
        if kind == "torn-write":
            cut = int(rng.integers(max(size - 1, 1)))
            with open(path, "rb+") as f:
                f.truncate(cut)
            return {"kind": kind, "blob": name, "cut_at": cut}
        byte = int(rng.integers(size))
        bit = int(rng.integers(8))
        with open(path, "rb+") as f:
            f.seek(byte)
            (old,) = f.read(1)
            f.seek(byte)
            f.write(bytes([old ^ (1 << bit)]))
        return {"kind": kind, "blob": name, "byte": byte, "bit": bit}

    if kind == "truncated-manifest":
        size = max(store_manifest_size(store, ident), 2)
        cut = int(rng.integers(1, size))
        with open(man_path, "rb+") as f:
            f.truncate(cut)
        return {"kind": kind, "cut_at": cut}

    if kind == "kill-between-rename":
        # The on-disk state a kill between the blob renames and the
        # manifest rename leaves: objects present, manifest absent.
        import os
        os.unlink(man_path)
        return {"kind": kind}

    # stale-version: a *self-consistent* manifest (valid checksum) whose
    # schema the reader does not speak — version skew, not bit rot.
    from repro.artifacts.store import manifest_self_sha
    manifest["schema"] = 0
    manifest["manifest_sha"] = manifest_self_sha(manifest)
    with open(man_path, "w") as f:
        json.dump(manifest, f, sort_keys=True)
    return {"kind": kind, "schema": 0}


def store_manifest_size(store, ident: str) -> int:
    import os
    return os.path.getsize(store.manifest_path(ident))
