"""The graceful-degradation ladder: a labelled answer beats no answer.

When a certified streaming solve cannot be had — retries exhausted,
stream dead — the serve tier walks down a ladder of progressively weaker
answers instead of failing outright.  Every rung is *labelled* on the
ticket (``Ticket.degradation``), because the one unforgivable outcome is
passing a weaker answer off as certified:

``artifact``        a verified offline artifact slice (DESIGN.md §12):
                    indices/mask bit-exact to the live ``omp_select`` at
                    the requested k, weights bit-exact to the anytime
                    session engine, served off the drain path in O(1).
                    Above ``certified`` in the ladder because it answers
                    without touching the pool at all; every blob was
                    SHA-256 + norm-sidecar verified on load, and any
                    verification failure falls through to ``certified``.
``certified``       the real thing: streaming solve, certificate ladder
                    intact (also covers in-memory batched solves).
``resumed``         certified solve completed by resuming from the
                    mid-solve checkpoint of a failed attempt — the
                    answer is still bit-identical to fault-free, the
                    label records that recovery did the work.
``prefix-shared``   brownout rung: the answer is the first-k prefix of a
                    *shared* anytime session solved once for a group of
                    same-pool differing-k requests — indices certified
                    bit-exact vs the one-shot k solve by the prefix
                    property, weights renormalized (approximate).
``anytime-prefix``  first-k prefix of a live anytime session on the same
                    pool content: indices certified by the prefix
                    property, weights renormalized (approximate).
``stochastic``      seeded stochastic-greedy OMP over a subsample — of
                    the rows resident in the pool's compressed chunk
                    cache (chunked pools), or of the pool matrix itself
                    (array pools under overload) — clearly approximate.
``shed``            no solve at all: the overload controller rejected
                    the request at submit to protect higher-priority
                    work; the ticket is labelled, never silently dropped.
``timeout``/``failed``  no answer: deadline expired before work started,
                    or every rung failed.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np


class DeadlineExceeded(RuntimeError):
    """The request's deadline expired before a solve could start."""


DEGRADE_LEVELS = ("artifact", "certified", "resumed", "prefix-shared",
                  "anytime-prefix", "stochastic", "shed", "timeout",
                  "failed")


def stochastic_fallback(cache, target, k: int, seed: int = 0,
                        lam: float = 0.5, eps: float = 1e-10,
                        positive: bool = True,
                        sample_factor: int = 4,
                        min_sample: int = 256):
    """Last-resort selection from whatever the chunk cache holds.

    Decompresses the live (non-quarantined) bf16 arena rows, draws a
    seeded subsample of ``max(sample_factor*k, min_sample)`` of them, and
    runs the in-memory OMP on the subsample — stochastic-greedy in
    spirit: cheap, loader-free, and approximate.  Returns a
    ``SelectionResult`` whose indices are *global* row ids, or ``None``
    when the cache holds nothing usable (the ladder's next stop is
    failure).
    """
    from repro.core import omp as omp_lib

    if cache is None or cache.gids is None:   # no arena (cache_bytes=0)
        return None
    gids = np.asarray(cache.gids)
    ok = np.asarray(cache.ok)
    live = (gids >= 0) & ok
    n_live = int(live.sum())
    if n_live == 0:
        return None
    pos = np.flatnonzero(live)
    sample = min(max(int(sample_factor) * int(k), int(min_sample)), n_live)
    rng = np.random.default_rng(int(seed))
    pick = np.sort(rng.choice(pos, size=sample, replace=False))
    rows = jnp.asarray(cache.rows[jnp.asarray(pick)], jnp.float32)
    idx, w, mask, err = omp_lib.omp_select(
        rows, jnp.asarray(target, jnp.float32), int(k), lam=lam, eps=eps,
        positive=positive)
    local = np.asarray(idx)
    m = np.asarray(mask)
    global_idx = np.where(m, gids[pick[np.clip(local, 0, sample - 1)]], -1)
    from repro.core.gradmatch import SelectionResult
    return SelectionResult(jnp.asarray(global_idx, jnp.int32), w,
                           jnp.asarray(m), err)


def stochastic_pool_select(grads, target, k: int, seed: int = 0,
                           lam: float = 0.5, eps: float = 1e-10,
                           positive: bool = True, valid=None,
                           sample_factor: int = 4,
                           min_sample: int = 256):
    """The stochastic rung for *array* pools (the overload brownout's
    floor): seeded subsample of the valid rows, in-memory OMP over the
    subsample, indices mapped back to global row ids.

    Same contract as ``stochastic_fallback`` but over a resident ``(n,
    d)`` matrix instead of a chunk cache — O(sample·d·k) instead of the
    full O(n·d·k) solve, which is the whole point under overload.
    Returns ``None`` when no valid rows exist.
    """
    from repro.core import omp as omp_lib
    from repro.core.gradmatch import SelectionResult

    g = jnp.asarray(grads, jnp.float32)
    n = int(g.shape[0])
    if valid is not None:
        pos = np.flatnonzero(np.asarray(valid, bool))
    else:
        pos = np.arange(n)
    if pos.size == 0:
        return None
    sample = min(max(int(sample_factor) * int(k), int(min_sample)),
                 int(pos.size))
    rng = np.random.default_rng(int(seed))
    pick = np.sort(rng.choice(pos, size=sample, replace=False))
    rows = g[jnp.asarray(pick)]
    idx, w, mask, err = omp_lib.omp_select(
        rows, jnp.asarray(target, jnp.float32), int(k), lam=lam, eps=eps,
        positive=positive)
    local = np.asarray(idx)
    m = np.asarray(mask)
    global_idx = np.where(m, pick[np.clip(local, 0, sample - 1)], -1)
    return SelectionResult(jnp.asarray(global_idx, jnp.int32), w,
                           jnp.asarray(m), err)
