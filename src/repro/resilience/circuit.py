"""Per-pool circuit breakers: fail fast on pools that keep failing.

A multi-tenant scheduler with bounded retry has a failure amplifier built
in: a permanently poisoned pool makes every request against it burn the
full retry budget before failing, and the queue behind it starves.  The
standard fix is a breaker per pool:

* **closed** — requests flow; consecutive pool-fault failures count up.
* **open** — after ``failure_threshold`` consecutive failures: requests
  fail immediately (``CircuitOpen``), no solve attempted, for
  ``cooldown_s``.
* **half-open** — after the cooldown one trial request is let through;
  success closes the breaker, failure re-opens it for another cooldown.

Only *pool-level* faults (transient I/O that exhausted retries, stream
death, pass-budget blowups) should be recorded — a caller's malformed
request says nothing about the pool's health.  That classification is the
scheduler's job; the breaker just counts what it is told.

The clock is injectable monotonic seconds so tests drive cooldown
deterministically (same pattern as ``serve/sessions.py``).
"""

from __future__ import annotations

import time
from typing import Callable, Dict


class CircuitOpen(RuntimeError):
    """The pool's breaker is open — failing fast without attempting work."""


class CircuitBreaker:
    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self.state = "closed"            # closed | open | half-open
        self.failures = 0                # consecutive pool-fault failures
        self.opened_at = 0.0
        self.trips = 0                   # times the breaker opened

    def allow(self) -> None:
        """Raise ``CircuitOpen`` unless a request may proceed.

        In the open state, reaching the cooldown transitions to half-open
        and admits exactly one trial (subsequent ``allow`` calls keep
        raising until that trial reports back).
        """
        if self.state == "closed":
            return
        if self.state == "open":
            if self._clock() - self.opened_at < self.cooldown_s:
                raise CircuitOpen(
                    f"circuit open ({self.failures} consecutive pool "
                    f"faults; retrying after "
                    f"{self.cooldown_s:.1f}s cooldown)")
            self.state = "half-open"
            return
        # half-open: one trial is already in flight
        raise CircuitOpen("circuit half-open: trial request in flight")

    def peek(self) -> None:
        """Raise ``CircuitOpen`` iff the breaker is open and still cooling,
        without consuming the half-open trial slot — the submit-time check
        (drain owns the real ``allow``)."""
        if (self.state == "open"
                and self._clock() - self.opened_at < self.cooldown_s):
            raise CircuitOpen(
                f"circuit open ({self.failures} consecutive pool faults; "
                f"retrying after {self.cooldown_s:.1f}s cooldown)")

    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half-open" or \
                self.failures >= self.failure_threshold:
            if self.state != "open":
                self.trips += 1
            self.state = "open"
            self.opened_at = self._clock()

    def stats(self) -> dict:
        return {"state": self.state, "failures": self.failures,
                "trips": self.trips}


class BreakerBoard:
    """One breaker per pool id, created on first contact, shared config."""

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._breakers: Dict[str, CircuitBreaker] = {}

    def get(self, pool_id: str) -> CircuitBreaker:
        br = self._breakers.get(pool_id)
        if br is None:
            br = CircuitBreaker(self.failure_threshold, self.cooldown_s,
                                self._clock)
            self._breakers[pool_id] = br
        return br

    def stats(self) -> dict:
        return {pid: br.stats() for pid, br in self._breakers.items()}
