"""Bounded retry with exponential backoff — the transient-fault answer.

One policy object is shared by every recovery site (loader passes, row
fetches, the serve tier's whole-solve retry) so "how hard do we try" is
configured in one place.  The sleeper is injectable: tests drive hundreds
of retries without waiting, production gets real backoff.

Only ``TransientFault`` subclasses are retried — permanent faults
(``StreamDied``, a poisoned pool) escape immediately so the caller's
degradation ladder, not a retry loop, decides what happens next.
Exhausted retries raise ``RetryExhausted`` (itself *not* transient: an
outer retry layer must not multiply an inner one).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional, Tuple, Type

from repro.resilience.faults import FaultError, TransientFault


class RetryExhausted(FaultError):
    """A transient fault outlived its retry budget — treated as permanent."""


@dataclass(frozen=True)
class RetryPolicy:
    """``max_retries`` re-attempts after the first try; delay before the
    i-th retry is ``backoff_s * backoff_mult**i`` capped at
    ``max_backoff_s``."""

    max_retries: int = 4
    backoff_s: float = 0.05
    backoff_mult: float = 2.0
    max_backoff_s: float = 1.0
    sleep: Callable[[float], None] = time.sleep

    def delay(self, attempt: int) -> float:
        return min(self.backoff_s * self.backoff_mult ** attempt,
                   self.max_backoff_s)


# Retry-free policy: transient faults raise straight through (attempt 0
# only).  Useful as an explicit "no recovery" switch in tests and gates.
NO_RETRY = RetryPolicy(max_retries=0, backoff_s=0.0)


def with_retries(fn: Callable, policy: RetryPolicy,
                 transient: Tuple[Type[BaseException], ...] = (
                     TransientFault,),
                 on_retry: Optional[Callable[[int, BaseException], None]]
                 = None):
    """Run ``fn()`` with bounded retry of ``transient`` exceptions.

    ``on_retry(attempt, exc)`` fires before each re-attempt (stats
    accounting hooks).  Non-transient exceptions propagate untouched.
    """
    attempt = 0
    while True:
        try:
            return fn()
        except transient as exc:
            if isinstance(exc, RetryExhausted) or \
                    attempt >= policy.max_retries:
                raise RetryExhausted(
                    f"gave up after {attempt} retr"
                    f"{'y' if attempt == 1 else 'ies'}: {exc}") from exc
            if on_retry is not None:
                on_retry(attempt, exc)
            policy.sleep(policy.delay(attempt))
            attempt += 1
