"""Fault model, injection harness, and recovery machinery (DESIGN.md §8).

The streaming engine (core/streaming.py) and the selection service
(serve/) promise *certified* answers — bit-identical to the dense
reference solve.  That promise is only worth anything in production if it
survives the failures production actually has: transient loader I/O
errors, corrupted chunk reads, slow storage, processes killed mid-solve,
and pools whose backing data has gone permanently bad.  This package
supplies both halves of that story:

* **Injection** (``faults``): seeded, deterministic wrappers that make a
  chunk factory or ``row_fetch`` misbehave on a reproducible schedule —
  the test substrate for every recovery path — plus seeded *disk* faults
  (torn writes, bit flips, truncated manifests, kills mid-commit,
  version skew) for the artifact store (DESIGN.md §12).
* **Recovery** (``recovery``): the bounded-retry / exponential-backoff
  policy shared by the streaming engine and the serve tier, with an
  injectable sleeper so tests never actually wait.
* **Circuit breaking** (``circuit``): per-pool closed → open → half-open
  breakers so a permanently poisoned pool fails fast instead of wedging
  the scheduler queue behind endless retries.
* **Degradation** (``degrade``): the graceful-degradation ladder the
  serve tier walks when a certified solve cannot be had — resume from
  checkpoint, answer from an anytime-session prefix, or fall back to a
  stochastic in-cache solve — each answer labelled with the level that
  produced it, never silently passed off as certified.
"""

from repro.resilience.circuit import BreakerBoard, CircuitBreaker, CircuitOpen
from repro.resilience.degrade import (DEGRADE_LEVELS, DeadlineExceeded,
                                      stochastic_fallback)
from repro.resilience.faults import (DISK_FAULT_KINDS, ChunkReadError,
                                     CorruptChunkError, FaultError,
                                     FaultPlan, FaultyChunkIterator,
                                     RowFetchError, SimulatedCrash,
                                     StreamDied, TransientFault,
                                     crash_after, faulty_row_fetch,
                                     inject_disk_fault)
from repro.resilience.recovery import (RetryExhausted, RetryPolicy,
                                       with_retries)

__all__ = [
    "BreakerBoard", "CircuitBreaker", "CircuitOpen",
    "DEGRADE_LEVELS", "DeadlineExceeded", "stochastic_fallback",
    "ChunkReadError", "CorruptChunkError", "DISK_FAULT_KINDS", "FaultError",
    "FaultPlan", "FaultyChunkIterator", "RowFetchError", "SimulatedCrash",
    "StreamDied", "TransientFault", "crash_after", "faulty_row_fetch",
    "inject_disk_fault",
    "RetryExhausted", "RetryPolicy", "with_retries",
]
