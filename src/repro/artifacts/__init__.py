"""Durable selection artifacts: content-addressed offline trajectories
with end-to-end integrity and a fail-closed serve fast path.

See DESIGN.md §12.  ``store`` is the crash-safe write half, ``verify``
the fail-closed read half, ``build`` the offline solve-and-commit
pipeline.
"""

from repro.artifacts.build import artifact_key_for, build_artifact
from repro.artifacts.store import (
    SCHEMA_VERSION,
    ArtifactKey,
    ArtifactStore,
    SelectionArtifact,
    content_digest_array,
    target_sha256,
)
from repro.artifacts.verify import VerifyError, load_verified

__all__ = [
    "SCHEMA_VERSION",
    "ArtifactKey",
    "ArtifactStore",
    "SelectionArtifact",
    "VerifyError",
    "artifact_key_for",
    "build_artifact",
    "content_digest_array",
    "load_verified",
    "target_sha256",
]
