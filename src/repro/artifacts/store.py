"""Durable content-addressed store for offline selection artifacts.

The MILO-style fast path (ROADMAP, DESIGN.md §12): GRAD-MATCH solves the
same gradient-matching OMP problem repeatedly over hot pools, so a full
anytime-OMP *trajectory* to ``k_max`` is precomputed once per
(pool-content, λ, ε, positive, target) tuple and any budget ``k <=
k_max`` is answered in O(1) by slicing it.  That fast path is only
shippable if the persistence layer is robust — a disk artifact must
survive kill-during-write, bit rot and version skew, and the serve tier
must be able to *trust or provably reject* what it reads (fail closed to
the live certified solver).  This module is the write half; the read/
verify half lives in ``verify.py``.

Layout under one store root::

    root/
      objects/<aa>/<sha256-hex>     content-addressed blobs (raw array
                                    bytes; <aa> = first two hex chars)
      manifests/<ident>.json        one manifest per artifact, named by
                                    the key's identity hash
      quarantine/<ident>.json       manifests the verifier rejected
      tmp/<pid>-<token>/            staging for in-flight commits

Integrity discipline (the ChunkCache checksum idea, applied to disk):

* every blob is referenced from the manifest by **SHA-256 + byte count +
  dtype/shape + an f64 norm sidecar** — the hash catches bit rot and
  torn writes, the norm is the semantic cross-check (a blob that hashes
  correctly but decodes to the wrong magnitudes is still rejected);
* the manifest carries an explicit ``schema`` version and a
  **self-checksum** (``manifest_sha`` over the canonical JSON of every
  other field), so truncation and in-place edits are detectable without
  trusting any field being checked;
* commits are **atomic**: blobs are staged in ``tmp/``, fsynced, renamed
  into ``objects/`` one at a time, and only then is the manifest fsynced
  and renamed into place.  A kill at any byte leaves either the previous
  state or a complete new artifact — never a manifest that references a
  partial blob.  (A kill *between* the blob renames and the manifest
  rename leaves orphaned objects; see GC.)

GC is **mark-then-sweep** and crash-safe by construction: mark = every
digest referenced by a parseable manifest; sweep = unreferenced objects
older than ``grace_s`` plus all stale ``tmp/`` dirs.  GC never touches
manifests, so a crash mid-sweep only leaves garbage that the next sweep
collects — it can never un-commit an artifact.  ``grace_s`` exists
because a concurrent ``put`` renames its blobs before its manifest: a
sweep racing it must not collect blobs younger than the grace window.

``ArtifactStore.put`` accepts a ``crash`` hook (see
``resilience.faults.crash_after``) that raises at named commit stages —
the kill-during-write adversary the fault suite drives.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import uuid
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

SCHEMA_VERSION = 1

# Named stages the ``crash`` hook is called at, in commit order.  A hook
# that raises at "between-rename" leaves committed blobs with no
# manifest — exactly the kill-between-rename fault the GC must sweep.
CRASH_STAGES = ("pre-blob", "between-rename", "post-commit")


def array_sha256(x: np.ndarray) -> str:
    """Content digest of one array's raw bytes (C-contiguous)."""
    return hashlib.sha256(
        np.ascontiguousarray(x).tobytes()).hexdigest()


def _norm_sidecar(x: np.ndarray) -> float:
    """f64 L2 norm of the array's values — the ChunkCache-style semantic
    checksum recorded next to the byte hash.  Deterministic for a given
    byte string, so the verifier can require exact agreement."""
    return float(np.linalg.norm(
        np.ascontiguousarray(x).astype(np.float64).reshape(-1)))


def _canonical(obj) -> bytes:
    return json.dumps(obj, sort_keys=True,
                      separators=(",", ":")).encode()


def manifest_self_sha(manifest: dict) -> str:
    """Self-checksum over every manifest field except ``manifest_sha``."""
    body = {k: v for k, v in manifest.items() if k != "manifest_sha"}
    return hashlib.sha256(_canonical(body)).hexdigest()


def content_digest_array(x, valid=None) -> str:
    """Full-content pool digest: SHA-256 over shape, dtype, every row's
    raw f32 bytes, and the validity mask.  This is the *artifact key*
    fingerprint — unlike the registry's 64-row sampled fingerprint (an
    in-memory dedupe heuristic), two pools differing in any single
    element can never collide here, so an artifact can never be served
    for the wrong pool."""
    arr = np.ascontiguousarray(np.asarray(x, np.float32))
    h = hashlib.sha256()
    h.update(repr((arr.shape, str(arr.dtype))).encode())
    h.update(arr.tobytes())
    if valid is not None:
        v = np.ascontiguousarray(np.asarray(valid, bool))
        h.update(b"|valid|")
        h.update(v.tobytes())
    return h.hexdigest()


@dataclass(frozen=True)
class ArtifactKey:
    """What one selection artifact answers for: a pool's *full-content*
    digest (``content_digest_array`` above — the registry's sampled
    fingerprint is a dedupe key, never an artifact key), the solve
    parameters, and the target vector's digest."""

    fingerprint: str          # full-content pool digest (sha256 hex)
    lam: float
    eps: float
    positive: bool
    target_sha: str           # sha256 hex of the f32 target bytes

    def ident(self) -> str:
        return hashlib.sha256(_canonical(
            [self.fingerprint, float(self.lam), float(self.eps),
             bool(self.positive), self.target_sha])).hexdigest()[:32]


def target_sha256(target) -> str:
    return array_sha256(np.asarray(target, np.float32))


class SelectionArtifact:
    """A *verified* artifact resident in memory: the anytime trajectory
    to ``k_max`` plus its per-round weight/residual traces.  ``slice``
    answers any budget ``k <= k_max`` in O(k) copies — the serve tier's
    O(1)-per-request fast path (no pool scan, no solve)."""

    def __init__(self, key: ArtifactKey, meta: dict,
                 arrays: dict[str, np.ndarray]):
        self.key = key
        self.meta = dict(meta)
        self.arrays = arrays

    @property
    def k_max(self) -> int:
        return int(self.meta["k_max"])

    @property
    def n(self) -> int:
        return int(self.meta["n"])

    @property
    def d(self) -> int:
        return int(self.meta["d"])

    def slice(self, k: int) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                     np.floating]:
        """(indices (k,), weights (k,), mask (k,), err ()) at budget
        ``k`` — bit-identical to what the anytime session engine reports
        after round ``k`` (and index-identical to a one-shot
        ``omp_select(k)``; see DESIGN.md §12)."""
        k = int(k)
        if not 1 <= k <= self.k_max:
            raise ValueError(
                f"artifact covers 1 <= k <= {self.k_max}, asked {k}")
        return (self.arrays["indices"][:k],
                self.arrays["weights_traj"][k - 1, :k],
                self.arrays["mask"][:k],
                self.arrays["err_trace"][k - 1])


class ArtifactStore:
    """Content-addressed, crash-safe artifact persistence (module doc)."""

    def __init__(self, root: str):
        self.root = str(root)
        self.objects_dir = os.path.join(self.root, "objects")
        self.manifests_dir = os.path.join(self.root, "manifests")
        self.quarantine_dir = os.path.join(self.root, "quarantine")
        self.tmp_dir = os.path.join(self.root, "tmp")
        for p in (self.objects_dir, self.manifests_dir,
                  self.quarantine_dir, self.tmp_dir):
            os.makedirs(p, exist_ok=True)
        self.puts = 0
        self.loads = 0
        self.quarantined = 0
        self.gc_objects_swept = 0
        self.gc_tmp_swept = 0

    # -- paths ---------------------------------------------------------------
    def object_path(self, digest: str) -> str:
        return os.path.join(self.objects_dir, digest[:2], digest)

    def manifest_path(self, ident: str) -> str:
        return os.path.join(self.manifests_dir, f"{ident}.json")

    def has(self, key: ArtifactKey) -> bool:
        return os.path.exists(self.manifest_path(key.ident()))

    def idents(self) -> list[str]:
        return sorted(f[:-5] for f in os.listdir(self.manifests_dir)
                      if f.endswith(".json"))

    # -- commit --------------------------------------------------------------
    def put(self, key: ArtifactKey, arrays: dict[str, np.ndarray],
            meta: dict,
            crash: Optional[Callable[[str], None]] = None) -> str:
        """Atomically commit one artifact; returns its manifest ident.

        Stage order (and the ``crash`` hook's stage names): every blob is
        written to ``tmp/``, fsynced, renamed into ``objects/``
        (``crash("pre-blob")`` before the first write,
        ``crash("between-rename")`` after the last blob rename); then the
        manifest is written to ``tmp/``, fsynced, and renamed into
        ``manifests/`` (``crash("post-commit")`` after).  Re-putting an
        existing ident atomically replaces the manifest — blobs are
        content-addressed, so identical payload bytes are shared, and a
        changed payload's old blobs become garbage for the next sweep.
        """
        ident = key.ident()
        stage = os.path.join(self.tmp_dir,
                             f"{os.getpid()}-{uuid.uuid4().hex[:12]}")
        os.makedirs(stage)
        try:
            if crash is not None:
                crash("pre-blob")
            blobs = {}
            for name in sorted(arrays):
                arr = np.ascontiguousarray(arrays[name])
                raw = arr.tobytes()
                digest = hashlib.sha256(raw).hexdigest()
                blobs[name] = {
                    "sha256": digest,
                    "nbytes": len(raw),
                    "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                    "norm": _norm_sidecar(arr),
                }
                final = self.object_path(digest)
                # Dedupe on collision — but never *trust* it: a resident
                # file at this path whose bytes no longer hash to its
                # name (bit rot, torn write) would make the recommit a
                # reference to corruption.  Verify, and heal in place
                # with an atomic replace if the bytes disagree.
                resident_ok = False
                if os.path.exists(final):
                    try:
                        with open(final, "rb") as f:
                            resident_ok = (hashlib.sha256(
                                f.read()).hexdigest() == digest)
                    except OSError:
                        resident_ok = False
                if not resident_ok:
                    os.makedirs(os.path.dirname(final), exist_ok=True)
                    tmp_blob = os.path.join(stage, f"blob-{name}")
                    with open(tmp_blob, "wb") as f:
                        f.write(raw)
                        f.flush()
                        os.fsync(f.fileno())
                    os.replace(tmp_blob, final)
            if crash is not None:
                crash("between-rename")
            manifest = {
                "schema": SCHEMA_VERSION,
                "key": {"fingerprint": key.fingerprint,
                        "lam": float(key.lam), "eps": float(key.eps),
                        "positive": bool(key.positive),
                        "target_sha": key.target_sha},
                "meta": dict(meta),
                "blobs": blobs,
            }
            manifest["manifest_sha"] = manifest_self_sha(manifest)
            tmp_man = os.path.join(stage, "manifest.json")
            with open(tmp_man, "w") as f:
                json.dump(manifest, f, sort_keys=True)
                f.flush()
                os.fsync(f.fileno())
            os.rename(tmp_man, self.manifest_path(ident))
            self._fsync_dir(self.manifests_dir)
            if crash is not None:
                crash("post-commit")
        finally:
            # Only the happy path cleans its staging dir: after a crash
            # hook fired, the partial state is exactly what the fault
            # suite wants on disk (GC sweeps it later).
            if crash is None:
                shutil.rmtree(stage, ignore_errors=True)
        self.puts += 1
        return ident

    @staticmethod
    def _fsync_dir(path: str) -> None:
        try:
            fd = os.open(path, os.O_RDONLY)
        except OSError:
            return
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    # -- read (delegates to verify.py) ---------------------------------------
    def get(self, key: ArtifactKey) -> Optional[SelectionArtifact]:
        """Verified artifact for ``key``, or None (miss *or* quarantined
        — either way the caller falls through to the live solver)."""
        from repro.artifacts.verify import load_verified
        return load_verified(self, key)

    def quarantine(self, ident: str, reason: str) -> None:
        """Fail closed: move the manifest out of the servable namespace
        (atomic rename) and record why.  The artifact becomes a plain
        miss; its now-unreferenced blobs are swept by the next GC.  The
        quarantined manifest is kept as evidence, with the reason in a
        sidecar, rather than deleted — a corrupt artifact is a bug report,
        not just garbage."""
        src = self.manifest_path(ident)
        dst = os.path.join(self.quarantine_dir, f"{ident}.json")
        try:
            os.replace(src, dst)
        except OSError:
            try:
                os.unlink(src)
            except OSError:
                pass
        try:
            with open(os.path.join(self.quarantine_dir,
                                   f"{ident}.reason"), "w") as f:
                f.write(reason)
        except OSError:
            pass
        self.quarantined += 1

    # -- GC ------------------------------------------------------------------
    def gc(self, grace_s: float = 3600.0) -> dict:
        """Mark-then-sweep: delete objects no parseable manifest
        references (older than ``grace_s``) and stale tmp dirs.  Never
        touches manifests, so it cannot un-commit an artifact; a crash
        mid-sweep leaves only garbage the next sweep collects."""
        marked: set[str] = set()
        for ident in self.idents():
            try:
                with open(self.manifest_path(ident)) as f:
                    man = json.load(f)
                for b in man.get("blobs", {}).values():
                    marked.add(str(b.get("sha256")))
            except (OSError, json.JSONDecodeError, AttributeError):
                # Unparseable manifest: mark nothing for it — its blobs
                # are unreachable anyway (the verifier quarantines it on
                # the next read).
                continue
        import time as _time
        now = _time.time()
        objects_swept = 0
        for sub in os.listdir(self.objects_dir):
            subdir = os.path.join(self.objects_dir, sub)
            if not os.path.isdir(subdir):
                continue
            for name in os.listdir(subdir):
                path = os.path.join(subdir, name)
                if name in marked:
                    continue
                try:
                    if now - os.path.getmtime(path) < grace_s:
                        continue
                    os.unlink(path)
                    objects_swept += 1
                except OSError:
                    continue
        tmp_swept = 0
        for name in os.listdir(self.tmp_dir):
            path = os.path.join(self.tmp_dir, name)
            try:
                if now - os.path.getmtime(path) < grace_s:
                    continue
            except OSError:
                continue
            shutil.rmtree(path, ignore_errors=True)
            tmp_swept += 1
        self.gc_objects_swept += objects_swept
        self.gc_tmp_swept += tmp_swept
        return {"marked": len(marked), "objects_swept": objects_swept,
                "tmp_swept": tmp_swept}

    def stats(self) -> dict:
        return {"artifacts": len(self.idents()),
                "puts": self.puts,
                "loads": self.loads,
                "quarantined": self.quarantined,
                "gc_objects_swept": self.gc_objects_swept,
                "gc_tmp_swept": self.gc_tmp_swept}
