"""Fail-closed artifact verification (the read half of the store).

Nothing read off disk is trusted until it has survived every check below;
anything that fails any check is **quarantined** (moved out of the
servable namespace with a recorded reason) and reported as a miss, so
the caller falls through the PR 6 degradation ladder to the live
certified solver.  A stale-but-valid artifact is a correct answer; a
corrupt one is silently wrong — so the bias is always toward rejecting.

Check order on load (each failure names the fault kind it catches):

1. manifest parses as JSON                 — torn/truncated manifest
2. ``manifest_sha`` self-checksum matches  — in-place edit, bit-flip in
                                             the manifest itself
3. ``schema == SCHEMA_VERSION``            — version skew (an old reader
                                             must not guess at a new
                                             layout, and vice versa)
4. key fields round-trip                   — manifest filed under the
                                             wrong ident
5. required blobs present with coherent
   shapes (k_max/n/d cross-checks)         — builder bugs, partial puts
6. per blob: byte count, SHA-256 over the
   raw bytes, dtype/shape decode           — bit rot, torn blob writes,
                                             kill-between-rename (blob
                                             file missing entirely)
7. per blob: f64 norm sidecar matches      — semantic cross-check (a
                                             hash collision or a check
                                             ordering bug still cannot
                                             serve wrong magnitudes)
8. trajectory invariants: indices valid in
   [0, n) where masked, weights_traj lower
   -triangular, err_trace finite           — a *valid-looking* artifact
                                             that would still poison the
                                             solver contract
"""

from __future__ import annotations

import json
import os
from typing import Optional

import numpy as np

from repro.artifacts.store import (
    SCHEMA_VERSION,
    ArtifactKey,
    ArtifactStore,
    SelectionArtifact,
    _norm_sidecar,
    manifest_self_sha,
)

import hashlib

# Blobs every selection artifact must carry (name -> expected dtype).
REQUIRED_BLOBS = {
    "indices": "int32",
    "mask": "bool",
    "weights_traj": "float32",
    "err_trace": "float32",
    "target": "float32",
}

# Norm sidecars are f64 recomputed from the exact bytes read back, so
# agreement is near-exact; the tolerance only absorbs the JSON float
# round-trip (IEEE doubles survive json exactly, but keep a belt).
_NORM_RTOL = 1e-12


class VerifyError(Exception):
    """One named reason an artifact failed verification."""


def _fail(reason: str) -> None:
    raise VerifyError(reason)


def read_manifest(store: ArtifactStore, ident: str) -> dict:
    """Parse + self-check + schema-check one manifest (checks 1-3)."""
    path = store.manifest_path(ident)
    try:
        with open(path) as f:
            manifest = json.load(f)
    except FileNotFoundError:
        raise
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        _fail(f"manifest-unreadable: {e.__class__.__name__}")
    if not isinstance(manifest, dict):
        _fail("manifest-not-an-object")
    recorded = manifest.get("manifest_sha")
    if recorded != manifest_self_sha(manifest):
        _fail("manifest-self-checksum-mismatch")
    schema = manifest.get("schema")
    if schema != SCHEMA_VERSION:
        _fail(f"schema-version-skew: artifact={schema!r} "
              f"reader={SCHEMA_VERSION}")
    return manifest


def _verify_blob(store: ArtifactStore, name: str, spec: dict) -> np.ndarray:
    """Checks 6-7 for one blob: bytes exist, hash, decode, norm."""
    digest = spec.get("sha256")
    if not isinstance(digest, str) or len(digest) != 64:
        _fail(f"blob-{name}: malformed digest")
    path = store.object_path(digest)
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except OSError:
        _fail(f"blob-{name}: object missing ({digest[:12]})")
    if len(raw) != int(spec.get("nbytes", -1)):
        _fail(f"blob-{name}: size {len(raw)} != recorded "
              f"{spec.get('nbytes')}")
    if hashlib.sha256(raw).hexdigest() != digest:
        _fail(f"blob-{name}: sha256 mismatch")
    try:
        dtype = np.dtype(spec["dtype"])
        shape = tuple(int(s) for s in spec["shape"])
        arr = np.frombuffer(raw, dtype=dtype).reshape(shape)
    except (KeyError, TypeError, ValueError) as e:
        _fail(f"blob-{name}: undecodable ({e})")
    norm = _norm_sidecar(arr)
    recorded = spec.get("norm")
    if not isinstance(recorded, (int, float)) or not np.isclose(
            norm, float(recorded), rtol=_NORM_RTOL, atol=0.0):
        _fail(f"blob-{name}: norm sidecar mismatch "
              f"({norm!r} != {recorded!r})")
    return arr


def verify_manifest(store: ArtifactStore, key: ArtifactKey,
                    manifest: dict) -> SelectionArtifact:
    """Checks 4-8: key round-trip, blob set, blob integrity, semantics.

    Raises VerifyError on the first failure; returns the fully-verified
    in-memory artifact otherwise.
    """
    mkey = manifest.get("key", {})
    if (mkey.get("fingerprint") != key.fingerprint
            or mkey.get("target_sha") != key.target_sha
            or float(mkey.get("lam", np.nan)) != float(key.lam)
            or float(mkey.get("eps", np.nan)) != float(key.eps)
            or bool(mkey.get("positive")) != bool(key.positive)):
        _fail("key-mismatch: manifest filed under wrong ident")

    meta = manifest.get("meta", {})
    try:
        n, d, k_max = int(meta["n"]), int(meta["d"]), int(meta["k_max"])
    except (KeyError, TypeError, ValueError):
        _fail("meta-missing-dims")
    if k_max < 1 or n < 1 or d < 1:
        _fail(f"meta-bad-dims: n={n} d={d} k_max={k_max}")

    blobs = manifest.get("blobs", {})
    missing = sorted(set(REQUIRED_BLOBS) - set(blobs))
    if missing:
        _fail(f"blobs-missing: {missing}")

    arrays: dict[str, np.ndarray] = {}
    for name in sorted(blobs):
        arr = _verify_blob(store, name, blobs[name])
        want = REQUIRED_BLOBS.get(name)
        if want is not None and str(arr.dtype) != want:
            _fail(f"blob-{name}: dtype {arr.dtype} != {want}")
        arrays[name] = arr

    expect = {"indices": (k_max,), "mask": (k_max,),
              "weights_traj": (k_max, k_max), "err_trace": (k_max,),
              "target": (d,)}
    for name, shape in expect.items():
        if arrays[name].shape != shape:
            _fail(f"blob-{name}: shape {arrays[name].shape} != {shape}")

    # Check 8: semantic invariants of a trajectory (a byte-perfect blob
    # can still be a builder bug; refuse to serve it).
    idx, mask = arrays["indices"], arrays["mask"]
    if ((mask & ((idx < 0) | (idx >= n))).any()
            or (~mask & (idx != -1)).any()):
        _fail("trajectory-invalid-indices")
    wt = arrays["weights_traj"]
    if np.any(wt[np.triu_indices(k_max, k=1)] != 0.0):
        _fail("trajectory-weights-not-lower-triangular")
    if not np.all(np.isfinite(wt)) or not np.all(
            np.isfinite(arrays["err_trace"])):
        _fail("trajectory-nonfinite")

    return SelectionArtifact(key, meta, arrays)


def load_verified(store: ArtifactStore,
                  key: ArtifactKey) -> Optional[SelectionArtifact]:
    """The store's read path: verified artifact, or None (miss).

    A clean miss (no manifest on disk) returns None without side
    effects.  *Any* verification failure quarantines the manifest — the
    artifact becomes a durable miss and the reason is kept as evidence —
    then returns None.  Either way the caller must fall through to the
    live solver; there is no partially-trusted result.
    """
    ident = key.ident()
    if not os.path.exists(store.manifest_path(ident)):
        return None
    try:
        manifest = read_manifest(store, ident)
        art = verify_manifest(store, key, manifest)
    except FileNotFoundError:
        return None          # raced a concurrent quarantine: plain miss
    except VerifyError as e:
        store.quarantine(ident, str(e))
        return None
    store.loads += 1
    return art
