"""Offline artifact builder: one anytime solve → one durable artifact.

``build_artifact`` is what the launch pipeline
(``repro.launch.build_artifacts``) and the serve warm path call: it runs
``omp_session_trajectory`` to ``k_max`` over a pool, packages the
trajectory with the target and the optional FL-scan cache, and commits
it to an ``ArtifactStore`` under the pool's full-content digest.  The
solve is the expensive part (an offline O(k_max) anytime solve); every
later request at any ``k <= k_max`` is an O(1) verified slice.
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.artifacts.store import (
    ArtifactKey,
    ArtifactStore,
    content_digest_array,
    target_sha256,
)
from repro.core.omp import omp_session_trajectory


def artifact_key_for(grads, target, lam: float, eps: float,
                     positive: bool, valid=None,
                     fingerprint: Optional[str] = None) -> ArtifactKey:
    """Key a (pool, target, params) tuple the way the builder does.

    ``fingerprint`` short-circuits the O(n·d) content digest when the
    caller (the registry) already computed it at pool admission.
    """
    if fingerprint is None:
        fingerprint = content_digest_array(grads, valid)
    return ArtifactKey(fingerprint=fingerprint, lam=float(lam),
                       eps=float(eps), positive=bool(positive),
                       target_sha=target_sha256(target))


def build_artifact(
    store: ArtifactStore,
    grads,                     # (n, d) candidate pool
    target,                    # (d,)
    k_max: int,
    lam: float = 0.5,
    eps: float = 1e-10,
    nnls_iters: int = 50,
    positive: bool = True,
    valid=None,
    block: int = 128,
    fingerprint: Optional[str] = None,
    fl_l_max: Optional[float] = None,   # FL-scan cache (registry peek)
    crash: Optional[Callable[[str], None]] = None,
) -> tuple[ArtifactKey, str]:
    """Solve to ``k_max`` and commit the trajectory; returns (key, ident).

    ``crash`` is forwarded to ``ArtifactStore.put`` — the fault suite's
    kill-during-commit hook.  ``fl_l_max`` (the pool's cached FL
    similarity scan bound) rides along as an extra verified blob so an
    artifact-warmed registry entry skips that pool scan too.
    """
    grads_np = np.ascontiguousarray(np.asarray(grads, np.float32))
    target_np = np.ascontiguousarray(np.asarray(target, np.float32))
    n, d = grads_np.shape
    k_max = int(k_max)
    key = artifact_key_for(grads_np, target_np, lam, eps, positive,
                           valid=valid, fingerprint=fingerprint)

    _, traj = omp_session_trajectory(
        grads_np, target_np, k_max, lam=lam, eps=eps,
        nnls_iters=nnls_iters, positive=positive, valid=valid,
        block=block)

    arrays = {
        "indices": traj.indices,
        "mask": traj.mask,
        "weights_traj": traj.weights_traj,
        "err_trace": traj.err_trace,
        "target": target_np,
    }
    if fl_l_max is not None:
        arrays["fl_l_max"] = np.asarray([fl_l_max], np.float32)
    meta = {
        "n": int(n), "d": int(d), "k_max": k_max, "block": int(block),
        "lam": float(lam), "eps": float(eps),
        "nnls_iters": int(nnls_iters), "positive": bool(positive),
    }
    ident = store.put(key, arrays, meta, crash=crash)
    return key, ident
