"""Open-loop load generation for the selection service (DESIGN.md §10).

Closed-loop benchmarks (submit a batch, drain, repeat) measure solver
throughput but say nothing about overload: production arrivals do not
wait for the queue to drain.  This module generates **open-loop**
traffic — seeded Poisson arrivals over configurable
pool/strategy/k/tenant/priority mixes — and drives a ``SelectionService``
through it, recording per-request latency, outcome and degradation rung
plus the shed-accounting invariants.

Time is virtual.  The service is synchronous (``submit``/``drain_step``),
so the harness owns a ``SimClock`` injected as the service clock: all
arrivals due at the current virtual time are submitted, one
``drain_step`` runs, and the clock advances by that step's *measured*
wall time (or an injected ``step_cost`` for fully deterministic tests).
Nothing reads the wall clock for scheduling decisions — the arrival
schedule is a pure function of the spec's seed, so a trace replays
bit-identically while the latency numbers stay real.

Invariants checked after every run (``LoadReport.violations``):

* ``admitted == completed + shed + failed + pending`` — no ticket is
  ever silently dropped (a queue wedge or a lost ticket shows up here);
* every tenant's in-flight count returns to zero — no leaked slots;
* every metered unit charged is accounted for by a delivered ticket —
  failed work was refunded exactly once, shed work was never charged.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from repro.serve.admission import AdmissionError
from repro.resilience.circuit import CircuitOpen
from repro.serve.registry import UnknownPool
from repro.serve.scheduler import SelectRequest


class SimClock:
    """Injectable virtual clock: ``now()`` reads, ``advance()`` moves.

    Pass ``clock=sim.now`` to ``SelectionService`` so deadlines, breaker
    cooldowns and session TTLs all live in the same virtual timeline the
    load harness advances.
    """

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clock cannot go backwards (dt={dt})")
        self._t += dt
        return self._t


@dataclass(frozen=True)
class Arrival:
    t: float                  # virtual arrival time
    request: SelectRequest


@dataclass(frozen=True)
class LoadSpec:
    """Seeded description of an open-loop trace.

    ``rate_rps`` is the Poisson arrival rate (exponential inter-arrival
    gaps); each categorical field draws independently from its weighted
    mix.  ``deadline_s`` maps a priority class to its SLO deadline
    (None = no deadline for that class).
    """

    seed: int = 0
    requests: int = 64
    rate_rps: float = 100.0
    pools: Sequence[str] = ()
    pool_weights: Optional[Sequence[float]] = None
    ks: Sequence[int] = (32,)
    k_weights: Optional[Sequence[float]] = None
    tenants: Sequence[str] = ("default",)
    tenant_weights: Optional[Sequence[float]] = None
    priorities: Sequence[str] = ("interactive",)
    priority_weights: Optional[Sequence[float]] = None
    strategies: Sequence[str] = ("gradmatch",)
    strategy_weights: Optional[Sequence[float]] = None
    lam: float = 0.5
    eps: float = 1e-10
    deadline_s: Optional[dict] = None     # priority -> deadline


def _choice(rng, options, weights):
    if len(options) == 1:
        return options[0]
    p = None
    if weights is not None:
        w = np.asarray(weights, np.float64)
        p = w / w.sum()
    return options[int(rng.choice(len(options), p=p))]


def make_arrivals(spec: LoadSpec) -> list[Arrival]:
    """The trace: a pure function of the spec (same seed, same trace)."""
    if not spec.pools:
        raise ValueError("LoadSpec.pools must name at least one pool")
    rng = np.random.default_rng(int(spec.seed))
    t = 0.0
    out: list[Arrival] = []
    for i in range(int(spec.requests)):
        t += float(rng.exponential(1.0 / float(spec.rate_rps)))
        priority = _choice(rng, tuple(spec.priorities),
                           spec.priority_weights)
        deadline = (spec.deadline_s or {}).get(priority)
        out.append(Arrival(t=t, request=SelectRequest(
            pool_id=_choice(rng, tuple(spec.pools), spec.pool_weights),
            k=int(_choice(rng, tuple(spec.ks), spec.k_weights)),
            strategy=_choice(rng, tuple(spec.strategies),
                             spec.strategy_weights),
            lam=spec.lam, eps=spec.eps,
            tenant=_choice(rng, tuple(spec.tenants), spec.tenant_weights),
            priority=priority, seed=i, deadline_s=deadline)))
    return out


@dataclass
class LoadReport:
    requests: int = 0
    completed: int = 0
    shed: int = 0
    failed: int = 0
    timeouts: int = 0
    rejected: int = 0                 # QueueFull / budget / breaker raises
    duration_s: float = 0.0           # first arrival -> last settle
    sustained_rps: float = 0.0        # completed / duration
    p50_ms: float = 0.0
    p99_ms: float = 0.0
    class_p99_ms: dict = field(default_factory=dict)
    tenant_p99_ms: dict = field(default_factory=dict)
    rungs: dict = field(default_factory=dict)
    tenant_served_units: dict = field(default_factory=dict)
    fairness_ratio: Optional[float] = None   # min/max weighted service
    violations: list = field(default_factory=list)
    records: list = field(default_factory=list, repr=False)

    @property
    def ok(self) -> bool:
        return not self.violations


def _pctl(lat_s: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(lat_s), q) * 1e3) if lat_s \
        else 0.0


def run_load(service, arrivals: Sequence[Arrival], clock: SimClock,
             timer: Callable[[], float] = time.perf_counter,
             step_cost: Optional[Callable] = None,
             max_steps: int = 1_000_000) -> LoadReport:
    """Drive ``service`` through ``arrivals`` on the virtual ``clock``.

    The service must have been constructed with ``clock=clock.now``.
    ``step_cost(finalized_tickets) -> seconds`` replaces the measured
    drain-step wall time for deterministic tests.  ``max_steps`` is an
    anti-wedge bound: exceeding it is itself reported as a violation
    (a healthy queue always finishes draining a finite trace).
    """
    sched = service.scheduler
    base_used = {t: s["used_units"]
                 for t, s in service.admission.stats().items()}
    arr = sorted(arrivals, key=lambda a: a.t)
    recs: list[dict] = []
    open_recs: dict[str, dict] = {}
    rejected = 0
    i = 0
    steps = 0
    while i < len(arr) or sched.pending():
        now = clock.now()
        while i < len(arr) and arr[i].t <= now + 1e-12:
            a = arr[i]
            i += 1
            try:
                tk = sched.submit(a.request)
            except (AdmissionError, CircuitOpen, UnknownPool):
                rejected += 1
                continue
            rec = {"ticket": tk, "t_arr": a.t, "t_done": None}
            recs.append(rec)
            if tk.status == "shed":
                rec["t_done"] = now
            else:
                open_recs[tk.ticket_id] = rec
        if sched.pending():
            steps += 1
            if steps > max_steps:
                break
            t0 = timer()
            out = sched.drain_step()
            dt = (step_cost(out) if step_cost is not None
                  else timer() - t0)
            clock.advance(dt)
            done_at = clock.now()
            for tk in out:
                rec = open_recs.pop(tk.ticket_id, None)
                if rec is not None:
                    rec["t_done"] = done_at
        elif i < len(arr):
            clock.advance(max(arr[i].t - clock.now(), 0.0))
    return _report(service, recs, rejected, base_used,
                   wedged=steps > max_steps)


def _report(service, recs, rejected, base_used, wedged=False
            ) -> LoadReport:
    rep = LoadReport(requests=len(recs) + rejected, rejected=rejected,
                     records=recs)
    lat_all: list[float] = []
    lat_by_class: dict[str, list] = {}
    lat_by_tenant: dict[str, list] = {}
    t_first = min((r["t_arr"] for r in recs), default=0.0)
    t_last = t_first
    for r in recs:
        t = r["ticket"]
        rep.rungs[t.degradation] = rep.rungs.get(t.degradation, 0) + 1
        if r["t_done"] is not None:
            t_last = max(t_last, r["t_done"])
        if t.status == "done":
            rep.completed += 1
            rep.tenant_served_units[t.request.tenant] = (
                rep.tenant_served_units.get(t.request.tenant, 0.0)
                + t.cost)
            lat = r["t_done"] - r["t_arr"]
            lat_all.append(lat)
            lat_by_class.setdefault(t.request.priority, []).append(lat)
            lat_by_tenant.setdefault(t.request.tenant, []).append(lat)
        elif t.status == "shed":
            rep.shed += 1
        else:
            rep.failed += 1
            if t.degradation == "timeout":
                rep.timeouts += 1
    rep.duration_s = max(t_last - t_first, 0.0)
    rep.sustained_rps = (rep.completed / rep.duration_s
                         if rep.duration_s > 0 else 0.0)
    rep.p50_ms = _pctl(lat_all, 50)
    rep.p99_ms = _pctl(lat_all, 99)
    rep.class_p99_ms = {c: _pctl(v, 99) for c, v in lat_by_class.items()}
    rep.tenant_p99_ms = {c: _pctl(v, 99)
                         for c, v in lat_by_tenant.items()}
    if len(rep.tenant_served_units) > 1:
        shares = [units / service.admission.account(tn).weight
                  for tn, units in rep.tenant_served_units.items()]
        rep.fairness_ratio = min(shares) / max(shares)
    rep.violations = _violations(service, recs, base_used)
    if wedged:
        rep.violations.append("queue wedge: max_steps exceeded with "
                              f"{service.scheduler.pending()} pending")
    return rep


def _violations(service, recs, base_used) -> list[str]:
    """The run's accounting invariants; empty list = clean."""
    v: list[str] = []
    c = service.scheduler.counters
    pending = service.scheduler.pending()
    if c["admitted"] != (c["completed"] + c["shed"] + c["failed"]
                         + pending):
        v.append(
            f"shed accounting broken: admitted={c['admitted']} != "
            f"completed={c['completed']} + shed={c['shed']} + "
            f"failed={c['failed']} + pending={pending}")
    for tenant, s in service.admission.stats().items():
        if s["inflight"] != 0:
            v.append(f"inflight slot leak: tenant {tenant!r} ends at "
                     f"{s['inflight']}")
    # Exactly-once refunds: a tenant's used_units moved by exactly the
    # cost of its *delivered* tickets — failed work refunded once, shed
    # work never charged.  (Only this run's tickets: prior usage is in
    # base_used.)
    expected: dict[str, float] = {}
    for r in recs:
        t = r["ticket"]
        if t.status == "done":
            expected[t.request.tenant] = (
                expected.get(t.request.tenant, 0.0) + t.cost)
    for tenant, s in service.admission.stats().items():
        want = base_used.get(tenant, 0.0) + expected.get(tenant, 0.0)
        if abs(s["used_units"] - want) > 1e-6 * max(want, 1.0):
            v.append(
                f"budget leak: tenant {tenant!r} used_units="
                f"{s['used_units']:.6g}, expected {want:.6g} "
                "(failed work not refunded exactly once, or shed work "
                "charged)")
    return v


__all__ = ["Arrival", "LoadReport", "LoadSpec", "SimClock",
           "make_arrivals", "run_load"]
