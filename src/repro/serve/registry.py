"""Pool registry: admit a candidate pool once, serve many requests off it.

The selection service (DESIGN.md §6) is multi-tenant over *shared* pools —
the whole point of batched serving is that B concurrent requests against
the same pool share one solve.  The registry is where "the same pool" is
established and where everything derivable from the pool alone (no target,
no budget) is computed once and cached:

* a content **fingerprint** (shape/dtype + sampled row bytes, folded with
  the validity mask — the same rows under a different mask are a
  different pool), so a client re-registering identical data gets the
  existing ``pool_id`` back instead of a duplicate device copy;
* the default GRAD-MATCH **target** ``sum_i g_i`` (eq. 2 of the paper);
* lazily, the CRAIG **FL similarity** — resident ``(n, n)`` tiles below
  the greedy engine's on-the-fly threshold, otherwise just the ``l_max``
  offset for the tiled scan — shared by every CRAIG request against the
  pool instead of rebuilt per call.

Pools come in two kinds: ``"array"`` (an in-memory ``(n, d)`` proxy
matrix, device-resident, batchable) and ``"chunked"`` (a
``data.loader.ChunkedPool`` or compatible chunk factory — served through
the streaming block-OMP, one request at a time; its default target costs
one summing pass and is likewise cached).

Eviction is LRU over registered pools (``max_pools``): evicting drops the
device arrays and cached precompute but not client state — sessions pin
their own derived buffers (see ``serve/sessions.py``).
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.artifacts.store import content_digest_array
from repro.core import greedy as greedy_lib
from repro.core import streaming as stream_lib
from repro.resilience.faults import TransientFault


class UnknownPool(KeyError):
    """Raised for a ``pool_id`` that was never registered or was evicted."""


def _fingerprint_array(x: np.ndarray, sample_rows: int = 64) -> str:
    """Content hash over shape/dtype + up to ``sample_rows`` strided rows.

    Sampling keeps admission O(sample·d) for huge pools; strided rows (not
    just a head slice) catch the common "same head, different tail" case.
    Collisions only cost a spurious dedupe of byte-identical samples —
    acceptable for a cache key, and ``register(pool_id=...)`` overrides.
    **Never** an artifact key: two pools differing outside the sampled
    rows must not share a durable artifact, so those are keyed by the
    full-content ``PoolEntry.content_digest`` instead.
    """
    h = hashlib.sha1()
    h.update(repr((x.shape, str(x.dtype))).encode())
    n = x.shape[0]
    step = max(n // sample_rows, 1)
    sample = np.ascontiguousarray(x[::step][:sample_rows])
    h.update(sample.tobytes())
    return h.hexdigest()[:16]


def _fold_valid(fp: str, valid) -> str:
    """Fold the validity mask into a content fingerprint — the same rows
    under a different mask are a different pool (deduping across masks
    would silently hand one caller another caller's exclusions)."""
    if valid is None:
        return fp
    v = np.asarray(valid, bool)
    return hashlib.sha1(
        (fp + "+valid").encode() + v.tobytes()).hexdigest()[:16]


@dataclass
class PoolEntry:
    pool_id: str
    kind: str                      # "array" | "chunked"
    n: int
    d: int
    fingerprint: str
    # Full-content SHA-256 over every pool byte + the validity mask —
    # the *artifact* key (``fingerprint`` above samples 64 rows and is
    # only a dedupe heuristic).  None for chunked pools, which have no
    # artifact fast path.
    content_digest: Optional[str] = None
    grads: Optional[jnp.ndarray] = None          # array pools, (n, d) f32
    chunk_iter: Optional[Callable] = None        # chunked pools: factory
    valid: Optional[jnp.ndarray] = None          # (n,) bool or None
    target_sum: Optional[jnp.ndarray] = None     # (d,) default target
    # Chunked pools: the compressed chunk cache (DESIGN.md §7), warmed by
    # the admission summing pass and shared by every streaming request —
    # certified buffer rounds re-verify against it instead of re-reading
    # the loader — plus the exact-row fetch capability for the engine's
    # repair/refill tiers (None for factory-only pools).
    cache: Optional[stream_lib.ChunkCache] = field(default=None,
                                                   repr=False)
    row_fetch: Optional[Callable] = field(default=None, repr=False)
    # Partition count for "gradmatch-partitioned" requests against this
    # pool (core/partition.py, DESIGN.md §9); 0 = the solver's auto
    # sizing (~128k rows per partition for chunked pools).
    partitions: int = 0
    # Async admission (DESIGN.md §10): "warm" = target/cache ready;
    # "warming" = the summing pass is still being stepped off the drain
    # path (target_sum is None, requests wait against their deadline);
    # "failed" = the warm pass died permanently (requests fail fast).
    warm_state: str = "warm"
    warm_error: Optional[str] = None
    warmed_chunks: int = 0
    _warm: Optional[Iterator] = field(default=None, repr=False)
    # CRAIG scan cache, resolved lazily on the first craig request:
    _fl: Optional[tuple] = field(default=None, repr=False)

    @property
    def batchable(self) -> bool:
        return self.kind == "array"

    def fl_scan(self, method: str = "lazy"):
        """(sim | None, l_max, on_the_fly) for the greedy engine — resolved
        once per pool and reused by every CRAIG request against it."""
        if self.kind != "array":
            raise UnknownPool(
                f"pool {self.pool_id!r} is chunked: CRAIG requests need a "
                "resident pool")
        if self._fl is None:
            self._fl = greedy_lib.resolve_fl_scan(self.grads, None, method)
        return self._fl


def _warm_steps(entry: PoolEntry, chunk_iter: Callable,
                cache: Optional[stream_lib.ChunkCache], retry,
                n_expect: int) -> Iterator[None]:
    """Incremental twin of ``streaming_target``: one summed+cached chunk
    per ``next()``, so the admission pass can be advanced off the drain
    path.  A transient fault restarts the pass (accumulators are
    pass-local, ``cache.offer`` is idempotent for resident chunks — the
    same exactness argument as the one-shot scan) up to ``retry``'s
    budget; permanent faults propagate to ``step_warm``.  On completion
    the entry flips to ``warm_state="warm"`` with its target installed.
    """
    attempt = 0
    while True:
        total = None
        count = 0
        idx = 0
        try:
            for chunk, v in chunk_iter():
                c = jnp.asarray(chunk, jnp.float32)
                if v is not None:
                    c = c * jnp.asarray(v)[:, None].astype(jnp.float32)
                s = jnp.sum(c, axis=0)
                total = s if total is None else total + s
                stream_lib.offer_chunk(cache, idx, count, chunk, v)
                count += chunk.shape[0]
                idx += 1
                entry.warmed_chunks = idx
                yield
            break
        except TransientFault as exc:
            if retry is None or attempt >= retry.max_retries:
                raise
            retry.sleep(retry.delay(attempt))
            attempt += 1
    if total is None:
        raise ValueError("empty pool iterator")
    if count != n_expect:
        raise ValueError(
            f"deferred-warm row count mismatch: admission said "
            f"{n_expect} rows, the pass saw {count} — the fingerprint "
            "and cost estimates are wrong; re-register with the true n")
    if cache is not None and cache.covers(idx):
        cache.complete = idx
    entry.target_sum = total
    entry.warm_state = "warm"


class PoolRegistry:
    """Admit pools once; hand out cached entries by ``pool_id``.

    With ``artifacts`` (an ``repro.artifacts.ArtifactStore``), array
    pools additionally get the offline fast path (DESIGN.md §12):
    ``artifact_lookup`` answers a (pool, params, target) ask from a
    *verified* precomputed trajectory, memoizing each verified artifact
    in memory so repeat hits are a dict probe + slice — O(1), no disk,
    no pool scan.  Verification failures quarantine on the spot and
    report a miss (the scheduler falls through to the live solver).
    """

    def __init__(self, max_pools: int = 8, artifacts=None):
        self.max_pools = int(max_pools)
        self.artifacts = artifacts
        self._pools: OrderedDict[str, PoolEntry] = OrderedDict()
        self._by_fp: dict[str, str] = {}
        self.evictions = 0
        # ident -> verified SelectionArtifact; idents never verify twice.
        self._art_memo: dict[str, object] = {}
        self.art_hits = 0
        self.art_misses = 0
        self.art_quarantined = 0

    # -- admission -----------------------------------------------------------
    def register(self, pool, pool_id: Optional[str] = None,
                 valid=None, partitions: int = 0) -> str:
        """Admit an in-memory ``(n, d)`` proxy pool; returns its id.

        Re-registering content with a known fingerprint returns the
        existing id (no second device copy) unless an explicit distinct
        ``pool_id`` is given.  ``partitions`` configures how
        "gradmatch-partitioned" requests split this pool (0 = auto).
        """
        x = np.asarray(pool, np.float32)
        if x.ndim != 2 or x.shape[0] == 0:
            raise ValueError(f"pool must be (n, d), got {x.shape}")
        fp = _fold_valid(_fingerprint_array(x), valid)
        known = self._by_fp.get(fp)
        if known is not None and known in self._pools and pool_id is None:
            self._pools.move_to_end(known)
            return known
        pid = pool_id or f"pool-{fp}"
        g = jnp.asarray(x)
        v = None if valid is None else jnp.asarray(valid, bool)
        gv = g if v is None else g * v[:, None].astype(g.dtype)
        entry = PoolEntry(
            pool_id=pid, kind="array", n=x.shape[0], d=x.shape[1],
            fingerprint=fp, content_digest=content_digest_array(x, valid),
            grads=g, valid=v,
            target_sum=jnp.sum(gv, axis=0), partitions=int(partitions),
        )
        self._admit(pid, fp, entry)
        return pid

    def register_chunked(self, pool, pool_id: Optional[str] = None,
                         valid=None,
                         cache_bytes: int = stream_lib.DEFAULT_CACHE_BYTES,
                         retry=None, partitions: int = 0,
                         warm: str = "sync",
                         n: Optional[int] = None) -> str:
        """Admit a ``ChunkedPool`` (or any ``(chunk, valid)`` factory).

        The default target is computed with one summing pass — and the
        *same* pass warms the pool's compressed chunk cache, so the
        admission scan is never re-paid: every streaming request's
        certified rounds (and, for ``ChunkedPool``-backed pools, its
        exact-row repairs) hit memory instead of the loader.  ``retry``
        (a ``repro.resilience.RetryPolicy``) lets the admission pass ride
        through transient loader faults the same way serving solves do.

        ``warm="sync"`` (the default) runs that pass here, blocking until
        the pool is servable.  ``warm="deferred"`` (DESIGN.md §10) admits
        immediately in the ``"warming"`` state and leaves the pass to be
        advanced chunk-at-a-time by ``step_warm`` — the scheduler calls
        it off the drain path, so registering a huge pool never
        head-of-line-blocks the serving queue.  Deferred admission needs
        the row count up front (``ChunkedPool.n``, or ``n=`` for factory
        pools) because the fingerprint folds it in.
        """
        if warm not in ("sync", "deferred"):
            raise ValueError(f"warm must be 'sync' or 'deferred', "
                             f"got {warm!r}")
        if callable(pool):
            if valid is not None:
                raise ValueError(
                    "valid= is only supported for ChunkedPool admission; "
                    "bake the mask into a custom chunk factory's (chunk, "
                    "valid) pairs instead")
            chunk_iter = pool
            row_fetch = None
            n_known = None if n is None else int(n)
        else:
            chunk_iter = stream_lib.chunked_pool_iter(pool, valid=valid)
            row_fetch = stream_lib.array_row_fetch(pool.x)
            n_known = int(pool.n)
        first = next(iter(chunk_iter()), None)
        if first is None:
            raise ValueError("empty pool iterator")
        first_chunk = first[0]
        cache = stream_lib.ChunkCache(
            int(cache_bytes), int(np.asarray(first_chunk).shape[1]))
        if warm == "sync":
            target, n_rows = stream_lib.streaming_target(
                chunk_iter, cache=cache, retry=retry)
        else:
            if n_known is None:
                raise ValueError(
                    "warm='deferred' needs n= for factory pools: the row "
                    "count is part of the fingerprint and is otherwise "
                    "only known after the summing pass")
            target, n_rows = None, n_known
        fp_src = np.asarray(first_chunk, np.float32)
        fp = hashlib.sha1(
            repr((n_rows, fp_src.shape)).encode()
            + _fingerprint_array(fp_src).encode()).hexdigest()[:16]
        fp = _fold_valid(fp, valid)
        known = self._by_fp.get(fp)
        if known is not None and known in self._pools and pool_id is None:
            self._pools.move_to_end(known)
            return known
        pid = pool_id or f"chunked-{fp}"
        entry = PoolEntry(pool_id=pid, kind="chunked", n=int(n_rows),
                          d=int(np.asarray(first_chunk).shape[1]),
                          fingerprint=fp,
                          chunk_iter=chunk_iter, target_sum=target,
                          cache=cache, row_fetch=row_fetch,
                          partitions=int(partitions))
        if warm == "deferred":
            entry.warm_state = "warming"
            entry._warm = _warm_steps(entry, chunk_iter, cache, retry,
                                      int(n_rows))
        self._admit(pid, fp, entry)
        return pid

    # -- async warming (DESIGN.md §10) ---------------------------------------
    def step_warm(self, pool_id: str, max_chunks: int = 8) -> bool:
        """Advance a deferred admission pass by up to ``max_chunks``
        chunks; returns True once the pool is no longer warming (warm or
        failed).  A permanent warm failure is recorded on the entry
        (``warm_state="failed"``, ``warm_error``) rather than raised —
        the scheduler fails queued requests against it on the next step.
        """
        entry = self._pools.get(pool_id)
        if entry is None or entry.warm_state != "warming" \
                or entry._warm is None:
            return True
        try:
            for _ in range(int(max_chunks)):
                next(entry._warm)
        except StopIteration:
            entry._warm = None
        except Exception as exc:
            entry.warm_state = "failed"
            entry.warm_error = f"{type(exc).__name__}: {exc}"
            entry._warm = None
        return entry.warm_state != "warming"

    def warming(self) -> list[str]:
        return [pid for pid, e in self._pools.items()
                if e.warm_state == "warming"]

    def _admit(self, pid: str, fp: str, entry: PoolEntry) -> None:
        # Re-registering an explicit pool_id with different content must
        # also retire the replaced content's fingerprint — otherwise a
        # later no-id registration of the *old* content would dedupe onto
        # an entry that now holds different data.
        old = self._pools.get(pid)
        if old is not None and old.fingerprint != fp:
            if self._by_fp.get(old.fingerprint) == pid:
                del self._by_fp[old.fingerprint]
        self._pools[pid] = entry
        self._pools.move_to_end(pid)
        self._by_fp[fp] = pid
        while len(self._pools) > self.max_pools:
            old_id, old = self._pools.popitem(last=False)
            self._by_fp.pop(old.fingerprint, None)
            self.evictions += 1

    # -- artifact fast path (DESIGN.md §12) ----------------------------------
    def artifact_lookup(self, entry: PoolEntry, k: int, lam: float,
                        eps: float, positive: bool, target):
        """Verified artifact covering this ask, or None (fall through).

        Misses are *not* negative-cached: an offline builder may commit
        the artifact at any time, and a clean miss is one ``exists``
        probe.  Hits are memoized by manifest ident, so the per-request
        cost after first verification is a dict probe.  A quarantine
        bumps the counter and leaves the store with the manifest moved
        aside — the next probe is a clean miss.
        """
        if self.artifacts is None or entry.content_digest is None:
            return None
        from repro.artifacts import artifact_key_for

        key = artifact_key_for(None, np.asarray(target, np.float32),
                               lam, eps, positive,
                               fingerprint=entry.content_digest)
        ident = key.ident()
        art = self._art_memo.get(ident)
        if art is None:
            before = self.artifacts.quarantined
            art = self.artifacts.get(key)
            self.art_quarantined += self.artifacts.quarantined - before
            if art is None:
                self.art_misses += 1
                return None
            if art.n != entry.n or art.d != entry.d:
                # A full-content digest collision would be required to
                # get here; treat it as corruption all the same.
                self.artifacts.quarantine(ident, "dims-disagree-with-pool")
                self.art_quarantined += 1
                self.art_misses += 1
                return None
            self._art_memo[ident] = art
        if int(k) > art.k_max:
            self.art_misses += 1
            return None
        self.art_hits += 1
        return art

    # -- lookup --------------------------------------------------------------
    def peek(self, pool_id: str) -> Optional[PoolEntry]:
        """Entry or None, without touching LRU order — the scheduler's
        runnability scan must not promote pools it merely looked at."""
        return self._pools.get(pool_id)

    def get(self, pool_id: str) -> PoolEntry:
        entry = self._pools.get(pool_id)
        if entry is None:
            raise UnknownPool(
                f"unknown pool {pool_id!r} (evicted or never registered); "
                f"known: {list(self._pools)}")
        self._pools.move_to_end(pool_id)
        return entry

    def __contains__(self, pool_id: str) -> bool:
        return pool_id in self._pools

    def __len__(self) -> int:
        return len(self._pools)

    def stats(self) -> dict:
        return {
            "pools": len(self._pools),
            "warming": len(self.warming()),
            "evictions": self.evictions,
            "artifact_hits": self.art_hits,
            "artifact_misses": self.art_misses,
            "artifact_quarantined": self.art_quarantined,
            "resident_bytes": sum(
                e.n * e.d * 4 for e in self._pools.values()
                if e.kind == "array"),
            "cache_bytes": sum(
                e.cache.stats()["resident_bytes"]
                for e in self._pools.values() if e.cache is not None),
        }
