"""Anytime-budget sessions: checkpointed OMP state with TTL + LRU eviction.

A client that asked for ``k`` selected examples and later wants ``k'`` has,
with a stateless server, exactly one option: a from-scratch ``k'`` solve.
The incremental-Gram solver's blocked prefix growth makes the cheap option
possible — the per-session ``OMPAnytimeState`` (core/omp.py) holds the
column cache, Gram, cached rows and residual at round ``k``, so the
extension runs only rounds ``[k, k')`` and is certified index-identical to
the one-shot ``k'`` solve (tests/test_serve.py runs the differential grid).

This module is the bookkeeping half: a bounded store of live sessions with

* **TTL expiry** — a session idle past ``ttl_s`` is dropped on the next
  sweep (state is O(k·(n_cols + d) + k²) floats; clients that walked away
  must not pin it forever);
* **LRU eviction** — beyond ``max_sessions`` the least-recently-used
  session is evicted even if fresh (capacity beats fairness — an evicted
  client degrades to a one-shot solve, it is never wrong);
* a monotonic injectable ``clock`` so the tests drive expiry
  deterministically.

The compute half (running the extension) lives in ``serve/service.py``,
which owns the registry the pool arrays come from.
"""

from __future__ import annotations

import itertools
import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.omp import OMPAnytimeState


class SessionGone(KeyError):
    """Session expired, was evicted, or never existed."""


@dataclass
class Session:
    session_id: str
    pool_id: str
    pool_fingerprint: str    # content at open time — a pool replaced
                             # under the same id must not serve this state
    tenant: str
    state: OMPAnytimeState
    created_at: float
    last_used: float
    extensions: int = 0


@dataclass
class StreamSession:
    """A continual-stream session: one tenant POSTing gradient batches
    forever against a bounded ``repro.continual.BufferMaintainer``
    (DESIGN.md §11).  Unlike an anytime :class:`Session` there is no pool
    id — the buffer *is* the pool, fed incrementally — but the TTL/LRU
    bookkeeping is shared: an abandoned stream must not pin its arena.
    The compute half (admission charging, batch pushes, checkpointed
    resume) lives in ``serve/service.py``."""

    session_id: str
    tenant: str
    maintainer: Any          # repro.continual.BufferMaintainer
    created_at: float
    last_used: float
    batches: int = 0


class SessionStore:
    def __init__(self, max_sessions: int = 32, ttl_s: float = 600.0,
                 clock: Callable[[], float] = time.monotonic):
        self.max_sessions = int(max_sessions)
        self.ttl_s = float(ttl_s)
        self._clock = clock
        self._sessions: OrderedDict[str, Session] = OrderedDict()
        self._ids = itertools.count()
        self.evictions = 0
        self.expirations = 0
        # Churn counters for the ``stats()`` snapshot: brownout decisions
        # and tests read these to see whether capacity is beating
        # fairness (high evictions) or clients are walking away (misses).
        self.puts = 0
        self.hits = 0
        self.misses = 0

    def _insert(self, sess) -> None:
        self._sessions[sess.session_id] = sess
        self.puts += 1
        self.sweep()
        while len(self._sessions) > self.max_sessions:
            self._sessions.popitem(last=False)
            self.evictions += 1

    def put(self, pool_id: str, tenant: str, state: OMPAnytimeState,
            pool_fingerprint: str = "") -> Session:
        now = self._clock()
        sess = Session(session_id=f"sess-{next(self._ids)}",
                       pool_id=pool_id, pool_fingerprint=pool_fingerprint,
                       tenant=tenant, state=state, created_at=now,
                       last_used=now)
        self._insert(sess)
        return sess

    def put_stream(self, tenant: str, maintainer) -> StreamSession:
        """Register a continual :class:`StreamSession`.  Streams share the
        anytime sessions' TTL/LRU machinery (``get``/``sweep``/``close``
        only touch ``session_id``/``last_used``) but should live in their
        *own* store — the degradation ladder's prefix scan expects anytime
        state (``serve/service.py`` keeps ``svc.streams`` separate)."""
        now = self._clock()
        sess = StreamSession(session_id=f"stream-{next(self._ids)}",
                             tenant=tenant, maintainer=maintainer,
                             created_at=now, last_used=now)
        self._insert(sess)
        return sess

    def get(self, session_id: str) -> Session:
        self.sweep()
        sess = self._sessions.get(session_id)
        if sess is None:
            self.misses += 1
            raise SessionGone(
                f"session {session_id!r} not found (expired after "
                f"{self.ttl_s}s idle, LRU-evicted, or never opened)")
        self.hits += 1
        sess.last_used = self._clock()
        self._sessions.move_to_end(session_id)
        return sess

    def update(self, session_id: str, state: OMPAnytimeState) -> None:
        sess = self.get(session_id)
        sess.state = state
        sess.extensions += 1

    def close(self, session_id: str) -> bool:
        return self._sessions.pop(session_id, None) is not None

    def live(self) -> list[Session]:
        """Snapshot of live sessions (sweeps first, does not touch LRU) —
        the degradation ladder scans this for a same-pool anytime prefix."""
        self.sweep()
        return list(self._sessions.values())

    def sweep(self) -> int:
        """Drop sessions idle past the TTL; returns how many were dropped."""
        now = self._clock()
        dead = [sid for sid, s in self._sessions.items()
                if now - s.last_used > self.ttl_s]
        for sid in dead:
            del self._sessions[sid]
        self.expirations += len(dead)
        return len(dead)

    def __len__(self) -> int:
        return len(self._sessions)

    def stats(self) -> dict:
        return {"sessions": len(self._sessions),
                "max_sessions": self.max_sessions,
                "puts": self.puts,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "expirations": self.expirations}
