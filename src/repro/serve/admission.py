"""Admission control: bounded queues and per-tenant budget accounting.

A shared selection service dies one of two deaths without backpressure: an
unbounded queue (every caller sees unbounded latency) or one hot tenant
starving the rest.  Admission is therefore checked at ``submit`` time, and
rejections are *errors the client sees immediately* — never silent drops:

* ``QueueFull`` — the global queue is at ``max_queue``; retry after a
  drain.  This is the load-shedding backstop, tenant-blind by design.
* ``BudgetExhausted`` — the tenant has spent its cost budget or has too
  many requests in flight.  Budgets are charged in abstract *work units*
  estimated from the request shape (``estimate_cost``), debited at
  admission (optimistic — the scheduler refunds nothing for batched
  amortization, so the budget is a worst-case sequential bound and
  batching is pure headroom for the operator).  Work that *fails* is
  refunded via ``complete(refund=...)``: a metered tenant never pays for
  selections that were not delivered.

``TenantAccount.budget_units=None`` means unmetered (the default tenant) —
in-flight caps still apply, so even unmetered tenants cannot occupy the
whole queue.

Two load-time extensions (DESIGN.md §10):

* ``TenantAccount.weight`` feeds the scheduler's deficit-round-robin fair
  queue — a tenant with weight 2 drains twice the work units per rotation
  of a weight-1 tenant, instead of FIFO letting whoever submitted first
  monopolize the drain.
* ``OverloadController`` maps queue pressure to a brownout **level** with
  hysteresis, and decides *at submit* which priority classes are shed.
  Shedding is visible (the caller gets a labelled ``"shed"`` ticket) and
  never charged — the accounting invariant ``admitted == completed + shed
  + failed + pending`` is checked by the load harness and the parity gate.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


class AdmissionError(RuntimeError):
    """Base class: request rejected at admission (client should back off)."""


class QueueFull(AdmissionError):
    pass


class BudgetExhausted(AdmissionError):
    pass


def estimate_cost(n: int, d: int, k: int) -> float:
    """Work units for one selection: the pool scan + per-round solve term.

    ``n·d`` (one scoring pass over the pool) + ``k·(n + d)`` (per-round
    argmax + cache growth) — the incremental solver's leading terms.  Units
    are arbitrary but consistent, which is all budget *ratios* need.
    """
    return float(n) * d + float(k) * (n + d)


@dataclass
class TenantAccount:
    tenant: str
    budget_units: Optional[float] = None   # None = unmetered
    max_inflight: int = 16
    weight: float = 1.0                    # fair-queue share (DRR)
    used_units: float = 0.0
    inflight: int = 0
    admitted: int = 0
    rejected: int = 0

    @property
    def remaining_units(self) -> Optional[float]:
        if self.budget_units is None:
            return None
        return max(self.budget_units - self.used_units, 0.0)


class AdmissionController:
    def __init__(self, max_queue: int = 64,
                 default_budget_units: Optional[float] = None,
                 max_inflight_per_tenant: int = 16):
        self.max_queue = int(max_queue)
        self.default_budget_units = default_budget_units
        self.max_inflight_per_tenant = int(max_inflight_per_tenant)
        self._accounts: dict[str, TenantAccount] = {}

    def account(self, tenant: str) -> TenantAccount:
        acct = self._accounts.get(tenant)
        if acct is None:
            acct = TenantAccount(
                tenant=tenant, budget_units=self.default_budget_units,
                max_inflight=self.max_inflight_per_tenant)
            self._accounts[tenant] = acct
        return acct

    def set_budget(self, tenant: str, budget_units: Optional[float],
                   max_inflight: Optional[int] = None,
                   weight: Optional[float] = None) -> TenantAccount:
        acct = self.account(tenant)
        acct.budget_units = budget_units
        if max_inflight is not None:
            acct.max_inflight = int(max_inflight)
        if weight is not None:
            acct.weight = float(weight)
        return acct

    def set_weight(self, tenant: str, weight: float) -> TenantAccount:
        """Set a tenant's fair-queue share; must be > 0."""
        if not weight > 0:
            raise ValueError(f"weight must be > 0, got {weight}")
        acct = self.account(tenant)
        acct.weight = float(weight)
        return acct

    def admit(self, tenant: str, cost: float, queue_depth: int) -> float:
        """Charge ``cost`` units to ``tenant`` or raise; returns the cost."""
        acct = self.account(tenant)
        if queue_depth >= self.max_queue:
            acct.rejected += 1
            raise QueueFull(
                f"queue at capacity ({queue_depth}/{self.max_queue}); "
                "drain before submitting more")
        if acct.inflight >= acct.max_inflight:
            acct.rejected += 1
            raise BudgetExhausted(
                f"tenant {tenant!r} has {acct.inflight} requests in flight "
                f"(max {acct.max_inflight})")
        if (acct.budget_units is not None
                and acct.used_units + cost > acct.budget_units):
            acct.rejected += 1
            raise BudgetExhausted(
                f"tenant {tenant!r} budget exhausted: {acct.used_units:.3g}"
                f" + {cost:.3g} > {acct.budget_units:.3g} units")
        acct.used_units += cost
        acct.inflight += 1
        acct.admitted += 1
        return cost

    def complete(self, tenant: str, refund: float = 0.0) -> None:
        """Release an in-flight slot; ``refund`` credits back admission
        units for work that failed (a metered tenant must not pay for
        selections that were never delivered — successful batched work is
        still charged its full sequential estimate, that amortization
        stays operator headroom)."""
        acct = self.account(tenant)
        acct.inflight = max(acct.inflight - 1, 0)
        if refund:
            acct.used_units = max(acct.used_units - refund, 0.0)

    def stats(self) -> dict:
        return {t: {"used_units": a.used_units, "inflight": a.inflight,
                    "admitted": a.admitted, "rejected": a.rejected,
                    "weight": a.weight,
                    "remaining_units": a.remaining_units}
                for t, a in self._accounts.items()}


class OverloadController:
    """Queue pressure -> brownout level, with hysteresis.

    Levels (DESIGN.md §10):

    * ``0`` normal — the scheduler runs its ordinary certified paths.
    * ``1`` brownout — best-effort requests are shed at submit; the
      scheduler routes same-pool differing-k gradmatch groups through one
      shared anytime session (each answered as a bit-exact index prefix).
    * ``2`` overload — batch-class requests are shed too, and queued
      non-interactive gradmatch work takes the stochastic rung instead of
      a full solve.

    Thresholds are fractions of ``max_queue``; ``recover_at`` sits below
    ``brownout_at`` so the level does not flap at the boundary — it takes
    a genuinely drained queue to leave brownout, not one lucky step.
    Interactive traffic is never shed here; its backstop stays the
    tenant-blind ``QueueFull`` limit.
    """

    def __init__(self, max_queue: int = 64, brownout_at: float = 0.5,
                 overload_at: float = 0.85, recover_at: float = 0.25):
        if not 0.0 <= recover_at <= brownout_at <= overload_at <= 1.0:
            raise ValueError(
                "need 0 <= recover_at <= brownout_at <= overload_at <= 1,"
                f" got {recover_at}/{brownout_at}/{overload_at}")
        self.max_queue = int(max_queue)
        self.brownout_at = float(brownout_at)
        self.overload_at = float(overload_at)
        self.recover_at = float(recover_at)
        self.level = 0
        self.transitions = 0
        self.sheds: dict[str, int] = {}     # priority -> shed count

    def observe(self, queue_depth: int) -> int:
        """Update and return the level for the current queue depth."""
        f = queue_depth / max(self.max_queue, 1)
        new = self.level
        if f >= self.overload_at:
            new = 2
        elif f >= self.brownout_at:
            new = max(self.level, 1)
        elif f <= self.recover_at:
            new = 0
        elif self.level == 2:
            new = 1                          # partial recovery: 2 -> 1
        if new != self.level:
            self.transitions += 1
            self.level = new
        return self.level

    def should_shed(self, priority: str) -> bool:
        return ((self.level >= 1 and priority == "best-effort")
                or (self.level >= 2 and priority == "batch"))

    def record_shed(self, priority: str) -> None:
        self.sheds[priority] = self.sheds.get(priority, 0) + 1

    def stats(self) -> dict:
        return {"level": self.level, "transitions": self.transitions,
                "sheds": dict(self.sheds)}
