"""Admission control: bounded queues and per-tenant budget accounting.

A shared selection service dies one of two deaths without backpressure: an
unbounded queue (every caller sees unbounded latency) or one hot tenant
starving the rest.  Admission is therefore checked at ``submit`` time, and
rejections are *errors the client sees immediately* — never silent drops:

* ``QueueFull`` — the global queue is at ``max_queue``; retry after a
  drain.  This is the load-shedding backstop, tenant-blind by design.
* ``BudgetExhausted`` — the tenant has spent its cost budget or has too
  many requests in flight.  Budgets are charged in abstract *work units*
  estimated from the request shape (``estimate_cost``), debited at
  admission (optimistic — the scheduler refunds nothing for batched
  amortization, so the budget is a worst-case sequential bound and
  batching is pure headroom for the operator).  Work that *fails* is
  refunded via ``complete(refund=...)``: a metered tenant never pays for
  selections that were not delivered.

``TenantAccount.budget_units=None`` means unmetered (the default tenant) —
in-flight caps still apply, so even unmetered tenants cannot occupy the
whole queue.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


class AdmissionError(RuntimeError):
    """Base class: request rejected at admission (client should back off)."""


class QueueFull(AdmissionError):
    pass


class BudgetExhausted(AdmissionError):
    pass


def estimate_cost(n: int, d: int, k: int) -> float:
    """Work units for one selection: the pool scan + per-round solve term.

    ``n·d`` (one scoring pass over the pool) + ``k·(n + d)`` (per-round
    argmax + cache growth) — the incremental solver's leading terms.  Units
    are arbitrary but consistent, which is all budget *ratios* need.
    """
    return float(n) * d + float(k) * (n + d)


@dataclass
class TenantAccount:
    tenant: str
    budget_units: Optional[float] = None   # None = unmetered
    max_inflight: int = 16
    used_units: float = 0.0
    inflight: int = 0
    admitted: int = 0
    rejected: int = 0

    @property
    def remaining_units(self) -> Optional[float]:
        if self.budget_units is None:
            return None
        return max(self.budget_units - self.used_units, 0.0)


class AdmissionController:
    def __init__(self, max_queue: int = 64,
                 default_budget_units: Optional[float] = None,
                 max_inflight_per_tenant: int = 16):
        self.max_queue = int(max_queue)
        self.default_budget_units = default_budget_units
        self.max_inflight_per_tenant = int(max_inflight_per_tenant)
        self._accounts: dict[str, TenantAccount] = {}

    def account(self, tenant: str) -> TenantAccount:
        acct = self._accounts.get(tenant)
        if acct is None:
            acct = TenantAccount(
                tenant=tenant, budget_units=self.default_budget_units,
                max_inflight=self.max_inflight_per_tenant)
            self._accounts[tenant] = acct
        return acct

    def set_budget(self, tenant: str, budget_units: Optional[float],
                   max_inflight: Optional[int] = None) -> TenantAccount:
        acct = self.account(tenant)
        acct.budget_units = budget_units
        if max_inflight is not None:
            acct.max_inflight = int(max_inflight)
        return acct

    def admit(self, tenant: str, cost: float, queue_depth: int) -> float:
        """Charge ``cost`` units to ``tenant`` or raise; returns the cost."""
        acct = self.account(tenant)
        if queue_depth >= self.max_queue:
            acct.rejected += 1
            raise QueueFull(
                f"queue at capacity ({queue_depth}/{self.max_queue}); "
                "drain before submitting more")
        if acct.inflight >= acct.max_inflight:
            acct.rejected += 1
            raise BudgetExhausted(
                f"tenant {tenant!r} has {acct.inflight} requests in flight "
                f"(max {acct.max_inflight})")
        if (acct.budget_units is not None
                and acct.used_units + cost > acct.budget_units):
            acct.rejected += 1
            raise BudgetExhausted(
                f"tenant {tenant!r} budget exhausted: {acct.used_units:.3g}"
                f" + {cost:.3g} > {acct.budget_units:.3g} units")
        acct.used_units += cost
        acct.inflight += 1
        acct.admitted += 1
        return cost

    def complete(self, tenant: str, refund: float = 0.0) -> None:
        """Release an in-flight slot; ``refund`` credits back admission
        units for work that failed (a metered tenant must not pay for
        selections that were never delivered — successful batched work is
        still charged its full sequential estimate, that amortization
        stays operator headroom)."""
        acct = self.account(tenant)
        acct.inflight = max(acct.inflight - 1, 0)
        if refund:
            acct.used_units = max(acct.used_units - refund, 0.0)

    def stats(self) -> dict:
        return {t: {"used_units": a.used_units, "inflight": a.inflight,
                    "admitted": a.admitted, "rejected": a.rejected,
                    "remaining_units": a.remaining_units}
                for t, a in self._accounts.items()}
