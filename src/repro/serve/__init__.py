"""Selection-as-a-service (DESIGN.md §6).

Multi-tenant batched selection over registered pools: a pool registry
with per-pool precompute, a micro-batching request scheduler over the
vmapped/batched multi-target OMP, anytime-budget sessions (k -> k'
extension as a certified resume), and tenant admission/backpressure.
"""

from repro.resilience.circuit import (BreakerBoard, CircuitBreaker,
                                      CircuitOpen)
from repro.resilience.degrade import DEGRADE_LEVELS, DeadlineExceeded
from repro.resilience.recovery import RetryExhausted, RetryPolicy
from repro.serve.admission import (AdmissionController, AdmissionError,
                                   BudgetExhausted, OverloadController,
                                   QueueFull, estimate_cost)
from repro.serve.loadgen import (Arrival, LoadReport, LoadSpec, SimClock,
                                 make_arrivals, run_load)
from repro.serve.registry import PoolEntry, PoolRegistry, UnknownPool
from repro.serve.scheduler import (PRIORITIES, RequestScheduler,
                                   SelectRequest, Ticket)
from repro.serve.service import SelectionService
from repro.serve.sessions import (Session, SessionGone, SessionStore,
                                  StreamSession)

__all__ = [
    "AdmissionController", "AdmissionError", "Arrival", "BreakerBoard",
    "BudgetExhausted", "CircuitBreaker", "CircuitOpen", "DEGRADE_LEVELS",
    "DeadlineExceeded", "LoadReport", "LoadSpec", "OverloadController",
    "PRIORITIES", "QueueFull", "SimClock", "estimate_cost",
    "make_arrivals", "run_load", "PoolEntry",
    "PoolRegistry", "RetryExhausted", "RetryPolicy", "UnknownPool",
    "RequestScheduler", "SelectRequest", "Ticket", "SelectionService",
    "Session", "SessionGone", "SessionStore", "StreamSession",
]
