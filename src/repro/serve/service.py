"""`SelectionService`: the one object a serving deployment instantiates.

Ties the subsystem together (DESIGN.md §6): the **pool registry**
(admit/fingerprint/precompute), the **admission controller** (tenant
budgets + queue backpressure), the **request scheduler** (micro-batched
solves) and the **session store** (anytime budgets).  The driver
(``launch/serve_selection.py``) and the example are thin shells over this.

Typical flow::

    svc = SelectionService(max_batch=32)
    pid = svc.register_pool(proxies)                  # once per pool
    t1 = svc.submit(pid, k=256, tenant="team-a")      # queued
    t2 = svc.submit(pid, k=256, tenant="team-b")      # same batch key
    svc.drain()                                       # one batched solve
    subset = t1.result                                # SelectionResult

    sid, res = svc.open_session(pid, k=256)           # anytime budget
    res2 = svc.extend_session(sid, 512)               # resume, not re-solve

Sessions charge admission for the *delta* rounds only — that is the whole
economic point of checkpointing the solver state.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.continual.buffer import BufferMaintainer
from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.omp import (omp_session_extend, omp_session_start,
                            session_prefix_result, session_result)
from repro.resilience.circuit import BreakerBoard
from repro.resilience.recovery import RetryPolicy
from repro.serve.admission import (AdmissionController, OverloadController,
                                   estimate_cost)
from repro.serve.registry import PoolRegistry, UnknownPool
from repro.serve.scheduler import RequestScheduler, SelectRequest, Ticket
from repro.serve.sessions import SessionGone, SessionStore


class SelectionService:
    def __init__(
        self,
        max_batch: int = 32,
        max_queue: int = 64,
        max_pools: int = 8,
        max_sessions: int = 32,
        session_ttl_s: float = 600.0,
        default_budget_units: Optional[float] = None,
        max_inflight_per_tenant: int = 16,
        clock=None,
        retry_policy: Optional[RetryPolicy] = None,
        breaker_threshold: int = 3,
        breaker_cooldown_s: float = 30.0,
        checkpoint_root: Optional[str] = None,
        degrade: bool = True,
        overload: bool = True,
        brownout_at: float = 0.5,
        overload_at: float = 0.85,
        recover_at: float = 0.25,
        artifact_store=None,
    ):
        # ``artifact_store`` (repro.artifacts.ArtifactStore or a path to
        # one) turns on the offline fast path: gradmatch submits against
        # array pools are answered from verified precomputed trajectories
        # at submit time, rung "artifact" (DESIGN.md §12).
        if isinstance(artifact_store, (str, bytes)):
            from repro.artifacts import ArtifactStore
            artifact_store = ArtifactStore(artifact_store)
        self.artifacts = artifact_store
        self.registry = PoolRegistry(max_pools=max_pools,
                                     artifacts=artifact_store)
        self.admission = AdmissionController(
            max_queue=max_queue,
            default_budget_units=default_budget_units,
            max_inflight_per_tenant=max_inflight_per_tenant)
        clock_kw = {} if clock is None else {"clock": clock}
        self.breakers = BreakerBoard(failure_threshold=breaker_threshold,
                                     cooldown_s=breaker_cooldown_s,
                                     **clock_kw)
        self.overload = (OverloadController(
            max_queue=max_queue, brownout_at=brownout_at,
            overload_at=overload_at, recover_at=recover_at)
            if overload else None)
        self.scheduler = RequestScheduler(
            self.registry, self.admission, max_batch=max_batch,
            retry=retry_policy, breakers=self.breakers,
            checkpoint_root=checkpoint_root, degrade=degrade,
            session_lookup=self._prefix_lookup,
            overload=self.overload, session_save=self._session_save,
            **clock_kw)
        self.retry_policy = retry_policy
        self.sessions = SessionStore(max_sessions=max_sessions,
                                     ttl_s=session_ttl_s, **clock_kw)
        # Continual streams get their own store: the degradation ladder's
        # prefix scan over ``self.sessions`` expects anytime OMP state.
        self.streams = SessionStore(max_sessions=max_sessions,
                                    ttl_s=session_ttl_s, **clock_kw)

    # -- pools ---------------------------------------------------------------
    def register_pool(self, pool, pool_id: Optional[str] = None,
                      valid=None, **kw) -> str:
        return self.registry.register(pool, pool_id=pool_id, valid=valid,
                                      **kw)

    def register_chunked_pool(self, pool, pool_id: Optional[str] = None,
                              valid=None, **kw) -> str:
        return self.registry.register_chunked(pool, pool_id=pool_id,
                                              valid=valid,
                                              retry=self.retry_policy, **kw)

    # -- one-shot requests ---------------------------------------------------
    def submit(self, pool_id: str, k: int, strategy: str = "gradmatch",
               tenant: str = "default", **kw) -> Ticket:
        return self.scheduler.submit(SelectRequest(
            pool_id=pool_id, k=k, strategy=strategy, tenant=tenant, **kw))

    def drain(self) -> list[Ticket]:
        return self.scheduler.drain()

    def drain_step(self) -> list[Ticket]:
        """One fair scheduling quantum (the load harness's drive unit)."""
        return self.scheduler.drain_step()

    def select(self, pool_id: str, k: int, **kw) -> SelectionResult:
        """Blocking convenience: submit + drain + unwrap one request.

        Note this drains the *whole* queue — batching still happens if
        other requests are already waiting.
        """
        ticket = self.submit(pool_id, k, **kw)
        self.drain()
        if ticket.status != "done":
            raise RuntimeError(f"request failed: {ticket.error}")
        return ticket.result

    # -- anytime sessions ----------------------------------------------------
    def open_session(self, pool_id: str, k: int, lam: float = 0.5,
                     eps: float = 1e-10, positive: bool = True,
                     target=None, valid=None, tenant: str = "default"
                     ) -> tuple[str, SelectionResult]:
        """Solve ``k`` rounds and keep the solver state for extension."""
        entry = self.registry.get(pool_id)
        if not entry.batchable:
            raise UnknownPool(
                f"pool {pool_id!r} is chunked: anytime sessions need a "
                "resident pool")
        cost = estimate_cost(entry.n, entry.d, k)
        self.admission.admit(tenant, cost, self.scheduler.pending())
        try:
            tgt = (entry.target_sum if target is None
                   else jnp.asarray(target, jnp.float32))
            v = entry.valid
            if valid is not None:
                vv = jnp.asarray(valid, bool)
                v = vv if v is None else (v & vv)
            state = omp_session_start(entry.grads, tgt, k, lam=lam, eps=eps,
                                      positive=positive, valid=v)
        except Exception:
            self.admission.complete(tenant, refund=cost)
            raise
        self.admission.complete(tenant)
        sess = self.sessions.put(pool_id, tenant, state,
                                 pool_fingerprint=entry.fingerprint)
        return sess.session_id, self._session_selection(state)

    def extend_session(self, session_id: str, k_new: int
                       ) -> SelectionResult:
        """Extend a session's budget ``k -> k_new``; only the delta runs.

        The continuation is certified index-identical to a one-shot
        ``k_new`` solve (tests/test_serve.py, parity gate) — the client
        gets exactly what re-submitting at ``k_new`` would return, minus
        the recompute.
        """
        sess = self.sessions.get(session_id)          # raises SessionGone
        entry = self.registry.get(sess.pool_id)
        if entry.fingerprint != sess.pool_fingerprint:
            # The pool id was re-registered with different content: the
            # cached c0/Gram/colcache no longer describe these gradients.
            self.sessions.close(session_id)
            raise SessionGone(
                f"session {session_id!r} is stale: pool {sess.pool_id!r} "
                "content changed since the session opened — re-open")
        if k_new < sess.state.k:
            raise ValueError(
                f"cannot shrink an anytime session: have k={sess.state.k},"
                f" asked k'={k_new} (slice the previous result instead)")
        if k_new == sess.state.k:                     # idempotent retry:
            self.sessions.get(session_id)             # touch, charge 0
            return self._session_selection(sess.state)
        delta = k_new - sess.state.k
        cost = estimate_cost(entry.n, entry.d, delta)
        self.admission.admit(sess.tenant, cost, self.scheduler.pending())
        try:
            state = omp_session_extend(entry.grads, sess.state, k_new)
        except Exception:
            self.admission.complete(sess.tenant, refund=cost)
            raise
        self.admission.complete(sess.tenant)
        self.sessions.update(session_id, state)
        return self._session_selection(state)

    def close_session(self, session_id: str) -> bool:
        return self.sessions.close(session_id)

    # -- continual streams (DESIGN.md §11) -----------------------------------
    def open_stream(self, d: int, k: int, target, capacity: int = 1024,
                    tenant: str = "default", lam: float = 0.5,
                    eps: float = 1e-10, positive: bool = True,
                    seed: int = 0, compress: bool = True,
                    checkpoint_dir: Optional[str] = None,
                    checkpoint_every: int = 1) -> str:
        """Open an infinite-stream session: the tenant will POST gradient
        batches forever via :meth:`push_stream` against one bounded
        ``BufferMaintainer``.  The explicit ``target`` is required — a
        stream has no pool to sum.  Admission charges one buffer-solve of
        units up front (the arena allocation + worst-case re-solve);
        every push then pays per-batch.  With ``checkpoint_dir`` set, a
        previously killed stream resumes bit-exactly from its last
        snapshot (and keeps snapshotting every ``checkpoint_every``
        batches)."""
        cost = estimate_cost(int(capacity), int(d), int(k))
        self.admission.admit(tenant, cost, self.scheduler.pending())
        try:
            maintainer = (BufferMaintainer.restore(checkpoint_dir)
                          if checkpoint_dir else None)
            if maintainer is None:
                maintainer = BufferMaintainer(
                    capacity=int(capacity), d=int(d), target=target,
                    k=int(k), lam=lam, eps=eps, positive=positive,
                    seed=seed, compress=compress,
                    checkpoint_dir=checkpoint_dir,
                    checkpoint_every=checkpoint_every)
        except Exception:
            self.admission.complete(tenant, refund=cost)
            raise
        self.admission.complete(tenant)
        return self.streams.put_stream(tenant, maintainer).session_id

    def push_stream(self, stream_id: str, rows, gids=None
                    ) -> SelectionResult:
        """Admit one batch into a stream; returns the maintained coreset
        (gid space, ``SelectStats`` attached — admit/evict/downdate/
        resolve counters included).  Per-batch admission units scale with
        the batch, not the buffer; a failed admit refunds them."""
        sess = self.streams.get(stream_id)            # raises SessionGone
        rows = np.asarray(rows, np.float32)
        m = sess.maintainer
        cost = estimate_cost(rows.shape[0], m.d, m.k)
        self.admission.admit(sess.tenant, cost, self.scheduler.pending())
        try:
            m.admit(rows, gids=gids)
        except Exception:
            self.admission.complete(sess.tenant, refund=cost)
            raise
        self.admission.complete(sess.tenant)
        sess.batches += 1
        return m.result()

    def stream_result(self, stream_id: str) -> SelectionResult:
        """Current maintained coreset without admitting anything."""
        return self.streams.get(stream_id).maintainer.result()

    def close_stream(self, stream_id: str) -> bool:
        return self.streams.close(stream_id)

    @staticmethod
    def _session_selection(state) -> SelectionResult:
        idx, w, mask, err = session_result(state)
        return SelectionResult(idx, _normalize(w, mask), mask, err)

    def _session_save(self, pool_id: str, fingerprint: str,
                      state) -> None:
        """Park a brownout shared-solve session so later same-pool groups
        (and the degradation ladder's anytime-prefix rung) reuse it.
        Owned by the service, not a client tenant — TTL/LRU churn is
        visible in ``sessions.stats()``."""
        self.sessions.put(pool_id, "__brownout__", state,
                          pool_fingerprint=fingerprint)

    def _prefix_lookup(self, pool_id: str, fingerprint: str,
                       k: int) -> Optional[SelectionResult]:
        """Anytime-prefix rung of the degradation ladder: the first-``k``
        prefix of a live session over the same pool *content*.  Indices
        are certified by the prefix property; weights are the session's
        (renormalized, approximate for the prefix)."""
        for sess in self.sessions.live():
            if (sess.pool_id == pool_id
                    and sess.pool_fingerprint == fingerprint
                    and sess.state.k >= k):
                idx, w, mask, err = session_prefix_result(sess.state, k)
                return SelectionResult(idx, _normalize(w, mask), mask, err)
        return None

    # -- observability -------------------------------------------------------
    def stats(self) -> dict:
        return {"registry": self.registry.stats(),
                "scheduler": self.scheduler.stats(),
                "sessions": self.sessions.stats(),
                "streams": self.streams.stats(),
                "tenants": self.admission.stats(),
                "breakers": self.breakers.stats(),
                "artifacts": (None if self.artifacts is None
                              else self.artifacts.stats())}


__all__ = ["SelectionService", "SelectRequest", "Ticket", "SessionGone",
           "UnknownPool"]
