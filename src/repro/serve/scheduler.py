"""Request scheduler: queue `SelectRequest`s, micro-batch same-pool solves.

The serving shape this implements (DESIGN.md §6): clients ``submit()``
and get a ticket back immediately (admission control runs here — see
``serve/admission.py``); ``drain()`` executes the queue.  Execution groups
queued requests by **batch key** ``(pool_id, strategy, k, lam, eps,
positive)`` — requests that are the *same solve over the same pool up to
their target/validity vectors* — and runs each group as one
``omp_select_batched`` call: one column-cache/Gram growth schedule and one
pool scan per round serve the whole group, so B queued requests cost one
batched solve instead of B sequential ones (benchmarks/bench_selection.py
``run_serve`` records the throughput ratio; acceptance ≥ 5x at B = 32).

Batch sizes are padded up to a power-of-two bucket (extra rows re-solve
request 0 and are dropped) so the jit cache holds O(log max_batch)
programs instead of one per observed batch size.

Non-batchable work degrades gracefully to per-request execution: CRAIG
tiers reuse the registry's cached FL scan, chunked pools run the
streaming block-OMP, everything else goes through the ordinary
``selection.select`` dispatch.  Results are per-ticket ``SelectionResult``
(weights re-normalized per request, exactly as the library path returns).

Resilience (DESIGN.md §8): requests carry optional deadlines (expired
tickets fail fast as ``timeout`` without burning a solve); chunked solves
run under a bounded-retry policy with optional mid-solve checkpoints; a
per-pool circuit breaker fails a poisoned pool fast instead of wedging
the queue; and when a certified streaming solve cannot be had, the
scheduler walks the graceful-degradation ladder (resume → anytime-prefix
→ stochastic fallback), recording the rung on ``Ticket.degradation``.
"""

from __future__ import annotations

import hashlib
import itertools
import os
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import craig as craig_lib
from repro.core import glister as glister_lib
from repro.core import partition as part_lib
from repro.core import random_sel
from repro.core import streaming as stream_lib
from repro.core.gradmatch import SelectionResult, _normalize
from repro.core.omp import omp_select_batched
from repro.resilience.circuit import BreakerBoard, CircuitOpen
from repro.resilience.degrade import DeadlineExceeded, stochastic_fallback
from repro.resilience.faults import FaultError
from repro.resilience.recovery import RetryPolicy
from repro.serve.admission import AdmissionController, estimate_cost
from repro.serve.registry import PoolEntry, PoolRegistry, UnknownPool

SERVABLE = ("gradmatch", "gradmatch-partitioned", "craig", "craig-lazy",
            "craig-stochastic", "glister", "random")

_CRAIG_METHODS = {"craig": "dense", "craig-lazy": "lazy",
                  "craig-stochastic": "stochastic"}


@dataclass(frozen=True)
class SelectRequest:
    """One selection ask.  ``target=None`` means the pool's cached default
    (the eq.-2 sum); a per-request ``valid`` intersects the pool's own."""

    pool_id: str
    k: int
    strategy: str = "gradmatch"
    lam: float = 0.5
    eps: float = 1e-10
    positive: bool = True
    target: Optional[object] = None     # (d,) array-like
    valid: Optional[object] = None      # (n,) bool array-like
    tenant: str = "default"
    seed: int = 0                       # random / craig-stochastic
    deadline_s: Optional[float] = None  # fail fast past this queue age

    def batch_key(self):
        # deadline_s deliberately excluded: it shapes *when* a ticket may
        # still run, not *what* solve it is.
        return (self.pool_id, self.strategy, self.k, float(self.lam),
                float(self.eps), self.positive)


@dataclass
class Ticket:
    ticket_id: str
    request: SelectRequest
    cost: float
    status: str = "queued"              # queued | done | failed
    result: Optional[SelectionResult] = None
    error: Optional[str] = None
    batched_with: int = 0               # group size the solve ran at
    degradation: str = "none"           # rung served (resilience.DEGRADE_LEVELS)
    submitted_at: float = 0.0           # scheduler clock at submit()


def _bucket_b(b: int) -> int:
    p = 1
    while p < b:
        p *= 2
    return p


class RequestScheduler:
    def __init__(self, registry: PoolRegistry,
                 admission: Optional[AdmissionController] = None,
                 max_batch: int = 32,
                 stream_buffer: int = 256,
                 clock: Callable[[], float] = time.monotonic,
                 retry: Optional[RetryPolicy] = None,
                 breakers: Optional[BreakerBoard] = None,
                 checkpoint_root: Optional[str] = None,
                 checkpoint_every: int = 8,
                 degrade: bool = True,
                 session_lookup: Optional[Callable] = None):
        self.registry = registry
        self.admission = admission or AdmissionController()
        self.max_batch = int(max_batch)
        self.stream_buffer = int(stream_buffer)
        self._clock = clock
        self.retry = retry
        self.breakers = breakers or BreakerBoard(clock=clock)
        self.checkpoint_root = checkpoint_root
        self.checkpoint_every = int(checkpoint_every)
        self.degrade = bool(degrade)
        # (pool_id, fingerprint, k) -> SelectionResult | None; wired by
        # SelectionService to its session store (anytime-prefix rung).
        self.session_lookup = session_lookup
        self._queue: list[Ticket] = []
        self._ids = itertools.count()
        self.batches_run = 0
        self.singles_run = 0
        self.degraded_served = {}          # rung -> count

    # -- intake --------------------------------------------------------------
    def submit(self, req: SelectRequest) -> Ticket:
        if req.strategy not in SERVABLE:
            raise ValueError(
                f"unservable strategy {req.strategy!r}; servable: "
                f"{SERVABLE}")
        if req.k <= 0:
            raise ValueError(f"k must be positive, got {req.k}")
        entry = self.registry.get(req.pool_id)   # raises UnknownPool
        # Fail fast before charging the tenant: an open breaker means
        # this request would only queue behind a poisoned pool.
        self.breakers.get(req.pool_id).peek()    # raises CircuitOpen
        cost = estimate_cost(entry.n, entry.d, req.k)
        self.admission.admit(req.tenant, cost, len(self._queue))
        ticket = Ticket(ticket_id=f"req-{next(self._ids)}", request=req,
                        cost=cost, submitted_at=self._clock())
        self._queue.append(ticket)
        return ticket

    def pending(self) -> int:
        return len(self._queue)

    # -- execution -----------------------------------------------------------
    def drain(self) -> list[Ticket]:
        """Run the whole queue; returns the tickets in completion order.

        A failing request fails its ticket(s), never the queue: tenants
        get their in-flight slot back either way, and failed work refunds
        its admission charge (a metered tenant must not pay for
        selections that were never delivered).
        """
        done: list[Ticket] = []
        while self._queue:
            head = self._queue[0]
            try:
                entry = self.registry.get(head.request.pool_id)
            except UnknownPool as exc:
                # Pool evicted between submit and drain: fail every ticket
                # queued against it (same fate at their own head position).
                group = self._take_group_by_pool(head.request.pool_id)
                for t in group:
                    t.status = "failed"
                    t.error = f"{type(exc).__name__}: {exc}"
            else:
                try:
                    # The real admission through the breaker (submit only
                    # peeks): an open pool fails its whole queued group
                    # immediately — no solve, no retry burn, no wedge.
                    self.breakers.get(head.request.pool_id).allow()
                except CircuitOpen as exc:
                    group = self._take_group_by_pool(head.request.pool_id)
                    for t in group:
                        t.status = "failed"
                        t.degradation = "failed"
                        t.error = f"{type(exc).__name__}: {exc}"
                else:
                    if (head.request.strategy == "gradmatch"
                            and entry.batchable):
                        group = self._take_group(head.request.batch_key())
                        self._run_gradmatch_batch(entry, group)
                    else:
                        group = [self._queue.pop(0)]
                        self._run_single(entry, group[0])
            for t in group:
                self.admission.complete(
                    t.request.tenant,
                    refund=t.cost if t.status == "failed" else 0.0)
            done.extend(group)
        return done

    def _take_group_by_pool(self, pool_id: str) -> list[Ticket]:
        group = [t for t in self._queue if t.request.pool_id == pool_id]
        taken = set(id(t) for t in group)
        self._queue = [t for t in self._queue if id(t) not in taken]
        return group

    def _take_group(self, key) -> list[Ticket]:
        group = [t for t in self._queue
                 if t.request.batch_key() == key][: self.max_batch]
        taken = set(id(t) for t in group)
        self._queue = [t for t in self._queue if id(t) not in taken]
        return group

    def _run_gradmatch_batch(self, entry: PoolEntry,
                             group: list[Ticket]) -> None:
        req0 = group[0].request
        b = len(group)
        try:
            # Operand assembly inside the guard too: a malformed
            # per-request target/valid (submit() does not shape-check
            # them) must fail the group, not escape drain().
            targets = jnp.stack([
                entry.target_sum if t.request.target is None
                else jnp.asarray(t.request.target, jnp.float32)
                for t in group])
            base_valid = (entry.valid if entry.valid is not None
                          else jnp.ones((entry.n,), bool))
            valids = jnp.stack([
                base_valid if t.request.valid is None
                else base_valid & jnp.asarray(t.request.valid, bool)
                for t in group])
            # Pad to the power-of-two bucket so the jit cache stays
            # bounded; pad rows re-solve request 0 and are dropped below.
            bb = min(_bucket_b(b), self.max_batch)
            if bb > b:
                pad = bb - b
                targets = jnp.concatenate(
                    [targets, jnp.broadcast_to(targets[0], (pad,) +
                                               targets.shape[1:])])
                valids = jnp.concatenate(
                    [valids, jnp.broadcast_to(valids[0], (pad,) +
                                              valids.shape[1:])])
            idx, w, mask, err = omp_select_batched(
                entry.grads, targets, k=req0.k, lam=req0.lam, eps=req0.eps,
                positive=req0.positive, valid=valids)
        except Exception as exc:          # fail the group, not the queue
            for t in group:
                t.status = "failed"
                t.error = f"{type(exc).__name__}: {exc}"
            return
        for i, t in enumerate(group):
            t.result = SelectionResult(idx[i], _normalize(w[i], mask[i]),
                                       mask[i], err[i])
            t.status = "done"
            t.batched_with = b
            t.degradation = "certified"
        self.breakers.get(entry.pool_id).record_success()
        self.batches_run += 1

    @staticmethod
    def _is_pool_fault(exc: BaseException) -> bool:
        """Failures that indict the *pool* (count toward its breaker), as
        opposed to a caller's malformed request: injected/real I-O faults
        that exhausted retries, stream death, pass-budget blowups."""
        return isinstance(exc, (FaultError,
                                stream_lib.StreamingPassBudgetError))

    def _run_single(self, entry: PoolEntry, ticket: Ticket) -> None:
        req = ticket.request
        breaker = self.breakers.get(entry.pool_id)
        try:
            age = self._clock() - ticket.submitted_at
            if req.deadline_s is not None and age > req.deadline_s:
                ticket.degradation = "timeout"
                raise DeadlineExceeded(
                    f"deadline of {req.deadline_s}s expired before the "
                    f"solve started (queued {age:.3f}s)")
            ticket.result = self._execute_single(entry, req)
            ticket.status = "done"
            ticket.batched_with = 1
            ticket.degradation = "certified"
            breaker.record_success()
        except DeadlineExceeded as exc:
            # Not a pool fault: the pool never got to run.
            ticket.status = "failed"
            ticket.error = f"{type(exc).__name__}: {exc}"
        except Exception as exc:          # surface, don't wedge the queue
            if self._is_pool_fault(exc):
                breaker.record_failure()
                if (self.degrade and req.strategy == "gradmatch"
                        and entry.kind == "chunked"
                        and self._degrade_chunked(entry, ticket, breaker)):
                    self.singles_run += 1
                    return
            ticket.status = "failed"
            ticket.degradation = "failed"
            ticket.error = f"{type(exc).__name__}: {exc}"
        self.singles_run += 1

    def _degrade_chunked(self, entry: PoolEntry, ticket: Ticket,
                         breaker) -> bool:
        """Walk the degradation ladder for a chunked gradmatch solve whose
        certified attempt died on a pool fault.  Returns True when a rung
        produced an answer (labelled on the ticket); the winning rung is
        counted in ``degraded_served``."""
        req = ticket.request
        target = (entry.target_sum if req.target is None
                  else jnp.asarray(req.target, jnp.float32))
        # Rung 2: re-run the certified solve, resuming from the failed
        # attempt's mid-solve checkpoint.  Still bit-identical to
        # fault-free when it completes — the label records that recovery
        # (not the first attempt) produced it.
        if self.checkpoint_root is not None:
            try:
                ticket.result = self._execute_single(entry, req)
            except Exception as exc2:
                if self._is_pool_fault(exc2):
                    breaker.record_failure()
            else:
                self._served(ticket, "resumed")
                breaker.record_success()
                return True
        # Rung 3: first-k prefix of a live anytime session over the same
        # pool content (indices certified by the prefix property).
        if self.session_lookup is not None:
            res = self.session_lookup(entry.pool_id, entry.fingerprint,
                                      req.k)
            if res is not None:
                ticket.result = res
                self._served(ticket, "anytime-prefix")
                return True
        # Rung 4: seeded stochastic-greedy over the rows still resident in
        # the pool's compressed cache — approximate, loader-free.
        res = stochastic_fallback(entry.cache, target, req.k,
                                  seed=req.seed, lam=req.lam, eps=req.eps,
                                  positive=req.positive)
        if res is not None:
            ticket.result = SelectionResult(
                res.indices, _normalize(res.weights, res.mask), res.mask,
                res.err)
            self._served(ticket, "stochastic")
            return True
        return False

    def _served(self, ticket: Ticket, rung: str) -> None:
        ticket.status = "done"
        ticket.batched_with = 1
        ticket.degradation = rung
        self.degraded_served[rung] = self.degraded_served.get(rung, 0) + 1

    def _execute_single(self, entry: PoolEntry,
                        req: SelectRequest) -> SelectionResult:
        if req.strategy == "random":
            valid = entry.valid
            if req.valid is not None:
                v = jnp.asarray(req.valid, bool)
                valid = v if valid is None else (valid & v)
            return random_sel.random_select(
                jax.random.PRNGKey(req.seed), entry.n, req.k, valid=valid)
        if req.strategy == "gradmatch" and entry.kind == "chunked":
            if req.valid is not None:
                # The chunk factory was frozen at registration; silently
                # selecting masked rows would be worse than refusing.
                raise ValueError(
                    "per-request valid masks are not supported on chunked "
                    "pools — register the pool with the mask instead")
            target = (entry.target_sum if req.target is None
                      else jnp.asarray(req.target, jnp.float32))
            # The admission-warmed compressed cache + row fetcher make
            # this request's certified rounds and repairs hit memory
            # instead of re-paying loader passes (DESIGN.md §7).
            return stream_lib.gradmatch_streaming(
                entry.chunk_iter, req.k, target=target, lam=req.lam,
                eps=req.eps, buffer_size=self.stream_buffer,
                cache=entry.cache, row_fetch=entry.row_fetch,
                retry=self.retry,
                checkpoint_dir=self._checkpoint_dir(entry, req, target),
                checkpoint_every=self.checkpoint_every)
        if req.strategy == "gradmatch-partitioned":
            # Partition-and-merge (core/partition.py, DESIGN.md §9): the
            # pool's registered partition count (0 = solver auto) shapes
            # the split; chunked pools stream contiguous row ranges
            # through the certified engine, resident pools solve hashed
            # partitions device-parallel.
            target = (None if req.target is None
                      else jnp.asarray(req.target, jnp.float32))
            if entry.kind == "chunked":
                if req.valid is not None:
                    raise ValueError(
                        "per-request valid masks are not supported on "
                        "chunked pools — register the pool with the mask "
                        "instead")
                return part_lib.gradmatch_partitioned_stream(
                    pool_iter=entry.chunk_iter, k=req.k, n=entry.n,
                    partitions=entry.partitions, row_fetch=entry.row_fetch,
                    target=target, lam=req.lam, eps=req.eps,
                    buffer_size=self.stream_buffer, retry=self.retry)
            valid = entry.valid
            if req.valid is not None:
                v = jnp.asarray(req.valid, bool)
                valid = v if valid is None else (valid & v)
            return part_lib.gradmatch_partitioned(
                entry.grads, req.k, partitions=entry.partitions,
                target=target, lam=req.lam, eps=req.eps, valid=valid)
        if entry.kind != "array":
            raise ValueError(
                f"strategy {req.strategy!r} needs a resident pool")
        valid = entry.valid
        if req.valid is not None:
            v = jnp.asarray(req.valid, bool)
            valid = v if valid is None else (valid & v)
        if req.strategy in _CRAIG_METHODS:
            sim, lm, otf = entry.fl_scan(_CRAIG_METHODS[req.strategy])
            return craig_lib.craig(
                entry.grads, req.k, sim=sim, valid=valid,
                method=_CRAIG_METHODS[req.strategy], l_max=lm,
                on_the_fly=otf, key=jax.random.PRNGKey(req.seed))
        if req.strategy == "glister":
            target = (entry.target_sum if req.target is None
                      else jnp.asarray(req.target, jnp.float32))
            return glister_lib.glister(entry.grads, target, req.k,
                                       valid=valid)
        raise ValueError(f"unservable strategy {req.strategy!r}")

    def _checkpoint_dir(self, entry: PoolEntry,
                        req: SelectRequest, target) -> Optional[str]:
        """Per-*solve* checkpoint directory under ``checkpoint_root``.

        The solver refuses to resume a checkpoint from an incompatible
        solve, but the target vector is not part of its compatibility
        check — so the directory key hashes everything that defines the
        solve (pool content, k, lam/eps/positive, target bytes).  Two
        different asks never share a directory.
        """
        if self.checkpoint_root is None:
            return None
        h = hashlib.sha1(repr(
            (entry.fingerprint, req.k, float(req.lam), float(req.eps),
             req.positive)).encode())
        h.update(np.asarray(target, np.float32).tobytes())
        return os.path.join(self.checkpoint_root,
                            f"{entry.pool_id}-{h.hexdigest()[:12]}")

    def stats(self) -> dict:
        return {"pending": len(self._queue),
                "batches_run": self.batches_run,
                "singles_run": self.singles_run,
                "degraded_served": dict(self.degraded_served),
                "breakers": self.breakers.stats()}
